"""Kernel parity: every Pallas path vs its pure-jnp oracle (kernels/ref.py)
in interpret mode on CPU, with tolerances per dtype.

Complements test_kernels.py's shape sweeps: here the contract under test is
numerical parity as a function of input precision — f32 must be tight,
bf16 within accumulation noise — across all three kernels.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gallery_match import gallery_match_pallas
from repro.kernels.mamba2_ssd import mamba2_ssd_pallas

# per-dtype (atol, rtol): bf16 has ~8 mantissa bits, so parity against the
# f32 oracle is dominated by input rounding, not kernel error
TOL = {
    jnp.float32: dict(atol=2e-5, rtol=1e-4),
    jnp.bfloat16: dict(atol=5e-2, rtol=5e-2),
}
DTYPES = sorted(TOL, key=str)


def _close(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# -- gallery match ------------------------------------------------------------
@pytest.mark.parametrize("dtype", DTYPES)
def test_gallery_match_parity(dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (11, 64)).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (301, 64)).astype(dtype)
    qn = (q / jnp.linalg.norm(q.astype(jnp.float32), axis=-1,
                              keepdims=True).astype(dtype))
    gn = (g / jnp.linalg.norm(g.astype(jnp.float32), axis=-1,
                              keepdims=True).astype(dtype))
    s, i = gallery_match_pallas(qn, gn, k=5, interpret=True)
    sr, ir = R.gallery_match_ref(qn, gn, k=5)
    _close(s, sr, dtype)
    # index disagreement is only legal on score ties (within tolerance)
    agree = np.asarray(i) == np.asarray(ir)
    tie = np.isclose(np.asarray(s, np.float32), np.asarray(sr, np.float32),
                     **TOL[dtype])
    assert np.all(agree | tie)


# -- flash attention ----------------------------------------------------------
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_parity(dtype, causal):
    B, H, S, D = 1, 2, 192, 64
    q = (jax.random.normal(jax.random.PRNGKey(0), (B, H, S, D)) * 0.3
         ).astype(dtype)
    k = (jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D)) * 0.3
         ).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D)).astype(dtype)
    o = flash_attention_pallas(q, k, v, causal=causal, bq=128, bk=128,
                               interpret=True)
    orf = R.flash_attention_ref(q, k, v, causal=causal)
    _close(o, orf, dtype)


@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_windowed_parity(dtype):
    B, H, S, D = 1, 2, 256, 32
    q = (jax.random.normal(jax.random.PRNGKey(3), (B, H, S, D)) * 0.3
         ).astype(dtype)
    k = (jax.random.normal(jax.random.PRNGKey(4), (B, H, S, D)) * 0.3
         ).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(5), (B, H, S, D)).astype(dtype)
    o = flash_attention_pallas(q, k, v, causal=True, window=64,
                               bq=128, bk=128, interpret=True)
    orf = R.flash_attention_ref(q, k, v, causal=True, window=64)
    _close(o, orf, dtype)


# -- mamba2 ssd ---------------------------------------------------------------
@pytest.mark.parametrize("dtype", DTYPES)
def test_mamba2_ssd_parity(dtype):
    Bt, L, H, P, N = 1, 128, 2, 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (Bt, L, H, P)).astype(dtype)
    dt = (jax.nn.softplus(
        jax.random.normal(jax.random.PRNGKey(1), (Bt, L, H))) * 0.1
    ).astype(dtype)
    A = -jax.nn.softplus(
        jax.random.normal(jax.random.PRNGKey(2), (H,))).astype(dtype)
    B = (jax.random.normal(jax.random.PRNGKey(3), (Bt, L, N)) * 0.3
         ).astype(dtype)
    C = (jax.random.normal(jax.random.PRNGKey(4), (Bt, L, N)) * 0.3
         ).astype(dtype)
    y, st = mamba2_ssd_pallas(x, dt, A, B, C, chunk=64, interpret=True)
    yr, str_ = R.mamba2_ssd_ref(x, dt, A, B, C)
    _close(y, yr, dtype)
    _close(st, str_, dtype)


def test_mamba2_ssd_state_carries_across_chunks():
    """Chunked scan with a non-trivial initial state in the oracle: the
    Pallas kernel's final state must equal running the oracle end-to-end
    over a double-length sequence split in two."""
    Bt, L, H, P, N = 1, 128, 1, 8, 8
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (Bt, 2 * L, H, P), jnp.float32)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.PRNGKey(8), (Bt, 2 * L, H))) * 0.1
    A = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(9), (H,)))
    B = jax.random.normal(jax.random.PRNGKey(10), (Bt, 2 * L, N)) * 0.3
    C = jax.random.normal(jax.random.PRNGKey(11), (Bt, 2 * L, N)) * 0.3
    _, st_full = R.mamba2_ssd_ref(x, dt, A, B, C)
    _, st_k = mamba2_ssd_pallas(x, dt, A, B, C, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_full),
                               atol=2e-4, rtol=1e-3)
