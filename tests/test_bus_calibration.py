"""Bus calibration round-trip: ``calibrate_from_fps`` inverts three rows of
Table 1 (N = 1, 2, 5) and ``simulate_broadcast_fps`` must then reproduce
EVERY published row — including the N = 3, 4 rows the fit never saw —
within the paper's ±1 FPS reporting granularity."""
import pytest

from repro.bus import (TABLE1, calibrate_from_fps, calibrated,
                       simulate_broadcast_fps)


@pytest.mark.parametrize("device", sorted(TABLE1))
@pytest.mark.parametrize("n", [1, 2, 3, 4, 5])
def test_calibration_roundtrip_every_row(device, n):
    p = calibrated(device)
    fps = simulate_broadcast_fps(p, n)
    assert abs(fps - TABLE1[device][n - 1]) <= 1.0, \
        f"{device} N={n}: {fps:.2f} vs {TABLE1[device][n-1]}"


@pytest.mark.parametrize("device", sorted(TABLE1))
def test_anchor_rows_are_exact(device):
    """The three rows the solver was pinned to must come back exactly."""
    row = TABLE1[device]
    p = calibrated(device)
    for n, fps in [(1, row[0]), (2, row[1]), (5, row[4])]:
        assert simulate_broadcast_fps(p, n) == pytest.approx(fps, abs=1e-6)


@pytest.mark.parametrize("device", sorted(TABLE1))
def test_calibrated_params_physical(device):
    """The fit must land on physically meaningful constants."""
    p = calibrated(device)
    assert p.t_comp_s > 0
    assert p.base_overhead_s >= 0
    assert p.arbitration_s >= 0
    # compute dominates a single-device cycle (the sticks are the
    # bottleneck, not USB3): t_comp within 30% of 1/fps1
    assert p.t_comp_s > 0.7 / TABLE1[device][0]


@pytest.mark.parametrize("device", sorted(TABLE1))
def test_fps_monotone_in_contention(device):
    p = calibrated(device)
    fps = [simulate_broadcast_fps(p, n) for n in range(1, 6)]
    assert all(a >= b for a, b in zip(fps, fps[1:])), fps


def test_recalibration_is_stable():
    """Calibrating from simulated FPS reproduces the same parameters
    (the solver and the simulator agree on the cycle model)."""
    p = calibrated("ncs2")
    f1 = simulate_broadcast_fps(p, 1)
    f2 = simulate_broadcast_fps(p, 2)
    f5 = simulate_broadcast_fps(p, 5)
    p2 = calibrate_from_fps("ncs2_rt", f1, f2, f5)
    assert p2.t_comp_s == pytest.approx(p.t_comp_s, rel=1e-6)
    assert p2.arbitration_s == pytest.approx(p.arbitration_s, rel=1e-6)
    assert p2.base_overhead_s == pytest.approx(p.base_overhead_s, abs=1e-9)
