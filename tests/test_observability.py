"""Flight recorder + metrics registry invariants (PR 9).

The load-bearing guarantees, in order of importance:

1. Tracing NEVER perturbs the simulation: traced and untraced runs of
   the same scenario produce float-for-float identical reports —
   Table 1 replication, cross-hub hedging, and the seed-11 chaos storm
   are each pinned.
2. Span accounting closes: every span opened is closed once the engine
   runs to quiescence, and frame-span counts reconcile exactly with the
   engine's completed/lost/duplicate counters.
3. Sampling is replay-stable: the same seed traces the identical frame
   set across runs, and the ring evicts (never grows) under load.
4. The serialization surfaces hold: ``EngineReport.to_json()``
   round-trips with numpy scalars coerced, the Perfetto export is
   structurally valid trace-event JSON, and ``StreamingHistogram.merge``
   equals recording the concatenated samples (hypothesis property).
"""
import json
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # optional dep: property tests skip
    HAVE_HYPOTHESIS = False

from repro.runtime import replication as R
from repro.runtime.engine import EngineReport
from repro.runtime.faults import FaultPlan, QuarantinePolicy, RetryPolicy
from repro.runtime.metrics import StreamingHistogram
from repro.runtime.trace import (COMPLETE, DISPATCH, FRAME, INGEST, SERVICE,
                                 TRANSFER, FlightRecorder, MetricsRegistry,
                                 jsonable)

INF = float("inf")


def full_sig(rep):
    """Everything float-valued the engine computes, exactly."""
    return (rep.frames_in, rep.frames_out, rep.sim_time, rep.last_out_t,
            tuple(rep.latencies),
            tuple(sorted(rep.hedges.items())),
            tuple(sorted(rep.faults.items())),
            tuple(rep.downtime),
            rep.bus_bytes)


def seed11_storm():
    names = R.chaos_lane_names()
    return FaultPlan.storm(11, 3.0, lanes=names, hubs=[0, 1],
                           links=[(0, 1)], crash_rate=1.2, hang_rate=0.8,
                           hub_loss_rate=0.15, link_down_rate=0.5,
                           corrupt_p=0.02)


def chaos_pair(**trace_kw):
    kw = dict(retry=RetryPolicy(), quarantine=QuarantinePolicy())
    off = R.build_chaos_engine(seed11_storm(), **kw).run(until=INF)
    on = R.build_chaos_engine(seed11_storm(), **kw,
                              **trace_kw).run(until=INF)
    return off, on


# ---------------------------------------------------------------------------
# 1. bit-identity: tracing observes, never perturbs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["broadcast", "shard"])
def test_table1_bit_identical_traced(mode):
    off = R.run_replicated("ncs2", 4, mode=mode, n_frames=80)
    on = R.run_replicated("ncs2", 4, mode=mode, n_frames=80, trace=True)
    assert full_sig(off) == full_sig(on)


def test_hedge_scenario_bit_identical_traced():
    off = R.build_cross_hub_hedge_engine().run(until=INF)
    on = R.build_cross_hub_hedge_engine(trace=True).run(until=INF)
    assert full_sig(off) == full_sig(on)


@pytest.mark.parametrize("sample", [1, 7])
def test_chaos_storm_bit_identical_traced(sample):
    off, on = chaos_pair(trace=True, trace_sample=sample)
    assert full_sig(off) == full_sig(on)


def test_power_budget_bit_identical_traced():
    off = R.run_battery(3.0, n_frames=120)
    on = R.run_battery(3.0, n_frames=120, trace=True)
    assert full_sig(off) == full_sig(on)
    states = [e for e in on.trace.entries() if e["kind"] == "power.state"]
    assert states, "budgeted run must record throttle transitions"
    assert states[0]["args"]["prev"] == "nominal"


def test_trace_off_has_no_recorder():
    rep = R.run_replicated("ncs2", 2, mode="shard", n_frames=10)
    assert rep.trace is None


# ---------------------------------------------------------------------------
# 2. span accounting + counter reconciliation
# ---------------------------------------------------------------------------
def test_all_spans_closed_at_quiescence():
    _, on = chaos_pair(trace=True)
    rec = on.trace
    s = rec.snapshot()
    assert s["spans_opened"] == s["spans_closed"]
    assert s["open_frames"] == 0
    assert s["end_misses"] == 0


def test_frame_spans_reconcile_with_counters():
    off, on = chaos_pair(trace=True)
    rec = on.trace
    # every arriving frame was admitted at sample=1
    assert rec.frames_admitted == on.frames_in
    entries = rec.entries()
    frame_spans = [e for e in entries if e["kind"] == FRAME]
    closed = [e for e in frame_spans if e.get("t1") is not None]
    # frame spans close once per distinct delivered frame: duplicates
    # re-complete but cannot re-close
    dups = on.faults["duplicates"]
    # a frame span closes once per distinct delivered frame: duplicates
    # re-complete but cannot re-close, lost frames never close
    assert len(closed) == on.frames_out - dups
    assert len(closed) == on.frames_in - on.lost
    open_spans = [e for e in frame_spans if e.get("t1", 0) is None]
    assert len(open_spans) == on.lost
    completes = [e for e in entries if e["kind"] == COMPLETE]
    assert len(completes) == on.frames_out
    # the storm actually exercised the recovery paths being traced
    kinds = {e["kind"] for e in entries}
    assert {"fault.injected", "quarantine", "reinstate", "retry"} <= kinds


def test_frame_trace_causal_timeline():
    _, on = chaos_pair(trace=True)
    rec = on.trace
    # pick a frame that retried (the storm guarantees some)
    retried = [e["frame"] for e in rec.entries() if e["kind"] == "retry"]
    assert retried
    fid = retried[0]
    tl = rec.frame_trace(fid)
    kinds = [e["kind"] for e in tl]
    assert kinds[0] == FRAME                 # lifetime span leads
    assert kinds[1] == INGEST
    assert DISPATCH in kinds and "retry" in kinds
    assert kinds[-1] == COMPLETE
    # entries are in event order and timestamps never go backwards
    t = [e["t0"] for e in tl]
    assert t == sorted(t)
    # the lifetime span covers the whole timeline
    assert tl[0]["t0"] <= min(t) and tl[0]["t1"] >= max(t)


def test_service_spans_nested_in_frame_span():
    rep = R.run_replicated("ncs2", 4, mode="shard", n_frames=40, trace=True)
    rec = rep.trace
    for fid in (0, 7, 23):
        tl = rec.frame_trace(fid)
        frame = tl[0]
        assert frame["kind"] == FRAME
        for e in tl[1:]:
            if e["kind"] in (SERVICE, TRANSFER):
                assert frame["t0"] <= e["t0"]
                assert e.get("t1", e["t0"]) <= frame["t1"]


# ---------------------------------------------------------------------------
# 3. sampling determinism + ring eviction
# ---------------------------------------------------------------------------
def test_sampling_replay_stable():
    _, a = chaos_pair(trace=True, trace_sample=4)
    _, b = chaos_pair(trace=True, trace_sample=4)
    sa = {e["frame"] for e in a.trace.entries() if e["kind"] == FRAME}
    sb = {e["frame"] for e in b.trace.entries() if e["kind"] == FRAME}
    assert sa == sb and sa
    assert a.trace.frames_admitted == b.trace.frames_admitted
    assert a.trace.frames_admitted < a.frames_in
    assert a.trace.frames_admitted + a.trace.frames_skipped == a.frames_in


def test_sampling_seed_changes_frame_set():
    rec1 = FlightRecorder(sample=4, seed=1)
    rec2 = FlightRecorder(sample=4, seed=2)
    s1 = {f for f in range(4000) if rec1.admit(f)}
    s2 = {f for f in range(4000) if rec2.admit(f)}
    assert s1 != s2
    # rate lands near 1/4 for both
    for s in (s1, s2):
        assert 0.15 < len(s) / 4000 < 0.35


def test_ring_eviction_fixed_memory():
    rec = FlightRecorder(capacity=64)
    for f in range(200):
        rec.admit(f)
        rec.frame_begin(f, float(f))
        rec.instant("x", float(f) + 0.1, f)
        rec.frame_end(f, float(f) + 0.5)
    s = rec.snapshot()
    assert s["entries"] == 64                # never grows past capacity
    assert s["evicted"] == 2 * 200 - 64      # frame span + instant per frame
    assert len(rec.entries()) == 64
    # oldest-first ordering survives wraparound
    ids = [e["id"] for e in rec.entries()]
    assert ids == sorted(ids)


def test_evicted_open_span_is_counted_miss():
    rec = FlightRecorder(capacity=4)
    sid = rec.begin("service", 0.0, 1)
    for i in range(8):                        # push the open span out
        rec.instant("x", float(i), 1)
    rec.end(sid, 9.0)
    assert rec.end_misses == 1
    assert rec.spans_closed == 0


def test_open_frame_span_forgotten_on_eviction():
    rec = FlightRecorder(capacity=4)
    rec.admit(5)
    rec.frame_begin(5, 0.0)
    assert rec.open_frames == 1
    for i in range(8):
        rec.instant("x", float(i), 5)
    assert rec.open_frames == 0               # stale sid dropped with row
    rec.frame_end(5, 9.0)                     # clean no-op
    assert rec.spans_closed == 0


def test_recorder_validates_args():
    with pytest.raises(ValueError):
        FlightRecorder(capacity=1)
    with pytest.raises(ValueError):
        FlightRecorder(sample=0)


# ---------------------------------------------------------------------------
# 4. exporters + registry + histogram merge
# ---------------------------------------------------------------------------
def test_perfetto_export_structure(tmp_path):
    _, on = chaos_pair(trace=True)
    path = tmp_path / "storm.json"
    n = on.trace.to_perfetto(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == n
    phases = {e["ph"] for e in evs}
    assert phases <= {"M", "X", "i"}
    slices = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert slices and instants and metas
    names = {e["args"]["name"] for e in metas}
    assert "frame" in names                   # the frame-timeline track
    for e in slices:
        assert e["dur"] >= 0.0 and e["ts"] >= 0.0
    for e in instants:
        assert e["s"] == "t"
    # everything must already be json-native (json.dump just succeeded),
    # and frames are cross-referenced through args
    assert any(e["args"].get("frame") is not None for e in slices)


def test_report_to_json_round_trip(tmp_path):
    plan = seed11_storm()
    rep = R.run_chaos(plan, RetryPolicy(), QuarantinePolicy(), trace=True)
    path = tmp_path / "report.json"
    text = rep.to_json(str(path), indent=2)
    assert path.read_text() == text
    doc = json.loads(text)
    assert doc["schema"] == "champ.engine_report.v1"
    # the stable sections all round-trip
    for key in ("frames", "latency", "power", "faults", "hedges",
                "events", "profile", "metrics", "swap_log", "downtime"):
        assert key in doc
    assert doc["frames"]["in"] == rep.frames_in
    assert doc["frames"]["out"] == rep.frames_out
    assert doc["latency"]["end_to_end"]["count"] == rep.frames_out
    assert doc["faults"]["injected"] == rep.faults["injected"]
    # numpy scalars were coerced: re-serializing the parsed doc is exact
    assert json.dumps(doc, sort_keys=True) == \
        json.dumps(json.loads(text), sort_keys=True)


def test_to_json_coerces_numpy_scalars():
    rep = EngineReport()
    rep.frames_in = np.int64(3)
    rep.sim_time = np.float64(1.5)
    rep.power = {"total_j": np.float32(2.5), "hubs": {0: {"n": np.int32(1)}}}
    doc = json.loads(rep.to_json())
    assert doc["frames"]["in"] == 3
    assert doc["sim_time_s"] == 1.5
    assert doc["power"]["hubs"]["0"]["n"] == 1


def test_jsonable_nested():
    out = jsonable({"a": np.int64(1), "b": (np.float32(0.5), [np.bool_(True)]),
                    3: np.arange(2)})
    assert out == {"a": 1, "b": [0.5, [True]], "3": [0, 1]}
    json.dumps(out)


def test_metrics_registry_stable_names():
    plan = seed11_storm()
    rep = R.run_chaos(plan, RetryPolicy(), QuarantinePolicy(), trace=True)
    m = rep.metrics()
    expected = ["engine.frames.in", "engine.frames.out",
                "engine.frames.lost", "engine.sim_time_s",
                "engine.throughput_fps", "engine.availability",
                "engine.latency.p99", "engine.events.pushed",
                "engine.events.popped", "hedge.issued", "faults.retries",
                "faults.quarantined", "bus.bytes_moved", "power.total_j",
                "trace.spans_opened", "trace.frames_admitted"]
    for name in expected:
        assert name in m, name
    assert m["engine.frames.in"] == rep.frames_in
    assert m["engine.events.pushed"] > 0
    # flat scalars only, sorted iteration, json-safe
    snap = m.snapshot()
    assert list(snap) == sorted(snap)
    json.dumps(snap)
    for v in snap.values():
        assert not isinstance(v, (dict, list, tuple, np.ndarray, np.generic))


def test_metrics_registry_ingest_flattens():
    m = MetricsRegistry()
    m.ingest("power", {"hubs": {0: {"state": "parked", "w": np.float64(2)}},
                       "lanes": [1, 2, 3], "total_j": 5.0})
    assert m["power.hubs.0.state"] == "parked"
    assert m["power.hubs.0.w"] == 2.0 and isinstance(m["power.hubs.0.w"],
                                                     float)
    assert m["power.total_j"] == 5.0
    assert "power.lanes" not in m             # list leaves are skipped
    assert m.get("missing", 42) == 42
    assert len(m) == 3


def test_gallery_metrics_namespace():
    from repro.crypto.gallery import SecureGallery
    g = SecureGallery(16, seed=3)
    g.enroll(np.random.default_rng(0).normal(size=(12, 16)), list(range(12)))
    gm = g.metrics()
    assert gm["rows"] == 12 and gm["failovers"] == 0
    m = MetricsRegistry().ingest("gallery", gm)
    assert m["gallery.ann.trainings"] == 0


def _check_merge_equals_concat(xs, ys):
    a = StreamingHistogram()
    b = StreamingHistogram()
    c = StreamingHistogram()
    for x in xs:
        a.record(x)
        c.record(x)
    for y in ys:
        b.record(y)
        c.record(y)
    a.merge(b)
    # exact bin counts, count, min, max — quantiles follow for free
    assert np.array_equal(a.counts, c.counts)
    assert a.count == c.count
    assert a.min == c.min and a.max == c.max
    assert math.isclose(a.total, c.total, rel_tol=1e-12, abs_tol=1e-12)
    for q in (0.0, 0.5, 0.95, 1.0):
        assert a.quantile(q) == c.quantile(q)


def test_histogram_merge_equals_concat_deterministic():
    rng = np.random.default_rng(5)
    for trial in range(8):
        xs = list(rng.lognormal(-3, 2, size=rng.integers(0, 60)))
        ys = list(rng.lognormal(-1, 1, size=rng.integers(0, 60)))
        _check_merge_equals_concat(xs, ys)
    _check_merge_equals_concat([], [])
    _check_merge_equals_concat([1e-6, 1e4], [])


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(xs=st.lists(st.floats(min_value=1e-6, max_value=1e4,
                                 allow_nan=False), max_size=60),
           ys=st.lists(st.floats(min_value=1e-6, max_value=1e4,
                                 allow_nan=False), max_size=60))
    def test_histogram_merge_equals_concat(xs, ys):
        _check_merge_equals_concat(xs, ys)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           sample=st.integers(min_value=1, max_value=9),
           cap=st.integers(min_value=2, max_value=128))
    def test_span_pairing_property(seed, sample, cap):
        """Every span opened through the frame API is either closed or
        accounted for (evicted / skipped) — no silent leaks, any ring
        size, any sampling rate."""
        rec = FlightRecorder(capacity=cap, sample=sample, seed=seed)
        n = 80
        for f in range(n):
            if not rec.admit(f):
                continue
            rec.frame_begin(f, float(f))
            rec.instant("x", float(f) + 0.25, f)
            rec.frame_end(f, float(f) + 0.5)
        assert rec.frames_admitted + rec.frames_skipped == n
        assert rec.open_frames == 0
        # closes + misses account for every open exactly once
        assert rec.spans_closed + rec.end_misses == rec.spans_opened
        s = rec.snapshot()
        assert s["entries"] <= cap


def test_histogram_merge_rejects_geometry_mismatch():
    a = StreamingHistogram()
    b = StreamingHistogram(lo=1e-3)
    with pytest.raises(ValueError):
        a.merge(b)


def test_event_queue_stats_in_report():
    rep = R.run_replicated("ncs2", 2, mode="shard", n_frames=30)
    assert rep.events["pushed"] > 0
    assert rep.events["popped"] > 0
    assert rep.events["pushed"] >= rep.events["popped"]


def test_match_stage_spans_carry_scan_stats():
    """Service spans on a gallery-backed lane attach rows_scored /
    scan_fraction from the match kernel."""
    import jax.numpy as jnp
    from repro.bus import BusParams, SharedBus
    from repro.crypto.gallery import SecureGallery
    from repro.launch.serve import EMB_DIM, WatchlistCartridge
    from repro.runtime import CapabilityRegistry, StreamEngine

    n = 40
    rng = np.random.default_rng(21)
    g = rng.normal(size=(n, EMB_DIM)).astype(np.float32)
    gallery = SecureGallery(EMB_DIM, seed=7)
    gallery.enroll(g, [f"s{i}" for i in range(n)])
    reg = CapabilityRegistry()
    reg.insert(0, WatchlistCartridge(gallery))
    eng = StreamEngine(reg, SharedBus(BusParams("t", base_overhead_s=1e-4)),
                       execute_payloads=True, trace=True)
    eng.feed(6, interval_s=0.0, payload_fn=lambda i: jnp.asarray(g[i % n]),
             frame_bytes=EMB_DIM * 4)
    rep = eng.run(until=60)
    assert rep.frames_out == 6
    svc = [e for e in rep.trace.entries() if e["kind"] == SERVICE]
    assert svc
    tagged = [e for e in svc if "rows_scored" in e.get("args", {})]
    assert tagged, "match-stage spans must carry gallery scan stats"
    for e in tagged:
        assert e["args"]["rows_scored"] == n
        assert e["args"]["scan_fraction"] == 1.0
