"""Identification fast path: kernel dtype family, sharded SecureGallery,
engine event core, and the batched match stage.

The hypothesis property pins the whole kernel family (fp32 / bf16 / int8,
interpret mode) to a ``jax.lax.top_k`` oracle on both scores and indices —
including exact score ties (integer-grid embeddings), tail-padding blocks
(N not a multiple of bn), sub-block query counts (Q < 8), and the k > N
sentinel contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                        # property tests need hypothesis; the rest don't
    from hypothesis import given, settings, strategies as stn
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(**kw):        # leave decorated tests collectable (skipped)
        return lambda fn: pytest.mark.skip("hypothesis not installed")(fn)

    class _StnStub:         # strategy expressions evaluate at import time
        def __getattr__(self, name):
            return lambda *a, **k: None

    stn = _StnStub()

from repro.crypto import SecureGallery
from repro.kernels import ref as R
from repro.kernels.gallery_match import (NEG, dequantize_gallery,
                                         gallery_match_pallas,
                                         gallery_match_quant_pallas,
                                         quantize_gallery)

if HAVE_HYPOTHESIS:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")

DTYPES = ("fp32", "bf16", "int8")


def _normalize(x):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


# ---------------------------------------------------------------------------
# hypothesis property: every dtype path vs the jax.lax.top_k oracle
# ---------------------------------------------------------------------------
@given(seed=stn.integers(0, 2**31 - 1),
       Q=stn.integers(1, 12),
       N=stn.integers(1, 300),
       k=stn.integers(1, 8),
       path=stn.sampled_from(DTYPES),
       ties=stn.booleans())
def test_gallery_match_property(seed, Q, N, k, path, ties):
    rng = np.random.default_rng(seed)
    D = 16
    if ties:
        # integer-grid embeddings force exact duplicate scores, so the
        # tie-breaking discipline itself is under test
        q = rng.integers(-1, 2, (Q, D)).astype(np.float32)
        g = rng.integers(-1, 2, (N, D)).astype(np.float32)
        q[np.all(q == 0, axis=1)] = 1.0          # avoid zero rows
        g[np.all(g == 0, axis=1)] = 1.0
    else:
        q = rng.normal(size=(Q, D)).astype(np.float32)
        g = rng.normal(size=(N, D)).astype(np.float32)
    qn = np.asarray(_normalize(jnp.asarray(q)))
    gn = np.asarray(_normalize(jnp.asarray(g)))

    # bn=64 < 300 exercises multi-block merges and tail-padding blocks
    if path == "int8":
        g_q, g_s = quantize_gallery(jnp.asarray(gn))
        s, i = gallery_match_quant_pallas(jnp.asarray(qn), g_q, g_s, k=k,
                                          bq=8, bn=64, interpret=True)
        g_oracle = np.asarray(dequantize_gallery(g_q, g_s))
    elif path == "bf16":
        qb = jnp.asarray(qn).astype(jnp.bfloat16)
        gb = jnp.asarray(gn).astype(jnp.bfloat16)
        s, i = gallery_match_pallas(qb, gb, k=k, bq=8, bn=64, interpret=True)
        # oracle sees the same storage-rounded values (fp32 accumulation)
        qn = np.asarray(qb.astype(jnp.float32))
        g_oracle = np.asarray(gb.astype(jnp.float32))
    else:
        s, i = gallery_match_pallas(jnp.asarray(qn), jnp.asarray(gn), k=k,
                                    bq=8, bn=64, interpret=True)
        g_oracle = gn
    sr, ir = R.gallery_match_ref(jnp.asarray(qn), jnp.asarray(g_oracle), k=k)
    s, i, sr, ir = (np.asarray(x) for x in (s, i, sr, ir))

    assert s.shape == (Q, k) and i.shape == (Q, k)
    k_eff = min(k, N)
    # k > N sentinel contract
    assert np.all(i[:, k_eff:] == -1) and np.all(s[:, k_eff:] == NEG)
    valid_s, valid_i = s[:, :k_eff], i[:, :k_eff]
    # scores match the oracle exactly-ish (both paths accumulate in fp32)
    np.testing.assert_allclose(valid_s, sr[:, :k_eff], atol=2e-5, rtol=1e-5)
    assert np.all(np.diff(valid_s, axis=1) <= 1e-6)          # descending
    assert np.all((valid_i >= 0) & (valid_i < N))
    # indices agree with the oracle except across exact-tie permutations
    agree = valid_i == ir[:, :k_eff]
    tie = np.isclose(valid_s, sr[:, :k_eff], atol=2e-5)
    assert np.all(agree | tie)
    # every returned (score, index) pair is self-consistent: the score IS
    # the cosine of the row it claims (robust to any tie permutation)
    recomputed = np.take_along_axis(qn @ g_oracle.T, valid_i, axis=1)
    np.testing.assert_allclose(valid_s, recomputed, atol=2e-5, rtol=1e-5)


def test_k_exceeds_gallery_sentinels():
    q = jnp.asarray(np.eye(3, 8, dtype=np.float32))
    g = jnp.asarray(np.eye(2, 8, dtype=np.float32))
    s, i = gallery_match_pallas(q, g, k=5, interpret=True)
    assert s.shape == (3, 5) and i.shape == (3, 5)
    assert np.all(np.asarray(i)[:, 2:] == -1)
    assert np.all(np.asarray(s)[:, 2:] == NEG)
    sr, ir = R.gallery_match_ref(q, g, k=5)
    np.testing.assert_allclose(np.asarray(s)[:, :2], np.asarray(sr)[:, :2],
                               atol=1e-6)


def test_fused_normalize_matches_separate_normalize():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(7, 32)).astype(np.float32)) * 5.0
    g = _normalize(jnp.asarray(rng.normal(size=(90, 32)).astype(np.float32)))
    s_fused, i_fused = gallery_match_pallas(q, g, k=4, fuse_norm=True,
                                            bn=64, interpret=True)
    s_sep, i_sep = gallery_match_pallas(_normalize(q), g, k=4, bn=64,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(s_fused), np.asarray(s_sep),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_fused), np.asarray(i_sep))


def test_quantize_gallery_roundtrip_error_bounded():
    rng = np.random.default_rng(4)
    g = np.asarray(_normalize(jnp.asarray(
        rng.normal(size=(50, 64)).astype(np.float32))))
    g_q, g_s = quantize_gallery(jnp.asarray(g))
    back = np.asarray(dequantize_gallery(g_q, g_s))
    # symmetric per-row: error <= half a quantization step per element
    step = np.asarray(g_s)[:, None]
    assert np.all(np.abs(back - g) <= 0.5 * step + 1e-7)


# ---------------------------------------------------------------------------
# sharded SecureGallery
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", DTYPES)
def test_sharded_match_agrees_with_monolithic(dtype):
    rng = np.random.default_rng(11)
    dim, n = 48, 400
    g = rng.normal(size=(n, dim)).astype(np.float32)
    labels = [f"id{i}" for i in range(n)]
    q = g[[7, 200, 333]] + 0.05 * rng.normal(size=(3, dim)).astype(np.float32)

    mono = SecureGallery(dim, seed=5)
    mono.enroll(g, labels)
    lm, sm = mono.match(q, k=3)

    store = SecureGallery(dim, seed=5, n_shards=4, match_dtype=dtype)
    store.enroll(g, labels)
    assert store.shard_sizes() == [100, 100, 100, 100]
    ls, ss = store.match(q, k=3)
    assert list(ls[:, 0]) == list(lm[:, 0])          # top-1 identical
    assert np.all(np.diff(np.asarray(ss), axis=1) <= 1e-6)
    if dtype == "fp32":
        np.testing.assert_allclose(np.asarray(ss), np.asarray(sm), atol=1e-5)


def test_shard_lifecycle_enroll_reshard_rekey_seal():
    rng = np.random.default_rng(12)
    dim = 32
    g = rng.normal(size=(120, dim)).astype(np.float32)
    store = SecureGallery(dim, seed=9, n_shards=3, match_dtype="int8")
    for lo in range(0, 120, 40):                     # incremental enrollment
        store.enroll(g[lo:lo + 40], list(range(lo, lo + 40)))
    assert sum(store.shard_sizes()) == 120
    assert max(store.shard_sizes()) - min(store.shard_sizes()) <= 1
    q = g[[17]] + 0.02 * rng.normal(size=(1, dim)).astype(np.float32)
    assert store.match(q, k=1)[0][0, 0] == 17
    store.reshard(5)
    assert store.n_shards == 5 and sum(store.shard_sizes()) == 120
    assert store.match(q, k=1)[0][0, 0] == 17
    store.rekey(77)                                  # revocation
    assert store.match(q, k=1)[0][0, 0] == 17
    store.seal()                                     # drop plaintext views
    assert all(not p for p in store._prep)
    assert store.match(q, k=1)[0][0, 0] == 17
    assert store.protected_gallery().shape == (120, dim)


def test_sharded_merge_sorts_when_k_spans_whole_gallery():
    """Regression: with sum(per-shard k) == k the merge must still sort —
    the per-shard result columns are not globally ordered."""
    rng = np.random.default_rng(14)
    dim, n = 16, 5
    g = rng.normal(size=(n, dim)).astype(np.float32)
    store = SecureGallery(dim, seed=3, n_shards=2)
    store.enroll(g, list(range(n)))
    labels, scores = store.match(g[[4]], k=n)              # k == gallery size
    assert labels[0, 0] == 4                               # exact self-match
    s = np.asarray(scores)
    assert np.all(np.diff(s, axis=1) <= 1e-6)              # globally sorted
    assert s[0, 0] >= 1.0 - 1e-5


def test_int8_recall_at_1_on_noisy_queries():
    rng = np.random.default_rng(13)
    dim, n, nq = 64, 2000, 128
    g = rng.normal(size=(n, dim)).astype(np.float32)
    store = SecureGallery(dim, seed=2, n_shards=4)
    store.enroll(g, list(range(n)))
    qidx = rng.integers(0, n, nq)
    q = g[qidx] + 0.1 * rng.normal(size=(nq, dim)).astype(np.float32)
    truth = store.match(q, k=1, dtype="fp32")[0][:, 0].astype(np.int64)
    got = store.match(q, k=1, dtype="int8")[0][:, 0].astype(np.int64)
    assert np.mean(got == truth) >= 0.99


# ---------------------------------------------------------------------------
# engine event core
# ---------------------------------------------------------------------------
@given(events=stn.lists(stn.tuples(stn.floats(0, 10, allow_nan=False),
                                   stn.integers(0, 99)),
                        min_size=1, max_size=200))
def test_event_queue_disciplines_pop_identically(events):
    from repro.runtime.events import HeapEventQueue, ListEventQueue
    heap, lst = HeapEventQueue(), ListEventQueue()
    for t, tag in events:
        heap.push(t, None, (tag,))
        lst.push(t, None, (tag,))
    order_h = [heap.pop()[:2] for _ in range(len(events))]
    order_l = [lst.pop()[:2] for _ in range(len(events))]
    assert order_h == order_l                        # min-time, FIFO on ties
    assert len(heap) == len(lst) == 0


def test_engine_reports_identical_under_both_queues():
    from repro.bus import BusParams, SharedBus
    from repro.core import messages as msg
    from repro.core.cartridge import DeviceModel, FnCartridge
    from repro.runtime import (CapabilityRegistry, HeapEventQueue,
                               ListEventQueue, StreamEngine)
    reports = []
    for qcls in (HeapEventQueue, ListEventQueue):
        reg = CapabilityRegistry()
        spec = msg.MessageSpec(msg.IMAGE_FRAME)
        for i in range(3):
            reg.insert(i, FnCartridge(f"s{i}", lambda p, x: x, spec, spec,
                                      device=DeviceModel(service_s=0.01)))
        eng = StreamEngine(reg, SharedBus(BusParams("t",
                                                    base_overhead_s=1e-4)),
                           event_queue=qcls())
        eng.feed(60, interval_s=0.005)
        eng.schedule_remove(0.1, slot=1)             # hot-swap mid-run
        reports.append(eng.run(until=30))
    a, b = reports
    assert a.frames_out == b.frames_out == 60
    assert a.sim_time == b.sim_time
    np.testing.assert_allclose(a.latencies, b.latencies)


# ---------------------------------------------------------------------------
# batched match stage
# ---------------------------------------------------------------------------
def test_watchlist_stage_coalesces_microbatch_into_one_kernel_call():
    from repro.bus import BusParams, SharedBus
    from repro.core import messages as msg
    from repro.launch.serve import EMB_DIM, WatchlistCartridge
    from repro.runtime import CapabilityRegistry, StreamEngine
    rng = np.random.default_rng(21)
    g = rng.normal(size=(40, EMB_DIM)).astype(np.float32)
    gallery = SecureGallery(EMB_DIM, seed=7, n_shards=2)
    gallery.enroll(g, [f"s{i}" for i in range(40)])
    cart = WatchlistCartridge(gallery)
    reg = CapabilityRegistry()
    reg.insert(0, cart)
    eng = StreamEngine(reg, SharedBus(BusParams("t", base_overhead_s=1e-4)),
                       execute_payloads=True, queue_cap=8)
    n = 24
    eng.feed(n, interval_s=0.0,                      # all queued: max batches
             payload_fn=lambda i: jnp.asarray(g[i % 40]),
             frame_bytes=EMB_DIM * 4)
    rep = eng.run(until=60)
    assert rep.frames_out == n
    assert cart.stats["processed"] == n
    # coalesced: far fewer kernel dispatches than frames
    assert cart.stats["match_calls"] <= -(-n // 2)
    assert rep.stage_stats["watchlist_db"].max_batch > 1