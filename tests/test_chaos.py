"""Chaos fabric: deterministic fault injection, recovery guarantees,
quarantine hysteresis, and the fault-free bit-identity contract."""
import numpy as np
import pytest

from repro.runtime import (EngineReport, build_chaos_engine,
                           chaos_lane_names, run_chaos, run_replicated)
from repro.runtime.elastic import ElasticController, largest_mesh
from repro.runtime.faults import (FAULT_KINDS, HUB_POWER_LOSS, LANE_CRASH,
                                  LANE_HANG, LINK_DOWN, FaultEvent,
                                  FaultPlan, QuarantinePolicy, RetryPolicy,
                                  frame_checksum)
from repro.runtime.health import QuarantineLedger

QUICK = QuarantinePolicy(lease_s=0.2, probation_s=0.2)


def _chaos(plan, n_bursts=40, **kw):
    return run_chaos(plan, quarantine=QUICK, n_bursts=n_bursts, **kw)


def _assert_zero_loss_exactly_once(rep):
    assert rep.frames_out == rep.frames_in, \
        f"lost {rep.frames_in - rep.frames_out} frames"
    assert rep.faults["duplicates"] == 0, \
        f"{rep.faults['duplicates']} duplicate deliveries"


# -- plan determinism ---------------------------------------------------------
def test_storm_is_replay_stable():
    kw = dict(horizon_s=3.0, lanes=chaos_lane_names(), hubs=(0, 1),
              links=((0, 1),), crash_rate=2.0, hang_rate=1.0,
              hub_loss_rate=0.5, link_down_rate=1.0, corrupt_p=0.05)
    a = FaultPlan.storm(seed=9, **kw)
    b = FaultPlan.storm(seed=9, **kw)
    assert a.events == b.events
    assert [a.corrupt_draw(s, 0) for s in range(50)] == \
        [b.corrupt_draw(s, 0) for s in range(50)]
    c = FaultPlan.storm(seed=10, **kw)
    assert a.events != c.events


def test_storm_respects_window_and_targets():
    lanes = chaos_lane_names()
    plan = FaultPlan.storm(seed=3, horizon_s=2.0, lanes=lanes,
                           links=((0, 1),), crash_rate=5.0,
                           link_down_rate=2.0, t0=0.1)
    assert plan.events, "a 5 faults/s storm over ~2 s must emit events"
    for ev in plan.events:
        assert 0.1 <= ev.t <= 2.0
        assert ev.kind in FAULT_KINDS
        if ev.kind == LANE_CRASH:
            assert ev.target in lanes
        if ev.kind == LINK_DOWN:
            assert ev.target == (0, 1)
            assert ev.duration > 0        # outages always have a window


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(0.1, "meteor_strike", "detect")
    with pytest.raises(ValueError):
        FaultEvent(-0.1, LANE_CRASH, "detect")
    with pytest.raises(ValueError):
        FaultPlan(corrupt_p=1.0)


def test_empty_plan_and_describe():
    assert FaultPlan().empty
    assert not FaultPlan(corrupt_p=0.1).empty
    plan = FaultPlan.storm(seed=1, horizon_s=2.0,
                           lanes=chaos_lane_names(), crash_rate=3.0)
    d = plan.describe()
    assert d["n_events"] == len(plan.events) > 0
    assert d["by_kind"][LANE_CRASH] == len(plan.events)


def test_retry_backoff_shape():
    r = RetryPolicy(base_s=0.01, factor=2.0, max_s=0.1, jitter=0.0)
    assert r.backoff(0) == pytest.approx(0.01)
    assert r.backoff(2) == pytest.approx(0.04)
    assert r.backoff(10) == pytest.approx(0.1)       # capped
    j = RetryPolicy(base_s=0.01, jitter=0.5)
    # jitter is deterministic per (key, attempt) and bounded
    assert j.backoff(1, key="a") == j.backoff(1, key="a")
    assert j.backoff(1, key="a") != j.backoff(1, key="b")
    assert 0.01 <= j.backoff(1, key="a") <= 0.03


def test_frame_checksum_covers_identity():
    class M:
        def __init__(self, seq, kind, b):
            self.seq, self.kind, self.meta = seq, kind, {"bytes": b}
    a, b = M(1, "image", 100), M(2, "image", 100)
    assert frame_checksum(a) != frame_checksum(b)
    assert frame_checksum(a) == frame_checksum(M(1, "image", 100))


# -- recovery guarantees ------------------------------------------------------
def test_lane_crash_zero_loss():
    plan = FaultPlan(events=(FaultEvent(0.1, LANE_CRASH, "detect"),
                             FaultEvent(0.2, LANE_CRASH, "embed#h1r0")))
    rep = _chaos(plan)
    _assert_zero_loss_exactly_once(rep)
    assert rep.faults["lane_crash"] == 2
    assert rep.faults["quarantined"] == 2
    assert rep.faults["reinstated"] == 2


def test_lane_hang_promoted_by_watchdog():
    plan = FaultPlan(events=(FaultEvent(0.15, LANE_HANG, "detect"),))
    eng = build_chaos_engine(plan, quarantine=QUICK, n_bursts=40)
    rep = eng.run(until=float("inf"))
    _assert_zero_loss_exactly_once(rep)
    assert rep.faults["lane_hang"] == 1
    assert rep.faults["hang_promoted"] == 1
    # the hung cycle was aborted, not measured as a latency sample
    assert any(k == "aborted" for _, k, _ in eng.health.events)


def test_hub_power_loss_survives_on_other_hub():
    plan = FaultPlan(events=(FaultEvent(0.2, HUB_POWER_LOSS, 0),))
    rep = _chaos(plan)
    _assert_zero_loss_exactly_once(rep)
    assert rep.faults["hub_power_loss"] == 1
    assert rep.faults["quarantined"] == 4      # both stages' hub-0 lanes
    assert any("power loss" in a for _, a in rep.alerts)


def test_link_down_reroutes_or_holds():
    plan = FaultPlan.storm(seed=5, horizon_s=1.5, links=((0, 1),),
                           link_down_rate=3.0, link_down_s=0.2)
    rep = _chaos(plan)
    _assert_zero_loss_exactly_once(rep)
    assert rep.faults["link_down"] == rep.faults["link_up"] > 0


def test_transfer_corruption_detected_and_resent():
    rep = _chaos(FaultPlan(corrupt_p=0.08, seed=11))
    _assert_zero_loss_exactly_once(rep)
    assert rep.faults["corrupt_detected"] > 0
    assert rep.faults["resends"] >= rep.faults["corrupt_detected"]


def test_full_storm_zero_loss_exactly_once_multiseed():
    for seed in (1, 2, 3):
        plan = FaultPlan.storm(
            seed=seed, horizon_s=2.0, lanes=chaos_lane_names(),
            hubs=(0, 1), links=((0, 1),), crash_rate=4.0, hang_rate=2.0,
            hub_loss_rate=0.5, link_down_rate=1.0, corrupt_p=0.05)
        rep = _chaos(plan)
        _assert_zero_loss_exactly_once(rep)
        assert rep.faults["injected"] == len(plan.events)


def test_chaos_runs_are_deterministic():
    plan = FaultPlan.storm(seed=4, horizon_s=1.5,
                           lanes=chaos_lane_names(), crash_rate=3.0,
                           hang_rate=1.0, corrupt_p=0.03)
    a, b = _chaos(plan), _chaos(plan)
    assert a.throughput() == b.throughput()
    assert a.p99() == b.p99()
    assert a.faults == b.faults


def test_empty_plan_bit_identical_to_no_plan():
    plain = run_replicated("ncs2", 5, "broadcast", 120)
    chaos = run_replicated("ncs2", 5, "broadcast", 120,
                           fault_plan=FaultPlan())
    assert plain.throughput() == chaos.throughput()   # exact, not approx
    assert plain.p99() == chaos.p99()
    assert plain.frames_out == chaos.frames_out


# -- quarantine hysteresis (lease state machine) ------------------------------
def test_quarantine_lease_and_probation_windows():
    led = QuarantineLedger(QuarantinePolicy(lease_s=1.0, probation_s=0.5,
                                            probation_penalty=4.0))
    until = led.quarantine("lane", t=0.0)
    assert until == pytest.approx(1.0)
    assert led.quarantined("lane", 0.5)
    assert not led.quarantined("lane", 1.0)
    assert led.penalty("lane", 0.5) == 1.0          # benched, not penalized
    assert led.penalty("lane", 1.2) == 4.0          # on probation
    assert led.penalty("lane", 1.6) == 1.0          # clean


def test_flap_at_exact_probation_boundary_escalates():
    """Satellite 6: a lane that faults at *exactly* the probation period
    must not oscillate in/out of the pick set with a constant period —
    each boundary flap doubles the lease up to the cap."""
    p = QuarantinePolicy(lease_s=0.5, probation_s=0.5, flap_factor=2.0,
                         lease_cap_s=8.0)
    led = QuarantineLedger(p)
    t = 0.0
    leases = []
    for _ in range(6):
        until = led.quarantine("flapper", t)
        leases.append(until - t)
        t = until + p.probation_s       # fault again at the exact boundary
    # 0.5, 1.0, 2.0, 4.0, 8.0, 8.0 (capped): strictly increasing to cap
    assert leases == pytest.approx([0.5, 1.0, 2.0, 4.0, 8.0, 8.0])
    assert led.summary()["flapper"]["flaps"] == 5


def test_fault_after_clean_probation_resets_lease():
    p = QuarantinePolicy(lease_s=0.5, probation_s=0.5, flap_factor=2.0)
    led = QuarantineLedger(p)
    until = led.quarantine("lane", 0.0)
    until = led.quarantine("lane", until + p.probation_s)   # flap: 1.0
    assert led._st["lane"].lease_s == pytest.approx(1.0)
    # survives probation cleanly, then faults much later: back to base
    led.quarantine("lane", until + p.probation_s + 5.0)
    assert led._st["lane"].lease_s == pytest.approx(0.5)


def test_flapping_lane_engine_no_oscillation():
    """A lane crashed repeatedly at its own reinstatement cadence spends
    exponentially longer benched: total quarantines stay far below what
    constant-period oscillation would produce, and every frame still
    arrives exactly once."""
    q = QuarantinePolicy(lease_s=0.05, probation_s=0.05, flap_factor=2.0,
                         lease_cap_s=2.0)
    events = tuple(FaultEvent(0.05 + 0.1 * i, LANE_CRASH, "detect")
                   for i in range(12))
    rep = run_chaos(FaultPlan(events=events), quarantine=q, n_bursts=40)
    _assert_zero_loss_exactly_once(rep)
    led = rep.faults["quarantine"]["detect"]
    # most of the 12 scheduled crashes hit an already-benched lane
    assert rep.faults["quarantined"] < 12
    assert led["flaps"] >= 2
    assert led["lease_s"] > q.lease_s     # lease escalated, not constant


# -- engine accounting (satellite 2: downtime merge) --------------------------
def test_downtime_merge_overlapping_windows():
    rep = EngineReport()
    rep.sim_time = 10.0
    rep.downtime = [(1.0, 3.0, "swap"), (2.0, 4.0, "fault"),
                    (6.0, 7.0, "swap"), (6.5, 6.8, "fault"),
                    (9.0, 9.0, "noop")]
    assert rep.merged_downtime() == [(1.0, 4.0), (6.0, 7.0)]
    assert rep.total_downtime() == pytest.approx(4.0)
    assert rep.availability() == pytest.approx(0.6)


def test_downtime_merge_disjoint_unchanged():
    rep = EngineReport()
    rep.sim_time = 10.0
    rep.downtime = [(1.0, 2.0, "a"), (3.0, 4.5, "b")]
    assert rep.total_downtime() == pytest.approx(2.5)
    assert rep.availability() == pytest.approx(0.75)
    assert EngineReport().availability() == 1.0     # no sim time yet


# -- elastic controller (satellite 1: all-devices-failed) ---------------------
def test_largest_mesh_zero_devices():
    assert largest_mesh(0, 2) == (0, 0)
    assert largest_mesh(-1, 1) == (0, 0)


def test_elastic_all_failed_pauses_instead_of_crashing():
    import jax
    devs = jax.devices()
    ctl = ElasticController(list(devs), model_parallel=1)
    assert not ctl.paused
    for i in range(len(devs)):
        ctl.fail(i, step=10)
    mesh = ctl.remesh(step=10)          # must not ZeroDivisionError
    assert mesh is None and ctl.paused
    assert any(e.kind == "paused" for e in ctl.events)
    ctl.join(0, step=20)
    assert ctl.remesh(step=20) is not None
    assert not ctl.paused


# -- gallery shard failover ---------------------------------------------------
def _enrolled_store(n_shards=3, n=90, dim=32, seed=7):
    from repro.crypto.gallery import SecureGallery
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(n, dim)).astype(np.float32)
    store = SecureGallery(dim, seed=seed, n_shards=n_shards)
    store.enroll(g, list(range(n)))
    return store, g


def test_gallery_failover_preserves_matching():
    store, g = _enrolled_store()
    before, _ = store.match(g[[5, 40, 80]], k=1)
    into = store.failover_shard(1)
    assert into != 1 and store.failovers == 1
    assert store.shard_sizes()[1] == 0
    assert sum(store.shard_sizes()) == len(g)
    after, _ = store.match(g[[5, 40, 80]], k=1)
    np.testing.assert_array_equal(before, after)


def test_gallery_failover_works_after_seal():
    """Recovery must read the encrypted-at-rest blob, never a plaintext
    working-set view — so it works with every decrypted view dropped."""
    store, g = _enrolled_store()
    store.match(g[[0]], k=1)            # populate plaintext views...
    store.seal()                        # ...then drop them all
    assert all(not p for p in store._prep)
    store.failover_shard(0, into=2)
    got, _ = store.match(g[[5, 40, 80]], k=1)
    assert list(got[:, 0]) == [5, 40, 80]


def test_gallery_failover_validation():
    store, _ = _enrolled_store()
    with pytest.raises(ValueError):
        store.failover_shard(99)
    with pytest.raises(ValueError):
        store.failover_shard(0, into=0)
    from repro.crypto.gallery import SecureGallery
    single = SecureGallery(8, n_shards=1)
    single.enroll(np.eye(8, dtype=np.float32), list(range(8)))
    with pytest.raises(ValueError):
        single.failover_shard(0)


def test_gallery_failover_ann_survives():
    store, g = _enrolled_store(n_shards=3, n=120)
    store.build_ann_index(n_cells=8)
    before, _ = store.match(g[[7, 63]], k=1, mode="ann", nprobe=8)
    store.failover_shard(2)
    after, _ = store.match(g[[7, 63]], k=1, mode="ann", nprobe=8)
    np.testing.assert_array_equal(before, after)


# -- registry fault state -----------------------------------------------------
def test_registry_failed_devices_leave_arbitration():
    from repro.core import messages as msg
    from repro.core.cartridge import DeviceModel, FnCartridge
    from repro.runtime import CapabilityRegistry
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    reg = CapabilityRegistry()
    prim = FnCartridge("a", lambda p, x: x, spec, spec, capability_id=1,
                       device=DeviceModel(service_s=0.01))
    reg.insert(0, prim, hub=0)
    rep1 = prim.clone("b")
    reg.add_replica(0, rep1, hub=1)
    assert reg.n_endpoints() == 2
    reg.set_failed(rep1)
    assert reg.is_failed(rep1) and reg.n_failed() == 1
    assert reg.n_endpoints() == 1
    assert reg.n_endpoints_on(1) == 0 and reg.n_endpoints_on(0) == 1
    reg.set_failed(rep1, False)
    assert reg.n_endpoints() == 2 and reg.n_endpoints_on(1) == 1
    # unplugging a failed device clears its fault state
    reg.set_failed(rep1)
    reg.remove_replica(0, rep1)
    assert reg.n_failed() == 0
    with pytest.raises(ValueError):
        reg.set_failed(rep1)            # no longer plugged


# -- fabric link state --------------------------------------------------------
def test_fabric_link_state_and_cost():
    from repro.bus import BusParams
    from repro.bus.fabric import FabricRouter
    fab = FabricRouter([BusParams("h0"), BusParams("h1")])
    assert fab.link_ok(0, 1) and not fab.has_down_links()
    assert fab.route_cost(0, 1, 1000) < float("inf")
    fab.set_link_state(0, 1, up=False)
    assert not fab.link_ok(0, 1) and fab.has_down_links()
    assert fab.route_cost(0, 1, 1000) == float("inf")
    assert fab.route_cost(0, 0, 1000) < float("inf")  # local unaffected
    with pytest.raises(RuntimeError):
        fab.transfer(0.0, 1000, src=0, dst=1)
    fab.set_link_state(0, 1, up=True)
    assert fab.link_ok(0, 1) and not fab.has_down_links()
    fab.transfer(0.0, 1000, src=0, dst=1)     # flows again
    with pytest.raises(ValueError):
        fab.set_link_state(0, 0, up=False)
