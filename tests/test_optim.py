"""Optimizers, quantized state, gradient compression, checkpoint, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, TokenStream, Prefetcher
from repro.optim import (adamw, adafactor, constant, cosine_warmup,
                         dequantize, quantize)
from repro.optim.compress import (compress_with_feedback, decompress,
                                  init_residual)
from repro.runtime.elastic import largest_mesh


def _quadratic_problem():
    target = jnp.array([1.0, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros(4), "b": jnp.zeros(())}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + (p["b"] - 1.0) ** 2
    return params, loss


@pytest.mark.parametrize("make", [
    lambda: adamw(constant(0.05), weight_decay=0.0),
    lambda: adamw(constant(0.05), weight_decay=0.0, int8_state=True),
    lambda: adafactor(constant(0.5)),
])
def test_optimizers_descend(make):
    params, loss = _quadratic_problem()
    opt = make()
    st = opt.init(params)
    l0 = float(loss(params))
    for i in range(60):
        g = jax.grad(loss)(params)
        params, st, _ = opt.update(g, st, params, jnp.int32(i))
    assert float(loss(params)) < 0.05 * l0


def test_layer_mapped_update_matches_unmapped():
    """lax.map over stacked-layer leaves must not change the math."""
    key = jax.random.PRNGKey(0)
    stacked = jax.random.normal(key, (4, 8, 16))  # (layers, ...)
    g = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    opt = adamw(constant(0.1), weight_decay=0.01)
    st = opt.init({"w": stacked})
    p1, _, _ = opt.update({"w": g}, st, {"w": stacked}, jnp.int32(0))
    # reference: run each layer separately
    opt2 = adamw(constant(0.1), weight_decay=0.01)
    outs = []
    for i in range(4):
        sti = opt2.init({"w": stacked[i]})
        pi, _, _ = opt2.update({"w": g[i]}, sti, {"w": stacked[i]},
                               jnp.int32(0))
        outs.append(pi["w"])
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray(jnp.stack(outs)), atol=5e-6)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q = quantize(x)
    err = jnp.abs(dequantize(q) - x)
    # blockwise symmetric int8: error <= blockmax/127
    assert float(jnp.max(err)) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_compression_error_feedback_converges():
    """Error feedback: mean of compressed grads over steps ~= mean of raw."""
    gs = [jax.random.normal(jax.random.PRNGKey(i), (256,)) for i in range(20)]
    resid = init_residual({"g": gs[0]})
    acc_c = jnp.zeros(256)
    for g in gs:
        qg, resid = compress_with_feedback({"g": g}, resid)
        acc_c = acc_c + decompress(qg)["g"]
    acc = sum(gs)
    # residual re-injection keeps the accumulated error bounded (not O(T))
    assert float(jnp.max(jnp.abs(acc_c - acc))) < 0.2


def test_cosine_warmup_shape():
    lr = cosine_warmup(1e-3, warmup=10, total=100)
    vals = [float(lr(jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert vals[0] < vals[1] < vals[2]
    assert vals[2] == pytest.approx(1e-3, rel=0.1)
    assert vals[4] < vals[3] < vals[2]


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (10, 20, 30):
        store.save(step, jax.tree.map(lambda x: x + step, tree), block=True)
    assert store.steps() == [20, 30]  # gc keeps 2
    step, got = store.restore(tree)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"] + 30))


def test_checkpoint_crash_mid_save_never_corrupts(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"w": jnp.ones(8)}
    store.save(1, tree, block=True)
    # simulate a crash: a stale tmp dir with garbage
    bad = tmp_path / ".tmp-2-999"
    bad.mkdir()
    (bad / "shards.npz").write_bytes(b"garbage")
    step, got = store.restore(tree)
    assert step == 1


def test_elastic_largest_mesh():
    assert largest_mesh(16, 4) == (4, 4)
    assert largest_mesh(15, 4) == (2, 4)   # drops to power of two
    assert largest_mesh(7, 2) == (2, 2)
    assert largest_mesh(512, 16) == (32, 16)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_sharded():
    c0 = DataConfig(seed=7, vocab_size=100, seq_len=32, global_batch=8,
                    n_shards=2, shard=0)
    c1 = c0.__class__(**{**c0.__dict__, "shard": 1})
    s0, s0b, s1 = TokenStream(c0), TokenStream(c0), TokenStream(c1)
    b0, b0b, b1 = s0.batch_at(5), s0b.batch_at(5), s1.batch_at(5)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])  # determinism
    assert not np.array_equal(b0["tokens"], b1["tokens"])       # disjoint
    assert b0["tokens"].shape == (4, 32)                        # local batch


def test_prefetcher_resumes_at_step():
    c = DataConfig(seed=1, vocab_size=50, seq_len=16, global_batch=2)
    src = TokenStream(c)
    pf = Prefetcher(src, start_step=100, depth=2)
    step, batch = pf.next()
    pf.close()
    assert step == 100
    np.testing.assert_array_equal(batch["tokens"], src.batch_at(100)["tokens"])
