"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gallery_match import gallery_match_pallas
from repro.kernels.mamba2_ssd import mamba2_ssd_pallas


# ---------------------------------------------------------------------------
# gallery_match
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("Q,N,D,k", [
    (1, 16, 32, 1),
    (7, 100, 64, 5),
    (37, 1000, 128, 5),
    (128, 2048, 256, 10),
    (5, 513, 64, 8),       # non-multiple gallery vs block
    (3, 2, 16, 5),         # k > N: sentinel tail (NEG, -1)
    (2, 600, 32, 5),       # Q < 8 with a multi-block gallery
    (6, 127, 64, 8),       # tail-padding block just under bn
])
def test_gallery_match_matches_ref(Q, N, D, k):
    kq = jax.random.PRNGKey(Q * 1000 + N)
    q = jax.random.normal(kq, (Q, D), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(1), (N, D), jnp.float32)
    qn = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    gn = g / jnp.linalg.norm(g, axis=-1, keepdims=True)
    s, i = gallery_match_pallas(qn, gn, k=k, interpret=True)
    sr, ir = R.gallery_match_ref(qn, gn, k=k)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-5)
    # indices may differ on exact ties; scores must agree
    agree = np.asarray(i) == np.asarray(ir)
    tie_ok = np.isclose(np.asarray(s), np.asarray(sr), atol=1e-5)
    assert np.all(agree | tie_ok)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gallery_match_dtypes(dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (9, 64)).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (257, 64)).astype(dtype)
    s, i = K.gallery_match(q, g, k=3)
    assert s.shape == (9, 3) and i.shape == (9, 3)
    assert bool(jnp.all(jnp.diff(s, axis=1) <= 1e-6))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,Kh,S,D,causal,window", [
    (1, 2, 2, 128, 64, True, 0),
    (2, 4, 2, 256, 64, True, 0),       # GQA group 2
    (1, 8, 1, 512, 128, True, 0),      # MQA
    (2, 2, 2, 256, 64, False, 0),      # bidirectional
    (1, 4, 4, 512, 64, True, 128),     # sliding window
    (1, 2, 2, 384, 32, True, 0),       # non-multiple of block
])
def test_flash_matches_ref(B, H, Kh, S, D, causal, window):
    kq = jax.random.PRNGKey(S + H)
    q = jax.random.normal(kq, (B, H, S, D), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Kh, S, D),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Kh, S, D), jnp.float32)
    o = flash_attention_pallas(q, k, v, causal=causal, window=window,
                               bq=128, bk=128, interpret=True)
    orf = R.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=2e-5, rtol=1e-4)


def test_flash_mla_asymmetric_head_dims():
    """qk dim 192 vs v dim 128 (the MLA layout)."""
    B, H, S = 1, 2, 256
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, 192)) * 0.2
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, 192)) * 0.2
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, 128))
    o = flash_attention_pallas(q, k, v, causal=True, bq=128, bk=128,
                               interpret=True)
    orf = R.flash_attention_ref(q, k, v, causal=True, scale=192 ** -0.5)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=2e-5, rtol=1e-4)


def test_flash_bf16():
    B, H, S, D = 1, 2, 256, 64
    q = (jax.random.normal(jax.random.PRNGKey(0), (B, H, S, D)) * 0.3
         ).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D)) * 0.3
         ).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D)
                          ).astype(jnp.bfloat16)
    o = flash_attention_pallas(q, k, v, interpret=True)
    orf = R.flash_attention_ref(q, k, v)
    assert float(jnp.max(jnp.abs(o.astype(jnp.float32) - orf))) < 0.05


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("Bt,L,H,P,N,chunk", [
    (1, 128, 1, 16, 8, 64),
    (2, 256, 3, 32, 16, 128),
    (1, 512, 2, 64, 32, 256),
    (2, 64, 4, 8, 8, 64),              # single chunk
])
def test_ssd_matches_sequential_ref(Bt, L, H, P, N, chunk):
    key = jax.random.PRNGKey(L + P)
    x = jax.random.normal(key, (Bt, L, H, P), jnp.float32)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.PRNGKey(1), (Bt, L, H))) * 0.1
    A = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(2), (H,)))
    B = jax.random.normal(jax.random.PRNGKey(3), (Bt, L, N)) * 0.3
    C = jax.random.normal(jax.random.PRNGKey(4), (Bt, L, N)) * 0.3
    y, st = mamba2_ssd_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, str_ = R.mamba2_ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=2e-4, rtol=1e-3)
