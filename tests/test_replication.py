"""Replicated-stage scheduler: lane groups, per-replica dispatch, and the
engine-driven reproduction of Table 1 (§4.1)."""
import pytest

from repro.bus import BusParams, SharedBus, TABLE1, calibrated, \
    simulate_broadcast_fps
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import (CapabilityRegistry, StreamEngine,
                           build_replicated_engine, engine_broadcast_fps,
                           engine_shard_fps, run_replicated)

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)


def _cart(name, service_s=0.03, load_s=1.5, capability_id=7):
    return FnCartridge(name, lambda p, x: x, SPEC, SPEC,
                       capability_id=capability_id,
                       device=DeviceModel(service_s=service_s, load_s=load_s))


def _bus():
    return SharedBus(BusParams("test", bandwidth=400e6,
                               base_overhead_s=1e-4, arbitration_s=2e-4))


# -- registry replica sets -----------------------------------------------------
def test_registry_replica_roundtrip():
    reg = CapabilityRegistry()
    primary = _cart("infer")
    rec = reg.insert(0, primary)
    r1 = primary.clone()
    r2 = primary.clone()
    reg.add_replica(0, r1)
    reg.add_replica(0, r2)
    assert reg.n_replicas(0) == 3
    assert reg.n_endpoints() == 3
    assert rec.replicas == [primary, r1, r2]
    assert reg.chain() == [primary]          # chain stays primary-only
    reg.remove_replica(0, r1)
    assert rec.replicas == [primary, r2]
    # removing the primary promotes a survivor
    reg.remove_replica(0, primary)
    assert rec.cartridge is r2
    # removing the last replica removes the slot
    reg.remove_replica(0, r2)
    assert 0 not in reg.slots


def test_registry_rejects_incompatible_replica():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("infer"))
    alien = FnCartridge("alien", lambda p, x: x,
                        msg.MessageSpec(msg.EMBEDDING),
                        msg.MessageSpec(msg.EMBEDDING),
                        capability_id=7)
    with pytest.raises(ValueError):
        reg.add_replica(0, alien)
    wrong_cap = _cart("other", capability_id=8)
    with pytest.raises(ValueError):
        reg.add_replica(0, wrong_cap)


def test_registry_rejects_duplicate_physical_device():
    """The same cartridge object is one physical stick: it cannot back two
    lanes (clone() it instead)."""
    reg = CapabilityRegistry()
    primary = _cart("infer")
    reg.insert(0, primary)
    with pytest.raises(ValueError):
        reg.add_replica(0, primary)          # same object, same slot
    rep = primary.clone()
    reg.add_replica(0, rep)
    with pytest.raises(ValueError):
        reg.add_replica(0, rep)              # replica added twice
    reg.insert(1, _cart("infer2"))
    with pytest.raises(ValueError):
        reg.add_replica(1, rep)              # already backing slot 0


def test_retired_replica_stats_survive_lane_pruning():
    """Unplugged lanes are pruned from the live map but their stats stay
    visible in the report."""
    reg = CapabilityRegistry()
    primary = _cart("infer", service_s=0.03)
    reg.insert(0, primary)
    r1 = primary.clone()
    reg.add_replica(0, r1)
    eng = StreamEngine(reg, _bus())
    eng.feed(60, interval_s=0.01)
    eng.schedule_remove_replica(0.4, slot=0, cart=r1)
    rep = eng.run(until=30)
    assert rep.frames_out == 60
    assert id(r1) not in eng._lane_by_cart           # pruned
    assert rep.stage_stats[r1.name].processed > 0    # but reported


def test_clone_shares_params_distinct_identity():
    primary = _cart("infer")
    primary.params = {"w": 1}
    rep = primary.clone()
    assert rep is not primary
    assert rep.name != primary.name
    assert rep.params is primary.params
    # the calibration record is VALUES-equal but never object-shared:
    # per-device mutation (thermal state, drift) must not alias replicas
    assert rep.device is not primary.device
    assert rep.device == primary.device
    assert rep.stats is not primary.stats


# -- the acceptance criterion: engine reproduces Table 1 ----------------------
@pytest.mark.parametrize("device", sorted(TABLE1))
def test_engine_broadcast_reproduces_table1(device):
    """Engine-driven replication must match every published FPS row
    (N = 1..5) within +-1 FPS — the paper's §4.1 measurement, executed by
    the StreamEngine scheduler rather than the side-channel simulator."""
    published = TABLE1[device]
    for n in range(1, 6):
        fps = engine_broadcast_fps(device, n)
        assert abs(fps - published[n - 1]) <= 1.0, \
            f"{device} N={n}: engine {fps:.2f} vs published {published[n-1]}"


@pytest.mark.parametrize("device", sorted(TABLE1))
@pytest.mark.parametrize("n", [1, 3, 5])
def test_engine_broadcast_matches_simulator(device, n):
    """The engine's lane-group dispatcher and the closed-form broadcast
    simulator are the same discrete-event process."""
    p = calibrated(device)
    assert engine_broadcast_fps(device, n) == pytest.approx(
        simulate_broadcast_fps(p, n), rel=1e-6)


def test_shard_mode_scales_throughput():
    """Load-balancing the same sticks (instead of broadcasting) multiplies
    aggregate FPS — the scaling the paper's architecture motivates."""
    one = engine_shard_fps("ncs2", 1)
    three = engine_shard_fps("ncs2", 3)
    five = engine_shard_fps("ncs2", 5)
    assert three > 2.0 * one
    assert five > 4.0 * one


def test_shard_dispatch_balances_replicas():
    rep = run_replicated("ncs2", 4, mode="shard", n_frames=120)
    per_lane = [rep.stage_stats[n].processed
                for n in rep.groups[0]["lanes"]]
    assert sum(per_lane) == 120
    assert min(per_lane) > 0.5 * max(per_lane), per_lane


def test_broadcast_every_replica_sees_every_frame():
    rep = run_replicated("coral", 3, mode="broadcast", n_frames=50)
    assert rep.frames_out == 50
    for name in rep.groups[0]["lanes"]:
        assert rep.stage_stats[name].processed == 50


# -- replica hot-swap: degrade, don't halt ------------------------------------
def test_remove_replica_degrades_without_pause():
    reg = CapabilityRegistry()
    primary = _cart("infer", service_s=0.03)
    reg.insert(0, primary)
    r1, r2 = primary.clone(), primary.clone()
    reg.add_replica(0, r1)
    reg.add_replica(0, r2)
    eng = StreamEngine(reg, _bus())
    eng.feed(150, interval_s=0.01)
    eng.schedule_remove_replica(0.5, slot=0, cart=r1)
    rep = eng.run(until=60)
    assert rep.frames_out == 150, f"lost {rep.lost}"
    assert rep.total_downtime() == 0.0       # no pipeline pause
    assert not rep.alerts                    # no operator alert
    assert rep.groups[0]["lanes"] == [primary.name, r2.name]
    assert any(k == "remove_replica" for _, k, _ in rep.swap_log)
    # the pulled replica did useful work before detach
    assert rep.stage_stats[r1.name].processed > 0


def test_remove_last_replica_falls_back_to_slot_semantics():
    """Pulling the only replica of a mid-chain slot is a whole-slot
    removal: bridge (same-type neighbors) + the ~0.5 s pause."""
    reg = CapabilityRegistry()
    for i in range(3):
        reg.insert(i, _cart(f"s{i}", 0.02))
    eng = StreamEngine(reg, _bus())
    eng.feed(80, interval_s=0.05)
    eng.schedule_remove_replica(1.0, slot=1)
    rep = eng.run(until=30)
    assert rep.frames_out == 80
    assert rep.total_downtime() > 0          # the removal pause happened
    assert 1 not in reg.slots


def test_add_replica_joins_after_handshake_and_speeds_up():
    def overloaded(add_replica):
        reg = CapabilityRegistry()
        primary = _cart("infer", service_s=0.05, load_s=0.2)
        reg.insert(0, primary)
        eng = StreamEngine(reg, _bus(), microbatch=False)
        eng.feed(100, interval_s=0.02)
        if add_replica:
            eng.schedule_add_replica(0.3, slot=0, cart=primary.clone())
        return eng.run(until=120)

    solo = overloaded(False)
    duo = overloaded(True)
    assert solo.frames_out == duo.frames_out == 100
    assert duo.total_downtime() == 0.0       # no pipeline pause on attach
    assert duo.sim_time < solo.sim_time      # second stick pulled its weight
    assert len(duo.groups[0]["lanes"]) == 2


def test_mid_chain_replicated_group_zero_loss():
    """Replicas of a middle stage, with swaps, still conserve frames."""
    reg = CapabilityRegistry()
    reg.insert(0, _cart("pre", 0.01, capability_id=1))
    mid = _cart("mid", 0.04, capability_id=2)
    reg.insert(1, mid)
    reg.add_replica(1, mid.clone())
    reg.add_replica(1, mid.clone())
    reg.insert(2, _cart("post", 0.01, capability_id=3))
    eng = StreamEngine(reg, _bus())
    eng.feed(120, interval_s=0.015)
    eng.schedule_remove_replica(0.8, slot=1)
    rep = eng.run(until=60)
    assert rep.frames_out == 120, f"lost {rep.lost}"
    # every frame crossed the mid group: surviving lanes + detached replica
    mid_total = sum(st.processed for name, st in rep.stage_stats.items()
                    if name.startswith("mid"))
    assert mid_total == 120


# -- quorum broadcast (first k of N results win) -------------------------------
@pytest.mark.parametrize("device", sorted(TABLE1))
def test_quorum_full_preserves_table1_parity(device):
    """quorum=N is the paper's full barrier: bit-identical FPS to the
    unqualified broadcast (and therefore to Table 1)."""
    for n in (2, 5):
        assert engine_broadcast_fps(device, n, n_frames=80, quorum=n) == \
            engine_broadcast_fps(device, n, n_frames=80)


def test_quorum_relaxes_the_barrier_monotonically():
    """Smaller quorums decide earlier: fps(k=1) >= fps(k=3) >= fps(k=5),
    strictly above the full barrier, without losing any replica's work."""
    full = run_replicated("ncs2", 5, "broadcast", 100)
    q3 = run_replicated("ncs2", 5, "broadcast", 100, quorum=3)
    q1 = run_replicated("ncs2", 5, "broadcast", 100, quorum=1)
    assert q1.throughput() >= q3.throughput() > full.throughput()
    # every replica still computed every frame (redundancy preserved)
    for name in q3.groups[0]["lanes"]:
        assert q3.stage_stats[name].processed == 100
    assert q3.groups[0]["quorum"] == 3


def test_quorum_stragglers_suppressed_on_bus():
    """Each frame's N-k stragglers lose their result handoff via the
    existing SharedBus.suppress path (pure accounting, no bus time)."""
    q3 = run_replicated("ncs2", 5, "broadcast", 60, quorum=3)
    assert q3.bus["suppressed_transfers"] == 60 * (5 - 3)
    assert q3.bus["suppressed_bytes"] > 0
    full = run_replicated("ncs2", 5, "broadcast", 60)
    assert full.bus["suppressed_transfers"] == 0
    # frames still conserved end to end
    assert q3.frames_out == 60


def test_quorum_straggler_serializes_and_reports_lag():
    """A replica cannot be >100% utilized: under quorum each lane's next
    frame gates on its own previous finish, so a permanently slow stick
    accumulates visible backlog (``straggler_lag_s``) instead of
    inflating throughput — and the quorum pace is set by the lanes that
    actually keep up."""
    reg = CapabilityRegistry()
    fast = _cart("fast", service_s=0.03)
    reg.insert(0, fast, mode="broadcast", quorum=1)
    reg.add_replica(0, fast.clone("slow", device=DeviceModel(
        service_s=0.3)))
    eng = StreamEngine(reg, _bus())
    eng.feed(120, interval_s=0.0)
    rep = eng.run(until=1e9)
    assert rep.frames_out == 120
    # pace ~= the fast lane's service rate, not faster
    assert rep.sim_time >= 120 * 0.03
    lag = dict(zip(rep.groups[0]["lanes"], rep.groups[0]["straggler_lag_s"]))
    assert lag["fast"] == 0.0
    assert lag["slow"] > 10.0            # real, visible backlog
    # full-barrier groups never lag
    full = run_replicated("ncs2", 3, "broadcast", 40)
    assert full.groups[0]["straggler_lag_s"] == [0.0, 0.0, 0.0]


def test_quorum_ties_still_count_as_stragglers():
    """On a symmetric multi-hub fabric, replicas on different unloaded
    hubs finish at exactly the same instant; a tie with the k-th
    completion is still a loser (only k results are fetched), so the
    per-frame N-k suppression accounting must hold under exact ties."""
    from repro.runtime import run_fabric

    rep = run_fabric([["ncs2"], ["ncs2"]], mode="broadcast", n_frames=40,
                     quorum=1)
    assert rep.frames_out == 40
    assert rep.bus["suppressed_transfers"] == 40 * (2 - 1)


def test_quorum_larger_than_group_clamps():
    assert engine_broadcast_fps("coral", 3, n_frames=60, quorum=7) == \
        engine_broadcast_fps("coral", 3, n_frames=60)


def test_quorum_tames_jittery_replica_tail():
    """The ROADMAP motivation: a redundant group with one stalling stick.
    Full-barrier broadcast waits out every stall; quorum=2 of 3 decides
    without the straggler and cuts p99."""
    def _run(quorum):
        reg = CapabilityRegistry()
        primary = _cart("infer", service_s=0.03)
        reg.insert(0, primary, mode="broadcast", quorum=quorum)
        reg.add_replica(0, primary.clone())
        jittery = primary.clone()
        jittery.device = DeviceModel(service_s=0.03, jitter_p=0.2,
                                     jitter_mult=10.0)
        reg.add_replica(0, jittery)
        eng = StreamEngine(reg, _bus())
        eng.feed(120, interval_s=0.0)
        return eng.run(until=1e9)

    full = _run(None)
    q2 = _run(2)
    assert full.frames_out == q2.frames_out == 120
    assert q2.p99() < full.p99()
    assert q2.throughput() > full.throughput()


# -- adaptive micro-batching ---------------------------------------------------
def test_microbatching_drains_backlog_faster():
    def burst(microbatch):
        reg = CapabilityRegistry()
        reg.insert(0, _cart("infer", service_s=0.04))
        eng = StreamEngine(reg, _bus(), microbatch=microbatch)
        eng.feed(80, interval_s=0.0)         # everything arrives at once
        return eng.run(until=120)

    plain = burst(False)
    batched = burst(True)
    assert plain.frames_out == batched.frames_out == 80
    assert batched.sim_time < 0.8 * plain.sim_time
    assert batched.stage_stats["infer"].max_batch > 1
    assert plain.stage_stats["infer"].max_batch == 1


def test_microbatch_respects_queue_cap():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("infer", service_s=0.04))
    eng = StreamEngine(reg, _bus(), queue_cap=4)
    eng.feed(60, interval_s=0.0)
    rep = eng.run(until=120)
    assert rep.frames_out == 60
    assert rep.stage_stats["infer"].max_batch <= 4


# -- bus contention accounting -------------------------------------------------
def test_bus_contention_stats_exposed():
    rep = run_replicated("ncs2", 4, mode="broadcast", n_frames=40)
    assert rep.bus["transfers"] == 160       # 40 frames x 4 replicas
    assert rep.bus["max_endpoints"] == 4
    assert rep.bus["arbitration_s"] > 0
    assert rep.bus["wire_s"] > 0
    assert rep.bus["busy_s"] >= rep.bus["arbitration_s"] + rep.bus["wire_s"]


def test_single_device_has_no_arbitration_cost():
    rep = run_replicated("ncs2", 1, mode="broadcast", n_frames=20)
    assert rep.bus["max_endpoints"] == 1
    assert rep.bus["arbitration_s"] == 0.0
