"""Two-level ANN matching: kernel parity, SecureGallery lifecycle
round-trips, incremental index maintenance, and the sharded-gallery bug
squash (enroll balancing, topology-invariant tie-breaks, event-queue
empty-pop discipline).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:                        # property tests need hypothesis; the rest don't
    from hypothesis import given, settings, strategies as stn
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(**kw):        # leave decorated tests collectable (skipped)
        return lambda fn: pytest.mark.skip("hypothesis not installed")(fn)

    class _StnStub:         # strategy expressions evaluate at import time
        def __getattr__(self, name):
            return lambda *a, **k: None

    stn = _StnStub()

from repro.crypto import SecureGallery
from repro.crypto.gallery import _deficit_alloc
from repro.kernels import ann_match as A
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.kernels.ann_match import NEG

if HAVE_HYPOTHESIS:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")

DTYPES = ("fp32", "bf16", "int8")


def _normed(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


# ---------------------------------------------------------------------------
# kernel parity: coarse scan + probed-cell rescore vs the oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", DTYPES)
def test_rescore_kernel_matches_oracle(dtype):
    rng = np.random.default_rng(5)
    N, D, Q, n_cells, c, k = 300, 32, 7, 12, 4, 5
    gn = _normed(rng, N, D)
    q = gn[rng.integers(0, N, Q)] + \
        0.05 * rng.normal(size=(Q, D)).astype(np.float32)
    cent = A.kmeans_lite(gn, n_cells, seed=1)
    layout = A.build_cell_layout(A.assign_cells(gn, cent), n_cells)
    lens = jnp.asarray(layout.cell_lens)
    _, ids = K.centroid_topc(jnp.asarray(q), jnp.asarray(cent), c=c)
    q_oracle = q
    if dtype == "bf16":
        # the oracle must see the same storage-rounded queries the
        # kernel casts (fp32 accumulation on both sides)
        q_oracle = np.asarray(jnp.asarray(q).astype(jnp.bfloat16)
                              .astype(jnp.float32))
    if dtype == "int8":
        p8, ps = A.pack_cells_quant(gn, layout)
        s, pos = K.cell_rescore_quant(jnp.asarray(q), jnp.asarray(p8),
                                      jnp.asarray(ps), ids, lens,
                                      k=k, L=layout.L)
        packed_oracle = np.asarray(A.dequantize_gallery(
            jnp.asarray(p8), jnp.asarray(ps)))
    else:
        packed = A.pack_cells(gn, layout)
        if dtype == "bf16":
            pb = jnp.asarray(packed).astype(jnp.bfloat16)
            s, pos = K.cell_rescore(jnp.asarray(q), pb, ids, lens,
                                    k=k, L=layout.L)
            packed_oracle = np.asarray(pb.astype(jnp.float32))
        else:
            s, pos = K.cell_rescore(jnp.asarray(q), jnp.asarray(packed),
                                    ids, lens, k=k, L=layout.L)
            packed_oracle = packed
    sr, posr = R.cell_rescore_ref(jnp.asarray(q_oracle),
                                  jnp.asarray(packed_oracle),
                                  ids, lens, k=k, L=layout.L)
    s, pos, sr, posr = (np.asarray(x) for x in (s, pos, sr, posr))
    np.testing.assert_allclose(s, sr, atol=2e-5, rtol=1e-5)
    # positions agree except across exact-tie permutations
    tie = np.isclose(s, sr, atol=2e-5)
    assert np.all((pos == posr) | tie)
    assert np.all(np.diff(s, axis=1) <= 1e-6)            # descending


def test_rescore_edges_c_exceeds_cells_and_k_exceeds_probed():
    """c > K pads the probe table with -1 sentinels; k beyond the probed
    row count fills (NEG, -1) output slots — both masked, never stale."""
    rng = np.random.default_rng(6)
    gn = _normed(rng, 3, 16)                              # single-row cells
    cent = A.kmeans_lite(gn, 3, seed=0)
    layout = A.build_cell_layout(A.assign_cells(gn, cent), 3)
    packed = A.pack_cells(gn, layout)
    q = jnp.asarray(gn[[0, 2]])
    _, ids = K.centroid_topc(q, jnp.asarray(cent), c=5)   # c > K
    assert np.all(np.asarray(ids)[:, 3:] == -1)
    s, pos = K.cell_rescore(q, jnp.asarray(packed), ids,
                            jnp.asarray(layout.cell_lens), k=7, L=layout.L)
    s, pos = np.asarray(s), np.asarray(pos)
    assert np.all(pos[:, 3:] == -1) and np.all(s[:, 3:] == NEG)
    rows = layout.pos_to_row[pos[:, 0]]
    np.testing.assert_array_equal(rows, [0, 2])           # exact self-match


def test_end_to_end_matches_flat_ann_oracle():
    """coarse scan -> rescore -> pos_to_row mapping equals the flat-gallery
    two-level oracle (same probes, exact scores, same row ids)."""
    rng = np.random.default_rng(7)
    N, D, Q, n_cells, c, k = 400, 24, 9, 16, 5, 4
    gn = _normed(rng, N, D)
    q = gn[rng.integers(0, N, Q)] + \
        0.03 * rng.normal(size=(Q, D)).astype(np.float32)
    cent = A.kmeans_lite(gn, n_cells, seed=2)
    assign = A.assign_cells(gn, cent)
    layout = A.build_cell_layout(assign, n_cells)
    packed = A.pack_cells(gn, layout)
    _, ids = K.centroid_topc(jnp.asarray(q), jnp.asarray(cent), c=c)
    s, pos = K.cell_rescore(jnp.asarray(q), jnp.asarray(packed), ids,
                            jnp.asarray(layout.cell_lens), k=k, L=layout.L)
    sr, rowsr = R.ann_match_ref(jnp.asarray(q), jnp.asarray(gn),
                                jnp.asarray(cent), jnp.asarray(assign),
                                nprobe=c, k=k)
    pos = np.asarray(pos)
    rows = np.where(pos >= 0, layout.pos_to_row[np.clip(pos, 0, None)], -1)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=2e-5)
    tie = np.isclose(np.asarray(s), np.asarray(sr), atol=2e-5)
    assert np.all((rows == np.asarray(rowsr)) | tie)


def test_kmeans_lite_deterministic_and_normalized():
    rng = np.random.default_rng(8)
    gn = _normed(rng, 200, 16)
    c1 = A.kmeans_lite(gn, 8, seed=3)
    c2 = A.kmeans_lite(gn, 8, seed=3)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_allclose(np.linalg.norm(c1, axis=-1), 1.0, atol=1e-5)
    assert A.kmeans_lite(gn, 500, seed=0).shape[0] == 200  # clamped to N


# ---------------------------------------------------------------------------
# hypothesis round-trip: enroll -> reshard -> rekey -> seal -> match
# ---------------------------------------------------------------------------
def _lifecycle_roundtrip(seed, n, shards, reshards, k, dtype):
    rng = np.random.default_rng(seed)
    D = 16
    g = rng.normal(size=(n, D)).astype(np.float32)
    q = g[rng.integers(0, n, 3)] + \
        0.02 * rng.normal(size=(3, D)).astype(np.float32)
    store = SecureGallery(D, seed=seed % 97, n_shards=shards)
    cut = rng.integers(0, n + 1)
    if cut:
        store.enroll(g[:cut], list(range(cut)))           # split enrollment
    if n - cut:
        store.enroll(g[cut:], list(range(cut, n)))
    n_cells = int(rng.integers(1, n + 1))                 # 1-row cells likely
    store.build_ann_index(n_cells=n_cells)
    store.reshard(reshards)                               # may empty shards
    store.rekey((seed % 89) + 1)
    store.seal()

    # fp32 raw-space oracle (rotation preserves cosine exactly)
    qn = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    gn = g / np.maximum(np.linalg.norm(g, axis=-1, keepdims=True), 1e-9)
    sr, ir = (np.asarray(x) for x in
              R.gallery_match_ref(jnp.asarray(qn), jnp.asarray(gn), k=k))

    lab, s = store.match(q, k=k, dtype="fp32")            # exact path
    s = np.asarray(s)
    k_eff = min(k, n)
    np.testing.assert_allclose(s[:, :k_eff], sr[:, :k_eff],
                               atol=3e-4, rtol=1e-4)
    # self-consistency: each returned score IS the cosine of its label row
    got = np.take_along_axis(qn @ gn.T,
                             lab[:, :k_eff].astype(np.int64), axis=1)
    np.testing.assert_allclose(s[:, :k_eff], got, atol=3e-4, rtol=1e-4)

    # ANN with every cell probed == exhaustive: scores match the oracle
    lab_a, s_a = store.match(q, k=k, dtype=dtype, mode="ann",
                             nprobe=store._ann_n_cells)
    s_a = np.asarray(s_a)
    assert store.ann_stats["trainings"] == 1              # never retrained
    live = lab_a[:, :k_eff] != None                       # noqa: E711
    assert np.all(live)                                   # full probe: k rows
    if dtype == "fp32":
        np.testing.assert_allclose(s_a[:, :k_eff], sr[:, :k_eff],
                                   atol=3e-4, rtol=1e-4)
    else:                                                 # quantized paths:
        got_a = np.take_along_axis(                       # self-consistent
            qn @ gn.T, lab_a[:, :k_eff].astype(np.int64), axis=1)
        np.testing.assert_allclose(s_a[:, :k_eff], got_a, atol=0.05)


@given(seed=stn.integers(0, 2**31 - 1),
       n=stn.integers(1, 40),
       shards=stn.integers(1, 6),
       reshards=stn.integers(1, 6),
       k=stn.integers(1, 6),
       dtype=stn.sampled_from(DTYPES))
def test_lifecycle_roundtrip_exact_and_ann_vs_fp32_oracle(
        seed, n, shards, reshards, k, dtype):
    _lifecycle_roundtrip(seed, n, shards, reshards, k, dtype)


@pytest.mark.parametrize("seed,n,shards,reshards,k,dtype", [
    (0, 1, 1, 1, 1, "fp32"),        # single row, single shard
    (1, 3, 6, 5, 5, "fp32"),        # empty shards + k > N
    (2, 17, 2, 4, 3, "int8"),       # single-row cells likely (n_cells~N)
    (3, 40, 4, 2, 6, "bf16"),
    (4, 9, 3, 1, 12, "int8"),       # k far beyond N
])
def test_lifecycle_roundtrip_edges(seed, n, shards, reshards, k, dtype):
    """Deterministic pin of the hypothesis round-trip across the edges the
    property explores (empty shards, k > N, single-row cells) — runs even
    where hypothesis isn't installed."""
    _lifecycle_roundtrip(seed, n, shards, reshards, k, dtype)


def test_ann_before_index_raises():
    store = SecureGallery(8, seed=1)
    store.enroll(np.eye(4, 8, dtype=np.float32), list(range(4)))
    with pytest.raises(ValueError, match="build_ann_index"):
        store.match(np.eye(1, 8, dtype=np.float32), k=1, mode="ann")


# ---------------------------------------------------------------------------
# incremental index maintenance (the no-silent-full-rebuild contract)
# ---------------------------------------------------------------------------
def test_enroll_rekey_reshard_never_retrain_index():
    rng = np.random.default_rng(20)
    D, n = 24, 300
    g = rng.normal(size=(n, D)).astype(np.float32)
    store = SecureGallery(D, seed=4, n_shards=3)
    store.enroll(g[:200], list(range(200)))
    store.build_ann_index(n_cells=16)
    assert store.ann_stats == {"trainings": 1, "assign_calls": 0, "packs": 0}

    q = g[[5, 150]] + 0.02 * rng.normal(size=(2, D)).astype(np.float32)
    store.match(q, k=1, mode="ann", nprobe=4)
    packs0 = store.ann_stats["packs"]
    assert packs0 == 3                                    # one per shard

    # enroll: new rows join existing cells; only receiving shards repack
    store.enroll(g[200:], list(range(200, n)))
    assert store.ann_stats["trainings"] == 1
    assert store.ann_stats["assign_calls"] == 1
    assert len(store._ann_assign) == n
    store.match(q, k=1, mode="ann", nprobe=4)

    # rekey rotates the codebook in place: no retrain, no reassignment
    assign_before = store._ann_assign.copy()
    store.rekey(55)
    assert store.ann_stats["trainings"] == 1
    np.testing.assert_array_equal(store._ann_assign, assign_before)
    lab, _ = store.match(q, k=1, mode="ann", nprobe=4)
    assert lab[0, 0] == 5 and lab[1, 0] == 150

    # reshard re-packs layouts only; assignments and codebook survive
    store.reshard(5)
    assert store.ann_stats["trainings"] == 1
    np.testing.assert_array_equal(store._ann_assign, assign_before)
    lab, _ = store.match(q, k=1, mode="ann", nprobe=4)
    assert lab[0, 0] == 5 and lab[1, 0] == 150
    assert store.ann_stats["trainings"] == 1


def test_seal_drops_codebook_and_packed_views_then_reprepares():
    rng = np.random.default_rng(21)
    D = 16
    g = rng.normal(size=(60, D)).astype(np.float32)
    store = SecureGallery(D, seed=6, n_shards=2)
    store.enroll(g, list(range(60)))
    store.build_ann_index(n_cells=8)
    store.match(g[[3]], k=1, mode="ann", nprobe=3)
    assert store._ann_codebook is not None
    store.seal()
    assert store._ann_codebook is None                    # plaintext dropped
    assert all(not p for p in store._prep)
    lab, _ = store.match(g[[3]], k=1, mode="ann", nprobe=3)
    assert lab[0, 0] == 3                                 # re-prepared
    assert store.ann_stats["trainings"] == 1


def test_ann_scan_fraction_tracked_and_small():
    rng = np.random.default_rng(22)
    D, n = 32, 2048
    g = rng.normal(size=(n, D)).astype(np.float32)
    store = SecureGallery(D, seed=8, n_shards=2)
    store.enroll(g, list(range(n)))
    store.build_ann_index(n_cells=64)
    q = g[rng.integers(0, n, 16)] + \
        0.05 * rng.normal(size=(16, D)).astype(np.float32)
    store.match(q, k=1, mode="ann", nprobe=4)
    st = store.last_match_stats
    assert st["mode"] == "ann" and st["rows_total"] == n
    assert st["rows_scored"] < 0.5 * n                    # far below exhaustive
    store.match(q, k=1, mode="exact")
    assert store.last_match_stats["rows_scored"] == n


# ---------------------------------------------------------------------------
# bug squash: enroll balancing
# ---------------------------------------------------------------------------
def test_deficit_alloc_levels_and_is_deterministic():
    sizes = np.array([10, 0, 3, 7])
    alloc = _deficit_alloc(sizes, 20)
    assert alloc.sum() == 20
    final = sizes + alloc
    assert final.max() - final.min() <= 1
    np.testing.assert_array_equal(alloc, _deficit_alloc(sizes, 20))
    # not enough rows to level: everything goes to the emptiest shards
    alloc2 = _deficit_alloc(sizes, 2)
    np.testing.assert_array_equal(alloc2, [0, 2, 0, 0])
    assert _deficit_alloc(sizes, 0).sum() == 0


def test_enroll_rebalances_after_uneven_history():
    """Regression: np.array_split over the least-full order ignored the
    existing imbalance — a shard 10 rows ahead stayed ~10 ahead forever,
    skewing per-replica-lane latency."""
    rng = np.random.default_rng(23)
    D = 8
    store = SecureGallery(D, seed=2, n_shards=3)
    # shard 0 gets a head start (single-shard enrollment, then reshard(1)
    # concentrates, then reshard back)
    store.enroll(rng.normal(size=(30, D)).astype(np.float32),
                 list(range(30)))
    store.reshard(3)
    # drop to an uneven state: enroll tiny batches repeatedly
    base = 30
    for b in (7, 1, 5, 2, 11):
        store.enroll(rng.normal(size=(b, D)).astype(np.float32),
                     list(range(base, base + b)))
        base += b
        sizes = store.shard_sizes()
        assert max(sizes) - min(sizes) <= 1, sizes
    assert sum(store.shard_sizes()) == base
    # matching still returns every row exactly once
    lab, _ = store.match(rng.normal(size=(1, D)).astype(np.float32), k=base)
    assert sorted(lab[0].astype(np.int64)) == list(range(base))


# ---------------------------------------------------------------------------
# bug squash: topology-invariant tie-breaks in the cross-shard merge
# ---------------------------------------------------------------------------
def test_merge_tiebreak_invariant_across_reshard_counts():
    """Regression: equal-score results used to reorder across reshard()
    counts (merge tie-broke by shard concatenation order).  Duplicate
    templates give exactly equal fp32 scores; the merge must return the
    lowest global ids first for every topology."""
    rng = np.random.default_rng(24)
    D, n_dup, n_bg = 16, 6, 30
    dup = rng.normal(size=(1, D)).astype(np.float32)
    bg = rng.normal(size=(n_bg, D)).astype(np.float32)
    g = np.concatenate([np.repeat(dup, n_dup, axis=0), bg])
    order = rng.permutation(len(g))
    g = g[order]
    dup_gids = sorted(np.where(order < n_dup)[0])
    results = []
    for shards in (1, 2, 3, 5):
        store = SecureGallery(D, seed=5, n_shards=shards)
        store.enroll(g, list(range(len(g))))
        lab, s = store.match(dup, k=4, dtype="fp32")
        results.append((lab[0].astype(np.int64).tolist(),
                        np.asarray(s)[0].round(5).tolist()))
    for got in results[1:]:
        assert got == results[0], results
    assert results[0][0] == dup_gids[:4]                  # lowest gids win


def test_ann_merge_tiebreak_invariant_across_reshard_counts():
    rng = np.random.default_rng(25)
    D = 16
    dup = rng.normal(size=(1, D)).astype(np.float32)
    g = np.concatenate([np.repeat(dup, 4, axis=0),
                        rng.normal(size=(40, D)).astype(np.float32)])
    results = []
    for shards in (1, 3, 4):
        store = SecureGallery(D, seed=5, n_shards=shards)
        store.enroll(g, list(range(len(g))))
        store.build_ann_index(n_cells=6)
        lab, _ = store.match(dup, k=3, dtype="fp32", mode="ann", nprobe=6)
        results.append(lab[0].astype(np.int64).tolist())
    assert results[0] == [0, 1, 2]
    for got in results[1:]:
        assert got == results[0], results


# ---------------------------------------------------------------------------
# bug squash: event-queue empty pop/peek discipline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qcls_name", ["HeapEventQueue", "ListEventQueue"])
def test_event_queue_empty_pop_raises_without_counter_corruption(qcls_name):
    """Regression: HeapEventQueue.pop incremented ``popped`` before
    heappop could raise, corrupting the events/sec stats; peek_time
    raised a bare IndexError.  Both now raise descriptively and leave
    every counter untouched; ListEventQueue mirrors the contract."""
    from repro.runtime import events as E
    q = getattr(E, qcls_name)()
    with pytest.raises(IndexError, match=qcls_name):
        q.pop()
    assert q.popped == 0 and q.pushed == 0
    with pytest.raises(IndexError, match=qcls_name):
        q.peek_time()
    h = q.push(1.0, None, ())
    q.cancel(h)
    with pytest.raises(IndexError, match=qcls_name):      # only-dead queue
        q.pop()
    assert q.popped == 0 and q.cancelled == 1
    q.push(2.0, None, ("x",))
    assert q.pop()[3] == ("x",)                           # still functional
    assert q.popped == 1
    with pytest.raises(IndexError, match=qcls_name):
        q.pop()
    assert q.popped == 1                                  # stats intact
