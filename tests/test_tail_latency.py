"""Tail-latency fast path: weighted EWMA dispatch over heterogeneous lane
groups, hedged shard dispatch with exactly-once delivery, cancellable
events, and the streaming latency histogram."""
import pytest

from repro.bus import BusParams, SharedBus
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import (CapabilityRegistry, EngineReport, StreamEngine,
                           StreamingHistogram, build_mixed_engine)
from repro.runtime.events import HeapEventQueue, ListEventQueue

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)

FAST = dict(name="coral", service_s=0.02)
JITTERY = dict(name="coral", service_s=0.02, jitter_p=0.03, jitter_mult=10.0)
SLOW = dict(name="ncs2_degraded", service_s=0.10,
            jitter_p=0.05, jitter_mult=10.0)


def _cart(name, service_s=0.03, capability_id=7, **dev):
    return FnCartridge(name, lambda p, x: x, SPEC, SPEC,
                       capability_id=capability_id,
                       device=DeviceModel(service_s=service_s, **dev))


def _bus():
    return SharedBus(BusParams("test", bandwidth=400e6,
                               base_overhead_s=1e-4, arbitration_s=2e-4))


def _burst_feed(eng, n_bursts=100, burst=5, period=0.06):
    for i in range(n_bursts):
        eng.feed(burst, interval_s=0.0, t0=i * period)
    return n_bursts * burst


def _mixed(dispatch, hedge, devices=(FAST, FAST, SLOW), **kw):
    eng = build_mixed_engine([DeviceModel(**d) for d in devices],
                             dispatch=dispatch, hedge=hedge, **kw)
    n = _burst_feed(eng)
    rep = eng.run(until=1e9)
    assert rep.frames_out == n, f"lost {rep.lost}"
    return rep


# -- streaming histogram -------------------------------------------------------
def test_histogram_quantiles_approximate_sorted_rank():
    h = StreamingHistogram()
    xs = [0.001 * (i + 1) for i in range(1000)]
    for x in xs:
        h.record(x)
    assert h.count == 1000
    assert h.mean() == pytest.approx(sum(xs) / len(xs))
    for q in (0.5, 0.95, 0.99):
        exact = xs[int(q * (len(xs) - 1))]
        assert h.quantile(q) == pytest.approx(exact, rel=0.15)
    assert h.quantile(1.0) == pytest.approx(max(xs))


def test_histogram_single_value_is_exact():
    h = StreamingHistogram()
    for _ in range(50):
        h.record(0.02)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.quantile(q) == pytest.approx(0.02)


def test_histogram_empty_is_zero_not_crash():
    h = StreamingHistogram()
    assert h.quantile(0.99) == 0.0
    assert h.mean() == 0.0
    assert h.summary()["count"] == 0


def test_histogram_single_count_bins_not_pinned_to_upper_edge():
    """The PR's quantile bugfix: with every bin holding exactly one
    sample, low-q quantiles used to return each bin's UPPER geometric
    edge (frac=(rank-seen+1)/c == 1), biasing them a full bin high.
    Mid-rank interpolation keeps the estimate within half a bin of the
    true order statistic."""
    h = StreamingHistogram(bins_per_decade=32)
    xs = [10 ** (i / 8) for i in range(-20, 21)]   # 1 sample per 4th bin
    for x in xs:
        h.record(x)
    half_bin = 10 ** (0.5 / 32)
    for q in (0.1, 0.25, 0.5, 0.75, 0.9):
        exact = sorted(xs)[round(q * (len(xs) - 1))]
        est = h.quantile(q)
        assert exact / (half_bin * 1.001) <= est <= exact * half_bin * 1.001


def test_histogram_quantile_tracks_numpy_percentile_oracle():
    """Hypothesis property: for arbitrary positive samples the histogram
    quantile lands inside the bracket of the neighboring order
    statistics, widened by the documented ~7% bin-width bound
    (10**(1/bins_per_decade) at the default 32 bins/decade)."""
    hypothesis = pytest.importorskip("hypothesis")
    import numpy as np
    from hypothesis import given, settings, strategies as st

    bin_ratio = 10 ** (1 / 32) * (1 + 1e-9)

    @settings(deadline=None, max_examples=200)
    @given(st.lists(st.floats(min_value=1e-5, max_value=1e4,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=200),
           st.floats(min_value=0.0, max_value=1.0))
    def check(xs, q):
        h = StreamingHistogram()
        for x in xs:
            h.record(x)
        est = h.quantile(q)
        # the true fractional rank lies between these two samples
        lo = float(np.percentile(xs, q * 100, method="lower"))
        hi = float(np.percentile(xs, q * 100, method="higher"))
        assert lo / bin_ratio <= est <= hi * bin_ratio
        # extremes stay exact (clamped to the true min/max)
        assert min(xs) <= est <= max(xs)

    check()


# -- EngineReport zero-completion guards ---------------------------------------
def test_report_guards_zero_completions():
    rep = EngineReport()
    assert rep.throughput() == 0.0
    assert rep.mean_latency() == 0.0
    assert rep.p50() == rep.p95() == rep.p99() == 0.0
    assert rep.latency_summary()["end_to_end"]["count"] == 0
    # sim time advanced but nothing completed: still 0.0, not ZeroDivision
    rep.sim_time = 12.5
    assert rep.throughput() == 0.0


def test_report_guards_engine_with_no_frames():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("idle"))
    eng = StreamEngine(reg, _bus())
    rep = eng.run(until=10)
    assert rep.frames_out == 0
    assert rep.throughput() == 0.0
    assert rep.mean_latency() == 0.0


# -- cancellable events --------------------------------------------------------
@pytest.mark.parametrize("qcls", [HeapEventQueue, ListEventQueue])
def test_event_cancellation(qcls):
    q = qcls()
    fired = []
    h1 = q.push(1.0, fired.append, ("a",))
    h2 = q.push(2.0, fired.append, ("b",))
    h3 = q.push(3.0, fired.append, ("c",))
    assert len(q) == 3
    assert q.cancel(h2) is True
    assert q.cancel(h2) is False          # double-cancel is a no-op
    assert len(q) == 2
    order = []
    while len(q):
        t, _, fn, args = q.pop()
        order.append(args[0])
    assert order == ["a", "c"]
    assert q.cancel(h1) is False          # already fired
    assert q.cancelled == 1


@pytest.mark.parametrize("qcls", [HeapEventQueue, ListEventQueue])
def test_cancel_head_keeps_peek_consistent(qcls):
    q = qcls()
    h1 = q.push(1.0, lambda: None, ())
    q.push(5.0, lambda: None, ())
    q.cancel(h1)
    assert len(q) == 1
    assert q.peek_time() == 5.0


def test_heap_and_list_same_order_under_cancellation():
    ops = [("push", t) for t in (3.0, 1.0, 2.0, 1.0, 4.0)]
    hq, lq = HeapEventQueue(), ListEventQueue()
    hh = [hq.push(t, lambda: None, (t,)) for _, t in ops]
    lh = [lq.push(t, lambda: None, (t,)) for _, t in ops]
    hq.cancel(hh[3])
    lq.cancel(lh[3])
    horder = [hq.pop()[:2] for _ in range(len(hq))]
    lorder = [lq.pop()[:2] for _ in range(len(lq))]
    assert horder == lorder


# -- heterogeneous lane groups + weighted dispatch -----------------------------
def test_mixed_group_registers_and_reports_devices():
    rep = _mixed("ewma", False)
    g = rep.groups[0]
    assert g["heterogeneous"] is True
    assert set(g["devices"]) == {"coral", "ncs2_degraded"}
    assert len(g["est_s"]) == 3


def test_weighted_dispatch_starves_slow_stick_under_bursts():
    """Queue-depth-only dispatch hands burst frames to the idle slow
    stick; weighted dispatch absorbs them on fast lanes instead."""
    naive = _mixed("naive", False)
    ewma = _mixed("ewma", False)
    slow_share = lambda r: sum(
        st.processed for name, st in r.stage_stats.items()
        if name.startswith("ncs2_degraded"))
    assert slow_share(ewma) < slow_share(naive)
    assert ewma.p99() < 0.5 * naive.p99()


def test_weighted_dispatch_p99_improvement_2x_with_hedging():
    """The PR acceptance scenario: mixed-replica straggler group, equal
    offered load, hedging+weighted vs the PR 2 baseline discipline."""
    base = _mixed("naive", False)
    fast = _mixed("ewma", True)
    assert fast.p99() * 2.0 <= base.p99(), \
        f"p99 {fast.p99():.4f} vs baseline {base.p99():.4f}"
    # equal offered load, throughput within 5%
    assert fast.throughput() >= 0.95 * base.throughput()


def test_ewma_adapts_to_lying_device_model():
    """A stick whose DeviceModel advertises 10 ms but actually runs 100 ms
    (thermal throttling) loses its load share as the EWMA converges."""
    liar = DeviceModel(name="liar", service_s=0.01,
                       jitter_p=1.0, jitter_mult=10.0)   # always 10x
    honest = DeviceModel(name="honest", service_s=0.02)
    eng = build_mixed_engine([honest, liar], dispatch="ewma")
    n = _burst_feed(eng, n_bursts=80, burst=4, period=0.1)
    rep = eng.run(until=1e9)
    assert rep.frames_out == n
    est = dict(zip(rep.groups[0]["lanes"], rep.groups[0]["est_s"]))
    liar_lane = next(k for k in est if "liar" in k)
    assert est[liar_lane] > 0.05          # converged toward observed 0.1
    honest_lane = next(k for k in est if "honest" in k)
    assert rep.stage_stats[honest_lane].processed > \
        2 * rep.stage_stats[liar_lane].processed


def test_homogeneous_weighted_matches_naive_dispatch():
    """With identical, jitter-free replicas the weighted discipline
    degenerates to least-loaded: identical virtual-time results."""
    def run(dispatch):
        eng = build_mixed_engine([DeviceModel(**FAST)] * 3,
                                 dispatch=dispatch)
        eng.feed(200, interval_s=0.008)
        return eng.run(until=1e9)

    a, b = run("naive"), run("ewma")
    assert a.frames_out == b.frames_out == 200
    assert a.sim_time == pytest.approx(b.sim_time)
    assert sorted(a.latencies) == pytest.approx(sorted(b.latencies))


def test_unknown_dispatch_rejected():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("x"))
    with pytest.raises(ValueError):
        StreamEngine(reg, _bus(), dispatch="round_robin")


# -- hedged dispatch -----------------------------------------------------------
def test_hedged_duplicates_never_double_count():
    """Exactly-once: every offered frame completes exactly once even when
    hedges fire, win, lose, and get suppressed."""
    rep = _mixed("ewma", True, devices=(JITTERY, JITTERY, JITTERY))
    assert rep.hedges["issued"] > 0, "scenario must actually hedge"
    assert rep.frames_out == rep.frames_in
    assert len(rep.latencies) == rep.frames_out
    assert rep.latency_hist.count == rep.frames_out
    # every issued hedge is accounted: won / wasted / cancelled
    assert rep.hedges["wasted"] + rep.hedges["cancelled_queued"] >= \
        rep.hedges["won_by_backup"]
    # suppressed losers never crossed the bus
    assert rep.bus["suppressed_transfers"] == rep.hedges["wasted"]
    assert rep.bus["suppressed_bytes"] > 0


def test_hedging_cuts_jitter_tail():
    unhedged = _mixed("ewma", False, devices=(JITTERY, JITTERY, JITTERY))
    hedged = _mixed("ewma", True, devices=(JITTERY, JITTERY, JITTERY))
    assert hedged.frames_out == unhedged.frames_out
    assert hedged.p99() < unhedged.p99()
    assert hedged.hedges["issued"] > 0
    assert hedged.hedges["won_by_backup"] > 0


def test_hedging_is_free_on_deterministic_lanes():
    """Jitter-free lanes always finish inside the deadline margin: the
    hedge path must issue nothing and cost nothing in virtual time."""
    plain = _mixed("ewma", False)
    hedged = _mixed("ewma", True)
    assert hedged.hedges["issued"] == 0
    assert hedged.sim_time == pytest.approx(plain.sim_time)


def test_hedging_off_in_broadcast_mode():
    eng = build_mixed_engine([DeviceModel(**JITTERY)] * 3,
                             mode="broadcast", hedge=True)
    eng.feed(60, interval_s=0.0)
    rep = eng.run(until=1e9)
    assert rep.frames_out == 60
    assert rep.hedges["issued"] == 0


def test_hedge_survives_replica_hotswap():
    """Pulling a lane mid-stream with hedging armed neither loses nor
    duplicates frames."""
    reg = CapabilityRegistry()
    primary = _cart("infer", service_s=0.02, jitter_p=0.05, jitter_mult=10.0)
    reg.insert(0, primary)
    r1 = primary.clone()
    r2 = primary.clone()
    reg.add_replica(0, r1)
    reg.add_replica(0, r2)
    eng = StreamEngine(reg, _bus(), hedge=True)
    n = _burst_feed(eng, n_bursts=60, burst=5, period=0.05)
    eng.schedule_remove_replica(1.1, slot=0, cart=r1)
    rep = eng.run(until=1e9)
    assert rep.frames_out == n, f"lost {rep.lost}"
    assert rep.total_downtime() == 0.0


def test_health_monitor_sees_hedges_as_stragglers():
    rep_engine = build_mixed_engine(
        [DeviceModel(**JITTERY)] * 3, dispatch="ewma", hedge=True)
    n = _burst_feed(rep_engine)
    rep = rep_engine.run(until=1e9)
    assert rep.frames_out == n
    if rep.hedges["issued"]:
        mon = rep_engine.health
        straggler_events = [e for e in mon.events if e[1] == "straggler"]
        assert len(straggler_events) == rep.hedges["issued"]
        assert sum(w.backup_dispatches
                   for w in mon.workers.values()) == rep.hedges["issued"]


# -- latency breakdown ---------------------------------------------------------
def test_stage_latency_breakdown_recorded():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("detect", 0.01, capability_id=1))
    reg.insert(1, _cart("embed", 0.03, capability_id=2))
    eng = StreamEngine(reg, _bus())
    eng.feed(50, interval_s=0.02)
    rep = eng.run(until=1e9)
    assert rep.frames_out == 50
    summary = rep.latency_summary()
    assert summary["end_to_end"]["count"] == 50
    assert set(summary["stages"]) == {"detect", "embed"}
    for st in summary["stages"].values():
        assert st["count"] == 50
        assert st["p99"] >= st["p50"] > 0
    # stage residence can't exceed end-to-end
    assert summary["stages"]["embed"]["p50"] <= \
        summary["end_to_end"]["p50"] + 1e-9
