"""Tier-1 test configuration.

Pin JAX to the CPU backend before any test module imports jax: the CI
image (and some dev containers) carry libtpu without a TPU, and an
unpinned import stalls ~60 s probing for one.  Pinning here makes tier-1
deterministic and fast everywhere, not only in ``benchmarks/*`` entry
points (which set the same guard themselves).
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
