"""Multi-hub bus fabric: router cost model, hub-partitioned arbitration,
engine integration (routed handoffs, cross-hub hedging, suppression)."""
import pytest

from repro.bus import (BusParams, FabricRouter, LinkParams, SharedBus,
                       TABLE1, calibrated, simulate_broadcast_fps,
                       uniform_fabric)
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import (CapabilityRegistry, StreamEngine,
                           build_cross_hub_hedge_engine,
                           build_fabric_engine, engine_shard_fps,
                           fabric_shard_fps, run_fabric)

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)

PARAMS = BusParams("hub", bandwidth=100e6, base_overhead_s=2e-4,
                   arbitration_s=1e-4)
LINK = LinkParams(bandwidth=300e6, overhead_s=1e-4)


def _router(n_hubs=2, suppression=True):
    return uniform_fabric(PARAMS, n_hubs, link=LINK, suppression=suppression)


# -- router cost model --------------------------------------------------------
def test_local_route_identical_to_bare_bus():
    """A one-hub router (and any same-hub route) is bit-identical to the
    bare SharedBus it wraps."""
    bus = SharedBus(PARAMS)
    fab = _router(1)
    reqs = [(0.0, 150528, 3), (0.001, 40000, 3), (0.5, 150528, 1),
            (0.5001, 9000, 5)]
    for t, nbytes, n_end in reqs:
        assert fab.transfer(t, nbytes, n_end) == \
            bus.transfer(t, nbytes, n_end)
    assert fab.hubs[0].bus.stats() == bus.stats()


def test_cross_hub_route_serializes_three_legs():
    fab = _router(2)
    nbytes = 100_000
    done = fab.transfer(0.0, nbytes, n_endpoints=2, src=0, dst=1,
                        dst_endpoints=3)
    # unloaded route cost: egress + link + ingress, each with its own
    # overhead/arbitration terms
    egress = PARAMS.base_overhead_s + PARAMS.arbitration_s * 1 \
        + nbytes / PARAMS.bandwidth
    link = LINK.overhead_s + nbytes / LINK.bandwidth
    ingress = PARAMS.base_overhead_s + PARAMS.arbitration_s * 2 \
        + nbytes / PARAMS.bandwidth
    assert done == pytest.approx(egress + link + ingress)
    assert fab.cross_hub_transfers == 1
    assert fab.hubs[0].bus.transfers == 1
    assert fab.hubs[1].bus.transfers == 1
    assert fab.link(0, 1).transfers == 1
    # a second transfer queues FIFO behind the first on every leg
    done2 = fab.transfer(0.0, nbytes, 2, src=0, dst=1, dst_endpoints=3)
    assert done2 > done


def test_one_sided_routes_collapse_to_local():
    """src-only (egress to host) and dst-only (host fan-in) routes touch
    exactly one hub bus and no link."""
    fab = _router(3)
    fab.transfer(0.0, 1000, 1, src=2)
    fab.transfer(0.0, 1000, 1, dst=1)
    assert fab.hubs[2].bus.transfers == 1
    assert fab.hubs[1].bus.transfers == 1
    assert fab.hubs[0].bus.transfers == 0
    assert not fab._links           # no link ever materialized
    assert fab.cross_hub_transfers == 0


def test_router_stats_aggregate_and_breakdown():
    fab = _router(2)
    fab.transfer(0.0, 50_000, 2, src=0, dst=1)
    fab.transfer(0.0, 50_000, 1, src=1, dst=1)
    s = fab.stats()
    assert s["n_hubs"] == 2
    assert s["transfers"] == 4          # 2 hub legs + 1 local + 1 link
    assert s["cross_hub_transfers"] == 1
    assert set(s["hubs"]) == {0, 1}
    assert "0<->1" in s["links"]
    assert s["busy_s"] == pytest.approx(
        s["hubs"][0]["busy_s"] + s["hubs"][1]["busy_s"]
        + s["links"]["0<->1"]["busy_s"], abs=1e-5)


def test_suppress_saves_link_and_destination_hub_time():
    """Cross-hub suppression books savings in every domain on the route —
    the source hub, the link, AND the destination hub."""
    fab = _router(2)
    nbytes = 150_528
    fab.suppress(nbytes, src=0, dst=1, t=0.0)
    s = fab.stats()
    assert s["suppressed_transfers"] == 1
    assert s["suppressed_bytes"] == nbytes
    assert s["hubs"][0]["suppressed_transfers"] == 1
    assert s["hubs"][1]["suppressed_transfers"] == 1
    assert s["links"]["0<->1"]["suppressed_transfers"] == 1
    expect = 2 * (PARAMS.base_overhead_s + nbytes / PARAMS.bandwidth) \
        + LINK.overhead_s + nbytes / LINK.bandwidth
    assert s["suppressed_saved_s"] == pytest.approx(expect, abs=1e-6)
    # suppression moved no payload and consumed no bus time
    assert s["transfers"] == 0
    assert s["busy_s"] == 0.0
    # local suppression saves strictly less (no link, no second hub)
    fab2 = _router(2)
    fab2.suppress(nbytes, src=0, t=0.0)
    assert fab2.stats()["suppressed_saved_s"] < expect


def test_suppression_disabled_executes_the_wasted_route():
    fab = _router(2, suppression=False)
    fab.suppress(100_000, src=0, dst=1, t=0.0)
    s = fab.stats()
    assert s["wasted_transfers"] == 1
    assert s["suppressed_transfers"] == 0
    assert s["transfers"] == 3          # the route really ran: 3 legs
    assert s["busy_s"] > 0.0


# -- engine on a one-hub fabric == engine on the bare bus ---------------------
@pytest.mark.parametrize("device", sorted(TABLE1))
def test_single_hub_fabric_reproduces_table1(device):
    """Swapping the router in where SharedBus sits today must not move
    the paper reproduction: a 1-hub fabric broadcast matches the
    closed-form simulator exactly."""
    p = calibrated(device)
    for n in (1, 3, 5):
        rep = run_fabric([[device] * n], mode="broadcast", n_frames=100)
        assert rep.throughput() == pytest.approx(
            simulate_broadcast_fps(p, n, n_frames=100), rel=1e-6)


def test_single_hub_fabric_shard_matches_single_bus():
    base = engine_shard_fps("ncs2", 4, n_frames=150)
    fab = fabric_shard_fps("ncs2", 1, 4, n_frames=150)
    assert fab == pytest.approx(base, rel=1e-6)


# -- the headline: hub partitioning beats the saturated single bus ------------
def test_multi_hub_beats_single_bus_at_equal_device_count():
    single = engine_shard_fps("ncs2", 8, n_frames=200)
    two_hub = fabric_shard_fps("ncs2", 2, 4, n_frames=200)
    four_hub = fabric_shard_fps("ncs2", 4, 2, n_frames=200)
    assert two_hub > single
    assert four_hub > single
    # and past the paper's 5-device knee
    knee = max(engine_shard_fps("ncs2", n, n_frames=200)
               for n in (4, 5, 6))
    assert two_hub > knee


def test_per_hub_arbitration_domain():
    """The fabric charges arbitration against the hub's endpoint count,
    not the fleet's: 2x2 sees max 2 endpoints per hub, 1x4 sees 4."""
    rep = run_fabric([["ncs2"] * 2, ["ncs2"] * 2], n_frames=60)
    assert rep.bus["max_endpoints"] == 2
    assert rep.bus["n_hubs"] == 2
    single = run_fabric([["ncs2"] * 4], n_frames=60)
    assert single.bus["max_endpoints"] == 4


def test_fabric_engine_conserves_frames_and_reports_hubs():
    rep = run_fabric([["ncs2"] * 2, ["ncs2"] * 3], n_frames=120)
    assert rep.frames_out == 120, f"lost {rep.lost}"
    assert sorted(rep.groups[0]["hubs"]) == [0, 0, 1, 1, 1]
    per_lane = [rep.stage_stats[n].processed
                for n in rep.groups[0]["lanes"]]
    assert sum(per_lane) == 120
    assert min(per_lane) > 0           # every hub pulled weight


# -- registry hub bookkeeping -------------------------------------------------
def test_registry_hub_placement_roundtrip():
    reg = CapabilityRegistry()
    a = FnCartridge("a", lambda p, x: x, SPEC, SPEC, capability_id=7,
                    device=DeviceModel(service_s=0.02))
    reg.insert(0, a, hub=1)
    b, c = a.clone(), a.clone()
    reg.add_replica(0, b)              # defaults to the primary's hub
    reg.add_replica(0, c, hub=2)
    assert reg.hub_of(a) == reg.hub_of(b) == 1
    assert reg.hub_of(c) == 2
    assert reg.hubs() == [1, 2]
    assert reg.n_endpoints_on(1) == 2
    assert reg.n_endpoints_on(2) == 1
    assert reg.n_endpoints_on(0) == 0
    reg.remove_replica(0, c)
    assert reg.hubs() == [1]
    reg.remove(0)
    assert reg.hub_of(a) == 0          # forgotten -> default hub


def test_registry_quorum_validation():
    reg = CapabilityRegistry()
    cart = FnCartridge("a", lambda p, x: x, SPEC, SPEC, capability_id=7)
    with pytest.raises(ValueError):
        reg.insert(0, cart, mode="shard", quorum=2)
    with pytest.raises(ValueError):
        reg.insert(0, cart, mode="broadcast", quorum=0)
    rec = reg.insert(0, cart, mode="broadcast", quorum=2)
    assert rec.quorum == 2


def test_build_fabric_engine_rejects_empty_topology():
    with pytest.raises(ValueError):
        build_fabric_engine([])
    with pytest.raises(ValueError):
        build_fabric_engine([[]])


def test_bad_hub_placement_fails_at_plug_time():
    """An out-of-range (or negative) hub id must fail loudly when the
    lane is plugged, not frames later inside a routed transfer — and
    never wrap to the wrong hub's accounting."""
    eng = build_fabric_engine([["ncs2"], ["ncs2"]], mode="shard")
    primary = eng.registry.slots[0].cartridge
    for bad in (7, -1):
        eng.schedule_add_replica(0.1, slot=0,
                                 cart=primary.clone(f"bad#{bad}"), hub=bad)
        with pytest.raises(ValueError, match="hub"):
            eng.run(until=1.0)
        eng.registry.remove_replica(0, eng.registry.slots[0].replicas[-1])
    # the router itself also refuses bad routes
    fab = _router(2)
    with pytest.raises(ValueError, match="hub"):
        fab.transfer(0.0, 1000, 1, src=0, dst=5)


def test_suppression_disabled_requires_request_time():
    """With suppression off the router executes the wasted route, so a
    SharedBus-shaped suppress(nbytes) call must fail loudly instead of
    silently booking a phantom transfer."""
    fab = _router(2, suppression=False)
    with pytest.raises(ValueError, match="request"):
        fab.suppress(1000)
    fab2 = _router(2, suppression=True)
    fab2.suppress(1000)                    # accounting-only: t optional
    assert fab2.suppressed_transfers == 1


# -- cross-hub hedging --------------------------------------------------------
# the scenario builder is shared with benchmarks/fabric_bench.py, so the
# invariants pinned here hold on the exact workload BENCH_fabric.json
# reports (jittery lanes on hub 0 hedging onto clean hub-1 lanes)
_hedged_cross_hub_engine = build_cross_hub_hedge_engine


def test_cross_hub_hedge_exactly_once():
    eng = _hedged_cross_hub_engine()
    rep = eng.run(until=1e12)
    assert rep.frames_out == 600, f"lost {rep.lost}"
    assert rep.hedges["cross_hub"] > 0
    # every decided hedge race was fully cleaned up
    assert not eng._hedges


def test_cross_hub_hedge_suppression_routed_through_link():
    """Hedge losers on the fabric are suppressed at the router: the saved
    time shows up on the link and on BOTH hubs of the route, not just the
    loser's local bus (the charging primitive itself — ingress-only for
    copies, full-route for suppressions — is pinned by the router unit
    tests above)."""
    rep = _hedged_cross_hub_engine().run(until=1e12)
    assert rep.hedges["cross_hub"] > 0
    assert rep.bus["suppressed_saved_s"] > 0.0
    link_stats = rep.bus["links"].get("0<->1")
    assert link_stats is not None
    assert link_stats["suppressed_transfers"] > 0
    assert rep.bus["hubs"][1]["suppressed_transfers"] > 0


def test_cross_hub_migration_charged_and_zero_loss():
    """A stalled hub-0 lane's queued backlog migrates to hub-1 lanes as a
    real host re-send: charged ingress on the destination hub, delivered
    only after the transfer lands, and — unlike a hedge copy — never
    dropped (each migrated frame is its only live instance)."""
    bad = DeviceModel(name="bad", service_s=0.02,
                      jitter_p=1.0, jitter_mult=25.0)
    good = DeviceModel(name="good", service_s=0.02)
    reg = CapabilityRegistry()
    infer = FnCartridge("infer", lambda p, x: x, SPEC, SPEC,
                        capability_id=7, device=bad)
    reg.insert(0, infer, mode="shard", hub=0)
    reg.add_replica(0, infer.clone("infer#g0", device=good), hub=1)
    reg.add_replica(0, infer.clone("infer#g1", device=good), hub=1)
    fabric = FabricRouter([BusParams("hub0", base_overhead_s=1e-4),
                           BusParams("hub1", base_overhead_s=1e-4)],
                          link=LINK)
    eng = StreamEngine(reg, fabric, hedge=True)
    for i in range(60):
        eng.feed(6, interval_s=0.0, t0=i * 0.045)
    rep = eng.run(until=1e12)
    assert rep.frames_out == 360, f"lost {rep.lost}"
    assert rep.hedges["migrated"] > 0
    assert not eng._hedges


def test_router_suppression_improves_tail():
    on = _hedged_cross_hub_engine(suppression=True).run(until=1e12)
    off = _hedged_cross_hub_engine(suppression=False).run(until=1e12)
    assert on.frames_out == off.frames_out == 600
    assert off.bus["wasted_transfers"] > 0
    assert on.bus["wasted_transfers"] == 0
    assert on.bus["suppressed_transfers"] > 0
    # suppression never makes the tail worse, and saves real bus time
    assert on.p99() <= off.p99()
    assert on.bus["busy_s"] < off.bus["busy_s"]
