"""End-to-end system tests: the paper's flagship biometric pipeline with
real JAX payloads, plus training-loop recovery behaviour."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import build_biometric_pipeline, run_biometric


def test_biometric_pipeline_end_to_end_with_hotswap():
    rep = run_biometric(n_frames=12, hotswap=True)
    assert rep.frames_out == 12
    assert rep.lost == 0
    assert 0.3 <= rep.total_downtime() <= 0.8   # the 0.5 s removal pause


def test_biometric_match_correctness():
    """The enrolled subject must be retrieved through the full chain."""
    reg, gallery = build_biometric_pipeline(seed=0)
    det = reg.slots[0].cartridge
    qual = reg.slots[1].cartridge
    emb = reg.slots[2].cartridge
    for c in (det, qual, emb):
        c.load()
    from repro.data import FrameStream
    src = FrameStream(seed=3)
    embs = []
    for i in range(6):
        crop = det._fn(det.params, jnp.asarray(src.frame_at(i)))
        crop = qual._fn(qual.params, crop)
        embs.append(np.asarray(emb._fn(emb.params, crop)))
    gallery.enroll(np.stack(embs), [f"s{i}" for i in range(6)])
    # frame 4 re-processed must match subject s4
    crop = det._fn(det.params, jnp.asarray(src.frame_at(4)))
    crop = qual._fn(qual.params, crop)
    q = np.asarray(emb._fn(emb.params, crop))
    labels, scores = gallery.match(q[None], k=1)
    assert labels[0, 0] == "s4"
    assert float(np.asarray(scores)[0, 0]) > 0.99


def test_train_recovers_from_failure(tmp_path):
    """Simulated node failure -> checkpoint restore -> identical final loss
    (deterministic replay)."""
    from repro.launch import train

    common = ["--arch", "tinyllama-1.1b", "--smoke", "--steps", "60",
              "--batch", "4", "--seq", "32", "--ckpt-every", "20",
              "--lr", "1e-3", "--log-every", "20"]
    clean = train.main(common + ["--ckpt-dir", str(tmp_path / "a")])
    recovered = train.main(common + ["--ckpt-dir", str(tmp_path / "b"),
                                     "--simulate-failure", "40"])
    assert abs(clean - recovered) < 1e-3
