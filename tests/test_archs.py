"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + finite values."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import base as cb
from repro.launch import specs as sp
from repro.models import model as mdl
from repro.optim import adamw, constant
from repro.sharding import init_params

S, B = 16, 2


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_forward_and_loss(arch, rng):
    cfg = cb.smoke(arch)
    params = init_params(mdl.param_specs(cfg), rng, jnp.bfloat16)
    batch = sp.make_batch(cfg, S, B, rng)
    logits, aux, _ = jax.jit(
        lambda p, b: mdl.forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = jax.jit(lambda p, b: mdl.loss_fn(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-v3-671b",
                                  "xlstm-1.3b"])
def test_train_step_descends(arch, rng):
    """One optimizer step lowers the loss on the same batch."""
    cfg = cb.smoke(arch)
    params = init_params(mdl.param_specs(cfg), rng, jnp.float32)
    batch = sp.make_batch(cfg, S, B, rng)
    opt = adamw(constant(3e-3), weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s, i):
        (l, m), g = jax.value_and_grad(
            lambda p: mdl.loss_fn(p, cfg, batch), has_aux=True)(p)
        p2, s2, _ = opt.update(g, s, p, i)
        return p2, s2, l

    losses = []
    for i in range(5):
        params, state, l = step(params, state, jnp.int32(i))
        losses.append(float(l))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "deepseek-v2-236b",
                                  "zamba2-2.7b", "whisper-base"])
def test_decode_matches_forward(arch, rng):
    """prefill + one decode step == full forward at position S."""
    cfg = cb.smoke(arch)
    params = init_params(mdl.param_specs(cfg), rng, jnp.bfloat16)
    batch = sp.make_batch(cfg, S, B, rng, with_labels=False)
    last, cache = jax.jit(lambda p, b: mdl.prefill(p, cfg, b))(params, batch)
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]

    cache_t = sp.init_cache(cfg, B, S + 4)

    def put(dst, src):
        if src.ndim == 0 or dst.shape == src.shape:
            return src.astype(dst.dtype)
        ax = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
              if a != b]
        sl = [slice(None)] * dst.ndim
        sl[ax[0]] = slice(0, src.shape[ax[0]])
        return dst.at[tuple(sl)].set(src.astype(dst.dtype))

    cache2 = jax.tree.map(put, cache_t, cache)
    got, _ = jax.jit(lambda p, t, c: mdl.decode_step(
        p, cfg, t, jnp.int32(S), c))(params, tok, cache2)

    b2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], tok], axis=1))
    ref, _, _ = jax.jit(lambda p, b: mdl.forward(p, cfg, b))(params, b2)
    ref = ref[:, -1].astype(jnp.float32)
    err = float(jnp.max(jnp.abs(ref - got.astype(jnp.float32)))
                / (jnp.max(jnp.abs(ref)) + 1e-6))
    assert err < 2e-2, (arch, err)


def test_all_full_configs_resolve():
    for arch in cb.ARCH_IDS:
        cfg = cb.get(arch)
        assert cfg.n_layers > 0 and cfg.d_model > 0
        assert mdl.param_specs(cfg) is not None


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "deepseek-v2-236b"])
def test_int8_kv_cache_decode_parity(arch, rng):
    """Quantized serving cache: decode matches full forward within 2%."""
    cfg = cb.smoke(arch).replace(kv_cache_dtype="int8")
    params = init_params(mdl.param_specs(cfg), rng, jnp.bfloat16)
    batch = sp.make_batch(cfg, S, B, rng, with_labels=False)
    last, cache = jax.jit(lambda p, b: mdl.prefill(p, cfg, b))(params, batch)
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    ct = sp.init_cache(cfg, B, S + 4)

    def put(dst, src):
        if src.ndim == 0 or dst.shape == src.shape:
            return src.astype(dst.dtype)
        ax = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
              if a != b][0]
        sl = [slice(None)] * dst.ndim
        sl[ax] = slice(0, src.shape[ax])
        return dst.at[tuple(sl)].set(src.astype(dst.dtype))

    cache2 = jax.tree.map(put, ct, cache)
    got, _ = jax.jit(lambda p, t, c: mdl.decode_step(
        p, cfg, t, jnp.int32(S), c))(params, tok, cache2)
    b2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], tok], axis=1))
    ref, _, _ = jax.jit(lambda p, b: mdl.forward(p, cfg, b))(params, b2)
    ref = ref[:, -1].astype(jnp.float32)
    err = float(jnp.max(jnp.abs(ref - got.astype(jnp.float32)))
                / (jnp.max(jnp.abs(ref)) + 1e-6))
    assert err < 2e-2, (arch, err)


def test_int8_expert_weights_parity(rng):
    """Weight-only quantized MoE matches the bf16 expert output closely."""
    import numpy as np
    from repro.models import moe as M

    cfg = cb.smoke("deepseek-v3-671b")
    cfg8 = cfg.replace(expert_weights_dtype="int8")
    p = init_params(M.moe_specs(cfg), rng, jnp.bfloat16)
    p8 = dict(p, **M.quantize_expert_weights(
        {k: p[k] for k in ("w_gate", "w_up", "w_down")}))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.bfloat16) * 0.5
    y_ref, _ = M.moe_fwd(p, x, cfg)
    y_q, _ = M.moe_fwd(p8, x, cfg8)
    ref = np.asarray(y_ref, np.float32)
    got = np.asarray(y_q, np.float32)
    denom = np.max(np.abs(ref)) + 1e-6
    assert np.max(np.abs(ref - got)) / denom < 3e-2
