"""Power-governed fabric dispatch: per-lane energy accounting, per-hub
watt budgets with the nominal -> throttled -> parked thermal state
machine, fabric-aware (routed-cost) lane picking, and the dispatch-layer
bug squash (clone device aliasing, registry error contracts)."""
import pytest

from repro.bus import BusParams, LinkParams, SharedBus, calibrated, \
    simulate_broadcast_fps
from repro.bus.fabric import uniform_fabric
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import (CapabilityRegistry, PowerGovernor, StreamEngine,
                           build_battery_engine, build_fabric_engine,
                           build_routed_pipeline_engine,
                           engine_broadcast_fps, run_battery, run_replicated)

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)

PARAMS = BusParams("hub", bandwidth=100e6, base_overhead_s=2e-4,
                   arbitration_s=1e-4)
LINK = LinkParams(bandwidth=300e6, overhead_s=1e-4)


def _cart(name, service_s=0.02, power_w=1.8, idle_w=0.3, capability_id=7,
          **dev):
    return FnCartridge(name, lambda p, x: x, SPEC, SPEC,
                       capability_id=capability_id,
                       device=DeviceModel(service_s=service_s,
                                          power_w=power_w, idle_w=idle_w,
                                          **dev))


def _bus():
    return SharedBus(BusParams("test", bandwidth=400e6,
                               base_overhead_s=1e-4, arbitration_s=2e-4))


# -- per-lane energy accounting ------------------------------------------------
def test_energy_matches_busy_idle_integral():
    """E = elapsed * idle_w + active_s * (power_w - idle_w), exactly."""
    reg = CapabilityRegistry()
    reg.insert(0, _cart("solo", service_s=0.05))
    eng = StreamEngine(reg, _bus(), microbatch=False)
    eng.feed(20, interval_s=0.1)           # 50% duty: half busy, half idle
    rep = eng.run(until=60)
    assert rep.frames_out == 20
    lane = rep.power["lanes"]["solo"]
    assert lane["active_s"] == pytest.approx(20 * 0.05)
    expect = rep.sim_time * 0.3 + 20 * 0.05 * (1.8 - 0.3)
    assert lane["energy_j"] == pytest.approx(expect, abs=1e-5)
    assert rep.energy_j() == pytest.approx(expect, abs=1e-5)
    assert lane["active_j"] == pytest.approx(20 * 0.05 * 1.8)
    # average draw sits strictly between idle and active rails
    assert 0.3 < rep.avg_power_w() < 1.8


def test_energy_splits_per_hub():
    eng = build_fabric_engine([["ncs2"] * 2, ["ncs2"] * 2], mode="shard")
    eng.feed(80, interval_s=0.0)
    rep = eng.run(until=1e9)
    assert rep.frames_out == 80
    hubs = rep.power["hubs"]
    assert set(hubs) == {0, 1}
    assert hubs[0]["lanes"] == hubs[1]["lanes"] == 2
    for h in hubs.values():
        assert h["energy_j"] > 0
        assert h["budget_w"] is None
        assert h["state"] == "nominal"
    total = sum(h["energy_j"] for h in hubs.values())
    assert rep.power["total_j"] == pytest.approx(total, abs=1e-6)


def test_detached_lane_stops_drawing_but_keeps_its_energy():
    reg = CapabilityRegistry()
    primary = _cart("infer", service_s=0.03)
    reg.insert(0, primary)
    r1 = primary.clone()
    reg.add_replica(0, r1)
    eng = StreamEngine(reg, _bus())
    eng.feed(100, interval_s=0.01)
    eng.schedule_remove_replica(0.4, slot=0, cart=r1)
    rep = eng.run(until=60)
    assert rep.frames_out == 100
    pulled = rep.power["lanes"][r1.name]
    assert pulled["detached"] is True
    assert pulled["energy_j"] > 0
    # the unplugged stick accrued idle only until detach (~0.4s), so its
    # total energy is bounded by full draw over that window
    assert pulled["energy_j"] <= 1.8 * 0.45 + 0.1


# -- budgets: throttle ---------------------------------------------------------
def test_unbudgeted_run_is_bit_identical_to_pre_governor():
    """Metering must be free: a huge budget (state machine armed but
    never triggered) and no budget at all produce identical runs."""
    a = run_battery(None, n_frames=120)
    b = run_battery(1e9, n_frames=120)
    assert a.sim_time == b.sim_time
    assert a.latencies == b.latencies
    assert a.power["total_j"] == b.power["total_j"]


def test_throttle_holds_average_power_under_budget():
    budget = 4.0
    free = run_battery(None, n_frames=400)
    capped = run_battery(budget, n_frames=400)
    hub = capped.power["hubs"][0]
    assert capped.frames_out == 400, f"lost {capped.lost}"
    assert hub["throttle_events"] >= 1
    assert hub["avg_w"] <= budget
    assert free.power["hubs"][0]["avg_w"] > budget   # the cap actually binds
    # throughput degrades gracefully, it does not collapse to zero
    assert 0.0 < capped.throughput() < free.throughput()
    assert hub["throttled_s"] > 0.0


def test_throttled_lane_effective_est_inflates_in_dispatch():
    """Dispatch must see the duty stretch: while throttled the governor's
    inflation multiplies the lane's effective est_s."""
    eng = build_battery_engine(3.0)
    eng.feed(200, interval_s=0.0)
    eng.run(until=1e9)
    gov = eng.governor
    t = eng.now
    assert gov.inflation(t, 0) > 1.0 or gov.parked(t, 0) is False
    # the EWMA itself kept learning the DEVICE, not the throttle
    lane = eng._groups[0].lanes[0]
    assert lane.est_s == pytest.approx(calibrated("ncs2").t_comp_s, rel=0.5)


def test_budget_sweep_monotone_energy():
    """Tighter caps -> lower average power (FPS pays for it)."""
    avgs, fps = [], []
    for budget in (5.0, 3.5, 2.5):
        r = run_battery(budget, n_frames=500)
        assert r.lost == 0
        avgs.append(r.power["hubs"][0]["avg_w"])
        fps.append(r.throughput())
        assert avgs[-1] <= budget
    assert avgs[0] > avgs[1] > avgs[2]
    assert fps[0] > fps[1] > fps[2] > 0


# -- budgets: park -------------------------------------------------------------
def test_deep_budget_parks_and_duty_cycles_with_zero_loss():
    """A cap below the min-duty draw forces park cycling: the hub runs
    throttled bursts, parks to cool, and every frame still comes out."""
    r = run_battery(2.0, n_frames=150)
    hub = r.power["hubs"][0]
    assert r.lost == 0
    assert hub["park_events"] >= 1
    assert hub["parked_s"] > 0.0
    assert hub["avg_w"] <= 2.0
    assert not hub["unsatisfiable"]


def test_unsatisfiable_budget_flagged_not_deadlocked():
    """A budget below the idle floor cannot be met by scheduling: the
    governor flags it and keeps the pipeline moving at deepest throttle
    instead of parking forever (which could never cool below the
    floor)."""
    r = run_battery(1.0, n_frames=60)   # floor = 4 x 0.3 = 1.2 W > 1.0 W
    hub = r.power["hubs"][0]
    assert r.lost == 0                   # no deadlock, no loss
    assert hub["unsatisfiable"] is True
    assert hub["park_events"] == 0
    assert hub["state"] == "throttled"


def test_per_hub_budget_dict_throttles_only_the_capped_hub():
    eng = build_fabric_engine([["ncs2"] * 2, ["ncs2"] * 2], mode="shard",
                              power_budget_w={0: 2.0})
    # arrivals over time (not one t=0 burst): later frames must see the
    # throttled hub's inflated est_s and land on the unconstrained hub
    eng.feed(300, interval_s=0.008)
    rep = eng.run(until=1e9)
    assert rep.lost == 0
    hubs = rep.power["hubs"]
    assert hubs[0]["budget_w"] == 2.0
    assert hubs[1]["budget_w"] is None
    assert hubs[0]["throttle_events"] >= 1
    assert hubs[1]["throttle_events"] == 0
    # dispatch shifted load to the unconstrained hub
    by_hub = {0: 0, 1: 0}
    g = rep.groups[0]
    for name, hub in zip(g["lanes"], g["hubs"]):
        by_hub[hub] += rep.stage_stats[name].processed
    assert by_hub[1] > by_hub[0]


def test_rebudget_off_mid_cycle_settles_uplift():
    """Dropping the budget while lanes are mid-cycle must settle their
    draw uplift: a later re-budget would otherwise see a phantom
    permanent load and could park a hub that can never cool."""
    eng = build_battery_engine(3.0)
    eng.feed(100, interval_s=0.0)
    eng._push_event(0.5, lambda: eng.governor.set_budget(None, eng.now))
    eng._push_event(2.0, lambda: eng.governor.set_budget(6.0, eng.now))
    rep = eng.run(until=1e9)
    assert rep.lost == 0
    # after the run every cycle has ended: no uplift may linger
    hs = eng.governor._hubs[0]
    assert hs.draw_w == pytest.approx(0.0, abs=1e-12)


def test_rebudget_below_idle_floor_is_flagged_not_parked_forever():
    """Tightening the cap below the idle floor mid-run must take the
    unsatisfiable deepest-duty hold, not the park path (a parked hub
    could never cool below its own floor)."""
    eng = build_battery_engine(4.0)          # floor = 1.2 W
    eng.feed(150, interval_s=0.0)
    eng._push_event(1.0, lambda: eng.governor.set_budget(0.8, eng.now))
    rep = eng.run(until=1e9)
    assert rep.lost == 0                     # no park deadlock
    hub = rep.power["hubs"][0]
    assert hub["unsatisfiable"] is True
    assert hub["state"] == "throttled"


def test_rebudget_mid_run_via_set_budget():
    """Battery saver: tightening the cap mid-mission starts throttling
    from that point on."""
    eng = build_battery_engine(None)
    eng.feed(300, interval_s=0.0)
    eng._push_event(1.0, lambda: eng.governor.set_budget(3.0, eng.now))
    rep = eng.run(until=1e9)
    assert rep.lost == 0
    hub = rep.power["hubs"][0]
    assert hub["budget_w"] == 3.0
    assert hub["throttle_events"] >= 1


# -- Table 1 / parity ----------------------------------------------------------
@pytest.mark.parametrize("device", ["ncs2", "coral"])
def test_unlimited_budget_broadcast_is_table1_bit_identical(device):
    p = calibrated(device)
    for n in (1, 5):
        assert engine_broadcast_fps(device, n, n_frames=80) == \
            pytest.approx(simulate_broadcast_fps(p, n, n_frames=80),
                          rel=1e-12)


def test_broadcast_budget_stretches_but_conserves():
    free = run_replicated("ncs2", 3, "broadcast", 60)
    eng = build_fabric_engine([["ncs2"] * 3], mode="broadcast",
                              power_budget_w=2.0)
    eng.feed(60, interval_s=0.0)
    capped = eng.run(until=1e9)
    assert capped.frames_out == 60
    assert capped.throughput() < free.throughput()
    assert capped.power["hubs"][0]["avg_w"] <= \
        free.power["hubs"][0]["avg_w"]


# -- fabric-aware dispatch (routed-cost pick_lane) -----------------------------
def _router(n_hubs=2):
    return uniform_fabric(PARAMS, n_hubs, link=LINK)


def test_route_cost_local_vs_cross():
    fab = _router(2)
    nbytes = 100_000
    local = fab.route_cost(0, 0, nbytes)
    cross = fab.route_cost(0, 1, nbytes)
    assert local == pytest.approx(PARAMS.base_overhead_s
                                  + nbytes / PARAMS.bandwidth)
    assert cross == pytest.approx(
        2 * local + LINK.overhead_s + nbytes / LINK.bandwidth)
    # pure query: nothing moved, no lazy link materialized
    assert fab.stats()["transfers"] == 0
    assert not fab._links


def test_route_cost_sees_fifo_backlog():
    """A hot route costs more *right now*: the loaded estimate includes
    each leg's free_at backlog, so dispatch avoids hot links."""
    fab = _router(2)
    unloaded = fab.route_cost(0, 1, 1000, t=0.0)
    fab.transfer(0.0, 4_000_000, 2, src=0, dst=1)   # heats all three legs
    loaded = fab.route_cost(0, 1, 1000, t=0.0)
    assert loaded > unloaded
    # and cools back down as time passes
    assert fab.route_cost(0, 1, 1000, t=1e9) == pytest.approx(unloaded)


def test_route_aware_dispatch_keeps_traffic_hub_local():
    """The retired ROADMAP item: folding the routed transfer cost into
    pick_lane's completion estimate reduces cross-hub traffic at equal
    offered load without giving up meaningful throughput."""
    blind = build_routed_pipeline_engine(route_aware=False).run(until=1e12)
    aware = build_routed_pipeline_engine(route_aware=True).run(until=1e12)
    assert blind.frames_out == aware.frames_out == 750
    assert aware.bus["cross_hub_transfers"] < \
        blind.bus["cross_hub_transfers"]
    assert aware.throughput() >= 0.9 * blind.throughput()


def test_route_aware_is_noop_on_single_hub_fabric():
    """With one hub the toll is constant across lanes: identical runs."""
    def run(aware):
        eng = build_fabric_engine([["ncs2"] * 3], mode="shard",
                                  route_aware=aware)
        eng.feed(120, interval_s=0.005)
        return eng.run(until=1e9)

    a, b = run(True), run(False)
    assert a.sim_time == b.sim_time
    assert a.latencies == b.latencies


# -- governor construction contracts ------------------------------------------
def test_governor_rejects_bad_budgets():
    with pytest.raises(ValueError):
        PowerGovernor(budget_w=0.0)
    with pytest.raises(ValueError):
        PowerGovernor(budget_w={0: -1.0})
    with pytest.raises(ValueError):
        PowerGovernor(exit_ratio=1.5)
    assert PowerGovernor().active is False
    assert PowerGovernor(budget_w=5.0).active is True
    assert PowerGovernor(budget_w={1: 5.0}).budget_of(0) is None


# -- dispatch-layer bug squash -------------------------------------------------
def test_clone_device_models_never_alias():
    """The PR's bugfix: replicas must not share one mutable DeviceModel
    (per-device thermal state / calibration drift would silently couple
    sibling lanes)."""
    primary = _cart("infer")
    r1 = primary.clone()
    r2 = primary.clone(device=DeviceModel(name="coral", service_s=0.01))
    assert r1.device is not primary.device
    assert r1.device == primary.device         # values preserved
    assert r2.device is not None
    # mutating one replica's calibration leaves its siblings untouched
    r1.device.service_s = 99.0
    assert primary.device.service_s == 0.02
    dev = DeviceModel(service_s=0.05)
    a, b = primary.clone(device=dev), primary.clone(device=dev)
    assert a.device is not b.device            # even an explicit device=
    a.device.jitter_p = 1.0
    assert b.device.jitter_p == 0.0


def test_clone_auto_names_deterministic_per_parent():
    """Auto-names number each parent's clones independently, so the
    crc32(lane, seq) jitter draws replay identically regardless of what
    else the process cloned first (engine _service_time)."""
    a = _cart("infer")
    burn = _cart("other")
    burn.clone(), burn.clone(), burn.clone()   # unrelated cloning activity
    b = _cart("infer")
    assert [a.clone().name, a.clone().name] == \
        [b.clone().name, b.clone().name] == ["infer#r1", "infer#r2"]
    # a replica numbers its own clones from scratch
    r = a.clone()
    assert r.clone().name == f"{r.name}#r1"


def test_registry_remove_unknown_slot_is_descriptive():
    reg = CapabilityRegistry()
    reg.insert(3, _cart("a"))
    with pytest.raises(ValueError, match="slot 7"):
        reg.remove(7)
    with pytest.raises(ValueError, match="slot 9"):
        reg.remove_replica(9)
    assert 3 in reg.slots                      # nothing was disturbed


def test_registry_remove_error_lists_plugged_slots():
    reg = CapabilityRegistry()
    with pytest.raises(ValueError, match="none"):
        reg.remove(0)
    reg.insert(1, _cart("a"))
    reg.insert(4, _cart("b", capability_id=8))
    with pytest.raises(ValueError, match=r"\[1, 4\]"):
        reg.remove(2)
