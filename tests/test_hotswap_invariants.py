"""Regression suite for the zero-loss hot-swap invariant (paper §4.2):
``frames_in == frames_out`` must survive every reconfiguration sequence —
bridged removals, halt-until-insert gaps, removals timed to land while
frames are mid-transfer on the bus, replica churn on a *remote hub* of
the multi-hub fabric (which must degrade that hub's share of the
throughput without pausing the others), and churn under an active power
throttle (the §4.3 governor must neither lose frames nor mis-account
energy at the edges: zero-frame runs, parked idle draw, exact-budget
steady states)."""
import pytest

from repro.bus import BusParams, SharedBus
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import (CapabilityRegistry, StreamEngine,
                           build_battery_engine, build_fabric_engine,
                           run_battery)

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)


def _cart(name, service_s=0.02, consumes=None, produces=None, load_s=0.5):
    return FnCartridge(name, lambda p, x: x, consumes or SPEC,
                       produces or SPEC,
                       device=DeviceModel(service_s=service_s, load_s=load_s))


def _engine(n_stages=3, service_s=0.02, queue_cap=8, base_overhead_s=1e-4,
            microbatch=True):
    reg = CapabilityRegistry()
    for i in range(n_stages):
        reg.insert(i, _cart(f"stage{i}", service_s))
    bus = SharedBus(BusParams("t", bandwidth=400e6,
                              base_overhead_s=base_overhead_s,
                              arbitration_s=2e-4))
    return StreamEngine(reg, bus, queue_cap=queue_cap,
                        microbatch=microbatch), reg


def _conserved(rep, n):
    assert rep.frames_in == n
    assert rep.frames_out == n, f"lost {rep.lost}"
    assert rep.lost == 0


# -- remove -> bridge ---------------------------------------------------------
@pytest.mark.parametrize("t_remove", [0.05, 0.5, 1.0, 2.37])
def test_remove_bridge_conserves_frames(t_remove):
    eng, reg = _engine(3)
    eng.feed(100, interval_s=0.03)
    eng.schedule_remove(t_remove, slot=1)
    rep = eng.run(until=60)
    _conserved(rep, 100)
    assert 1 not in reg.slots


def test_double_remove_bridge_conserves_frames():
    eng, reg = _engine(4)
    eng.feed(120, interval_s=0.03)
    eng.schedule_remove(0.7, slot=1)
    eng.schedule_remove(1.9, slot=2)
    rep = eng.run(until=60)
    _conserved(rep, 120)
    assert [c.name for c in reg.chain()] == ["stage0", "stage3"]


# -- remove -> halt -> insert -------------------------------------------------
def _typed_pipeline():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("det", produces=msg.MessageSpec(msg.BBOXES)))
    reg.insert(1, _cart("embed", consumes=msg.MessageSpec(msg.BBOXES),
                        produces=msg.MessageSpec(msg.EMBEDDING)))
    reg.insert(2, _cart("match", consumes=msg.MessageSpec(msg.EMBEDDING),
                        produces=msg.MessageSpec(msg.MATCH_RESULT)))
    bus = SharedBus(BusParams("t", base_overhead_s=1e-4))
    return StreamEngine(reg, bus), reg


@pytest.mark.parametrize("t_insert", [1.2, 3.0, 4.5])
def test_remove_halt_insert_conserves_frames(t_insert):
    eng, reg = _typed_pipeline()
    eng.feed(80, interval_s=0.04)
    eng.schedule_remove(1.0, slot=1)
    eng.schedule_insert(t_insert, slot=1,
                        cart=_cart("embed2",
                                   consumes=msg.MessageSpec(msg.BBOXES),
                                   produces=msg.MessageSpec(msg.EMBEDDING)))
    rep = eng.run(until=80)
    _conserved(rep, 80)
    assert rep.alerts                 # the halt raised an operator alert
    assert [c.name for c in reg.chain()] == ["det", "embed2", "match"]


def test_frames_arriving_during_halt_are_buffered_not_dropped():
    eng, reg = _typed_pipeline()
    # every frame arrives while the pipeline is halted
    eng.schedule_remove(0.1, slot=1)
    eng.feed(40, interval_s=0.02, t0=0.5)
    eng.schedule_insert(2.5, slot=1,
                        cart=_cart("embed2",
                                   consumes=msg.MessageSpec(msg.BBOXES),
                                   produces=msg.MessageSpec(msg.EMBEDDING)))
    rep = eng.run(until=80)
    _conserved(rep, 40)


# -- removal landing mid-transfer --------------------------------------------
@pytest.mark.parametrize("t_remove", [0.101, 0.217, 0.333, 0.449, 0.565])
def test_mid_transfer_removal_conserves_frames(t_remove):
    """Slow bus (20 ms per hop): removals land while frames sit on the
    wire or in flight between stages; every one must still come out."""
    eng, reg = _engine(3, service_s=0.01, base_overhead_s=0.02)
    eng.feed(60, interval_s=0.015)
    eng.schedule_remove(t_remove, slot=1)
    rep = eng.run(until=60)
    _conserved(rep, 60)


def test_mid_transfer_remove_then_reinsert_conserves_frames():
    eng, reg = _engine(3, service_s=0.01, base_overhead_s=0.02)
    eng.feed(90, interval_s=0.015)
    eng.schedule_remove(0.333, slot=1)
    eng.schedule_insert(1.1, slot=1, cart=_cart("stage1b", 0.01))
    rep = eng.run(until=60)
    _conserved(rep, 90)
    assert [c.name for c in reg.chain()] == ["stage0", "stage1b", "stage2"]


# -- swaps under saturation ---------------------------------------------------
def test_swap_under_backpressure_conserves_frames():
    """Tight queues + overload + a swap: backpressure holds and nothing
    falls on the floor."""
    eng, reg = _engine(3, service_s=0.03, queue_cap=2, microbatch=False)
    eng.feed(80, interval_s=0.005)
    eng.schedule_remove(0.4, slot=1)
    rep = eng.run(until=120)
    _conserved(rep, 80)


def test_remove_tail_stage_conserves_frames():
    eng, reg = _engine(3)
    eng.feed(70, interval_s=0.03)
    eng.schedule_remove(0.8, slot=2)
    rep = eng.run(until=60)
    _conserved(rep, 70)


def test_remove_head_stage_conserves_frames():
    eng, reg = _engine(3)
    eng.feed(70, interval_s=0.03)
    eng.schedule_remove(0.8, slot=0)
    rep = eng.run(until=60)
    _conserved(rep, 70)


# -- cross-hub hot-swap (multi-hub fabric) ------------------------------------
def _remote_replica(eng, hub):
    reg = eng.registry
    return next(c for c in reg.slots[0].replicas if reg.hub_of(c) == hub)


@pytest.mark.parametrize("t_remove", [0.3, 0.9, 1.7])
def test_remove_remote_hub_replica_degrades_without_pause(t_remove):
    """Pulling a stick from hub 1 must not pause hub 0: zero downtime,
    zero loss, and every surviving lane — on both hubs — keeps working."""
    eng = build_fabric_engine([["ncs2"] * 2, ["ncs2"] * 2], mode="shard")
    victim = _remote_replica(eng, hub=1)
    eng.feed(200, interval_s=0.008)
    eng.schedule_remove_replica(t_remove, slot=0, cart=victim)
    rep = eng.run(until=120)
    _conserved(rep, 200)
    assert rep.total_downtime() == 0.0       # no pipeline pause
    assert not rep.alerts                    # no operator alert
    assert rep.groups[0]["hubs"].count(1) == 1   # hub 1 degraded ...
    assert rep.groups[0]["hubs"].count(0) == 2   # ... hub 0 untouched
    for name in rep.groups[0]["lanes"]:
        assert rep.stage_stats[name].processed > 0
    assert rep.stage_stats[victim.name].processed > 0  # worked, then left


def test_remove_entire_remote_hub_conserves_frames():
    """Unplugging BOTH hub-1 sticks mid-stream leaves a one-hub group:
    degraded throughput, zero loss, no pause at any point."""
    eng = build_fabric_engine([["ncs2"] * 2, ["ncs2"] * 2], mode="shard")
    reg = eng.registry
    victims = [c for c in reg.slots[0].replicas if reg.hub_of(c) == 1]
    eng.feed(160, interval_s=0.01)
    eng.schedule_remove_replica(0.5, slot=0, cart=victims[0])
    eng.schedule_remove_replica(0.9, slot=0, cart=victims[1])
    rep = eng.run(until=120)
    _conserved(rep, 160)
    assert rep.total_downtime() == 0.0
    assert rep.groups[0]["hubs"] == [0, 0]
    assert reg.hubs() == [0]


def test_insert_replica_on_remote_hub_joins_and_speeds_up():
    """Hot-plugging a stick into a *different* hub mid-stream: no pause,
    the lane joins after its handshake, and the added hub pulls weight."""
    def run(add_remote):
        # second hub pre-provisioned but empty until the hot-plug lands
        eng = build_fabric_engine([["ncs2"] * 2, []], mode="shard")
        if add_remote:
            primary = eng.registry.slots[0].cartridge
            newbie = primary.clone(
                "late#h1", device=DeviceModel(name="ncs2",
                                              service_s=primary.
                                              device.service_s,
                                              load_s=0.2))
            eng.schedule_add_replica(0.3, slot=0, cart=newbie, hub=1)
        # arrivals keep coming after the join, slightly over 2-stick
        # capacity, so the late lane has work to steal
        eng.feed(150, interval_s=0.03)
        return eng.run(until=300)

    solo = run(False)
    grown = run(True)
    assert solo.frames_out == grown.frames_out == 150
    assert grown.total_downtime() == 0.0
    assert grown.sim_time < solo.sim_time    # the remote stick helped
    assert sorted(grown.groups[0]["hubs"]) == [0, 0, 1]
    assert grown.stage_stats["late#h1"].processed > 0


def test_cross_hub_swap_under_hedged_dispatch_conserves_frames():
    """Replica churn on a remote hub while hedging is live: exactly-once
    delivery and zero loss must survive the rebuild."""
    eng = build_fabric_engine([["ncs2"] * 2, ["ncs2"] * 2], mode="shard",
                              hedge=True)
    victim = _remote_replica(eng, hub=1)
    for i in range(40):
        eng.feed(5, interval_s=0.0, t0=i * 0.05)
    eng.schedule_remove_replica(0.7, slot=0, cart=victim)
    rep = eng.run(until=300)
    _conserved(rep, 200)
    assert eng._hedges == {}                 # every race fully resolved


# -- power accounting edge cases (§4.3 governor) ------------------------------
def test_zero_frame_run_reports_zero_energy():
    """No events -> no elapsed virtual time -> no energy, budgeted or
    not (an idle report must not invent idle-draw joules for a run that
    never advanced the clock)."""
    for budget in (None, 3.0):
        eng = build_battery_engine(budget)
        rep = eng.run(until=10)
        assert rep.frames_out == 0
        assert rep.sim_time == 0.0
        assert rep.energy_j() == 0.0
        assert rep.avg_power_w() == 0.0
        assert all(l["energy_j"] == 0.0
                   for l in rep.power["lanes"].values())


def test_parked_lane_idle_draw_still_accrues():
    """Parking stops cycles, not physics: a parked stick keeps pulling
    its idle watts, so the hub's energy keeps growing at (at least) the
    idle floor while parked."""
    rep = run_battery(2.0, n_frames=120)     # below min-duty draw: parks
    hub = rep.power["hubs"][0]
    assert hub["park_events"] >= 1
    assert hub["parked_s"] > 0.0
    # total energy can never fall below pure idle for the whole run ...
    floor_j = rep.sim_time * 4 * 0.3
    assert rep.energy_j() > floor_j
    # ... and every lane's ledger shows idle joules (duty-forced + parked)
    for lane in rep.power["lanes"].values():
        assert lane["idle_j"] > 0.0


def test_exact_budget_steady_state_does_not_oscillate():
    """A steady-state draw sitting EXACTLY at the budget is sustainable:
    the EWMA approaches it from below, entry is a strict inequality, and
    the machine must stay nominal — zero throttle/park events."""
    # closed loop, always-busy: steady draw = 4 x 1.8 = 7.2 W = budget
    rep = run_battery(7.2, n_frames=400)
    hub = rep.power["hubs"][0]
    assert rep.lost == 0
    assert hub["throttle_events"] == 0
    assert hub["park_events"] == 0
    assert hub["state"] == "nominal"
    # and the run is bit-identical to the unbudgeted engine
    free = run_battery(None, n_frames=400)
    assert rep.sim_time == free.sim_time
    assert rep.latencies == free.latencies


def test_hotswap_under_active_throttle_conserves_frames():
    """Pulling and re-adding sticks while the hub is throttled: the
    governor re-derives the hub's duty from the surviving population and
    the pipeline loses nothing."""
    eng = build_battery_engine(3.5)
    primary = eng.registry.slots[0].cartridge
    victim = eng.registry.slots[0].replicas[-1]
    # arrivals span past the hot-plug so the late lane has work to take
    eng.feed(250, interval_s=0.02)
    eng.schedule_remove_replica(1.5, slot=0, cart=victim)
    late = primary.clone("late#r9")
    late.device.load_s = 0.2
    eng.schedule_add_replica(2.5, slot=0, cart=late)
    rep = eng.run(until=1e9)
    _conserved(rep, 250)
    hub = rep.power["hubs"][0]
    assert hub["throttle_events"] >= 1       # the throttle was live
    assert hub["avg_w"] <= 3.5
    assert rep.power["lanes"][victim.name]["detached"] is True
    assert rep.power["lanes"]["late#r9"]["energy_j"] > 0.0
    assert rep.stage_stats["late#r9"].processed > 0


def test_whole_hub_park_then_removal_conserves_frames():
    """The harshest sequence: one hub parked by its budget, then that
    whole hub is unplugged mid-run — its queued frames redistribute and
    every frame still comes out."""
    eng = build_fabric_engine([["ncs2"] * 2, ["ncs2"] * 2], mode="shard",
                              power_budget_w={0: 1.5})  # hub 0 park-cycles
    reg = eng.registry
    victims = [c for c in reg.slots[0].replicas if reg.hub_of(c) == 0]
    eng.feed(200, interval_s=0.01)
    eng.schedule_remove_replica(1.2, slot=0, cart=victims[0])
    eng.schedule_remove_replica(1.4, slot=0, cart=victims[1])
    rep = eng.run(until=1e9)
    _conserved(rep, 200)
    assert rep.groups[0]["hubs"] == [1, 1]   # only hub 1 survives
