"""Regression suite for the zero-loss hot-swap invariant (paper §4.2):
``frames_in == frames_out`` must survive every reconfiguration sequence —
bridged removals, halt-until-insert gaps, and removals timed to land while
frames are mid-transfer on the bus."""
import pytest

from repro.bus import BusParams, SharedBus
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import CapabilityRegistry, StreamEngine

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)


def _cart(name, service_s=0.02, consumes=None, produces=None, load_s=0.5):
    return FnCartridge(name, lambda p, x: x, consumes or SPEC,
                       produces or SPEC,
                       device=DeviceModel(service_s=service_s, load_s=load_s))


def _engine(n_stages=3, service_s=0.02, queue_cap=8, base_overhead_s=1e-4,
            microbatch=True):
    reg = CapabilityRegistry()
    for i in range(n_stages):
        reg.insert(i, _cart(f"stage{i}", service_s))
    bus = SharedBus(BusParams("t", bandwidth=400e6,
                              base_overhead_s=base_overhead_s,
                              arbitration_s=2e-4))
    return StreamEngine(reg, bus, queue_cap=queue_cap,
                        microbatch=microbatch), reg


def _conserved(rep, n):
    assert rep.frames_in == n
    assert rep.frames_out == n, f"lost {rep.lost}"
    assert rep.lost == 0


# -- remove -> bridge ---------------------------------------------------------
@pytest.mark.parametrize("t_remove", [0.05, 0.5, 1.0, 2.37])
def test_remove_bridge_conserves_frames(t_remove):
    eng, reg = _engine(3)
    eng.feed(100, interval_s=0.03)
    eng.schedule_remove(t_remove, slot=1)
    rep = eng.run(until=60)
    _conserved(rep, 100)
    assert 1 not in reg.slots


def test_double_remove_bridge_conserves_frames():
    eng, reg = _engine(4)
    eng.feed(120, interval_s=0.03)
    eng.schedule_remove(0.7, slot=1)
    eng.schedule_remove(1.9, slot=2)
    rep = eng.run(until=60)
    _conserved(rep, 120)
    assert [c.name for c in reg.chain()] == ["stage0", "stage3"]


# -- remove -> halt -> insert -------------------------------------------------
def _typed_pipeline():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("det", produces=msg.MessageSpec(msg.BBOXES)))
    reg.insert(1, _cart("embed", consumes=msg.MessageSpec(msg.BBOXES),
                        produces=msg.MessageSpec(msg.EMBEDDING)))
    reg.insert(2, _cart("match", consumes=msg.MessageSpec(msg.EMBEDDING),
                        produces=msg.MessageSpec(msg.MATCH_RESULT)))
    bus = SharedBus(BusParams("t", base_overhead_s=1e-4))
    return StreamEngine(reg, bus), reg


@pytest.mark.parametrize("t_insert", [1.2, 3.0, 4.5])
def test_remove_halt_insert_conserves_frames(t_insert):
    eng, reg = _typed_pipeline()
    eng.feed(80, interval_s=0.04)
    eng.schedule_remove(1.0, slot=1)
    eng.schedule_insert(t_insert, slot=1,
                        cart=_cart("embed2",
                                   consumes=msg.MessageSpec(msg.BBOXES),
                                   produces=msg.MessageSpec(msg.EMBEDDING)))
    rep = eng.run(until=80)
    _conserved(rep, 80)
    assert rep.alerts                 # the halt raised an operator alert
    assert [c.name for c in reg.chain()] == ["det", "embed2", "match"]


def test_frames_arriving_during_halt_are_buffered_not_dropped():
    eng, reg = _typed_pipeline()
    # every frame arrives while the pipeline is halted
    eng.schedule_remove(0.1, slot=1)
    eng.feed(40, interval_s=0.02, t0=0.5)
    eng.schedule_insert(2.5, slot=1,
                        cart=_cart("embed2",
                                   consumes=msg.MessageSpec(msg.BBOXES),
                                   produces=msg.MessageSpec(msg.EMBEDDING)))
    rep = eng.run(until=80)
    _conserved(rep, 40)


# -- removal landing mid-transfer --------------------------------------------
@pytest.mark.parametrize("t_remove", [0.101, 0.217, 0.333, 0.449, 0.565])
def test_mid_transfer_removal_conserves_frames(t_remove):
    """Slow bus (20 ms per hop): removals land while frames sit on the
    wire or in flight between stages; every one must still come out."""
    eng, reg = _engine(3, service_s=0.01, base_overhead_s=0.02)
    eng.feed(60, interval_s=0.015)
    eng.schedule_remove(t_remove, slot=1)
    rep = eng.run(until=60)
    _conserved(rep, 60)


def test_mid_transfer_remove_then_reinsert_conserves_frames():
    eng, reg = _engine(3, service_s=0.01, base_overhead_s=0.02)
    eng.feed(90, interval_s=0.015)
    eng.schedule_remove(0.333, slot=1)
    eng.schedule_insert(1.1, slot=1, cart=_cart("stage1b", 0.01))
    rep = eng.run(until=60)
    _conserved(rep, 90)
    assert [c.name for c in reg.chain()] == ["stage0", "stage1b", "stage2"]


# -- swaps under saturation ---------------------------------------------------
def test_swap_under_backpressure_conserves_frames():
    """Tight queues + overload + a swap: backpressure holds and nothing
    falls on the floor."""
    eng, reg = _engine(3, service_s=0.03, queue_cap=2, microbatch=False)
    eng.feed(80, interval_s=0.005)
    eng.schedule_remove(0.4, slot=1)
    rep = eng.run(until=120)
    _conserved(rep, 80)


def test_remove_tail_stage_conserves_frames():
    eng, reg = _engine(3)
    eng.feed(70, interval_s=0.03)
    eng.schedule_remove(0.8, slot=2)
    rep = eng.run(until=60)
    _conserved(rep, 70)


def test_remove_head_stage_conserves_frames():
    eng, reg = _engine(3)
    eng.feed(70, interval_s=0.03)
    eng.schedule_remove(0.8, slot=0)
    rep = eng.run(until=60)
    _conserved(rep, 70)
