"""Front-door admission invariants, driven two ways: a standalone
heapq mini-sim (hypothesis property tests over arbitrary arrival
interleavings) and the real ``StreamEngine`` (end-to-end pins).

Invariants pinned:

  * conservation — per tenant, ``offered == admitted + shed + queued``
    under ANY arrival interleaving, rate caps, and queue caps;
  * weighted fairness — under sustained all-tenant backlog, long-run
    admission shares converge to the WFQ weights;
  * pass-through — one tenant, no caps: ``feed()`` through the trivial
    door is float-for-float identical to direct ingest (Table 1 cell);
  * class shed order — overload sheds bulk before standard before
    interactive, and the aggregate cap preempts only strictly-lower
    classes;
  * SLO coupling — a tenant SLO tightens the hedge deadline vs the
    uncoupled engine;
  * backpressure — live capacity collapsing to zero parks arrivals in
    bounded tenant queues (brown-out guard), and admission resumes
    after recovery.
"""
import heapq
import itertools
from types import SimpleNamespace

import pytest

from repro.runtime import FrontDoor, StreamEngine, Tenant
from repro.runtime import build_replicated_engine, run_fleet_sweep

try:
    from hypothesis import given, settings, strategies as stn
    settings.register_profile("ci", max_examples=30, deadline=None)
    settings.load_profile("ci")
    HAVE_HYPOTHESIS = True
except ImportError:        # property tests skip; deterministic pins still run
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# standalone mini-sim: the door bound to a bare heapq event loop
# ---------------------------------------------------------------------------
class _MiniSim:
    """Just enough host to drive a FrontDoor: a heap of timed callbacks,
    a virtual clock, and a sink that 'completes' admitted frames after a
    fixed service time at a bounded concurrency."""

    def __init__(self, fd: FrontDoor, service_s: float = 0.01,
                 capacity_fps: float = 100.0):
        self.fd = fd
        self.now = 0.0
        self.service_s = service_s
        self.capacity = capacity_fps
        self.admitted_order = []
        self._heap = []
        self._seq = itertools.count()
        fd.bind(clock=lambda: self.now,
                schedule=self._push,
                admit=self._on_admit,
                capacity=lambda: (self.capacity, self.capacity))

    def _push(self, t, fn, *a):
        heapq.heappush(self._heap, (t, next(self._seq), fn, a))

    def _on_admit(self, m):
        self.admitted_order.append(m.tenant)
        self._push(self.now + self.service_s, self._complete, m)

    def _complete(self, m):
        self.fd.on_complete(m.tenant, self.now - m.t_created, self.now)

    def offer(self, t, tenant):
        self._push(t, self._offer_now, tenant)

    def _offer_now(self, tenant):
        m = SimpleNamespace(tenant=tenant, t_created=self.now,
                            seq=next(self._seq))
        self.fd.offer(tenant, m, self.now)

    def run(self):
        while self._heap:
            self.now, _, fn, a = heapq.heappop(self._heap)
            fn(*a)


def _run_conservation_case(specs, arrivals):
    """Shared body: build a door from (priority, weight, rate, qcap)
    specs, offer the arrival list, and assert the per-tenant ledger."""
    fd = FrontDoor(total_queue_cap=48)
    for i, (prio, w, rate, qcap) in enumerate(specs):
        fd.add_tenant(Tenant(f"t{i}", priority=prio, weight=w,
                             rate_fps=rate, queue_cap=qcap))
    sim = _MiniSim(fd, service_s=0.02, capacity_fps=40.0)
    offered = {f"t{i}": 0 for i in range(len(specs))}
    for t, ti in arrivals:
        name = f"t{ti % len(specs)}"
        offered[name] += 1
        sim.offer(t, name)
    sim.run()
    ledger = fd.check_conservation()   # raises on any leak
    for name, n in offered.items():
        row = ledger[name]
        assert row["offered"] == n
        assert row["offered"] == (row["admitted"] + row["shed"]
                                  + row["queued"])


def _lcg(seed):
    """Tiny deterministic generator for the no-hypothesis fallback."""
    x = seed or 1
    while True:
        x = (x * 1103515245 + 12345) % (1 << 31)
        yield x


def test_conservation_fixed_interleavings():
    """Deterministic sweep of adversarial arrival patterns: bursts at
    one instant, steady trickle, all-at-once floods, capped tenants."""
    specs = [(0, 8.0, None, 4), (1, 2.0, 25.0, 8), (2, 1.0, None, 2)]
    rnd = _lcg(42)
    cases = [
        [(0.0, i % 3) for i in range(120)],            # t=0 flood, round-robin
        [(i * 0.001, 2) for i in range(150)],          # one tenant hammers
        [(next(rnd) % 2000 / 1000.0, next(rnd) % 3)    # scattered
         for _ in range(200)],
        [(0.5, 0)] * 40 + [(0.5, 1)] * 40 + [(0.5, 2)] * 40,  # synced bursts
    ]
    for arrivals in cases:
        _run_conservation_case(specs, arrivals)


def _contended_shares(order, n_each, names):
    """Admission shares over the contended window: the prefix of the
    admission order up to the first tenant exhausting its offers (after
    that, the drain is no longer a fair-queueing decision)."""
    counts = {n: 0 for n in names}
    window = dict(counts)
    for name in order:
        counts[name] += 1
        window = dict(counts)
        if counts[name] >= n_each:
            break
    total = sum(window.values())
    return {n: window[n] / total for n in names}, total


def test_wfq_shares_track_weights():
    """All tenants saturated and uncapped: admission shares over the
    contended window converge to the weight proportions."""
    weights = [8.0, 3.0, 1.0]
    fd = FrontDoor(total_queue_cap=100_000)
    for i, w in enumerate(weights):
        fd.add_tenant(Tenant(f"t{i}", weight=w, queue_cap=100_000))
    sim = _MiniSim(fd, service_s=0.001, capacity_fps=200.0)
    n_each = 400
    for i in range(len(weights)):
        for j in range(n_each):
            sim.offer(i * 1e-5 + j * 1e-4, f"t{i}")
    sim.run()
    fd.check_conservation()
    names = [f"t{i}" for i in range(len(weights))]
    shares, total = _contended_shares(sim.admitted_order, n_each, names)
    assert total >= 50
    total_w = sum(weights)
    for i, w in enumerate(weights):
        assert shares[f"t{i}"] == pytest.approx(w / total_w, abs=0.12), \
            (weights, shares)


if HAVE_HYPOTHESIS:
    TENANT_SPECS = stn.lists(
        stn.tuples(stn.integers(0, 2),                  # priority class
                   stn.floats(0.5, 8.0),                # WFQ weight
                   stn.one_of(stn.none(),
                              stn.floats(5.0, 200.0)),  # rate cap
                   stn.integers(1, 32)),                # queue cap
        min_size=1, max_size=4)

    ARRIVALS = stn.lists(
        stn.tuples(stn.floats(0.0, 2.0),                # offer time
                   stn.integers(0, 3)),                 # tenant index
        min_size=1, max_size=200)

    @given(TENANT_SPECS, ARRIVALS)
    def test_conservation_under_any_interleaving(specs, arrivals):
        """offered == admitted + shed + queued under ANY arrival
        pattern, caps, and queue bounds hypothesis can draw."""
        _run_conservation_case(specs, arrivals)

    @given(stn.lists(stn.floats(0.5, 8.0), min_size=2, max_size=4),
           stn.integers(0, 10_000))
    def test_wfq_shares_any_weights(weights, jitter_seed):
        """WFQ share convergence for arbitrary weight vectors and
        arrival phase offsets."""
        fd = FrontDoor(total_queue_cap=100_000)
        for i, w in enumerate(weights):
            fd.add_tenant(Tenant(f"t{i}", weight=w, queue_cap=100_000))
        sim = _MiniSim(fd, service_s=0.001, capacity_fps=200.0)
        n_each = 400
        for i in range(len(weights)):
            phase = ((jitter_seed >> i) & 0xFF) / 51200.0
            for j in range(n_each):
                sim.offer(phase + j * 1e-4, f"t{i}")
        sim.run()
        fd.check_conservation()
        names = [f"t{i}" for i in range(len(weights))]
        shares, total = _contended_shares(sim.admitted_order, n_each,
                                          names)
        if total < 50:      # degenerate draw: too few contended slots
            return
        total_w = sum(weights)
        for i, w in enumerate(weights):
            assert shares[f"t{i}"] == pytest.approx(w / total_w,
                                                    abs=0.15), \
                (weights, shares)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_conservation_under_any_interleaving():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_wfq_shares_any_weights():
        pass


def test_token_bucket_caps_admission_rate():
    """A rate-capped tenant admits at most burst + rate * T frames no
    matter how hard it offers."""
    fd = FrontDoor()
    fd.add_tenant(Tenant("capped", rate_fps=10.0, burst=5.0, queue_cap=8))
    sim = _MiniSim(fd, service_s=0.001, capacity_fps=1000.0)
    for j in range(300):
        sim.offer(j * 0.01, "capped")     # 100 fps offered for 3 s
    sim.run()
    row = fd.check_conservation()["capped"]
    # bucket ceiling: 5 burst + 10/s * 3 s, plus the queue drain tail
    assert row["admitted"] <= 5 + 10 * 3 + 8 + 1
    assert row["offered"] == 300


def test_class_shed_order_under_aggregate_pressure():
    """When the aggregate cap bites, bulk is preempted first and the
    interactive class never sheds."""
    fd = FrontDoor(total_queue_cap=12)
    fd.add_tenant(Tenant("gold", priority=0, weight=4.0, queue_cap=64))
    fd.add_tenant(Tenant("bulk", priority=2, weight=1.0, queue_cap=64))
    sim = _MiniSim(fd, service_s=1.0, capacity_fps=1.0)  # ~frozen pipe
    for j in range(40):                    # bulk floods first
        sim.offer(0.001 + j * 1e-4, "bulk")
    for j in range(10):                    # gold arrives into the jam
        sim.offer(0.01 + j * 1e-4, "gold")
    sim.run()
    ledger = fd.check_conservation()
    assert ledger["gold"]["shed"] == 0
    assert ledger["bulk"]["shed"] > 0


# ---------------------------------------------------------------------------
# end-to-end pins on the real engine
# ---------------------------------------------------------------------------
def _sig(rep):
    return (rep.frames_in, rep.frames_out, rep.sim_time, rep.last_out_t,
            tuple(rep.latencies), tuple(sorted(rep.hedges.items())),
            tuple(sorted(rep.faults.items())))


def test_single_tenant_feed_is_bit_identical():
    """The trivial door (one tenant, no caps) is a pure pass-through:
    feed() matches the direct-ingest path float for float."""
    e1 = build_replicated_engine("ncs2", 3)
    e1.feed(60, interval_s=0.0)
    r1 = e1.run(until=float("inf"))
    e2 = build_replicated_engine("ncs2", 3)
    for _ in range(60):
        e2._push_event(0.0, e2._frame_arrival, None, 150528)
    r2 = e2.run(until=float("inf"))
    assert _sig(r1) == _sig(r2)
    assert not e1._fd.engaged          # and the door never engaged


def test_fleet_overload_is_class_ordered():
    """2x offered load: interactive holds goodput 1.0 and its SLO p99;
    bulk sheds; nothing is lost in-pipeline; conservation holds."""
    rep = run_fleet_sweep(2.0, duration_s=3.0)
    assert rep.lost == 0
    fd = rep.frontdoor
    t = fd["tenants"]
    assert t["field_ops"]["goodput"] == 1.0
    assert t["field_ops"]["latency"]["p99"] <= t["field_ops"]["slo_s"]
    assert t["backfill"]["shed"] > 0
    gp = [t[n]["goodput"] for n in ("field_ops", "recon", "backfill")]
    assert gp == sorted(gp, reverse=True)
    for row in t.values():            # summary() already ran conservation
        assert row["offered"] == (row["admitted"] + row["shed"]
                                  + row["queued"])


def test_slo_tightens_hedge_deadline():
    """The same replicated scenario with a tight tenant SLO arms hedges
    earlier (or as early) and never later than the uncoupled engine."""
    base = build_replicated_engine("ncs2", 4, mode="shard", hedge=True)
    base.feed(80, interval_s=0.01)
    rb = base.run(until=float("inf"))

    fd = FrontDoor()
    fd.add_tenant(Tenant("tight", slo_s=0.05))
    eng = build_replicated_engine("ncs2", 4, mode="shard", hedge=True,
                                  frontdoor=fd)
    eng.feed_tenant("tight", 80, interval_s=0.01, frame_bytes=150528)
    rt = eng.run(until=float("inf"))
    assert rt.frames_out == rb.frames_out == 80
    assert sum(rt.hedges.values()) >= sum(rb.hedges.values())


def test_brownout_parks_then_recovers():
    """Capacity pinned to zero parks arrivals in bounded queues; when it
    recovers, the backlog drains and conservation still holds."""
    fd = FrontDoor(max_poll_s=0.05)
    fd.add_tenant(Tenant("a", queue_cap=64))
    fd.add_tenant(Tenant("b", queue_cap=64))   # two tenants: door engaged
    sim = _MiniSim(fd, service_s=0.005, capacity_fps=0.0)
    for j in range(30):
        sim.offer(j * 0.001, "a")
    # recovery: capacity comes back at t = 0.5
    sim._push(0.5, lambda: setattr(sim, "capacity", 100.0))
    sim.run()
    ledger = fd.check_conservation()
    assert ledger["a"]["admitted"] == 30       # nothing shed, all drained
    assert ledger["a"]["queued"] == 0
    assert fd.summary()["tenants"]["a"]["avg_wait_s"] > 0.0


# ---------------------------------------------------------------------------
# gallery tenancy: per-tenant shard views
# ---------------------------------------------------------------------------
def _tenant_gallery(n_shards=2, seed=0, dtype="fp32"):
    import numpy as np
    from repro.crypto import SecureGallery
    rng = np.random.default_rng(seed)
    g = SecureGallery(64, seed=7, n_shards=n_shards, match_dtype=dtype)
    a = rng.normal(size=(12, 64)).astype(np.float32)
    b = rng.normal(size=(9, 64)).astype(np.float32)
    g.enroll(a, [f"a{i}" for i in range(12)], tenant="alpha")
    g.enroll(b, [f"b{i}" for i in range(9)], tenant="beta")
    return g, a, b


def test_gallery_tenant_isolation():
    """A tenant-scoped match never returns another tenant's labels, and
    matches the brute-force oracle over that tenant's rows only."""
    import numpy as np
    g, a, b = _tenant_gallery()
    q = a[3:4] + 0.01
    labels, scores = g.match(q, k=5, tenant="alpha")
    assert all(l.startswith("a") for l in labels[0])
    labels_b, _ = g.match(q, k=5, tenant="beta")
    assert all(l.startswith("b") for l in labels_b[0])
    # unscoped search sees everything (the pre-tenancy behaviour)
    labels_all, _ = g.match(q, k=21)
    assert {l[0] for l in labels_all[0]} == {"a", "b"}


def test_gallery_tenant_scope_survives_reshard_and_failover():
    import numpy as np
    g, a, b = _tenant_gallery(n_shards=3)
    q = b[2:3]
    before, _ = g.match(q, k=3, tenant="beta")
    g.reshard(2)
    after, _ = g.match(q, k=3, tenant="beta")
    assert list(before[0]) == list(after[0])
    g.failover_shard(0)
    after2, _ = g.match(q, k=3, tenant="beta")
    assert list(before[0]) == list(after2[0])
    assert all(l.startswith("b") for l in after2[0])


def test_gallery_tenant_ann_path_stays_scoped():
    import numpy as np
    g, a, b = _tenant_gallery(n_shards=2)
    g.build_ann_index(n_cells=4)
    q = a[5:6]
    labels, _ = g.match(q, k=3, mode="ann", nprobe=4, tenant="alpha")
    assert all(l.startswith("a") for l in labels[0])


def test_gallery_unknown_or_empty_tenant_raises():
    import numpy as np
    import pytest as _pt
    g, a, b = _tenant_gallery()
    with _pt.raises(KeyError):
        g.match(a[:1], k=1, tenant="nobody")
    assert not g.has_tenant("nobody")
    assert g.has_tenant("alpha")
