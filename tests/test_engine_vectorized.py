"""Epoch-core equivalence: the vectorized engine must be a bitwise
drop-in for the heap core.

The epoch core changes *how* events execute (cohort drain + array
bookkeeping), never *which* events execute or in what order — so every
test here runs the identical scenario on both cores and asserts the full
observable trace matches exactly: completion counts, the per-frame
latency sample list (float-for-float), simulated time, and the
hedge/fault counters.  Scenarios are chosen so every engine subsystem
the vectorization touched actually fires: weighted dispatch over a
≥16-lane group (the argmin fast path), hedging with deadline
cancellation inside a cohort, and a full chaos storm (crash / hang /
hub loss / link flap / corruption) with quarantine and retries.
"""
from __future__ import annotations

import pytest

from repro.bus import TABLE1
from repro.core.cartridge import DeviceModel
from repro.runtime import replication as R
from repro.runtime import build_lane_sweep_engine
from repro.runtime.engine import ENGINE_CORES, VECTOR_PICK_MIN
from repro.runtime.faults import FaultPlan


def trace(rep):
    """The full observable outcome of a run, exact-equality comparable."""
    return (rep.frames_in, rep.frames_out, rep.sim_time,
            tuple(rep.latencies), tuple(sorted(rep.hedges.items())))


def fault_counters(rep):
    return {k: v for k, v in rep.faults.items() if not isinstance(v, dict)}


def run_both(build, *args, **kw):
    out = {}
    for core in ENGINE_CORES:
        eng = build(*args, core=core, **kw)
        out[core] = eng.run(until=float("inf"))
    return out


# ---------------------------------------------------------------------------
# Table 1 bit-identity: the paper's headline numbers must not move
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("device", sorted(TABLE1))
def test_table1_broadcast_bit_identical(device):
    for n in (1, 3, 5):
        a = R.run_replicated(device, n, "broadcast", core="heap")
        b = R.run_replicated(device, n, "broadcast", core="epoch")
        assert a.throughput() == b.throughput()   # exact, not approx
        assert trace(a) == trace(b)


# ---------------------------------------------------------------------------
# Mixed stragglers + hedging: deadline cancel inside cohorts
# ---------------------------------------------------------------------------
def _mixed_hedged(core):
    fast = dict(name="ncs2", service_s=0.012)
    strag = dict(name="ncs2_degraded", service_s=0.012,
                 jitter_p=0.05, jitter_mult=10.0)
    eng = R.build_mixed_engine(
        [DeviceModel(**fast), DeviceModel(**fast), DeviceModel(**strag)],
        hedge=True, core=core)
    eng.feed(600, 0.005)
    return eng


def test_mixed_straggler_hedge_trace_equivalence():
    reps = run_both(_mixed_hedged)
    assert trace(reps["heap"]) == trace(reps["epoch"])
    # the scenario must actually exercise hedging or the test is vacuous
    assert reps["heap"].hedges["issued"] > 0


# ---------------------------------------------------------------------------
# Chaos storm: every fault kind, quarantine + retry, zero loss
# ---------------------------------------------------------------------------
def _storm():
    return FaultPlan.storm(seed=11, horizon_s=4.0,
                           lanes=R.chaos_lane_names(),
                           hubs=[0, 1], links=[(0, 1)],
                           crash_rate=1.5, hang_rate=0.8, hub_loss_rate=0.3,
                           link_down_rate=0.8, corrupt_p=0.01)


def test_chaos_storm_trace_equivalence():
    a = R.run_chaos(_storm(), core="heap")
    b = R.run_chaos(_storm(), core="epoch")
    assert trace(a) == trace(b)
    assert fault_counters(a) == fault_counters(b)
    # the storm must inject real faults, and recovery must stay lossless
    assert fault_counters(a)["injected"] > 0
    assert a.lost == 0 and b.lost == 0
    assert fault_counters(a)["duplicates"] == 0


# ---------------------------------------------------------------------------
# Fleet-scale sweep group: the argmin fast path vs the scalar scan
# ---------------------------------------------------------------------------
def test_lane_sweep_trace_equivalence_vector_pick():
    n_lanes = 64
    assert n_lanes >= VECTOR_PICK_MIN   # the fast path actually engages
    reps = {}
    for core in ENGINE_CORES:
        eng = build_lane_sweep_engine(n_lanes, core=core)
        eng.feed(2000, interval_s=0.0)
        reps[core] = eng.run(until=float("inf"))
    assert trace(reps["heap"]) == trace(reps["epoch"])
    assert reps["epoch"].frames_out == 2000


def test_vector_pick_matches_scalar_min():
    # same engine, both pick implementations: force the scalar path by
    # shrinking below the gate and compare against a wide group
    for n in (VECTOR_PICK_MIN, VECTOR_PICK_MIN + 7):
        a = build_lane_sweep_engine(n, core="epoch")
        a.feed(500, interval_s=0.0)
        ra = a.run(until=float("inf"))
        b = build_lane_sweep_engine(n, core="heap")
        b.feed(500, interval_s=0.0)
        rb = b.run(until=float("inf"))
        assert tuple(ra.latencies) == tuple(rb.latencies)


# ---------------------------------------------------------------------------
# Profiling hook
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("core", ENGINE_CORES)
def test_profile_hook_populates_phases(core):
    eng = build_lane_sweep_engine(32, core=core, profile=True)
    eng.feed(500, interval_s=0.0)
    rep = eng.run(until=float("inf"))
    # phase timings surface through the metrics registry (engine.profile.*);
    # keyed access on report.profile is deprecated (shim warns)
    m = rep.metrics()
    assert m["engine.profile.core"] == core
    for key in ("dispatch_s", "service_s", "control_s", "bookkeeping_s"):
        assert m[f"engine.profile.{key}"] >= 0.0
    assert m["engine.profile.events.dispatch"] > 0
    assert m["engine.profile.events.service"] > 0
    # wall time actually accumulated somewhere
    assert m["engine.profile.dispatch_s"] + m["engine.profile.service_s"] \
        + m["engine.profile.control_s"] > 0.0


def test_profile_keyed_access_deprecated():
    eng = build_lane_sweep_engine(8, profile=True)
    eng.feed(50, interval_s=0.0)
    rep = eng.run(until=float("inf"))
    with pytest.warns(DeprecationWarning):
        assert rep.profile["core"] == "epoch"
    with pytest.warns(DeprecationWarning):
        rep.profile.get("dispatch_s")


def test_profile_off_by_default():
    eng = build_lane_sweep_engine(8)
    eng.feed(50, interval_s=0.0)
    rep = eng.run(until=float("inf"))
    assert rep.profile == {}


def test_profile_does_not_change_results():
    a = build_lane_sweep_engine(32, profile=True)
    a.feed(500, interval_s=0.0)
    b = build_lane_sweep_engine(32, profile=False)
    b.feed(500, interval_s=0.0)
    assert trace(a.run(until=float("inf"))) == \
        trace(b.run(until=float("inf")))


def test_invalid_core_rejected():
    with pytest.raises(ValueError):
        build_lane_sweep_engine(4, core="quantum")


# ---------------------------------------------------------------------------
# Event queue satellites: threshold compaction + cohort drain (plain
# unit tests — the hypothesis interleavings live in
# test_event_queue_properties.py and need the optional dependency)
# ---------------------------------------------------------------------------
def _noop():
    pass


def test_heap_threshold_compaction():
    """Sustained cancellation must rebuild the heap instead of letting
    dead entries dominate every push/pop."""
    from repro.runtime.events import HeapEventQueue
    q = HeapEventQueue()
    hs = [q.push(float(i), _noop, ()) for i in range(100)]
    for h in hs[:80]:
        q.cancel(h)
    assert q.compactions >= 1, "dead majority never triggered a rebuild"
    assert q.dead_peak > 0
    # invariant: after any cancel, dead entries never outnumber live ones
    assert len(q._dead) <= len(q._heap) - len(q._dead)
    # the survivors pop in order, unharmed by the rebuild
    assert [q.pop()[0] for _ in range(len(q))] == [float(i)
                                                  for i in range(80, 100)]
    assert q.cancelled == 80 and q.popped == 20


def test_cohort_drain_and_fire_semantics():
    from repro.runtime.events import HeapEventQueue, ListEventQueue
    for cls in (HeapEventQueue, ListEventQueue):
        q = cls()
        a = q.push(1.0, _noop, ())
        b = q.push(1.0, _noop, ())
        c = q.push(1.0, _noop, ())
        d = q.push(2.0, _noop, ())
        cohort = q.pop_cohort()
        assert [e[1] for e in cohort] == [a, b, c]   # seq (FIFO) order
        assert q.popped == 0                          # fires count, drains don't
        assert q.fire(a) is True
        # same-instant cancel after the drain: b must not execute
        assert q.cancel(b) is True
        assert q.fire(b) is False
        assert q.fire(c) is True
        assert q.popped == 2 and q.cancelled == 1
        assert q.peek_time() == 2.0 and len(q) == 1
        assert [e[1] for e in q.pop_cohort()] == [d]
        assert q.fire(d) is True
