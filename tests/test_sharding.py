"""Sharding-rule machinery + a miniature dry-run (8 fake devices) so the
AOT path is covered by pytest without the full 512-device sweep."""
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import (FSDP_RULES, RULE_SETS, TP_RULES, logical_to_pspec)


class _FakeMesh:
    def __init__(self, shape_map):
        self._m = shape_map

    @property
    def axis_names(self):
        return tuple(self._m)

    @property
    def shape(self):
        return self._m


MESH = _FakeMesh({"data": 4, "model": 2})


def test_pspec_basic_mapping():
    spec = logical_to_pspec(("batch", "seq", "embed"), TP_RULES, MESH,
                            (8, 16, 32))
    assert spec == P("data")          # pod missing -> dropped; seq/embed None


def test_pspec_drops_nondividing():
    spec = logical_to_pspec(("vocab", "embed"), TP_RULES, MESH, (3, 32))
    assert spec == P()                # 3 % 2 != 0 -> unsharded


def test_pspec_no_axis_reuse():
    # both vocab and mlp map to "model": second use must drop
    spec = logical_to_pspec(("vocab", "mlp"), TP_RULES, MESH, (4, 4))
    assert spec == P("model")


def test_fsdp_shards_weights_two_ways():
    spec = logical_to_pspec(("embed", "mlp"), FSDP_RULES, MESH, (8, 8))
    assert spec == P("data", "model")


def test_all_rule_sets_resolve_every_axis():
    axes = ["batch", "seq", "embed", "vocab", "heads", "kv_heads", "mlp",
            "experts", "expert_mlp", "cache_seq", "cache_batch", "layers",
            "embed_table"]
    for name, rules in RULE_SETS.items():
        for ax in axes:
            assert ax in rules, (name, ax)


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import base as cb
from repro.launch import specs as sp
from repro.launch.steps import make_train_step, make_serve_step
from repro.optim import adamw, constant
from repro.optim.optimizers import state_specs
from repro.sharding import RULE_SETS, use_rules, logical_to_pspec, spec_map
from repro.models import model as mdl
from jax.sharding import NamedSharding

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = cb.smoke("tinyllama-1.1b")
rules = RULE_SETS["tp"]
params = sp.param_structs(cfg, mesh, rules)
opt = adamw(constant(1e-3))
ost = spec_map(lambda s: jax.ShapeDtypeStruct(
    s.shape, s.dtype or jnp.float32,
    sharding=NamedSharding(mesh, logical_to_pspec(s.axes, rules, mesh, s.shape))),
    state_specs(opt, mdl.param_specs(cfg)))
batch = sp.batch_specs(cfg, 64, 8, with_labels=True, mesh=mesh, rules=rules)
with use_rules(rules, mesh):
    c = jax.jit(make_train_step(cfg, opt, n_micro=2),
                donate_argnums=(0, 1)).lower(
        params, ost, batch, jax.ShapeDtypeStruct((), jnp.int32)).compile()
ca = c.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca   # jax version compat
assert ca.get("flops", 0) > 0
dec = sp.input_specs(cfg, cb.ShapeSpec("d", 128, 8, "decode"), mesh, rules)
with use_rules(rules, mesh):
    c2 = jax.jit(make_serve_step(cfg), donate_argnums=(3,)).lower(
        params, dec["token"], dec["pos"], dec["cache"]).compile()
print("MINI_DRYRUN_OK")
"""


def test_mini_dryrun_8_devices():
    """Full AOT path (train + decode) on an 8-device fake mesh."""
    r = subprocess.run([sys.executable, "-c", MINI_DRYRUN],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "MINI_DRYRUN_OK" in r.stdout, r.stderr[-3000:]
