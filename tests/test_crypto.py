"""Template protection + encrypted gallery behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.crypto import (KeyedRotation, SecureGallery, cosine_scores,
                          decrypt_array, decrypt_bytes, encrypt_array,
                          encrypt_bytes)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # tier-1 must run without hypothesis installed
    HAVE_HYPOTHESIS = False


def test_rotation_preserves_cosine_exactly():
    rot = KeyedRotation(128, seed=3)
    a = jax.random.normal(jax.random.PRNGKey(0), (17, 128))
    b = jax.random.normal(jax.random.PRNGKey(1), (50, 128))
    raw = cosine_scores(a, b)
    prot = cosine_scores(rot.protect(a), rot.protect(b))
    np.testing.assert_allclose(np.asarray(raw), np.asarray(prot),
                               atol=2e-5)


def test_rotation_hides_templates():
    """Protected template far from raw (rotation is not near-identity)."""
    rot = KeyedRotation(64, seed=9)
    t = jax.random.normal(jax.random.PRNGKey(2), (10, 64))
    tp = rot.protect(t)
    cos = np.diag(np.asarray(cosine_scores(t, tp)))
    assert np.all(np.abs(cos) < 0.6), cos


def test_rotation_invertible_with_key():
    rot = KeyedRotation(96, seed=4)
    t = jax.random.normal(jax.random.PRNGKey(3), (5, 96))
    back = rot.unprotect(rot.protect(t))
    np.testing.assert_allclose(np.asarray(t), np.asarray(back), atol=1e-4)


def test_stream_cipher_roundtrip_and_diffusion():
    key = jax.random.PRNGKey(42)
    data = b"subject-4711:watchlist-alpha" * 33 + b"x"
    enc = encrypt_bytes(key, data)
    assert decrypt_bytes(key, enc) == data
    # ciphertext should look nothing like plaintext
    overlap = np.mean(enc[: len(data)] == np.frombuffer(data, np.uint8))
    assert overlap < 0.05
    # wrong key fails to decrypt
    bad = decrypt_bytes(jax.random.PRNGKey(43), enc)
    assert bad != data


def test_encrypt_array_roundtrip():
    key = jax.random.PRNGKey(7)
    x = np.random.default_rng(0).normal(size=(13, 8)).astype(np.float32)
    np.testing.assert_array_equal(decrypt_array(key, encrypt_array(key, x)), x)


def test_secure_gallery_end_to_end():
    rng = np.random.default_rng(1)
    dim, n = 64, 300
    gallery = rng.normal(size=(n, dim)).astype(np.float32)
    labels = [f"id{i}" for i in range(n)]
    store = SecureGallery(dim, seed=5)
    store.enroll(gallery, labels)
    # query = noisy copies of subjects 17 and 99
    q = gallery[[17, 99]] + 0.05 * rng.normal(size=(2, dim)).astype(np.float32)
    got, scores = store.match(q, k=3)
    assert got[0, 0] == "id17" and got[1, 0] == "id99"
    assert np.all(np.diff(np.asarray(scores), axis=1) <= 1e-6)  # descending


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(data=st.binary(min_size=0, max_size=257),
           seed=st.integers(0, 2**31 - 1))
    def test_stream_cipher_roundtrip_property(data, seed):
        """encrypt/decrypt is the identity for ANY payload: empty, odd
        (non-multiple-of-4) lengths crossing the uint32 padding path, and
        every seed."""
        key = jax.random.PRNGKey(seed)
        assert decrypt_bytes(key, encrypt_bytes(key, data)) == data

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(1, 64), seed=st.integers(0, 2**31 - 2))
    def test_stream_cipher_rekey_mismatch_property(n, seed):
        """Decrypting under a rotated key never round-trips (revocation
        actually revokes) — for any non-empty payload."""
        data = bytes(range(256))[:n] * 2
        enc = encrypt_bytes(jax.random.PRNGKey(seed), data)
        assert decrypt_bytes(jax.random.PRNGKey(seed + 1), enc) != data

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(0, 37))
    def test_stream_cipher_ciphertext_length_is_padded(n):
        """Blob layout: payload padded to a uint32 boundary + 1 pad byte."""
        key = jax.random.PRNGKey(0)
        enc = encrypt_bytes(key, b"z" * n)
        assert len(enc) == n + ((-n) % 4) + 1


def test_gallery_rekey_revokes_but_preserves_matching():
    rng = np.random.default_rng(2)
    dim, n = 32, 100
    g = rng.normal(size=(n, dim)).astype(np.float32)
    store = SecureGallery(dim, seed=11)
    store.enroll(g, list(range(n)))
    before = store.protected_gallery()
    store.rekey(new_seed=12)
    after = store.protected_gallery()
    # protected representations change entirely...
    assert float(jnp.max(jnp.abs(before - after))) > 0.1
    # ...but matching still works
    got, _ = store.match(g[[5]], k=1)
    assert got[0, 0] == 5
