"""Hypothesis property tests for bus accounting invariants, run against
BOTH arbitration models through one shared suite: the single ``SharedBus``
and the hub-partitioned ``FabricRouter`` (whose aggregate stats must obey
the same identities summed over hubs + links).

Invariants pinned:

  * accounting identity — ``busy_s == wire_s + arbitration_s + overhead``
    where overhead is each domain's per-transfer fixed cost times its
    transfer count;
  * ``free_at`` monotonicity — every FIFO domain's ``free_at`` never
    decreases, and every returned completion is >= its request time;
  * ``suppress`` is pure accounting — it never mutates transfer counts,
    payload bytes, busy time, or any ``free_at``.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as stn

from repro.bus import BusParams, FabricRouter, LinkParams, SharedBus

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")

N_HUBS = 3
HUB_PARAMS = BusParams("p", bandwidth=100e6, base_overhead_s=2e-4,
                       arbitration_s=1e-4)
LINK = LinkParams(bandwidth=300e6, overhead_s=1e-4)


def _make_shared():
    return SharedBus(HUB_PARAMS)


def _make_fabric():
    return FabricRouter([HUB_PARAMS] * N_HUBS, link=LINK)


MAKERS = [pytest.param(_make_shared, id="shared_bus"),
          pytest.param(_make_fabric, id="fabric_router")]


# one request: (inter-request gap, nbytes, n_endpoints, src hub, dst hub);
# SharedBus ignores the hub coordinates, the router routes on them
requests = stn.lists(
    stn.tuples(stn.floats(0.0, 0.05, allow_nan=False),
               stn.integers(1, 400_000),
               stn.integers(1, 6),
               stn.integers(0, N_HUBS - 1),
               stn.integers(0, N_HUBS - 1)),
    min_size=1, max_size=40)


def _drive(bus, seq):
    """Replay a request sequence; returns the completion times."""
    t, dones = 0.0, []
    for gap, nbytes, n_end, src, dst in seq:
        t += gap
        if isinstance(bus, FabricRouter):
            dones.append(bus.transfer(t, nbytes, n_end, src=src, dst=dst,
                                      dst_endpoints=n_end))
        else:
            dones.append(bus.transfer(t, nbytes, n_end))
    return dones


def _domains(bus):
    """Every FIFO domain inside a bus-like object, with its per-transfer
    fixed overhead (the piece of the accounting identity that is not wire
    or arbitration time)."""
    if isinstance(bus, FabricRouter):
        return [(h.bus, h.bus.p.base_overhead_s) for h in bus.hubs] + \
            [(lk, lk.p.overhead_s) for lk in bus._links.values()]
    return [(bus, bus.p.base_overhead_s)]


def _raw_totals(bus):
    """(busy, wire, arbitration, expected_overhead) from unrounded
    attributes — ``stats()`` rounds to 6 decimals, too coarse here."""
    busy = wire = arb = overhead = 0.0
    for dom, per_transfer in _domains(bus):
        busy += dom.busy_s
        wire += dom.wire_s
        arb += getattr(dom, "arbitration_s_total", 0.0)
        overhead += per_transfer * dom.transfers
    return busy, wire, arb, overhead


@pytest.mark.parametrize("make", MAKERS)
@given(seq=requests)
def test_accounting_identity(make, seq):
    bus = make()
    _drive(bus, seq)
    busy, wire, arb, overhead = _raw_totals(bus)
    assert busy == pytest.approx(wire + arb + overhead, rel=1e-9, abs=1e-12)


@pytest.mark.parametrize("make", MAKERS)
@given(seq=requests)
def test_free_at_monotone_and_completions_causal(make, seq):
    bus = make()
    t, frees = 0.0, {}
    for gap, nbytes, n_end, src, dst in seq:
        t += gap
        if isinstance(bus, FabricRouter):
            done = bus.transfer(t, nbytes, n_end, src=src, dst=dst)
        else:
            done = bus.transfer(t, nbytes, n_end)
        assert done >= t                    # causality
        for dom, _ in _domains(bus):
            prev = frees.get(id(dom), 0.0)
            assert dom.free_at >= prev      # FIFO never rewinds
            frees[id(dom)] = dom.free_at


@pytest.mark.parametrize("make", MAKERS)
@given(seq=requests, sup=stn.lists(
    stn.tuples(stn.integers(1, 400_000),
               stn.integers(0, N_HUBS - 1),
               stn.integers(0, N_HUBS - 1)),
    min_size=1, max_size=10))
def test_suppress_never_mutates_transfer_accounting(make, seq, sup):
    bus = make()
    _drive(bus, seq)
    if isinstance(bus, FabricRouter):
        # materialize every link up front so suppression can't change the
        # domain list between the before/after snapshots
        for a in range(N_HUBS):
            for b in range(a + 1, N_HUBS):
                bus.link(a, b)
    before = (_raw_totals(bus),
              [(dom.transfers, dom.bytes_moved, dom.free_at)
               for dom, _ in _domains(bus)])
    for nbytes, src, dst in sup:
        if isinstance(bus, FabricRouter):
            bus.suppress(nbytes, src=src, dst=dst, t=0.0)
        else:
            bus.suppress(nbytes)
    after = (_raw_totals(bus),
             [(dom.transfers, dom.bytes_moved, dom.free_at)
              for dom, _ in _domains(bus)])
    assert before == after
    assert bus.suppressed_transfers == len(sup)
