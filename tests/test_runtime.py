"""VDiSK runtime behaviour: typed chaining, hot-swap, backpressure,
zero-loss buffering — validated against the paper's §4.2 numbers."""
import numpy as np
import pytest

from repro.bus import BusParams, SharedBus, calibrated
from repro.core import messages as msg
from repro.core.cartridge import Cartridge, DeviceModel, FnCartridge, PassThrough
from repro.runtime import CapabilityRegistry, StreamEngine, validate_chain
from repro.runtime.engine import REMOVE_PAUSE_S


def _cart(name, service_s=0.03, consumes=None, produces=None, load_s=1.5):
    return FnCartridge(
        name, lambda p, x: x,
        consumes or msg.MessageSpec(msg.IMAGE_FRAME),
        produces or msg.MessageSpec(msg.IMAGE_FRAME),
        device=DeviceModel(service_s=service_s, load_s=load_s),
    )


def _engine(n_stages=3, service_s=0.03, queue_cap=8):
    reg = CapabilityRegistry()
    for i in range(n_stages):
        reg.insert(i, _cart(f"stage{i}", service_s))
    bus = SharedBus(BusParams("test", bandwidth=400e6,
                              base_overhead_s=1e-4, arbitration_s=2e-4))
    return StreamEngine(reg, bus, queue_cap=queue_cap), reg


# -- typed chaining -----------------------------------------------------------
def test_type_mismatch_rejected():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("det", produces=msg.MessageSpec(msg.BBOXES)))
    reg.insert(1, _cart("ocr", consumes=msg.MessageSpec(msg.TOKENS)))
    bus = SharedBus(BusParams("t"))
    with pytest.raises(msg.TypeError_):
        StreamEngine(reg, bus)


def test_chain_in_slot_order():
    reg = CapabilityRegistry()
    reg.insert(2, _cart("c"))
    reg.insert(0, _cart("a"))
    reg.insert(1, _cart("b"))
    assert [c.name for c in reg.chain()] == ["a", "b", "c"]


# -- pipeline latency (paper: sum of stages + ~5% handoff) ---------------------
def test_pipeline_latency_sum_plus_small_overhead():
    eng, _ = _engine(3, service_s=0.03)
    eng.feed(50, interval_s=0.2)  # slow feed: no queueing
    rep = eng.run(until=30)
    assert rep.frames_out == 50
    lat = rep.mean_latency()
    assert 0.09 <= lat <= 0.105, lat  # 3 x 30ms + <= ~5-10% handoff


def test_pipelined_throughput_not_sum():
    """Paper §4.1: '500% more compute only slows down by 50%' — a 5-stage
    chain streams at ~stage rate, not 1/(5 x service)."""
    eng, _ = _engine(5, service_s=0.03)
    eng.feed(200, interval_s=0.03)
    rep = eng.run(until=60)
    thr = rep.frames_out / (rep.latencies and max(1e-9, rep.sim_time) or 1)
    assert rep.frames_out == 200
    # serial processing would take 200 * 0.15s = 30s; pipelined ~6s
    assert rep.sim_time < 12.0, rep.sim_time


# -- hot-swap ------------------------------------------------------------------
def test_remove_bypasses_and_buffers_zero_loss():
    """Same-type neighbors: the chain simply shortens (paper: 'bridge the
    gap if the pipeline can continue without that function')."""
    eng, reg = _engine(3, service_s=0.02)
    eng.feed(100, interval_s=0.05)
    eng.schedule_remove(1.0, slot=1)
    rep = eng.run(until=30)
    assert rep.frames_out == 100, f"lost {rep.lost}"
    assert any("remove" in r for _, _, r in rep.downtime)
    # paper: ~0.5 s pause on removal
    d = rep.total_downtime()
    assert REMOVE_PAUSE_S <= d <= REMOVE_PAUSE_S + 0.2, d
    assert 1 not in reg.slots
    assert [c.name for c in reg.chain()] == ["stage0", "stage2"]


def test_remove_incompatible_halts_alerts_and_recovers_on_insert():
    """Type-incompatible gap: engine halts with an operator alert, buffers
    everything, and resumes (zero loss) once a compatible cartridge is
    inserted (paper: 'triggers an alert for operator intervention')."""
    reg = CapabilityRegistry()
    reg.insert(0, _cart("det", produces=msg.MessageSpec(msg.BBOXES)))
    reg.insert(1, _cart("embed", consumes=msg.MessageSpec(msg.BBOXES),
                        produces=msg.MessageSpec(msg.EMBEDDING)))
    reg.insert(2, _cart("match", consumes=msg.MessageSpec(msg.EMBEDDING),
                        produces=msg.MessageSpec(msg.MATCH_RESULT)))
    bus = SharedBus(BusParams("t", base_overhead_s=1e-4))
    eng = StreamEngine(reg, bus)
    eng.feed(60, interval_s=0.05)
    eng.schedule_remove(1.0, slot=1)
    replacement = _cart("embed2", consumes=msg.MessageSpec(msg.BBOXES),
                        produces=msg.MessageSpec(msg.EMBEDDING))
    eng.schedule_insert(3.0, slot=1, cart=replacement)
    rep = eng.run(until=40)
    assert rep.alerts and "embed" in rep.alerts[0][1]
    assert rep.frames_out == 60, f"lost {rep.lost}"
    # the halt window (~2 s) is recorded as downtime
    halts = [d for d in rep.downtime if "halted" in d[2]]
    assert halts and 1.8 <= halts[0][1] - halts[0][0] <= 2.2
    assert [c.name for c in reg.chain()] == ["det", "embed2", "match"]


def test_insert_pause_dominated_by_model_load():
    eng, reg = _engine(2, service_s=0.02)
    eng.feed(100, interval_s=0.05)
    cart = _cart("quality", 0.02, load_s=1.5)
    eng.schedule_insert(1.5, slot=5, cart=cart)
    rep = eng.run(until=30)
    assert rep.frames_out == 100
    d = rep.total_downtime()
    # paper: ~2 s reintegration (handshake + model reload)
    assert 1.5 <= d <= 2.5, d
    assert reg.slots[5].cartridge is cart


def test_remove_then_reinsert_roundtrip():
    eng, reg = _engine(3, service_s=0.02)
    eng.feed(150, interval_s=0.04)
    victim = reg.slots[1].cartridge
    eng.schedule_remove(1.0, slot=1)
    eng.schedule_insert(3.0, slot=1, cart=_cart("stage1b", 0.02))
    rep = eng.run(until=30)
    assert rep.frames_out == 150
    assert len(rep.downtime) == 2
    assert [c.name for c in reg.chain()] == ["stage0", "stage1b", "stage2"]


# -- flow control / backpressure ----------------------------------------------
def test_backpressure_bounds_queues():
    """Slow stage 2: queues must stay bounded (no unbounded buffering)."""
    reg = CapabilityRegistry()
    reg.insert(0, _cart("fast", 0.005))
    reg.insert(1, _cart("slow", 0.05))
    bus = SharedBus(BusParams("t", base_overhead_s=1e-4))
    eng = StreamEngine(reg, bus, queue_cap=4)
    eng.feed(100, interval_s=0.005)
    rep = eng.run(until=60)
    assert rep.frames_out == 100
    slow = rep.stage_stats["slow"]
    fast = rep.stage_stats["fast"]
    assert slow.processed == 100
    # fast stage must have been throttled (blocked time accrued)
    assert fast.blocked_s > 0


# -- paper power model (§4.3) ---------------------------------------------------
def test_power_model_order_of_magnitude():
    eng, _ = _engine(5, service_s=1 / 15.0)
    eng.feed(50, interval_s=1 / 15.0)
    rep = eng.run(until=20)
    total_w = 0.0
    for name, st in rep.stage_stats.items():
        util = st.busy_s / rep.sim_time
        total_w += util * 1.8 + (1 - util) * 0.3
    host_w = 3.0
    assert 3.0 <= total_w + host_w <= 15.0  # paper: ~10 W system
