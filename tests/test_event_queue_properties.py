"""Hypothesis property tests for event-queue cancel semantics.

The hedged-dispatch and chaos-recovery paths cancel events aggressively
(deadline timers, watchdogs, in-flight service cycles of crashed lanes),
so the cancel contract must hold under any interleaving of push, cancel,
and pop — on both the heap core and the linear-scan reference:

* ``cancel`` after the event fired (or was already cancelled) returns
  False and changes nothing;
* a cancelled event never pops;
* ``len`` always equals live events (pushed - popped - cancelled);
* the ``pushed``/``popped``/``cancelled`` counters never corrupt — a
  failed pop or no-op cancel must not move them;
* pop order (min time, FIFO on ties) is identical across both queues.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.runtime.events import HeapEventQueue, ListEventQueue

settings.register_profile("ci", max_examples=60, deadline=None)
settings.load_profile("ci")

QUEUES = (HeapEventQueue, ListEventQueue)

# an op is ("push", t) | ("cancel", i) | ("pop",): cancel targets the
# i-th handle ever pushed (mod count), so cancels hit fired, pending,
# and already-cancelled events alike
OPS = st.lists(
    st.one_of(
        st.tuples(st.just("push"),
                  st.floats(0.0, 100.0, allow_nan=False)),
        st.tuples(st.just("cancel"), st.integers(0, 200)),
        st.tuples(st.just("pop")),
    ),
    max_size=120)


def _noop():
    pass


def _run_ops(q, ops):
    """Drive one queue through the op list; returns the pop trace as
    (t, handle) pairs plus the handle bookkeeping sets."""
    handles, fired, killed, trace = [], set(), set(), []
    for op in ops:
        if op[0] == "push":
            handles.append(q.push(op[1], _noop, ()))
        elif op[0] == "cancel":
            if not handles:
                continue
            h = handles[op[1] % len(handles)]
            ok = q.cancel(h)
            assert ok == (h not in fired and h not in killed), \
                "cancel must succeed exactly once, and never after a pop"
            if ok:
                killed.add(h)
        else:
            try:
                t, h, fn, args = q.pop()
            except IndexError:
                assert len(q) == 0, "pop failed with live events queued"
                continue
            assert h not in killed, f"cancelled event {h} popped"
            assert h not in fired, f"event {h} popped twice"
            fired.add(h)
            trace.append((t, h))
    return handles, fired, killed, trace


@pytest.mark.parametrize("cls", QUEUES, ids=lambda c: c.__name__)
@given(ops=OPS)
def test_cancel_semantics_under_any_interleaving(cls, ops):
    q = cls()
    handles, fired, killed, trace = _run_ops(q, ops)
    # len == live events, and the counters reconcile exactly
    assert len(q) == len(handles) - len(fired) - len(killed)
    assert q.pushed == len(handles)
    assert q.popped == len(fired)
    assert q.cancelled == len(killed)
    # drain: everything left must pop in (time, handle) order,
    # and no cancelled/fired event may resurface
    last = None
    while len(q):
        t, h, fn, args = q.pop()
        assert h not in killed and h not in fired
        fired.add(h)
        if last is not None:
            assert (t, h) >= last
        last = (t, h)
    assert len(fired) + len(killed) == len(handles)
    with pytest.raises(IndexError):
        q.pop()
    with pytest.raises(IndexError):
        q.peek_time()
    # the failed pop/peek moved no counter
    assert q.popped == len(fired) and q.pushed == len(handles)


@given(ops=OPS)
def test_heap_and_list_queues_agree(ops):
    """Same ops, same pop trace: the benchmark baseline really is a
    reference implementation of the engine core's discipline."""
    _, _, _, heap_trace = _run_ops(HeapEventQueue(), ops)
    _, _, _, list_trace = _run_ops(ListEventQueue(), ops)
    assert heap_trace == list_trace


@pytest.mark.parametrize("cls", QUEUES, ids=lambda c: c.__name__)
@given(ops=OPS)
def test_cohort_drain_equals_sequential_pops(cls, ops):
    """pop_cohort + fire must replay the exact pop trace: run the same
    op list on two queues, one popping one-at-a-time, one draining
    cohorts and firing each member."""
    q_pop = cls()
    _, _, _, pop_trace = _run_ops(q_pop, ops)
    q_coh = cls()
    handles, fired, killed, trace = [], set(), set(), []
    pending = []                      # drained-but-unfired cohort tail
    for op in ops:
        if op[0] == "push":
            handles.append(q_coh.push(op[1], _noop, ()))
        elif op[0] == "cancel":
            if not handles:
                continue
            h = handles[op[1] % len(handles)]
            if q_coh.cancel(h):
                killed.add(h)
        else:
            if pending:
                ev = pending.pop(0)
            else:
                try:
                    cohort = q_coh.pop_cohort()
                except IndexError:
                    continue
                ev = cohort[0]
                pending = cohort[1:]
            if q_coh.fire(ev[1]):
                fired.add(ev[1])
                trace.append((ev[0], ev[1]))
    # drain both; cancelled-while-pending events must not fire
    while pending or len(q_coh):
        if not pending:
            pending = q_coh.pop_cohort()
        ev = pending.pop(0)
        if q_coh.fire(ev[1]):
            trace.append((ev[0], ev[1]))
    while len(q_pop):
        t, h, fn, args = q_pop.pop()
        pop_trace.append((t, h))
    assert trace == pop_trace
    assert q_coh.popped == q_pop.popped


@given(ops=OPS)
def test_compaction_invariant_under_any_interleaving(ops):
    q = HeapEventQueue()
    for op in ops:
        if op[0] == "push":
            q.push(op[1], _noop, ())
        elif op[0] == "cancel" and q.pushed:
            q.cancel(op[1] % q.pushed)
        elif op[0] == "pop" and len(q):
            q.pop()
        assert len(q._dead) <= max(len(q._heap) - len(q._dead), 0)
        assert q.dead_peak >= len(q._dead)


@pytest.mark.parametrize("cls", QUEUES, ids=lambda c: c.__name__)
def test_cancel_after_pop_returns_false(cls):
    q = cls()
    h = q.push(1.0, _noop, ())
    assert q.pop()[1] == h
    assert q.cancel(h) is False       # already fired
    assert q.cancelled == 0
    h2 = q.push(2.0, _noop, ())
    assert q.cancel(h2) is True
    assert q.cancel(h2) is False      # double-cancel is a no-op
    assert q.cancelled == 1
    assert len(q) == 0
