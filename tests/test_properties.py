"""Hypothesis property tests over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as stn

from repro.bus import BusParams, SharedBus
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.crypto import KeyedRotation, decrypt_bytes, encrypt_bytes
from repro.optim import dequantize, quantize
from repro.runtime import CapabilityRegistry, StreamEngine

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# -- quantization -------------------------------------------------------------
@given(stn.lists(stn.floats(-1e4, 1e4, allow_nan=False, width=32),
                 min_size=1, max_size=400))
def test_quantize_bounded_error(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    err = jnp.abs(dequantize(quantize(x)) - x)
    bound = jnp.max(jnp.abs(x)) / 127.0 + 1e-5
    assert float(jnp.max(err)) <= float(bound)


@given(stn.integers(1, 5000))
def test_quantize_preserves_shape(n):
    x = jnp.ones((n,), jnp.float32)
    assert dequantize(quantize(x)).shape == (n,)


# -- cipher ---------------------------------------------------------------------
@given(stn.binary(min_size=0, max_size=512), stn.integers(0, 2**31 - 1))
def test_cipher_roundtrip(data, seed):
    key = jax.random.PRNGKey(seed)
    assert decrypt_bytes(key, encrypt_bytes(key, data)) == data


# -- template rotation ----------------------------------------------------------
@given(stn.integers(0, 1000))
def test_rotation_is_isometry(seed):
    rot = KeyedRotation(16, seed=seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 16))
    nx = jnp.linalg.norm(x, axis=-1)
    np_ = jnp.linalg.norm(rot.protect(x), axis=-1)
    np.testing.assert_allclose(np.asarray(nx), np.asarray(np_), rtol=1e-4)


# -- message specs ----------------------------------------------------------------
kind_st = stn.sampled_from([msg.IMAGE_FRAME, msg.BBOXES, msg.EMBEDDING])
shape_st = stn.one_of(stn.none(), stn.tuples(
    stn.one_of(stn.none(), stn.integers(1, 64)),
    stn.one_of(stn.none(), stn.integers(1, 64))))


@given(kind_st, shape_st)
def test_spec_accepts_reflexive(kind, shape):
    s = msg.MessageSpec(kind, shape)
    assert s.accepts(s)


@given(kind_st, kind_st, shape_st)
def test_spec_kind_mismatch_rejected(k1, k2, shape):
    if k1 != k2:
        assert not msg.MessageSpec(k1, shape).accepts(msg.MessageSpec(k2, shape))


# -- engine conservation -----------------------------------------------------------
@given(stn.integers(1, 4), stn.integers(1, 60),
       stn.floats(0.001, 0.05), stn.integers(0, 1))
def test_engine_never_loses_frames(n_stages, n_frames, service_s, do_swap):
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    for i in range(n_stages):
        reg.insert(i, FnCartridge(f"s{i}", lambda p, x: x, spec, spec,
                                  device=DeviceModel(service_s=service_s)))
    eng = StreamEngine(reg, SharedBus(BusParams("t", base_overhead_s=1e-4)))
    eng.feed(n_frames, interval_s=0.01)
    if do_swap and n_stages >= 2:
        eng.schedule_remove(0.2, slot=1)
    rep = eng.run(until=120)
    assert rep.frames_out == n_frames
    assert sorted(rep.latencies) is not None
    assert all(l >= 0 for l in rep.latencies)


# -- bus monotonicity ---------------------------------------------------------------
@given(stn.integers(1, 5), stn.integers(1, 5))
def test_bus_fps_decreases_with_contention(n1, n2):
    from repro.bus import calibrated, simulate_broadcast_fps
    p = calibrated("ncs2")
    f1 = simulate_broadcast_fps(p, min(n1, n2))
    f2 = simulate_broadcast_fps(p, max(n1, n2))
    assert f2 <= f1 + 1e-6


# -- histogram bulk ingest -----------------------------------------------------------
@given(stn.lists(stn.one_of(
    stn.floats(1e-7, 1e6, allow_nan=False),       # spans below lo / above hi
    stn.sampled_from([1e-6, 1e-5, 1e-3, 1.0, 10.0, 1e5]),  # exact bin edges
), min_size=0, max_size=300))
def test_record_many_matches_repeated_record(xs):
    """The vectorized completion path must fill the same bins as the
    scalar one: counts/count/min/max bit-identical, total within
    summation-order ulps (quantiles never read total)."""
    from repro.runtime import StreamingHistogram
    a, b = StreamingHistogram(), StreamingHistogram()
    for x in xs:
        a.record(x)
    b.record_many(np.asarray(xs, dtype=np.float64))
    assert np.array_equal(a.counts, b.counts)
    assert a.count == b.count
    assert a.min == b.min and a.max == b.max
    assert b.total == pytest.approx(a.total, rel=1e-12, abs=1e-12)
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        assert a.quantile(q) == b.quantile(q)
