"""Multi-hub bus fabric, live: scale past the single-bus saturation knee,
then hot-plug a whole new hub mid-stream.

The paper's §4.1 bus saturates at five accelerators — every stick shares
one arbitration domain, so past the knee ADDING devices REDUCES
aggregate FPS.  The fabric partitions the fleet across hubs (each with
its own calibrated SharedBus) and routes between them through the host:

1. Sweep a single calibrated ncs2-class bus from 1 to 16 sticks and
   watch the shard FPS curve peak and collapse.
2. Run the SAME 8- and 16-stick fleets as 2x4 / 4x4 hub fabrics:
   aggregate FPS keeps scaling because each hub arbitrates only its own
   endpoints.
3. Mid-stream, hot-plug a second hub of sticks into a saturated one-hub
   engine: no pause, zero loss, and throughput climbs once the new
   lanes finish their handshake.

Run:  PYTHONPATH=src python examples/fabric_scaling.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

from repro.runtime import (build_fabric_engine, engine_shard_fps,
                           fabric_shard_fps)


def main():
    # 1. the single-bus knee ------------------------------------------------
    print("single ncs2-class bus, shard mode (aggregate FPS):")
    single = {}
    for n in (1, 2, 4, 5, 8, 10, 12, 16):
        single[n] = engine_shard_fps("ncs2", n, n_frames=200)
        print(f"  {n:>2} sticks : {single[n]:7.1f} FPS")
    knee_n = max(single, key=single.get)
    print(f"  -> saturation knee at {knee_n} sticks "
          f"({single[knee_n]:.1f} FPS); 16 sticks is "
          f"{single[16] / single[knee_n]:.2f}x the knee\n")

    # 2. same fleets, hub-partitioned --------------------------------------
    print("hub-partitioned fabrics at equal device count:")
    for hubs, per in ((2, 4), (4, 4)):
        total = hubs * per
        fps = fabric_shard_fps("ncs2", hubs, per, n_frames=200)
        print(f"  {hubs} hubs x {per} sticks ({total} total): "
              f"{fps:7.1f} FPS  ({fps / single[total]:.2f}x the "
              f"single bus, {fps / single[knee_n]:.2f}x the knee)")
        assert fps > single[total], "fabric must beat the shared bus"
        assert fps > single[knee_n], "fabric must clear the knee"
    print()

    # 3. hot-plug a second hub mid-stream -----------------------------------
    eng = build_fabric_engine([["ncs2"] * 4, []], mode="shard")
    primary = eng.registry.slots[0].cartridge
    for i in range(4):
        eng.schedule_add_replica(1.0, slot=0,
                                 cart=primary.clone(f"late#h1r{i}"), hub=1)
    eng.feed(600, interval_s=1 / 150.0)      # past one hub's capacity
    rep = eng.run(until=600)
    assert rep.lost == 0, f"lost {rep.lost} frames"
    assert rep.total_downtime() == 0.0, "hot-plug must not pause"
    hub1 = sum(rep.stage_stats[name].processed
               for name, hub in zip(rep.groups[0]["lanes"],
                                    rep.groups[0]["hubs"]) if hub == 1)
    assert hub1 > 0, "the late hub never pulled weight"
    print(f"hot-plugged hub 1 at t=1.0s: {rep.frames_out} frames, "
          f"zero loss, no pause; late hub processed {hub1} frames "
          f"({rep.throughput():.1f} FPS aggregate)")
    print(f"per-hub bus stats: "
          f"{ {h: s['transfers'] for h, s in rep.bus['hubs'].items()} }"
          f" transfers")
    print("\nfabric_scaling OK — partitioned hubs scale where the "
          "shared bus saturates")


if __name__ == "__main__":
    main()
