"""Flight-recorder walkthrough: trace a seeded chaos storm, reconstruct
one frame's causal timeline, and export the whole run for Perfetto.

Runs the canonical chaos scenario (two-stage pipeline across two hubs,
hedged dispatch) under the seed-11 fault storm with tracing on, then:

1. prints the unified metrics snapshot (engine / hedge / faults /
   trace namespaces, stable dotted names);
2. reconstructs the full causal timeline of one frame that hit the
   recovery path — ingest -> dispatch (lane + why) -> transfers ->
   service -> retry/hedge activity -> completion;
3. writes Chrome trace-event JSON to ``trace_chaos.perfetto.json`` —
   open it at https://ui.perfetto.dev (or chrome://tracing) to see
   lanes, hubs, the bus, and the frame timeline as parallel tracks.

Self-asserting: tracing must not perturb the run (bit-identical to the
untraced replay), every span must close, and the export must land.

Run:  PYTHONPATH=src python examples/trace_chaos.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json

from repro.runtime import replication as R
from repro.runtime.faults import FaultPlan, QuarantinePolicy, RetryPolicy

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "trace_chaos.perfetto.json")


def storm():
    return FaultPlan.storm(11, 3.0, lanes=R.chaos_lane_names(),
                           hubs=[0, 1], links=[(0, 1)], crash_rate=1.2,
                           hang_rate=0.8, hub_loss_rate=0.15,
                           link_down_rate=0.5, corrupt_p=0.02)


def sig(rep):
    return (rep.frames_in, rep.frames_out, rep.sim_time,
            tuple(rep.latencies), tuple(sorted(rep.faults.items())))


def main():
    kw = dict(retry=RetryPolicy(), quarantine=QuarantinePolicy())
    rep = R.run_chaos(storm(), **kw, trace=True)
    rec = rep.trace

    # -- 1. the unified metrics snapshot ------------------------------------
    m = rep.metrics()
    print(f"metrics registry: {len(m)} names")
    for name in ("engine.frames.in", "engine.frames.out",
                 "engine.latency.p99", "faults.injected", "faults.retries",
                 "faults.quarantined", "hedge.issued",
                 "trace.spans_opened", "trace.entries"):
        print(f"  {name:28s} = {m[name]}")

    # -- 2. one frame's causal timeline -------------------------------------
    retried = sorted({e["frame"] for e in rec.entries()
                      if e["kind"] == "retry"})
    assert retried, "the storm must force at least one retry"
    fid = retried[0]
    print(f"\nframe {fid} causal timeline "
          f"(hit the retry path {len(retried)} frames did):")
    for e in rec.frame_trace(fid):
        t1 = e.get("t1")
        span = f" .. {t1*1e3:8.3f}" if t1 else ""
        args = e.get("args") or {}
        detail = ", ".join(f"{k}={v}" for k, v in sorted(args.items())
                           if not isinstance(v, float))
        print(f"  {e['t0']*1e3:8.3f}{span} ms  {e['kind']:<14} "
              f"[{e['track']}] {detail}")

    # -- 3. Perfetto export --------------------------------------------------
    n = rec.to_perfetto(OUT)
    print(f"\nwrote {n} trace events to {OUT}")
    print("open at https://ui.perfetto.dev -> Open trace file")

    # -- self-checks ---------------------------------------------------------
    s = rec.snapshot()
    assert s["spans_opened"] == s["spans_closed"], "span leak"
    assert s["open_frames"] == 0 and s["end_misses"] == 0
    doc = json.load(open(OUT))
    assert len(doc["traceEvents"]) == n
    untraced = R.run_chaos(storm(), **kw)
    assert sig(untraced) == sig(rep), "tracing perturbed the simulation"
    assert rep.lost == 0, "the canonical storm is zero-loss"
    print("\nOK: bit-identical to the untraced replay, all spans closed, "
          f"{rep.frames_out} frames delivered, zero loss")


if __name__ == "__main__":
    main()
