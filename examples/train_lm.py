"""End-to-end training example: a ~100M-param TinyLlama-family model
trained for a few hundred steps on the synthetic token stream, with
checkpointing and a simulated mid-run node failure + recovery.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

import argparse
import shutil

from repro.configs import base as cb
from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    shutil.rmtree("/tmp/repro_ckpt_example", ignore_errors=True)
    # ~100M params: TinyLlama family scaled (12L x 768d x 12H, 16k vocab)
    import repro.configs.tinyllama_1_1b as tl
    orig_smoke = tl.smoke
    tl.smoke = lambda: tl.CONFIG.replace(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
        vocab_size=16384, remat=False)
    try:
        argv = ["--arch", "tinyllama-1.1b", "--smoke",
                "--steps", str(args.steps), "--batch", "8", "--seq", "128",
                "--ckpt-dir", "/tmp/repro_ckpt_example",
                "--ckpt-every", "50", "--lr", "1e-3"]
        if args.fail_at:
            argv += ["--simulate-failure", str(args.fail_at)]
        final = train.main(argv)
        assert final < 7.0, f"loss did not move: {final}"
        print(f"train_lm OK — final loss {final:.3f}")
    finally:
        tl.smoke = orig_smoke


if __name__ == "__main__":
    main()
