"""Multi-tenant fleet serving through the front door, live.

Three tenant tiers share one 8-lane edge kit: ``field_ops`` (checkpoint
operators, priority 0, tight SLO), ``recon`` (priority 1), and
``backfill`` (archive re-identification, priority 2, bulk).  The demo
drives the fleet at 1x, 2x, and 4x its nominal capacity and shows the
front door's graceful-degradation contract:

1. At 1x, everyone rides free: goodput ~1.0 across the board.
2. At 4x, the door sheds almost all of backfill, some of recon, and
   none of field_ops — and field_ops p99 stays pinned at its unloaded
   value, inside the SLO.  Overload lands on the bulk tier, never on
   the operator holding a device at a checkpoint.
3. Total completed frames NEVER drop as overload grows: shedding at
   admission protects the pipeline from queue collapse.

Every claim is asserted, not just printed.

Run:  PYTHONPATH=src python examples/fleet_serving.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

from repro.runtime import FLEET_TENANTS, run_fleet_sweep


def describe(overload, rep):
    fd = rep.frontdoor
    total = sum(t["completed"] for t in fd["tenants"].values())
    print(f"\noffered load {overload:g}x nominal "
          f"(completed {total}, shed {fd['shed']}, lost {rep.lost}):")
    for name, t in fd["tenants"].items():
        print(f"  {name:<10} [{t['class']:<11}] "
              f"goodput {t['goodput']:5.3f}  "
              f"p99 {t['latency']['p99'] * 1e3:7.1f} ms  "
              f"shed {t['shed']:5d}  slo_miss {t['slo_miss']}")
    return total


def main():
    tiers = {t.name: t for t in FLEET_TENANTS}
    print("fleet kit: 8 identical lanes behind the front door, tenant "
          "tiers " + ", ".join(f"{t.name}(p{t.priority}, w{t.weight:g})"
                               for t in FLEET_TENANTS))

    totals = {}
    reps = {}
    for overload in (1.0, 2.0, 4.0):
        rep = run_fleet_sweep(overload, duration_s=4.0)
        reps[overload] = rep
        totals[overload] = describe(overload, rep)
        assert rep.lost == 0, f"in-pipeline loss at {overload}x"

    # the graceful-degradation contract, asserted --------------------------
    peak = reps[4.0].frontdoor["tenants"]
    slo = tiers["field_ops"].slo_s
    assert peak["field_ops"]["goodput"] == 1.0, "interactive tier shed"
    assert peak["field_ops"]["latency"]["p99"] <= slo, \
        f"field_ops p99 {peak['field_ops']['latency']['p99']} > SLO {slo}"
    assert peak["backfill"]["shed"] > 0, "bulk never shed at 4x?"
    gp = [peak[n]["goodput"] for n in ("field_ops", "recon", "backfill")]
    assert gp == sorted(gp, reverse=True), f"shed order broke class order: {gp}"
    assert totals[4.0] >= 0.9 * totals[1.0], \
        f"throughput collapsed under overload: {totals}"

    print("\nall degradation invariants held: interactive SLO pinned, "
          "shed order == class order, no throughput collapse")


if __name__ == "__main__":
    main()
