"""Deep per-architecture verification: every assigned arch (reduced
config) through loss / prefill / decode, checking decode-vs-forward
consistency — the strongest cheap correctness signal for the KV-cache,
recurrent-state and MoE dispatch paths.

Run:  PYTHONPATH=src python examples/arch_smoke_all.py [arch ...]
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models import model as mdl
from repro.launch import specs as sp
from repro.sharding import init_params

ARCHS = sys.argv[1:] or cb.ARCH_IDS

for arch in ARCHS:
    try:
        cfg = cb.smoke(arch)
        key = jax.random.PRNGKey(0)
        params = init_params(mdl.param_specs(cfg), key, jnp.bfloat16)
        S, B = 32, 2
        batch = sp.make_batch(cfg, S, B, key)
        loss, metrics = jax.jit(lambda p, b: mdl.loss_fn(p, cfg, b))(params, batch)
        assert jnp.isfinite(loss), (arch, loss)

        pf_batch = {k: v for k, v in batch.items() if k != "labels"}
        last_logits, cache = jax.jit(
            lambda p, b: mdl.prefill(p, cfg, b))(params, pf_batch)
        tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
        cache_t = sp.init_cache(cfg, B, S + 8)

        def put(dst, src):
            if src.ndim == 0 or dst.shape == src.shape:
                return src.astype(dst.dtype)
            ax = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
                  if a != b]
            assert len(ax) == 1, (dst.shape, src.shape)
            sl = [slice(None)] * dst.ndim
            sl[ax[0]] = slice(0, src.shape[ax[0]])
            return dst.at[tuple(sl)].set(src.astype(dst.dtype))

        cache2 = jax.tree.map(put, cache_t, cache)
        logits2, _ = jax.jit(
            lambda p, t, c: mdl.decode_step(p, cfg, t, jnp.int32(S), c)
        )(params, tok, cache2)
        assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32))), arch

        toks3 = jnp.concatenate([batch["tokens"], tok], axis=1)
        b3 = dict(batch, tokens=toks3)
        b3.pop("labels")
        lg_full, _, _ = jax.jit(
            lambda p, b: mdl.forward(p, cfg, b))(params, b3)
        ref = lg_full[:, -1].astype(jnp.float32)
        got = logits2.astype(jnp.float32)
        err = jnp.max(jnp.abs(ref - got)) / (jnp.max(jnp.abs(ref)) + 1e-6)
        print(f"{arch:22s} loss={float(loss):8.4f} "
              f"decode_rel_err={float(err):.3e}")
        assert err <= 2e-2, f"DECODE MISMATCH {arch}"
    except Exception:
        print(f"{arch:22s} FAILED")
        traceback.print_exc()
