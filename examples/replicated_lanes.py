"""Replicated-lane scenario (paper §4.1 meets §4.2): scale out a hot stage
by plugging in more sticks, then survive losing one — live.

1. Build the detect -> embed -> match chain with ONE embedder stick: the
   35 ms embedder is the bottleneck and backlog piles up behind it.
2. Hot-plug two embedder replicas mid-stream (no pipeline pause — each
   lane joins after its own handshake + model load) and watch the lane
   group shard frames least-loaded across the sticks.
3. Pull one replica mid-mission: throughput degrades, nothing halts,
   nothing is lost.
4. Reproduce Table 1 through the same engine: a broadcast lane group of
   1..5 calibrated NCS2 sticks lands on the published FPS curve.

Run:  PYTHONPATH=src python examples/replicated_lanes.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

from repro.bus import BusParams, SharedBus, TABLE1, calibrated
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import (CapabilityRegistry, StreamEngine,
                           engine_broadcast_fps)

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)


def _cart(name, service_s, capability_id, load_s=0.4):
    return FnCartridge(name, lambda p, x: x, SPEC, SPEC,
                       capability_id=capability_id,
                       device=DeviceModel(service_s=service_s,
                                          load_s=load_s))


def scale_out_then_degrade():
    reg = CapabilityRegistry()
    reg.insert(0, _cart("detect", 0.008, 2))
    embed = _cart("embed", 0.035, 4)
    reg.insert(1, embed)
    reg.insert(2, _cart("match", 0.006, 9))
    bus = SharedBus(BusParams("usb3", base_overhead_s=1e-4,
                              arbitration_s=2e-4))
    eng = StreamEngine(reg, bus)

    eng.feed(400, interval_s=0.012)           # ~83 FPS offered load
    r1, r2 = embed.clone(), embed.clone()
    eng.schedule_add_replica(0.8, slot=1, cart=r1)    # hot-plug stick 2
    eng.schedule_add_replica(0.8, slot=1, cart=r2)    # hot-plug stick 3
    eng.schedule_remove_replica(3.5, slot=1, cart=r1)  # pull one live
    rep = eng.run(until=120)

    assert rep.frames_out == 400, f"lost {rep.lost}"
    assert rep.total_downtime() == 0.0        # replica swaps never pause
    assert not rep.alerts
    lanes = {n: rep.stage_stats[n].processed
             for n in ("embed", r1.name, r2.name)}
    print(f"[lanes] 400 frames, zero loss, zero downtime; "
          f"embed group load: {lanes}")
    print(f"[lanes] swap log: {[(round(t, 2), k) for t, k, _ in rep.swap_log]}")
    print(f"[lanes] bus contention: wait={rep.bus['wait_s']:.3f}s "
          f"arbitration={rep.bus['arbitration_s']:.3f}s "
          f"wire={rep.bus['wire_s']:.3f}s")
    assert lanes[r1.name] > 0 and lanes[r2.name] > 0


def reproduce_table1():
    print("[table1] engine-driven broadcast, ncs2 sticks:")
    for n in range(1, 6):
        fps = engine_broadcast_fps("ncs2", n)
        pub = TABLE1["ncs2"][n - 1]
        assert abs(fps - pub) <= 1.0
        print(f"  N={n}: engine {fps:5.2f} FPS vs published {pub:2d} FPS")


def main():
    scale_out_then_degrade()
    reproduce_table1()
    print("replicated_lanes OK — shard scale-out, pauseless replica "
          "swaps, Table 1 reproduced in-engine")


if __name__ == "__main__":
    main()
