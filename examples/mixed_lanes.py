"""Heterogeneous lane group under straggler fire: the tail-latency fast
path, live.

Field scenario: a checkpoint's embed slot holds three sticks — two
healthy Coral-class lanes and one NCS2 that has degraded in the sun
(5x service time, and ~5% of its service cycles stall another 10x).
Cameras deliver frames in synchronized bursts, so all three lanes look
"idle" at burst arrival and a queue-depth dispatcher happily feeds the
degraded stick.

Three runs at the SAME offered load:

  1. PR 2 baseline   — queue-depth least-loaded, no hedging
  2. EWMA dispatch   — weighted by each lane's observed service time
  3. EWMA + hedging  — tied-request backup on the best alternate lane
                       when a cycle overruns its p95 deadline, stalled
                       queues migrated to healthy lanes, loser handoffs
                       suppressed on the bus

The operator waits on p99, and p99 is what moves.

Run:  PYTHONPATH=src python examples/mixed_lanes.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

from repro.core.cartridge import DeviceModel
from repro.runtime import build_mixed_engine

N_BURSTS, BURST, PERIOD = 150, 5, 0.06   # ~83 FPS offered, capacity ~110

DEVICES = [
    DeviceModel(name="coral", service_s=0.02),
    DeviceModel(name="coral", service_s=0.02),
    DeviceModel(name="ncs2_degraded", service_s=0.10,
                jitter_p=0.05, jitter_mult=10.0),
]


def run(label, **engine_kw):
    eng = build_mixed_engine(DEVICES, **engine_kw)
    for i in range(N_BURSTS):
        eng.feed(BURST, interval_s=0.0, t0=i * PERIOD)
    rep = eng.run(until=1e9)
    n = N_BURSTS * BURST
    assert rep.frames_out == n, f"lost {rep.lost}"
    slow_frames = sum(st.processed for name, st in rep.stage_stats.items()
                      if "degraded" in name)
    print(f"[{label:13s}] p50={rep.p50()*1e3:6.1f}ms  "
          f"p95={rep.p95()*1e3:6.1f}ms  p99={rep.p99()*1e3:6.1f}ms  "
          f"throughput={rep.throughput():5.1f} FPS  "
          f"degraded-stick frames={slow_frames}")
    if rep.hedges["issued"]:
        print(f"{'':16s}hedges: issued={rep.hedges['issued']} "
              f"won_by_backup={rep.hedges['won_by_backup']} "
              f"migrated={rep.hedges['migrated']} "
              f"suppressed_handoffs={rep.bus['suppressed_transfers']}")
    return rep


def main():
    print(f"offered load: {BURST / PERIOD:.0f} FPS in bursts of {BURST} "
          f"(2x coral @50 FPS + 1x degraded ncs2 @10 FPS nominal)\n")
    base = run("pr2 baseline", dispatch="naive", hedge=False)
    run("ewma", dispatch="ewma", hedge=False)
    fast = run("ewma+hedge", dispatch="ewma", hedge=True)

    imp = base.p99() / fast.p99()
    print(f"\np99 improvement vs baseline: {imp:.1f}x "
          f"(throughput ratio {fast.throughput()/base.throughput():.3f})")
    assert imp >= 2.0, "tail-latency fast path must halve p99 here"
    assert fast.throughput() >= 0.95 * base.throughput()

    # same sticks, jitter everywhere: hedging as insurance
    print("\nhomogeneous group, every stick jittery (hedge = insurance):")
    jdev = [DeviceModel(name="coral", service_s=0.02,
                        jitter_p=0.03, jitter_mult=10.0)] * 3
    global DEVICES
    DEVICES = jdev
    unhedged = run("ewma", dispatch="ewma", hedge=False)
    hedged = run("ewma+hedge", dispatch="ewma", hedge=True)
    assert hedged.p99() < unhedged.p99()
    print(f"\nhedging cut the jitter tail "
          f"{unhedged.p99()/hedged.p99():.1f}x at equal offered load")


if __name__ == "__main__":
    main()
