"""Power-governed dispatch, live: a battery kit hits its watt budget
mid-mission and the governor throttles the fleet instead of the battery.

CHAMP's §4.3 power model (1-2 W per stick active, 0.3 W idle) is the
disaster-response constraint: the kit runs off a battery pack, so the
per-hub electrical draw is a hard cap, not telemetry.  This demo:

1. Streams a closed-loop burst through one 4-stick ncs2-class hub with
   no budget: ~7.2 W sustained (the unconstrained ablation).
2. Re-runs the same workload under a 4 W cap: the governor's thermal
   state machine trips (nominal -> throttled), every service cycle is
   duty-stretched, and the measured average draw lands under the cap —
   with zero frames lost.
3. Battery saver, live: starts unconstrained, then tightens the budget
   to 3 W at t=1.5 s via ``PowerGovernor.set_budget`` — the throttle
   engages mid-stream, no pause, no loss.

Run:  PYTHONPATH=src python examples/power_budget.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

from repro.runtime import build_battery_engine, run_battery


def describe(tag, rep):
    hub = rep.power["hubs"][0]
    print(f"  {tag:<14} {rep.throughput():7.2f} FPS  "
          f"avg {hub['avg_w']:5.2f} W  "
          f"energy {rep.power['total_j']:8.1f} J  "
          f"state={hub['state']:9s} "
          f"throttles={hub['throttle_events']} parks={hub['park_events']}")
    return hub


def main():
    print("battery kit: 4x ncs2 on one hub "
          "(full draw ~7.2 W, idle floor 1.2 W)\n")

    # 1 + 2: unconstrained vs capped, same closed-loop workload ----------
    print("budget sweep (400 frames, closed loop):")
    free = run_battery(None, n_frames=400)
    describe("unlimited", free)
    for budget in (4.0, 2.0):
        rep = run_battery(budget, n_frames=400)
        hub = describe(f"{budget:g} W cap", rep)
        assert rep.lost == 0, f"lost {rep.lost} frames"
        assert hub["avg_w"] <= budget, \
            f"cap violated: {hub['avg_w']} > {budget}"
        assert hub["throttle_events"] >= 1
    assert free.power["hubs"][0]["avg_w"] > 4.0
    print("  -> every cap held its average; deep caps park/duty-cycle\n")

    # 3: battery saver kicks in mid-mission ------------------------------
    eng = build_battery_engine(None)
    eng.feed(400, interval_s=0.0)
    eng._push_event(1.5, lambda: eng.governor.set_budget(3.0, eng.now))
    rep = eng.run(until=1e9)
    hub = rep.power["hubs"][0]
    assert rep.lost == 0, f"lost {rep.lost} frames"
    assert hub["throttle_events"] >= 1, "battery saver never engaged"
    assert rep.total_downtime() == 0.0, "throttling must not pause"
    print("battery saver at t=1.5s (3 W cap, mid-stream):")
    describe("live rebudget", rep)
    print(f"  throttled {hub['throttled_s']:.1f}s of "
          f"{rep.sim_time:.1f}s; zero loss, zero downtime")

    print("\npower_budget OK — the governor throttles the fleet, "
          "not the battery")


if __name__ == "__main__":
    main()
