"""Elastic-training demo (1000-node behaviour at laptop scale): a node
failure mid-run triggers checkpoint restore + re-mesh + deterministic
stream replay. The final loss matches an uninterrupted run bit-for-bit
when the failure lands on a checkpoint boundary.

Run:  PYTHONPATH=src python examples/elastic_recovery.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

import shutil

from repro.launch import train


def main():
    args_common = ["--arch", "tinyllama-1.1b", "--smoke", "--steps", "120",
                   "--batch", "8", "--seq", "64", "--ckpt-every", "40",
                   "--lr", "1e-3"]

    shutil.rmtree("/tmp/ck_a", ignore_errors=True)
    clean = train.main(args_common + ["--ckpt-dir", "/tmp/ck_a"])

    shutil.rmtree("/tmp/ck_b", ignore_errors=True)
    recovered = train.main(args_common + [
        "--ckpt-dir", "/tmp/ck_b", "--simulate-failure", "80"])

    print(f"clean final loss     {clean:.6f}")
    print(f"recovered final loss {recovered:.6f}")
    assert abs(clean - recovered) < 1e-3, \
        "deterministic replay must reproduce the clean run"
    print("elastic_recovery OK — failure at step 80 recovered exactly")


if __name__ == "__main__":
    main()
