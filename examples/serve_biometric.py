"""Field-biometrics scenario (paper §5): checkpoint watchlist screening.

1. enroll 10 subjects into the encrypted gallery (templates protected by
   the keyed rotation, stored under the Threefry stream cipher);
2. stream camera frames through detect -> quality -> embed -> match;
3. mid-mission, the operator pulls the quality cartridge (hot-swap) —
   screening continues with zero frame loss;
4. re-keying the gallery (revocation) keeps matching working.

Run:  PYTHONPATH=src python examples/serve_biometric.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

import numpy as np

from repro.launch.serve import build_biometric_pipeline, run_biometric


def main():
    rep = run_biometric(n_frames=30, hotswap=True)
    assert rep.lost == 0
    assert rep.total_downtime() < 1.0  # only the 0.5 s removal pause

    # revocation demo
    reg, gallery = build_biometric_pipeline(seed=1)
    rng = np.random.default_rng(0)
    raw = rng.normal(size=(5, 128)).astype(np.float32)
    gallery.enroll(raw, [f"s{i}" for i in range(5)])
    labels_before, _ = gallery.match(raw[[2]], k=1)
    gallery.rekey(new_seed=99)
    labels_after, _ = gallery.match(raw[[2]], k=1)
    assert labels_before[0, 0] == labels_after[0, 0] == "s2"
    print("serve_biometric OK — zero-loss hot-swap + revocable templates")


if __name__ == "__main__":
    main()
