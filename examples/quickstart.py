"""Quickstart: assemble a CHAMP pipeline like LEGO bricks, stream frames
through it, hot-swap a cartridge live, and match against an encrypted
watchlist.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU-only hosts

from repro.launch.serve import run_biometric


if __name__ == "__main__":
    rep = run_biometric(n_frames=24, hotswap=True)
    assert rep.lost == 0, "hot-swap must not lose frames"
    print("quickstart OK — zero frame loss across a live hot-swap")
