"""Multi-hub bus fabric benchmark — the tracked topology-scaling baseline.

One tracked artifact, written to the repo root:

* ``BENCH_fabric.json`` — the bus fabric measured for the two things it
  exists for:

  1. **Scaling past the single-bus saturation knee.**  Aggregate shard
     FPS of one calibrated ncs2-class bus as the device count grows
     (the curve *peaks* and then collapses — arbitration cost grows
     with the fleet) versus hub-partitioned fabrics at the SAME total
     device count (2x4, 4x2, 2x5, 4x4), where each hub arbitrates only
     its own endpoints.  Headline: the multi-hub/single-bus FPS ratio
     at equal device count, and multi-hub FPS clearing the best FPS a
     single bus achieves at ANY size (the knee).

  2. **Router-level hedge suppression.**  A cross-hub hedged scenario —
     two jittery lanes on hub 0, two clean lanes plus the post stage on
     hub 1, near-critical bus load — run with router suppression on vs
     off.  Off, every hedge loser's result actually crosses egress +
     link + ingress and is discarded at the host; the wasted transfers
     contend with the winning traffic exactly where it flows.
     Headline: p99 with suppression on <= off, plus the saved bus time.

All numbers are virtual-time deterministic (discrete-event simulation on
calibrated device models), so the committed ratios are exact on any
machine; the ``smoke_baseline`` is still measured as the min over 3
fresh subprocesses for discipline parity with the other benches.

Run:  PYTHONPATH=src python benchmarks/fabric_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible CI numbers

import argparse
import json

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FABRIC_JSON = os.path.join(ROOT, "BENCH_fabric.json")

FABRIC_SCHEMA = "champ.fabric_bench.v1"

FULL_CFG = dict(n_frames=300, single_sizes=(1, 2, 4, 5, 6, 8, 10, 16),
                topologies=((2, 4), (4, 2), (2, 5), (4, 4)),
                hedge_bursts=300)
SMOKE_CFG = dict(n_frames=150, single_sizes=(1, 4, 5, 8, 16),
                 topologies=((2, 4), (4, 4)),
                 hedge_bursts=120)

DEVICE = "ncs2"          # the paper's Table 1 calibration


# ---------------------------------------------------------------------------
# 1. shard scaling: one saturated bus vs hub-partitioned fabrics
# ---------------------------------------------------------------------------
def bench_scaling(cfg) -> dict:
    from repro.runtime import engine_shard_fps, fabric_shard_fps

    n = cfg["n_frames"]
    single = {str(k): round(engine_shard_fps(DEVICE, k, n_frames=n), 2)
              for k in cfg["single_sizes"]}
    knee_n, knee_fps = max(single.items(), key=lambda kv: kv[1])
    fabrics = {}
    for hubs, per in cfg["topologies"]:
        total = hubs * per
        fps = round(fabric_shard_fps(DEVICE, hubs, per, n_frames=n), 2)
        same_n = single.get(str(total))
        if same_n is None:
            same_n = round(engine_shard_fps(DEVICE, total, n_frames=n), 2)
            single[str(total)] = same_n
        fabrics[f"{hubs}x{per}"] = {
            "hubs": hubs, "devices_per_hub": per, "total_devices": total,
            "aggregate_fps": fps,
            "single_bus_fps_same_n": same_n,
            "speedup_vs_single_bus": round(fps / same_n, 2),
            "exceeds_knee": bool(fps > knee_fps),
        }
    best = max(fabrics.values(), key=lambda f: f["speedup_vs_single_bus"])
    return {
        "device": DEVICE,
        "single_bus_fps": single,
        "single_bus_knee": {"devices": int(knee_n), "fps": knee_fps},
        "single_bus_5dev_fps": single["5"],
        "fabrics": fabrics,
        "best_speedup_at_equal_devices": best["speedup_vs_single_bus"],
        "best_topology": f"{best['hubs']}x{best['devices_per_hub']}",
    }


# ---------------------------------------------------------------------------
# 2. cross-hub hedging: router suppression on vs off
# ---------------------------------------------------------------------------
def bench_hedge_suppression(cfg) -> dict:
    """The canonical cross-hub hedge scenario — the engine builder lives
    in ``repro.runtime.replication`` and is shared with the test suite,
    so the invariants the tests pin are measured on this exact
    workload."""
    from repro.runtime import build_cross_hub_hedge_engine

    out = {"workload": "2 jittery lanes on hub0 + 2 clean on hub1, "
                       "bursty @ 0.45 load, hedge_quantile=0.8"}
    for key, sup in (("suppression_on", True), ("suppression_off", False)):
        rep = build_cross_hub_hedge_engine(
            sup, cfg["hedge_bursts"]).run(until=1e12)
        assert rep.lost == 0, f"fabric hedge scenario lost {rep.lost}"
        out[key] = {
            "p50_ms": round(rep.p50() * 1e3, 2),
            "p95_ms": round(rep.p95() * 1e3, 2),
            "p99_ms": round(rep.p99() * 1e3, 2),
            "mean_ms": round(rep.mean_latency() * 1e3, 2),
            "hedges": {k: v for k, v in rep.hedges.items() if v},
            "bus_busy_s": rep.bus["busy_s"],
            "suppressed_transfers": rep.bus["suppressed_transfers"],
            "suppressed_saved_s": rep.bus["suppressed_saved_s"],
            "wasted_transfers": rep.bus["wasted_transfers"],
        }
    on, off = out["suppression_on"], out["suppression_off"]
    out["p99_off_over_on"] = round(
        off["p99_ms"] / max(on["p99_ms"], 1e-9), 3)
    out["bus_busy_saved_s"] = round(
        off["bus_busy_s"] - on["bus_busy_s"], 6)
    return out


def _acceptance(scaling: dict, hedge: dict) -> dict:
    on, off = hedge["suppression_on"], hedge["suppression_off"]
    return {
        "single_bus_knee_fps": scaling["single_bus_knee"]["fps"],
        "single_bus_5dev_fps": scaling["single_bus_5dev_fps"],
        "best_topology": scaling["best_topology"],
        "multi_hub_speedup": scaling["best_speedup_at_equal_devices"],
        # the issue's gate: multi-hub aggregate FPS must clear the
        # calibrated single-bus saturation point at equal device count
        "pass_scaling": bool(
            scaling["best_speedup_at_equal_devices"] > 1.0
            and all(f["exceeds_knee"]
                    for f in scaling["fabrics"].values())),
        "hedge_p99_on_ms": on["p99_ms"],
        "hedge_p99_off_ms": off["p99_ms"],
        "p99_off_over_on": hedge["p99_off_over_on"],
        "pass_hedge": bool(on["p99_ms"] <= off["p99_ms"]
                           and on["suppressed_transfers"] > 0
                           and off["wasted_transfers"] > 0),
    }


# ---------------------------------------------------------------------------
# schema validation + regression check
# ---------------------------------------------------------------------------
def validate_fabric(doc: dict):
    assert doc.get("schema") == FABRIC_SCHEMA, "bad/missing schema tag"
    assert doc.get("mode") in ("full", "smoke"), "bad mode"
    for section in ("scaling", "hedge", "acceptance"):
        assert section in doc, f"missing section {section!r}"
    for kk in ("multi_hub_speedup", "p99_off_over_on", "pass_scaling",
               "pass_hedge"):
        assert kk in doc["acceptance"], f"acceptance missing {kk!r}"
    if doc["mode"] == "full":
        assert "smoke_baseline" in doc, "missing smoke_baseline"
        for kk in ("multi_hub_speedup", "p99_off_over_on"):
            assert kk in doc["smoke_baseline"], \
                f"smoke_baseline missing {kk!r}"


def load_committed():
    try:
        doc = json.load(open(FABRIC_JSON))
        validate_fabric(doc)
    except Exception as e:
        return None, [f"committed BENCH_fabric.json malformed: {e}"]
    return doc, []


def run_check(fresh: dict, smoke: bool, committed: dict) -> list:
    failures = []
    base = committed["smoke_baseline"] if smoke else committed["acceptance"]
    got = fresh["acceptance"]["multi_hub_speedup"]
    want = base["multi_hub_speedup"]
    if got < 0.8 * want:
        failures.append(f"multi-hub speedup regressed >20%: "
                        f"{got} vs baseline {want}")
    if not fresh["acceptance"]["pass_scaling"]:
        failures.append("multi-hub FPS no longer clears the single-bus "
                        "saturation knee")
    if not fresh["acceptance"]["pass_hedge"]:
        failures.append(
            f"router suppression no longer helps the hedge tail: "
            f"p99 on {fresh['acceptance']['hedge_p99_on_ms']} vs "
            f"off {fresh['acceptance']['hedge_p99_off_ms']}")
    got_r = fresh["acceptance"]["p99_off_over_on"]
    want_r = base["p99_off_over_on"]
    if got_r < 0.8 * want_r:
        failures.append(f"suppression p99 ratio regressed >20%: "
                        f"{got_r} vs baseline {want_r}")
    return failures


def run() -> dict:
    """Validation-suite entry (``benchmarks/run.py``): smoke-size check
    that the fabric still clears its scaling + suppression gates."""
    scaling = bench_scaling(SMOKE_CFG)
    hedge = bench_hedge_suppression(SMOKE_CFG)
    acc = _acceptance(scaling, hedge)
    return {
        "acceptance": acc,
        "pass_fabric": bool(acc["pass_scaling"] and acc["pass_hedge"]),
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; writes BENCH_fabric.smoke.json "
                         "instead of overwriting the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_fabric.json and fail on "
                         ">20% ratio regression")
    args = ap.parse_args()

    cfg = SMOKE_CFG if args.smoke else FULL_CFG
    mode = "smoke" if args.smoke else "full"
    committed = None
    if args.check:
        committed, failures = load_committed()
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))

    print(f"[fabric_bench] mode={mode} frames={cfg['n_frames']} "
          f"topologies={cfg['topologies']}")
    doc = {"schema": FABRIC_SCHEMA, "mode": mode}
    doc["scaling"] = bench_scaling(cfg)
    doc["hedge"] = bench_hedge_suppression(cfg)
    doc["acceptance"] = _acceptance(doc["scaling"], doc["hedge"])

    if not args.smoke:
        # smoke baselines for CI parity with the other benches: min over 3
        # fresh subprocesses (the ratios are virtual-time deterministic,
        # so the min is a stability assertion, not noise filtering)
        print("[fabric_bench] measuring smoke baseline for CI "
              "(min of 3 fresh subprocesses)")
        import subprocess
        import sys
        smoke_path = os.path.join(ROOT, "BENCH_fabric.smoke.json")
        samples = []
        for _ in range(3):
            subprocess.run([sys.executable, os.path.abspath(__file__),
                            "--smoke"], check=True, cwd=ROOT)
            samples.append(json.load(open(smoke_path))["acceptance"])
        os.remove(smoke_path)
        doc["smoke_baseline"] = {
            "multi_hub_speedup": min(a["multi_hub_speedup"]
                                     for a in samples),
            "p99_off_over_on": min(a["p99_off_over_on"] for a in samples),
            "samples": [{"multi_hub_speedup": a["multi_hub_speedup"],
                         "p99_off_over_on": a["p99_off_over_on"]}
                        for a in samples],
        }

    if args.check:
        # check BEFORE writing: a failed check must not clobber the
        # committed baseline it was compared against
        failures = run_check(doc, args.smoke, committed)
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
        print("[fabric_bench] check OK — no tracked metric regressed")

    path = FABRIC_JSON if not args.smoke else \
        os.path.join(ROOT, "BENCH_fabric.smoke.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[fabric_bench] wrote {path}")
    print(json.dumps(doc["acceptance"], indent=2))


if __name__ == "__main__":
    main()
