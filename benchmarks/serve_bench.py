"""Fleet front-door benchmark — graceful degradation under overload.

One tracked artifact, written to the repo root:

* ``BENCH_serve.json`` (schema v1) — the multi-tenant overload sweep on
  the fleet cell (8 identical lanes behind the front door, three tenant
  tiers: interactive / standard / bulk at a 10/30/60 offered-load
  split).  Offered load runs at 1x, 2x, and 4x nominal capacity; each
  cell records per-tenant goodput, p99 latency, and shed counts.  Gates:

  - **bit-identity** (absolute, exact): a single-tenant uncapped
    ``feed()`` through the trivial front door produces a float-for-float
    identical report to the pre-door direct-ingest path on the Table 1
    replication scenario.  The door is a pure pass-through until a
    second tenant or a rate cap engages it.
  - **interactive SLO held at 4x** (absolute): the class-0 tenant's p99
    stays within its SLO even at 4x offered load — overload lands on
    bulk, not on the checkpoint operator.
  - **class-ordered degradation** (absolute): at every overload level,
    goodput is ordered interactive >= standard >= bulk, and bulk goodput
    is non-increasing as overload grows — the shed order is the priority
    order.
  - **no collapse** (absolute): total completed frames at 4x stay within
    10% of the 1x total — shedding protects throughput instead of
    letting queue growth destroy it.
  - **goodput retention** (the CI contract): completed(4x)/completed(1x)
    must not regress more than 20% against the committed baseline.

The simulation is deterministic (virtual time), so the committed
``smoke_baseline`` is measured over 3 fresh subprocesses and asserted
identical across them before being embedded — a CI ``--smoke --check``
run compares like-for-like against an exact, noise-free number.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible CI numbers

import argparse
import json
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVE_JSON = os.path.join(ROOT, "BENCH_serve.json")

SERVE_SCHEMA = "champ.serve_bench.v1"

OVERLOADS = (1.0, 2.0, 4.0)
FULL_CELL = {"duration_s": 20.0}
SMOKE_CELL = {"duration_s": 4.0}
IDENTITY_CELL = {"device": "ncs2", "n_lanes": 5, "frames": 200}

COLLAPSE_FLOOR = 0.90       # completed(4x) >= 90% of completed(1x)
RETENTION_REGRESSION = 0.20  # CI gate: >20% retention drop vs committed


def _sig(rep):
    """Everything float-valued the engine computes, exactly."""
    return (rep.frames_in, rep.frames_out, rep.sim_time, rep.last_out_t,
            tuple(rep.latencies), tuple(sorted(rep.hedges.items())),
            tuple(sorted(rep.faults.items())))


# ---------------------------------------------------------------------------
# gate 1: the trivial door is a pure pass-through
# ---------------------------------------------------------------------------
def bench_bit_identity(cell: dict) -> dict:
    """``feed()`` (through the lazily-attached trivial front door) vs the
    direct ``_frame_arrival`` ingest it replaced, on the Table 1
    replication scenario.  One perturbed float fails the bench."""
    from repro.runtime import build_replicated_engine

    e1 = build_replicated_engine(cell["device"], cell["n_lanes"])
    e1.feed(cell["frames"], interval_s=0.0)
    r1 = e1.run(until=float("inf"))

    e2 = build_replicated_engine(cell["device"], cell["n_lanes"])
    for _ in range(cell["frames"]):
        e2._push_event(0.0, e2._frame_arrival, None, 150528)
    r2 = e2.run(until=float("inf"))

    identical = _sig(r1) == _sig(r2)
    return {"workload": f"{cell['device']} x{cell['n_lanes']}, "
                        f"{cell['frames']} frames saturated (Table 1 cell)",
            "frames_out": r1.frames_out,
            "bit_identical": bool(identical)}


# ---------------------------------------------------------------------------
# the sweep: three tenant tiers at 1x / 2x / 4x offered load
# ---------------------------------------------------------------------------
def bench_overload_sweep(cell: dict) -> dict:
    from repro.runtime import FLEET_SPLIT, FLEET_TENANTS, run_fleet_sweep

    duration_s = cell["duration_s"]
    tiers = {t.name: t for t in FLEET_TENANTS}
    interactive = min(FLEET_TENANTS, key=lambda t: t.priority)
    by_prio = sorted(FLEET_TENANTS, key=lambda t: t.priority)
    out = {"workload": "8-lane fleet cell, tenant split "
                       + json.dumps(FLEET_SPLIT),
           "duration_s": duration_s,
           "tenants": {t.name: {"priority": t.priority, "weight": t.weight,
                                "slo_s": t.slo_s, "queue_cap": t.queue_cap}
                       for t in FLEET_TENANTS},
           "levels": {}}
    completed_total = {}
    for ov in OVERLOADS:
        t0 = time.perf_counter()
        rep = run_fleet_sweep(ov, duration_s=duration_s)
        wall = time.perf_counter() - t0
        fd = rep.frontdoor
        level = {"wall_s": round(wall, 3), "lost": rep.lost,
                 "completed": sum(t["completed"]
                                  for t in fd["tenants"].values()),
                 "shed": fd["shed"], "per_tenant": {}}
        for name, t in fd["tenants"].items():
            level["per_tenant"][name] = {
                "offered": t["offered"], "admitted": t["admitted"],
                "shed": t["shed"], "completed": t["completed"],
                "goodput": round(t["goodput"], 4),
                "p99_s": round(t["latency"]["p99"], 5),
                "slo_miss": t["slo_miss"],
            }
        completed_total[ov] = level["completed"]
        out["levels"][f"{ov:g}x"] = level

    # gate 2: interactive p99 within SLO at the highest overload
    peak = out["levels"][f"{OVERLOADS[-1]:g}x"]["per_tenant"]
    slo_held = peak[interactive.name]["p99_s"] <= tiers[interactive.name].slo_s
    # gate 3: class-ordered goodput at every level; bulk non-increasing
    ordered = True
    for lvl in out["levels"].values():
        gp = [lvl["per_tenant"][t.name]["goodput"] for t in by_prio]
        ordered &= all(a >= b - 1e-9 for a, b in zip(gp, gp[1:]))
    bulk = by_prio[-1].name
    bulk_gp = [out["levels"][f"{ov:g}x"]["per_tenant"][bulk]["goodput"]
               for ov in OVERLOADS]
    monotone = all(a >= b - 1e-9 for a, b in zip(bulk_gp, bulk_gp[1:]))
    # gate 4: no collapse — shed protects throughput
    retention = completed_total[OVERLOADS[-1]] / completed_total[OVERLOADS[0]]
    out["acceptance"] = {
        "interactive_p99_s": peak[interactive.name]["p99_s"],
        "interactive_slo_s": tiers[interactive.name].slo_s,
        "pass_interactive_slo_at_peak": bool(slo_held),
        "pass_class_ordered_goodput": bool(ordered),
        "pass_bulk_sheds_first": bool(monotone and bulk_gp[-1] < 1.0),
        "goodput_retention": round(retention, 4),
        "pass_no_collapse": bool(retention >= COLLAPSE_FLOOR),
    }
    return out


# ---------------------------------------------------------------------------
# schema validation + regression check
# ---------------------------------------------------------------------------
def validate_serve(doc: dict):
    assert doc.get("schema") == SERVE_SCHEMA, "bad/missing schema tag"
    assert doc.get("mode") in ("full", "smoke"), "bad mode"
    assert doc.get("bit_identity", {}).get("bit_identical") is not None, \
        "missing bit_identity section"
    sweep = doc.get("overload_sweep")
    assert sweep, "missing overload_sweep section"
    for ov in OVERLOADS:
        assert f"{ov:g}x" in sweep["levels"], f"missing {ov:g}x level"
    for kk in ("pass_interactive_slo_at_peak", "pass_class_ordered_goodput",
               "pass_bulk_sheds_first", "goodput_retention",
               "pass_no_collapse"):
        assert kk in sweep["acceptance"], f"acceptance missing {kk!r}"


def load_committed():
    try:
        committed = json.load(open(SERVE_JSON))
        validate_serve(committed)
    except Exception as e:  # malformed committed file is itself a failure
        return None, [f"committed BENCH_serve.json malformed: {e}"]
    return committed, []


def run_check(fresh: dict, smoke: bool, committed: dict) -> list:
    """Compare a fresh run against the committed baseline; returns a list
    of failure strings (empty = pass)."""
    failures = []
    if not fresh["bit_identity"]["bit_identical"]:
        failures.append("front door perturbed the single-tenant path: "
                        "feed() and direct ingest reports differ")
    acc = fresh["overload_sweep"]["acceptance"]
    for gate in ("pass_interactive_slo_at_peak", "pass_class_ordered_goodput",
                 "pass_bulk_sheds_first", "pass_no_collapse"):
        if not acc[gate]:
            failures.append(f"overload sweep gate failed: {gate}")
    got = acc["goodput_retention"]
    if smoke:
        base = committed.get("smoke_baseline", {}).get("goodput_retention")
        if base is not None and got < base * (1.0 - RETENTION_REGRESSION):
            failures.append(
                f"goodput retention {got} regressed >"
                f"{RETENTION_REGRESSION:.0%} vs committed baseline {base}")
    return failures


def run() -> dict:
    """Validation-suite entry (``benchmarks/run.py``): smoke-size check
    that the door stays pass-through for one tenant and degrades
    class-ordered under overload."""
    ident = bench_bit_identity(IDENTITY_CELL)
    sweep = bench_overload_sweep(SMOKE_CELL)
    return {
        "acceptance": sweep["acceptance"],
        "pass_bit_identical": bool(ident["bit_identical"]),
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; writes BENCH_serve.smoke.json instead "
                         "of overwriting the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_serve.json and fail on "
                         "bit-identity breakage, a broken degradation gate, "
                         "or a goodput-retention regression")
    args = ap.parse_args()

    cell = SMOKE_CELL if args.smoke else FULL_CELL
    mode = "smoke" if args.smoke else "full"
    committed = None
    if args.check:
        # snapshot the committed baseline BEFORE a full run overwrites it
        committed, failures = load_committed()
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
    print(f"[serve_bench] mode={mode} cell={cell}")
    doc = {"schema": SERVE_SCHEMA, "mode": mode}
    doc["bit_identity"] = bench_bit_identity(IDENTITY_CELL)
    doc["overload_sweep"] = bench_overload_sweep(cell)

    if not args.smoke:
        # embed the smoke-size baseline so CI runners compare
        # like-for-like; the sim is deterministic, so 3 fresh
        # subprocesses must agree exactly — disagreement is itself a bug
        print("[serve_bench] measuring smoke baseline for CI "
              "(3 fresh subprocesses)")
        import subprocess
        import sys
        smoke_path = os.path.join(ROOT, "BENCH_serve.smoke.json")
        samples = []
        for _ in range(3):
            subprocess.run([sys.executable, os.path.abspath(__file__),
                            "--smoke"], check=True, cwd=ROOT)
            samples.append(json.load(open(smoke_path)))
        os.remove(smoke_path)
        retentions = [s["overload_sweep"]["acceptance"]["goodput_retention"]
                      for s in samples]
        idents = [s["bit_identity"]["bit_identical"] for s in samples]
        assert all(idents), "smoke subprocess broke bit-identity"
        assert len(set(retentions)) == 1, \
            f"smoke sweep is nondeterministic: {retentions}"
        doc["smoke_baseline"] = {"goodput_retention": retentions[0],
                                 "samples": retentions}

    path = SERVE_JSON if not args.smoke else \
        os.path.join(ROOT, "BENCH_serve.smoke.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[serve_bench] wrote {path}")
    print(json.dumps({"acceptance": doc["overload_sweep"]["acceptance"],
                      "bit_identical": doc["bit_identity"]["bit_identical"]},
                     indent=2))

    if args.check:
        failures = run_check(doc, args.smoke, committed)
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
        print("[serve_bench] check OK — single-tenant path is pass-through "
              "and overload degrades class-ordered")


if __name__ == "__main__":
    main()
