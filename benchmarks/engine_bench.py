"""Engine-core benchmark — events/sec of the simulation hot loop.

One tracked artifact, written to the repo root:

* ``BENCH_engine.json`` (schema v2) — two sections:

  - ``lane_sweep``: the headline.  Simulated events/sec of the epoch
    core (cohort drain + vectorized lane bookkeeping + argmin dispatch)
    vs the classic pop-per-event heap core, on an identical saturated
    shard group at 100 / 1k / 10k lanes.  Both cores produce bitwise
    identical reports (``tests/test_engine_vectorized.py``), so the
    ratio is pure hot-loop speed.  Acceptance: epoch >= 10x heap at
    10k lanes — the fleet scale where the heap core's per-dispatch
    linear scan dominates.
  - ``event_queue``: the v1 microbench, kept as a yardstick: the
    O(log n) heap queue vs the O(n) linear-scan reference
    (``ListEventQueue``) on a 3-stage pipeline workload.

Like ``gallery_bench``, the committed file embeds a ``smoke_baseline``
measured as the min ratio over 3 fresh subprocesses at smoke sizes, so
CI can re-run ``--smoke --check`` anywhere and compare like-for-like
ratios (>20% regression fails; the 10x acceptance is absolute).

Run:  PYTHONPATH=src python benchmarks/engine_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible CI numbers

import argparse
import json
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENGINE_JSON = os.path.join(ROOT, "BENCH_engine.json")

ENGINE_SCHEMA = "champ.engine_bench.v2"

# lane count -> queued frames.  Frames shrink as lanes grow: the heap
# core's per-dispatch scan is O(lanes), so the 10k cell already costs
# seconds per rep at these sizes.
FULL_SWEEP = {100: 6000, 1000: 4000, 10000: 3000}
SMOKE_SWEEP = {100: 1500, 1000: 1000, 10000: 1000}

FULL_EVENTS = 10_000       # event_queue microbench workload
SMOKE_EVENTS = 5_000
REPS = 2                   # best-of-N: de-noises the wall-clock ratio
ACCEPT_LANES = 10_000
ACCEPT_RATIO = 10.0


# ---------------------------------------------------------------------------
# lane-count sweep: heap core vs epoch core
# ---------------------------------------------------------------------------
def bench_lane_sweep(sweep: dict) -> dict:
    from repro.runtime import build_lane_sweep_engine
    from repro.runtime.engine import ENGINE_CORES

    out = {"workload": "single shard group, identical lanes, saturated "
                       "(all frames queued at t=0)",
           "best_of": REPS, "cells": []}
    for n_lanes, n_frames in sweep.items():
        cell = {"lanes": n_lanes, "frames": n_frames}
        ref = None
        for core in ENGINE_CORES:
            best_wall, events = None, 0
            for _ in range(REPS):
                eng = build_lane_sweep_engine(n_lanes, core=core)
                eng.feed(n_frames, interval_s=0.0)
                t0 = time.perf_counter()
                rep = eng.run(until=float("inf"))
                wall = time.perf_counter() - t0
                assert rep.frames_out == n_frames, (core, rep.frames_out)
                events = eng._events.popped
                best_wall = wall if best_wall is None else min(best_wall,
                                                               wall)
            cell[core] = {
                "events_processed": events,
                "wall_s": round(best_wall, 4),
                "events_per_sec": round(events / best_wall, 1),
            }
            # same scenario, same events: cross-core report identity is
            # pinned by the test suite; here just guard the event count
            if ref is None:
                ref = events
            assert events == ref, f"core {core} fired {events} != {ref}"
        cell["epoch_vs_heap"] = round(
            cell["epoch"]["events_per_sec"] / cell["heap"]["events_per_sec"],
            2)
        out["cells"].append(cell)
    acc = [c for c in out["cells"] if c["lanes"] == ACCEPT_LANES][0]
    out["acceptance"] = {
        "lanes": ACCEPT_LANES,
        "epoch_vs_heap": acc["epoch_vs_heap"],
        "pass_10x": acc["epoch_vs_heap"] >= ACCEPT_RATIO,
    }
    return out


# ---------------------------------------------------------------------------
# event-queue microbench (the v1 heap-vs-list yardstick)
# ---------------------------------------------------------------------------
def bench_event_queue(n_frames: int) -> dict:
    from repro.bus import BusParams, SharedBus
    from repro.core import messages as msg
    from repro.core.cartridge import DeviceModel, FnCartridge
    from repro.runtime import (CapabilityRegistry, HeapEventQueue,
                               ListEventQueue, StreamEngine)

    out = {"queued_events": n_frames, "pipeline_stages": 3,
           "best_of": REPS,
           "baseline_note": "ListEventQueue is a reference O(n) "
                            "discipline, not a previously shipped core"}
    for name, qcls in (("heap", HeapEventQueue), ("list", ListEventQueue)):
        best_wall, events = None, 0
        for _ in range(REPS):                  # best-of-N (wall-clock noise)
            reg = CapabilityRegistry()
            spec = msg.MessageSpec(msg.IMAGE_FRAME)
            for i in range(3):
                reg.insert(i, FnCartridge(
                    f"s{i}", lambda p, x: x, spec, spec,
                    device=DeviceModel(service_s=2e-4)))
            eng = StreamEngine(reg, SharedBus(BusParams(
                "bench", base_overhead_s=1e-5)), event_queue=qcls(),
                core="heap")
            eng.feed(n_frames, interval_s=0.0)  # n_frames queued at t=0
            t0 = time.perf_counter()
            rep = eng.run(until=1e9)
            wall = time.perf_counter() - t0
            assert rep.frames_out == n_frames, (name, rep.frames_out)
            events = eng._events.popped
            best_wall = wall if best_wall is None else min(best_wall, wall)
        out[name] = {
            "events_processed": events,
            "wall_s": round(best_wall, 4),
            "events_per_sec": round(events / best_wall, 1),
        }
    out["heap_vs_list_speedup"] = round(
        out["heap"]["events_per_sec"] / out["list"]["events_per_sec"], 2)
    out["pass_3x"] = out["heap_vs_list_speedup"] >= 3.0
    return out


# ---------------------------------------------------------------------------
# schema validation + regression check
# ---------------------------------------------------------------------------
def validate_engine(doc: dict):
    assert doc.get("schema") == ENGINE_SCHEMA, "bad/missing schema tag"
    assert doc.get("mode") in ("full", "smoke"), "bad mode"
    for section in ("lane_sweep", "event_queue"):
        assert section in doc, f"missing section {section!r}"
    assert doc["lane_sweep"]["cells"], "empty lane sweep"
    for c in doc["lane_sweep"]["cells"]:
        for kk in ("lanes", "frames", "heap", "epoch", "epoch_vs_heap"):
            assert kk in c, f"sweep cell missing {kk!r}"
        for core in ("heap", "epoch"):
            assert "events_per_sec" in c[core]
    assert "epoch_vs_heap" in doc["lane_sweep"]["acceptance"]
    for section in ("heap", "list"):
        assert "events_per_sec" in doc["event_queue"][section]
    assert "heap_vs_list_speedup" in doc["event_queue"]


def load_committed():
    try:
        committed = json.load(open(ENGINE_JSON))
        validate_engine(committed)
    except Exception as e:  # malformed committed file is itself a failure
        return None, [f"committed BENCH_engine.json malformed: {e}"]
    return committed, []


def run_check(fresh: dict, smoke: bool, committed: dict) -> list:
    """Compare a fresh run against the committed baseline; returns a list
    of failure strings (empty = pass)."""
    failures = []
    base = committed["smoke_baseline"] if smoke else {
        "epoch_vs_heap": committed["lane_sweep"]["acceptance"]
                                  ["epoch_vs_heap"],
        "heap_vs_list_speedup": committed["event_queue"]
                                         ["heap_vs_list_speedup"],
    }
    got = fresh["lane_sweep"]["acceptance"]["epoch_vs_heap"]
    if got < ACCEPT_RATIO:
        failures.append(f"epoch core below 10x at {ACCEPT_LANES} lanes: "
                        f"{got}x")
    if got < 0.8 * base["epoch_vs_heap"]:
        failures.append(f"epoch_vs_heap regressed >20%: {got} vs baseline "
                        f"{base['epoch_vs_heap']}")
    got_q = fresh["event_queue"]["heap_vs_list_speedup"]
    if got_q < 0.8 * base["heap_vs_list_speedup"]:
        failures.append(f"heap_vs_list regressed >20%: {got_q} vs baseline "
                        f"{base['heap_vs_list_speedup']}")
    return failures


def run() -> dict:
    """Validation-suite entry (``benchmarks/run.py``): smoke-size check
    that the epoch core still clears 10x at fleet scale."""
    sweep = bench_lane_sweep(SMOKE_SWEEP)
    q = bench_event_queue(SMOKE_EVENTS)
    return {
        "acceptance": sweep["acceptance"],
        "heap_vs_list_speedup": q["heap_vs_list_speedup"],
        "pass_epoch_10x": bool(sweep["acceptance"]["pass_10x"]
                               and q["heap_vs_list_speedup"] >= 2.0),
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; writes BENCH_engine.smoke.json "
                         "instead of overwriting the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_engine.json and fail on "
                         ">20% ratio regression (10x acceptance is absolute)")
    args = ap.parse_args()

    sweep_cfg = SMOKE_SWEEP if args.smoke else FULL_SWEEP
    mode = "smoke" if args.smoke else "full"
    committed = None
    if args.check:
        # snapshot the committed baseline BEFORE a full run overwrites it
        committed, failures = load_committed()
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
    print(f"[engine_bench] mode={mode} sweep={sweep_cfg}")
    doc = {"schema": ENGINE_SCHEMA, "mode": mode}
    doc["lane_sweep"] = bench_lane_sweep(sweep_cfg)
    doc["event_queue"] = bench_event_queue(SMOKE_EVENTS if args.smoke
                                           else FULL_EVENTS)

    if not args.smoke:
        # embed smoke-size baselines so CI runners can compare
        # like-for-like.  Each sample runs in a FRESH subprocess (the
        # cold-process conditions a CI `--smoke --check` run sees) and the
        # committed baseline is the MINIMUM ratio over the samples — a
        # conservative lower bound, so a >20% drop below it is a real
        # regression, not wall-clock noise.
        print("[engine_bench] measuring smoke baseline for CI "
              "(min of 3 fresh subprocesses)")
        import subprocess
        import sys
        smoke_path = os.path.join(ROOT, "BENCH_engine.smoke.json")
        samples = []
        for _ in range(3):
            subprocess.run([sys.executable, os.path.abspath(__file__),
                            "--smoke"], check=True, cwd=ROOT)
            samples.append(json.load(open(smoke_path)))
        os.remove(smoke_path)
        ratios = [s["lane_sweep"]["acceptance"]["epoch_vs_heap"]
                  for s in samples]
        q_ratios = [s["event_queue"]["heap_vs_list_speedup"]
                    for s in samples]
        doc["smoke_baseline"] = {
            "epoch_vs_heap": min(ratios), "samples": ratios,
            "heap_vs_list_speedup": min(q_ratios),
            "heap_vs_list_samples": q_ratios,
        }

    path = ENGINE_JSON if not args.smoke else \
        os.path.join(ROOT, "BENCH_engine.smoke.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[engine_bench] wrote {path}")
    print(json.dumps({"lane_sweep_acceptance": doc["lane_sweep"]
                      ["acceptance"],
                      "event_queue": {kk: doc["event_queue"][kk] for kk in
                                      ("heap_vs_list_speedup", "pass_3x")}},
                     indent=2))

    if args.check:
        failures = run_check(doc, args.smoke, committed)
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
        print("[engine_bench] check OK — no tracked metric regressed")


if __name__ == "__main__":
    main()
