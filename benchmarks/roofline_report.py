"""Beyond-paper: render the dry-run roofline table from results JSONL.

Reads the records produced by ``repro.launch.dryrun --out`` and emits the
EXPERIMENTS.md-ready table: three terms per (arch x shape), dominant
bottleneck, MODEL_FLOPS ratio, memory fit.
"""
from __future__ import annotations

import json
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible benchmark numbers

DEFAULT_PATH = os.environ.get("DRYRUN_RESULTS", "results/dryrun_single.jsonl")


def load(path=DEFAULT_PATH):
    recs = []
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    # newest record per cell wins
    by_cell = {}
    for r in recs:
        by_cell[(r["arch"], r["shape"], r["mesh"])] = r
    return list(by_cell.values())


def table(recs) -> str:
    hdr = ("| arch | shape | mesh | rules | compute_s | memory_s | "
           "collective_s | dominant | useful | mem/dev GiB | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                         f"skip | — | — | — | — | — | {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                         f"ERROR | — | — | — | — | — | — |")
            continue
        rl, mem = r["roofline"], r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['rules']} | "
            f"{rl['compute_s']:.3e} | {rl['memory_s']:.3e} | "
            f"{rl['collective_s']:.3e} | {rl['dominant']} | "
            f"{rl['useful_ratio']:.2f} | "
            f"{mem['per_device_total'] / 2**30:.2f} | "
            f"{'y' if mem['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


def run() -> dict:
    recs = load()
    ok = [r for r in recs if r["status"] == "ok"]
    dom = {}
    for r in ok:
        dom[r["roofline"]["dominant"]] = dom.get(
            r["roofline"]["dominant"], 0) + 1
    return {
        "n_cells": len(recs),
        "n_ok": len(ok),
        "n_skip": sum(r["status"] == "skip" for r in recs),
        "n_error": sum(r["status"] == "error" for r in recs),
        "dominant_term_histogram": dom,
        "all_fit_hbm": all(r["memory"]["fits_hbm"] for r in ok) if ok else
        False,
    }


if __name__ == "__main__":
    print(table(load()))
    import json as j
    print(j.dumps(run(), indent=2))
