"""Paper §4.3 power extrapolation: 5 sticks ~1-2 W each under load =>
~7-8 W for accelerators, ~10 W with host overhead."""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible benchmark numbers

from repro.bus import calibrated
from repro.core.cartridge import DeviceModel
from repro.core import messages as msg
from repro.core.cartridge import FnCartridge
from repro.bus import BusParams, SharedBus
from repro.runtime import CapabilityRegistry, StreamEngine

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)
HOST_IDLE_W, HOST_PER_DEVICE_W = 2.0, 0.25


def run(n_devices: int = 5) -> dict:
    p = calibrated("ncs2")
    reg = CapabilityRegistry()
    for i in range(n_devices):
        reg.insert(i, FnCartridge(
            f"ncs2_{i}", lambda p_, x: x, SPEC, SPEC,
            device=DeviceModel(service_s=p.t_comp_s, power_w=1.8,
                               idle_w=0.3)))
    eng = StreamEngine(reg, SharedBus(p))
    eng.feed(300, interval_s=p.t_comp_s)
    rep = eng.run(until=120)
    device_w = 0.0
    per_device = {}
    for name, st in rep.stage_stats.items():
        util = min(st.busy_s / max(rep.sim_time, 1e-9), 1.0)
        w = util * 1.8 + (1 - util) * 0.3
        per_device[name] = round(w, 2)
        device_w += w
    host_w = HOST_IDLE_W + HOST_PER_DEVICE_W * n_devices
    return {
        "n_devices": n_devices,
        "per_device_w": per_device,
        "devices_total_w": round(device_w, 2),
        "host_w": round(host_w, 2),
        "system_w": round(device_w + host_w, 2),
        "paper_devices_band_w": [5, 10],
        "paper_system_w": 10,
        "in_band": bool(5 <= device_w + host_w <= 13),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
