"""Power-governed dispatch benchmark — the tracked §4.3 energy baseline.

One tracked artifact, written to the repo root:

* ``BENCH_power.json`` — the power governor measured for the three
  things it exists for:

  1. **Budget sweep.**  The battery kit (one hub of four calibrated
     ncs2-class sticks: ~7.2 W flat out, 1.2 W idle floor) run closed
     loop under a sweep of per-hub watt caps.  Each row reports
     aggregate FPS, p99, measured average watts, and the thermal state
     machine's activity (throttle/park events).  Acceptance: measured
     average power <= the cap in EVERY satisfiable budgeted row —
     including the deep caps that force park/duty cycling — while the
     unconstrained ablation shows what the cap costs in FPS.

  2. **Fabric-aware vs hub-blind dispatch.**  The routed two-stage
     pipeline (both stages span two hubs, deliberately slow inter-hub
     link) at equal offered load, with ``pick_lane`` either folding the
     router's current route cost into its completion estimate
     (``route_aware=True``) or chasing queue depth across the fabric
     (the pre-PR hub-blind behavior).  Acceptance: the fabric-aware
     discipline reduces cross-hub traffic share with <=10% shard-FPS
     cost.

  3. **Parity pin.**  An unlimited-budget one-hub broadcast run must
     stay bit-identical to the Table 1 closed-form simulator (the §4.1
     reproduction pinned by tests/test_replication.py) — metering is
     free, the governor only changes runs that configure a budget.

All numbers are virtual-time deterministic (discrete-event simulation
over calibrated device models), so the committed ratios are exact on
any machine; the ``smoke_baseline`` is still measured as the min over 3
fresh subprocesses for discipline parity with the other benches.

Run:  PYTHONPATH=src python benchmarks/power_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible CI numbers

import argparse
import json

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
POWER_JSON = os.path.join(ROOT, "BENCH_power.json")

POWER_SCHEMA = "champ.power_bench.v1"

FULL_CFG = dict(sweep_frames=600, budgets=(None, 6.0, 4.0, 3.0, 2.0),
                route_bursts=150, parity_frames=100)
# sweep_frames must amortize the cold-start ramp (the hub runs at full
# draw until the thermal estimate crosses the cap): ~450 frames is the
# smallest size where every smoke cap holds its average
SMOKE_CFG = dict(sweep_frames=450, budgets=(None, 4.0, 2.0),
                 route_bursts=80, parity_frames=60)

DEVICE = "ncs2"          # the paper's Table 1 calibration
N_STICKS = 4


# ---------------------------------------------------------------------------
# 1. budget sweep: FPS / p99 / measured watts vs per-hub cap
# ---------------------------------------------------------------------------
def bench_budget_sweep(cfg) -> dict:
    from repro.runtime import run_battery

    rows = {}
    for budget in cfg["budgets"]:
        rep = run_battery(budget, n_frames=cfg["sweep_frames"],
                          n_devices=N_STICKS, device=DEVICE)
        assert rep.lost == 0, f"budget {budget} lost {rep.lost} frames"
        hub = rep.power["hubs"][0]
        key = "unlimited" if budget is None else f"{budget:g}W"
        rows[key] = {
            "budget_w": budget,
            "fps": round(rep.throughput(), 2),
            "p99_ms": round(rep.p99() * 1e3, 1),
            "avg_w": hub["avg_w"],
            "energy_j": rep.power["total_j"],
            "state": hub["state"],
            "throttle_events": hub["throttle_events"],
            "park_events": hub["park_events"],
            "throttled_s": hub["throttled_s"],
            "parked_s": hub["parked_s"],
            "unsatisfiable": hub["unsatisfiable"],
            "within_budget": bool(budget is None
                                  or hub["avg_w"] <= budget
                                  or hub["unsatisfiable"]),
        }
    free = rows["unlimited"]
    for key, row in rows.items():
        row["fps_vs_unlimited"] = round(row["fps"] / free["fps"], 3)
    return {
        "workload": f"{N_STICKS}x {DEVICE} on one hub, closed loop, "
                    f"{cfg['sweep_frames']} frames",
        "idle_floor_w": round(N_STICKS * 0.3, 2),
        "full_draw_w": round(N_STICKS * 1.8, 2),
        "rows": rows,
    }


# ---------------------------------------------------------------------------
# 2. fabric-aware vs hub-blind dispatch (routed-cost pick_lane)
# ---------------------------------------------------------------------------
def bench_route_aware(cfg) -> dict:
    from repro.runtime import build_routed_pipeline_engine

    out = {"workload": "2-stage pipeline, both stages span 2 hubs, "
                       "slow inter-hub link (~5 ms/frame), bursty @ "
                       "0.85 load"}
    for key, aware in (("hub_blind", False), ("fabric_aware", True)):
        rep = build_routed_pipeline_engine(
            route_aware=aware, n_bursts=cfg["route_bursts"]).run(until=1e12)
        assert rep.lost == 0, f"{key} lost {rep.lost} frames"
        cross = rep.bus["cross_hub_transfers"]
        out[key] = {
            "fps": round(rep.throughput(), 2),
            "p50_ms": round(rep.p50() * 1e3, 2),
            "p99_ms": round(rep.p99() * 1e3, 2),
            "cross_hub_transfers": cross,
            "cross_hub_per_frame": round(cross / rep.frames_out, 4),
            "link_busy_s": rep.bus["links"].get(
                "0<->1", {}).get("busy_s", 0.0),
            "frames": rep.frames_out,
        }
    blind, aware = out["hub_blind"], out["fabric_aware"]
    out["cross_share_ratio"] = round(
        aware["cross_hub_per_frame"] /
        max(blind["cross_hub_per_frame"], 1e-9), 3)
    out["fps_ratio"] = round(aware["fps"] / max(blind["fps"], 1e-9), 4)
    return out


# ---------------------------------------------------------------------------
# 3. parity pin: unlimited budget == Table 1, bit-identical
# ---------------------------------------------------------------------------
def bench_parity(cfg) -> dict:
    from repro.bus import calibrated, simulate_broadcast_fps
    from repro.runtime import engine_broadcast_fps

    n = cfg["parity_frames"]
    rows = {}
    exact = True
    for device in ("ncs2", "coral"):
        p = calibrated(device)
        for k in (1, 5):
            eng = engine_broadcast_fps(device, k, n_frames=n)
            sim = simulate_broadcast_fps(p, k, n_frames=n)
            ok = abs(eng - sim) <= 1e-9 * max(eng, sim)
            exact = exact and ok
            rows[f"{device}_n{k}"] = {"engine_fps": eng, "simulator_fps": sim,
                                      "bit_identical": bool(ok)}
    return {"rows": rows, "all_bit_identical": bool(exact)}


def _acceptance(sweep: dict, route: dict, parity: dict) -> dict:
    budgeted = {k: r for k, r in sweep["rows"].items()
                if r["budget_w"] is not None}
    throttled = {k: r for k, r in budgeted.items()
                 if r["throttle_events"] > 0 and not r["unsatisfiable"]}
    return {
        "budgeted_rows": len(budgeted),
        "throttled_rows": len(throttled),
        # (a) measured average power respects the cap wherever it is
        #     physically satisfiable (incl. park/duty-cycling rows)
        "pass_budget": bool(budgeted
                            and all(r["within_budget"]
                                    for r in budgeted.values())
                            and len(throttled) >= 1),
        "worst_margin": round(min(
            (r["budget_w"] - r["avg_w"] for r in budgeted.values()
             if not r["unsatisfiable"]), default=0.0), 4),
        # (b) fabric-aware dispatch keeps traffic hub-local at <=10% cost
        "cross_share_ratio": route["cross_share_ratio"],
        "fps_ratio": route["fps_ratio"],
        "pass_route": bool(route["cross_share_ratio"] < 1.0
                           and route["fps_ratio"] >= 0.90),
        # (c) metering alone never moves the Table 1 reproduction
        "pass_parity": parity["all_bit_identical"],
    }


# ---------------------------------------------------------------------------
# schema validation + regression check
# ---------------------------------------------------------------------------
def validate_power(doc: dict):
    assert doc.get("schema") == POWER_SCHEMA, "bad/missing schema tag"
    assert doc.get("mode") in ("full", "smoke"), "bad mode"
    for section in ("budget_sweep", "route_aware", "parity", "acceptance"):
        assert section in doc, f"missing section {section!r}"
    for kk in ("pass_budget", "pass_route", "pass_parity",
               "cross_share_ratio", "fps_ratio"):
        assert kk in doc["acceptance"], f"acceptance missing {kk!r}"
    if doc["mode"] == "full":
        assert "smoke_baseline" in doc, "missing smoke_baseline"
        for kk in ("cross_share_ratio", "fps_ratio"):
            assert kk in doc["smoke_baseline"], \
                f"smoke_baseline missing {kk!r}"


def load_committed():
    try:
        doc = json.load(open(POWER_JSON))
        validate_power(doc)
    except Exception as e:
        return None, [f"committed BENCH_power.json malformed: {e}"]
    return doc, []


def run_check(fresh: dict, smoke: bool, committed: dict) -> list:
    failures = []
    base = committed["smoke_baseline"] if smoke else committed["acceptance"]
    acc = fresh["acceptance"]
    if not acc["pass_budget"]:
        failures.append("a budgeted configuration exceeded its watt cap")
    if not acc["pass_parity"]:
        failures.append("unlimited-budget run no longer bit-identical to "
                        "the Table 1 simulator")
    if not acc["pass_route"]:
        failures.append(
            f"fabric-aware dispatch stopped paying for itself: "
            f"cross-share ratio {acc['cross_share_ratio']}, "
            f"fps ratio {acc['fps_ratio']}")
    # cross-hub savings must not erode >20% vs the committed baseline
    # (ratios are <1; a LARGER ratio means less traffic kept local)
    got, want = acc["cross_share_ratio"], base["cross_share_ratio"]
    if (1.0 - got) < 0.8 * (1.0 - want):
        failures.append(f"cross-hub share reduction regressed >20%: "
                        f"ratio {got} vs baseline {want}")
    got_f, want_f = acc["fps_ratio"], base["fps_ratio"]
    if got_f < 0.8 * want_f:
        failures.append(f"route-aware fps ratio regressed >20%: "
                        f"{got_f} vs baseline {want_f}")
    return failures


def run() -> dict:
    """Validation-suite entry (``benchmarks/run.py``): smoke-size check
    that the governor still clears its budget/route/parity gates."""
    sweep = bench_budget_sweep(SMOKE_CFG)
    route = bench_route_aware(SMOKE_CFG)
    parity = bench_parity(SMOKE_CFG)
    acc = _acceptance(sweep, route, parity)
    return {
        "acceptance": acc,
        "pass_power": bool(acc["pass_budget"] and acc["pass_route"]
                           and acc["pass_parity"]),
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; writes BENCH_power.smoke.json "
                         "instead of overwriting the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_power.json and fail on "
                         ">20% ratio regression")
    args = ap.parse_args()

    cfg = SMOKE_CFG if args.smoke else FULL_CFG
    mode = "smoke" if args.smoke else "full"
    committed = None
    if args.check:
        committed, failures = load_committed()
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))

    print(f"[power_bench] mode={mode} sweep_frames={cfg['sweep_frames']} "
          f"budgets={cfg['budgets']}")
    doc = {"schema": POWER_SCHEMA, "mode": mode}
    doc["budget_sweep"] = bench_budget_sweep(cfg)
    doc["route_aware"] = bench_route_aware(cfg)
    doc["parity"] = bench_parity(cfg)
    doc["acceptance"] = _acceptance(doc["budget_sweep"], doc["route_aware"],
                                    doc["parity"])

    if not args.smoke:
        # smoke baselines for CI parity with the other benches: min over 3
        # fresh subprocesses (the ratios are virtual-time deterministic,
        # so the min is a stability assertion, not noise filtering)
        print("[power_bench] measuring smoke baseline for CI "
              "(min of 3 fresh subprocesses)")
        import subprocess
        import sys
        smoke_path = os.path.join(ROOT, "BENCH_power.smoke.json")
        samples = []
        for _ in range(3):
            subprocess.run([sys.executable, os.path.abspath(__file__),
                            "--smoke"], check=True, cwd=ROOT)
            samples.append(json.load(open(smoke_path))["acceptance"])
        os.remove(smoke_path)
        doc["smoke_baseline"] = {
            "cross_share_ratio": min(a["cross_share_ratio"]
                                     for a in samples),
            "fps_ratio": min(a["fps_ratio"] for a in samples),
            "samples": [{"cross_share_ratio": a["cross_share_ratio"],
                         "fps_ratio": a["fps_ratio"]} for a in samples],
        }

    if args.check:
        # check BEFORE writing: a failed check must not clobber the
        # committed baseline it was compared against
        failures = run_check(doc, args.smoke, committed)
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
        print("[power_bench] check OK — no tracked metric regressed")

    path = POWER_JSON if not args.smoke else \
        os.path.join(ROOT, "BENCH_power.smoke.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[power_bench] wrote {path}")
    print(json.dumps(doc["acceptance"], indent=2))


if __name__ == "__main__":
    main()
