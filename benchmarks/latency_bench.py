"""Tail-latency benchmark — the tracked p50/p95/p99 baseline.

One tracked artifact, written to the repo root:

* ``BENCH_latency.json`` — the StreamEngine dispatch hot path measured
  for *tail latency*: (homogeneous vs mixed lane groups) x (hedging
  on/off) x load factors, all at equal offered load per comparison.
  The headline cell is the mixed-replica straggler scenario — two clean
  Coral-class lanes plus one degraded, occasionally-stalling NCS2-class
  lane — where the PR 2 baseline discipline (queue-depth least-loaded, no
  hedging) is compared against the tail-aware fast path (EWMA-weighted
  dispatch + hedged shard lanes).  Acceptance: >=2x p99 improvement with
  shard throughput within 5%.

Throughput parity is tracked two ways:

* simulated — closed-loop shard FPS (the ``BENCH_engine.json`` workload
  shape: identical sticks, saturated) must agree within 5% between the
  baseline and fast-path disciplines; virtual-time results are exact and
  machine-portable.
* wall-clock — simulated events/sec of the hot loop with the fast path
  enabled vs the baseline discipline on the same queued-frame workload
  (the ``BENCH_engine.json`` microbench), so the EWMA/hedge bookkeeping
  shows up if it ever makes the loop itself slow.

Like ``gallery_bench``, the committed file embeds a ``smoke_baseline``
measured as the min over 3 fresh subprocesses at smoke sizes, so CI can
re-run ``--smoke --check`` anywhere and compare like-for-like ratios
(>20% regression fails).  Latency ratios are virtual-time deterministic;
only the hot-loop wall-clock ratio is machine-dependent.

Run:  PYTHONPATH=src python benchmarks/latency_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible CI numbers

import argparse
import json
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LATENCY_JSON = os.path.join(ROOT, "BENCH_latency.json")

LATENCY_SCHEMA = "champ.latency_bench.v1"

FULL_CFG = dict(n_bursts=200, burst=5, loads=(0.5, 0.75, 0.9),
                parity_frames=400, hotloop_frames=10_000, hotloop_reps=3)
SMOKE_CFG = dict(n_bursts=80, burst=5, loads=(0.75,),
                 parity_frames=150, hotloop_frames=3_000, hotloop_reps=3)

# lane-group rosters (DeviceModel kwargs).  The "straggler" is an
# NCS2-class stick that degraded in the field: 5x the Coral service time
# and a 5% chance any service cycle stalls another 10x (USB re-enumeration
# / thermal throttling).  Jitter draws hash (lane, seq): deterministic.
FAST = dict(name="coral", service_s=0.02)
JITTERY = dict(name="coral", service_s=0.02, jitter_p=0.03, jitter_mult=10.0)
STRAGGLER = dict(name="ncs2_degraded", service_s=0.10,
                 jitter_p=0.05, jitter_mult=10.0)

GROUPS = {
    "mixed_straggler": (FAST, FAST, STRAGGLER),
    "homogeneous_jittery": (JITTERY, JITTERY, JITTERY),
}

# dispatch discipline cells: PR 2 baseline vs the tail-aware fast path
CELLS = (
    ("pr2_baseline", dict(dispatch="naive", hedge=False)),
    ("ewma_only", dict(dispatch="ewma", hedge=False)),
    ("ewma_hedged", dict(dispatch="ewma", hedge=True)),
)


def _capacity(devs) -> float:
    return sum(1.0 / d["service_s"] for d in devs)


def _run_scenario(devs, load: float, n_bursts: int, burst: int, **engine_kw):
    """Bursty offered load (multi-camera sync pulls ``burst`` frames at
    once) at ``load`` x the group's nominal aggregate capacity."""
    from repro.core.cartridge import DeviceModel
    from repro.runtime import build_mixed_engine

    period = burst / (load * _capacity(devs))
    eng = build_mixed_engine([DeviceModel(**d) for d in devs], **engine_kw)
    for i in range(n_bursts):
        eng.feed(burst, interval_s=0.0, t0=i * period)
    rep = eng.run(until=1e12)
    n = n_bursts * burst
    assert rep.frames_out == n, \
        f"lost {rep.lost} frames ({engine_kw}, load={load})"
    return rep


def _cell_stats(rep) -> dict:
    return {
        "p50_ms": round(rep.p50() * 1e3, 2),
        "p95_ms": round(rep.p95() * 1e3, 2),
        "p99_ms": round(rep.p99() * 1e3, 2),
        "max_ms": round(rep.latency_hist.max * 1e3, 2),
        "mean_ms": round(rep.mean_latency() * 1e3, 2),
        "throughput_fps": round(rep.throughput(), 2),
        "hedges": dict(rep.hedges),
        "suppressed_transfers": rep.bus["suppressed_transfers"],
    }


def bench_latency(cfg) -> dict:
    out = {"config": {k: cfg[k] for k in ("n_bursts", "burst", "loads")},
           "groups": {}}
    for gname, devs in GROUPS.items():
        out["groups"][gname] = {
            "devices": [d["name"] for d in devs], "loads": {}}
        for load in cfg["loads"]:
            row = {}
            for cname, kw in CELLS:
                rep = _run_scenario(devs, load, cfg["n_bursts"],
                                    cfg["burst"], **kw)
                row[cname] = _cell_stats(rep)
            row["p99_improvement_vs_pr2"] = round(
                row["pr2_baseline"]["p99_ms"] /
                max(row["ewma_hedged"]["p99_ms"], 1e-9), 2)
            out["groups"][gname]["loads"][f"{load:.2f}"] = row
    return out


def bench_throughput_parity(cfg) -> dict:
    """Closed-loop shard FPS (identical sticks, saturated — the
    ``BENCH_engine.json`` workload shape): the fast path must not tax
    steady-state throughput.  Virtual time, exact on any machine."""
    from repro.runtime import engine_shard_fps

    n = cfg["parity_frames"]
    base = engine_shard_fps("ncs2", 3, n_frames=n,
                            dispatch="naive", hedge=False)
    fast = engine_shard_fps("ncs2", 3, n_frames=n,
                            dispatch="ewma", hedge=True)
    ratio = round(fast / base, 4)
    return {
        "workload": f"shard ncs2 x3, closed loop, {n} frames",
        "pr2_baseline_fps": round(base, 2),
        "ewma_hedged_fps": round(fast, 2),
        "ratio": ratio,
        "pass_5pct": ratio >= 0.95,
    }


def bench_hotloop(cfg) -> dict:
    """Wall-clock events/sec of the dispatch hot loop, fast path vs
    baseline, on the ``BENCH_engine.json`` queued-frame workload shape —
    the EWMA/hedge bookkeeping must not slow the loop itself.  The middle
    stage is a 3-replica jittery shard group so the hedged cell actually
    arms, fires, and suppresses hedges (asserted below): the ratio
    measures the machinery, not a no-op flag."""
    from repro.bus import BusParams, SharedBus
    from repro.core import messages as msg
    from repro.core.cartridge import DeviceModel, FnCartridge
    from repro.runtime import CapabilityRegistry, StreamEngine

    n_frames = cfg["hotloop_frames"]
    out = {"queued_events": n_frames, "pipeline_stages": 3,
           "mid_stage_replicas": 3, "best_of": cfg["hotloop_reps"]}
    for cname, kw in (("pr2_baseline", dict(dispatch="naive", hedge=False)),
                      ("ewma_hedged", dict(dispatch="ewma", hedge=True))):
        best, hedges = None, 0
        for _ in range(cfg["hotloop_reps"]):
            reg = CapabilityRegistry()
            spec = msg.MessageSpec(msg.IMAGE_FRAME)
            for i in range(3):
                reg.insert(i, FnCartridge(
                    f"s{i}", lambda p, x: x, spec, spec, capability_id=i,
                    device=DeviceModel(service_s=2e-4)))
            mid = reg.slots[1].cartridge
            mid.device = DeviceModel(service_s=2e-4,
                                     jitter_p=0.02, jitter_mult=10.0)
            for r in range(2):
                reg.add_replica(1, mid.clone())
            eng = StreamEngine(reg, SharedBus(BusParams(
                "bench", base_overhead_s=1e-5)), **kw)
            eng.feed(n_frames, interval_s=0.0)
            t0 = time.perf_counter()
            rep = eng.run(until=1e9)
            wall = time.perf_counter() - t0
            assert rep.frames_out == n_frames
            events = eng._events.popped
            hedges = rep.hedges["issued"]
            best = wall if best is None else min(best, wall)
        if cname == "ewma_hedged":
            assert hedges > 0, \
                "hot-loop workload no longer exercises the hedge machinery"
        out[cname] = {"wall_s": round(best, 4),
                      "events_per_sec": round(events / best, 1),
                      "hedges_issued": hedges}
    out["events_ratio"] = round(
        out["ewma_hedged"]["events_per_sec"] /
        out["pr2_baseline"]["events_per_sec"], 3)
    return out


def _acceptance(lat: dict, parity: dict, hotloop: dict, cfg) -> dict:
    # headline: mixed straggler at the highest measured load factor
    load_key = f"{max(cfg['loads']):.2f}"
    head = lat["groups"]["mixed_straggler"]["loads"][load_key]
    imp = head["p99_improvement_vs_pr2"]
    thr_ratio = round(head["ewma_hedged"]["throughput_fps"] /
                      max(head["pr2_baseline"]["throughput_fps"], 1e-9), 4)
    return {
        "scenario": f"mixed_straggler @ load {load_key}",
        "p99_baseline_ms": head["pr2_baseline"]["p99_ms"],
        "p99_fastpath_ms": head["ewma_hedged"]["p99_ms"],
        "p99_improvement": imp,
        "pass_p99_2x": imp >= 2.0,
        "offered_load_throughput_ratio": thr_ratio,
        "shard_throughput_ratio": parity["ratio"],
        "pass_throughput_5pct": bool(parity["pass_5pct"]
                                     and thr_ratio >= 0.95),
        "hotloop_events_ratio": hotloop["events_ratio"],
        # hard floor catches catastrophic slowdowns only; gradual drift is
        # caught by the >20%-vs-committed-smoke-baseline check (run_check)
        "pass_hotloop": hotloop["events_ratio"] >= 0.65,
    }


# ---------------------------------------------------------------------------
# schema validation + regression check
# ---------------------------------------------------------------------------
def validate_latency(doc: dict):
    assert doc.get("schema") == LATENCY_SCHEMA, "bad/missing schema tag"
    assert doc.get("mode") in ("full", "smoke"), "bad mode"
    for section in ("latency", "throughput_parity", "hotloop", "acceptance"):
        assert section in doc, f"missing section {section!r}"
    for g in ("mixed_straggler", "homogeneous_jittery"):
        assert g in doc["latency"]["groups"], f"missing group {g!r}"
    for kk in ("p99_improvement", "shard_throughput_ratio",
               "hotloop_events_ratio"):
        assert kk in doc["acceptance"], f"acceptance missing {kk!r}"
    if doc["mode"] == "full":       # committed baselines must carry the
        assert "smoke_baseline" in doc, "missing smoke_baseline"
        for kk in ("p99_improvement", "hotloop_events_ratio"):
            assert kk in doc["smoke_baseline"], \
                f"smoke_baseline missing {kk!r}"


def load_committed():
    try:
        doc = json.load(open(LATENCY_JSON))
        validate_latency(doc)
    except Exception as e:
        return None, [f"committed BENCH_latency.json malformed: {e}"]
    return doc, []


def run_check(fresh: dict, smoke: bool, committed: dict) -> list:
    failures = []
    base = committed["smoke_baseline"] if smoke else committed["acceptance"]
    got = fresh["acceptance"]["p99_improvement"]
    want = base["p99_improvement"]
    if got < 0.8 * want:
        failures.append(f"p99 improvement regressed >20%: "
                        f"{got} vs baseline {want}")
    if not fresh["acceptance"]["pass_p99_2x"]:
        failures.append(f"p99 improvement below 2x: {got}")
    if not fresh["acceptance"]["pass_throughput_5pct"]:
        failures.append(
            f"shard throughput parity broken: "
            f"{fresh['acceptance']['shard_throughput_ratio']}")
    got_ev = fresh["acceptance"]["hotloop_events_ratio"]
    want_ev = base["hotloop_events_ratio"]
    if got_ev < 0.8 * want_ev:
        failures.append(f"hot-loop events/sec ratio regressed >20%: "
                        f"{got_ev} vs baseline {want_ev}")
    return failures


def run() -> dict:
    """Validation-suite entry (``benchmarks/run.py``): smoke-size check
    that the fast path still clears its tail + parity gates."""
    lat = bench_latency(SMOKE_CFG)
    parity = bench_throughput_parity(SMOKE_CFG)
    hotloop = bench_hotloop(SMOKE_CFG)
    acc = _acceptance(lat, parity, hotloop, SMOKE_CFG)
    return {
        "acceptance": acc,
        "pass_tail": bool(acc["pass_p99_2x"]
                          and acc["pass_throughput_5pct"]
                          and acc["pass_hotloop"]),
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; writes BENCH_latency.smoke.json "
                         "instead of overwriting the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_latency.json and fail on "
                         ">20% ratio regression")
    args = ap.parse_args()

    cfg = SMOKE_CFG if args.smoke else FULL_CFG
    mode = "smoke" if args.smoke else "full"
    committed = None
    if args.check:
        committed, failures = load_committed()
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))

    print(f"[latency_bench] mode={mode} bursts={cfg['n_bursts']} "
          f"loads={cfg['loads']}")
    doc = {"schema": LATENCY_SCHEMA, "mode": mode}
    doc["latency"] = bench_latency(cfg)
    doc["throughput_parity"] = bench_throughput_parity(cfg)
    doc["hotloop"] = bench_hotloop(cfg)
    doc["acceptance"] = _acceptance(doc["latency"], doc["throughput_parity"],
                                    doc["hotloop"], cfg)

    if not args.smoke:
        # smoke baselines for CI: min over 3 FRESH subprocesses (the
        # cold-process conditions a CI `--smoke --check` run sees), so a
        # >20% drop below the committed floor is a real regression, not
        # wall-clock noise.  (Latency ratios are virtual-time exact; the
        # min matters for the hot-loop wall-clock ratio.)
        print("[latency_bench] measuring smoke baseline for CI "
              "(min of 3 fresh subprocesses)")
        import subprocess
        import sys
        smoke_path = os.path.join(ROOT, "BENCH_latency.smoke.json")
        samples = []
        for _ in range(3):
            subprocess.run([sys.executable, os.path.abspath(__file__),
                            "--smoke"], check=True, cwd=ROOT)
            samples.append(json.load(open(smoke_path))["acceptance"])
        os.remove(smoke_path)
        doc["smoke_baseline"] = {
            "p99_improvement": min(a["p99_improvement"] for a in samples),
            "hotloop_events_ratio": min(a["hotloop_events_ratio"]
                                        for a in samples),
            "samples": [{"p99_improvement": a["p99_improvement"],
                         "hotloop_events_ratio": a["hotloop_events_ratio"]}
                        for a in samples],
        }

    if args.check:
        # check BEFORE writing: a failed check must not clobber the
        # committed baseline it was compared against
        failures = run_check(doc, args.smoke, committed)
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
        print("[latency_bench] check OK — no tracked metric regressed")

    path = LATENCY_JSON if not args.smoke else \
        os.path.join(ROOT, "BENCH_latency.smoke.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[latency_bench] wrote {path}")
    print(json.dumps(doc["acceptance"], indent=2))


if __name__ == "__main__":
    main()
