"""Paper §3.1/§3.3: encrypted-gallery matching. Validates that matching in
the protected (rotated) space returns identical top-k to raw-space cosine
matching, and times the gallery_match kernel per gallery size."""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible benchmark numbers

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import KeyedRotation, SecureGallery
from repro.kernels import ops as K
from repro.kernels import ref as R


def run() -> dict:
    rng = np.random.default_rng(0)
    dim, nq = 512, 64                     # FaceNet-style embeddings
    out = {"cells": []}
    identical_all = True
    for n in (1_000, 10_000, 50_000):
        gallery = rng.normal(size=(n, dim)).astype(np.float32)
        queries = gallery[rng.integers(0, n, nq)] + \
            0.1 * rng.normal(size=(nq, dim)).astype(np.float32)
        rot = KeyedRotation(dim, seed=3)
        gq, gg = jnp.asarray(queries), jnp.asarray(gallery)
        pq, pg = rot.protect(gq), rot.protect(gg)

        # raw-space reference vs protected-space kernel
        qn = gq / jnp.linalg.norm(gq, axis=-1, keepdims=True)
        gn = gg / jnp.linalg.norm(gg, axis=-1, keepdims=True)
        _, idx_raw = R.gallery_match_ref(qn, gn, k=5)
        t0 = time.perf_counter()
        scores, idx_prot = K.gallery_match(pq, pg, k=5)
        jax.block_until_ready(scores)
        dt = time.perf_counter() - t0
        identical = bool(jnp.all(idx_raw == idx_prot))
        identical_all &= identical
        out["cells"].append({
            "gallery_size": n,
            "identical_topk_under_protection": identical,
            "match_us_per_query": round(dt / nq * 1e6, 1),
        })
    out["identical_all"] = identical_all
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
