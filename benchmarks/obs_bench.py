"""Observability benchmark — what does the flight recorder cost?

One tracked artifact, written to the repo root:

* ``BENCH_obs.json`` (schema v1) — the trace-overhead sweep on the
  10k-lane engine-bench cell (single saturated shard group, epoch
  core): simulated events/sec with tracing off, sampled (1 frame in
  16), and full (every frame).  Two gates:

  - **bit-identity** (absolute, exact): all three variants produce
    float-for-float identical reports — frames, sim time, the full
    per-frame latency list, hedge/fault counters.  The recorder only
    observes; a single perturbed float fails the bench.
  - **sampled overhead < 5%** (the CI contract): tracing at 1/16 must
    cost less than 5% events/sec vs tracing off.  Full tracing is
    reported but not gated — it is the debugging configuration, not
    the always-on one.

Like ``engine_bench``, the committed file embeds a ``smoke_baseline``
measured over 3 fresh subprocesses at smoke sizes, so a CI
``--smoke --check`` run compares like-for-like: bit-identity is checked
absolutely, and the smoke overhead gate allows 5 percentage points of
headroom over the committed smoke baseline (wall-clock noise at smoke
sizes is real; a genuine hot-path regression blows through both).

Run:  PYTHONPATH=src python benchmarks/obs_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible CI numbers

import argparse
import json
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OBS_JSON = os.path.join(ROOT, "BENCH_obs.json")

OBS_SCHEMA = "champ.obs_bench.v1"

# the engine-bench fleet cell: events/sec is dominated by per-event
# bookkeeping, which is exactly where recorder calls sit
FULL_CELL = {"lanes": 10_000, "frames": 3000}
SMOKE_CELL = {"lanes": 10_000, "frames": 1000}
SAMPLE = 16                 # the sampled-tracing rate under test
REPS = 5                    # best-of-N: de-noises the wall-clock ratio
ACCEPT_OVERHEAD_PCT = 5.0   # sampled tracing must cost < 5% events/sec
SMOKE_HEADROOM_PCT = 5.0    # smoke gate: baseline + headroom

VARIANTS = (                # name -> StreamEngine trace kwargs
    ("off", {}),
    ("sampled", {"trace": True, "trace_sample": SAMPLE}),
    ("full", {"trace": True}),
)


def _sig(rep):
    """Everything float-valued the engine computes, exactly."""
    return (rep.frames_in, rep.frames_out, rep.sim_time, rep.last_out_t,
            tuple(rep.latencies), tuple(sorted(rep.hedges.items())),
            tuple(sorted(rep.faults.items())))


# ---------------------------------------------------------------------------
# the sweep: off vs sampled vs full on one saturated fleet cell
# ---------------------------------------------------------------------------
def bench_trace_overhead(cell: dict) -> dict:
    from repro.runtime import build_lane_sweep_engine

    n_lanes, n_frames = cell["lanes"], cell["frames"]
    out = {"workload": "single shard group, identical lanes, saturated "
                       "(all frames queued at t=0), epoch core",
           "lanes": n_lanes, "frames": n_frames, "sample": SAMPLE,
           "best_of": REPS}
    sigs = {}
    best = {name: None for name, _ in VARIANTS}
    events = {name: 0 for name, _ in VARIANTS}
    trace_stats = {}
    # reps interleave ACROSS variants (off, sampled, full, off, ...):
    # each cell is sub-second, so a transient load spike during a
    # per-variant block would read as fake overhead — interleaving puts
    # every variant under the same drift, and best-of-N drops the spike
    for _ in range(REPS):
        for name, kw in VARIANTS:
            eng = build_lane_sweep_engine(n_lanes, **kw)
            eng.feed(n_frames, interval_s=0.0)
            t0 = time.perf_counter()
            rep = eng.run(until=float("inf"))
            wall = time.perf_counter() - t0
            assert rep.frames_out == n_frames, (name, rep.frames_out)
            events[name] = eng._events.popped
            if best[name] is None or wall < best[name]:
                best[name] = wall
            sigs[name] = _sig(rep)
            if rep.trace is not None:
                s = rep.trace.snapshot()
                trace_stats[name] = {k: s[k] for k in
                                     ("entries", "spans_opened", "instants",
                                      "evicted", "frames_admitted",
                                      "frames_skipped", "end_misses")}
    for name, _ in VARIANTS:
        out[name] = {
            "events_processed": events[name],
            "wall_s": round(best[name], 4),
            "events_per_sec": round(events[name] / best[name], 1),
        }
        if name in trace_stats:
            out[name]["trace"] = trace_stats[name]

    # gate 1: the recorder only observes — one perturbed float fails
    bit_identical = sigs["off"] == sigs["sampled"] == sigs["full"]
    # gate 2: sampled tracing costs < 5% events/sec
    eps = {name: out[name]["events_per_sec"] for name, _ in VARIANTS}
    overhead = {name: round((eps["off"] / eps[name] - 1.0) * 100.0, 2)
                for name in ("sampled", "full")}
    out["bit_identical"] = bool(bit_identical)
    out["overhead_pct"] = overhead
    out["acceptance"] = {
        "bit_identical": bool(bit_identical),
        "sampled_overhead_pct": overhead["sampled"],
        "pass_overhead_5pct": overhead["sampled"] < ACCEPT_OVERHEAD_PCT,
    }
    return out


# ---------------------------------------------------------------------------
# schema validation + regression check
# ---------------------------------------------------------------------------
def validate_obs(doc: dict):
    assert doc.get("schema") == OBS_SCHEMA, "bad/missing schema tag"
    assert doc.get("mode") in ("full", "smoke"), "bad mode"
    sweep = doc.get("trace_overhead")
    assert sweep, "missing trace_overhead section"
    for name, _ in VARIANTS:
        assert "events_per_sec" in sweep[name], f"variant {name} incomplete"
    for kk in ("bit_identical", "sampled_overhead_pct",
               "pass_overhead_5pct"):
        assert kk in sweep["acceptance"], f"acceptance missing {kk!r}"


def load_committed():
    try:
        committed = json.load(open(OBS_JSON))
        validate_obs(committed)
    except Exception as e:  # malformed committed file is itself a failure
        return None, [f"committed BENCH_obs.json malformed: {e}"]
    return committed, []


def run_check(fresh: dict, smoke: bool, committed: dict) -> list:
    """Compare a fresh run against the committed baseline; returns a list
    of failure strings (empty = pass)."""
    failures = []
    acc = fresh["trace_overhead"]["acceptance"]
    if not acc["bit_identical"]:
        failures.append("tracing perturbed the simulation: traced and "
                        "untraced reports differ")
    got = acc["sampled_overhead_pct"]
    if smoke:
        base = committed.get("smoke_baseline", {}).get(
            "sampled_overhead_pct", 0.0)
        limit = max(ACCEPT_OVERHEAD_PCT, base + SMOKE_HEADROOM_PCT)
    else:
        limit = ACCEPT_OVERHEAD_PCT
    if got >= limit:
        failures.append(f"sampled tracing overhead {got}% >= {limit}% "
                        f"(1/{SAMPLE} sampling on the "
                        f"{fresh['trace_overhead']['lanes']}-lane cell)")
    return failures


def run() -> dict:
    """Validation-suite entry (``benchmarks/run.py``): smoke-size check
    that tracing stays observation-only and sampled tracing stays cheap."""
    sweep = bench_trace_overhead(SMOKE_CELL)
    return {
        "acceptance": sweep["acceptance"],
        "overhead_pct": sweep["overhead_pct"],
        "pass_bit_identical": bool(sweep["bit_identical"]),
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; writes BENCH_obs.smoke.json instead "
                         "of overwriting the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_obs.json and fail on "
                         "bit-identity breakage or sampled overhead over "
                         "the gate")
    args = ap.parse_args()

    cell = SMOKE_CELL if args.smoke else FULL_CELL
    mode = "smoke" if args.smoke else "full"
    committed = None
    if args.check:
        # snapshot the committed baseline BEFORE a full run overwrites it
        committed, failures = load_committed()
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
    print(f"[obs_bench] mode={mode} cell={cell}")
    doc = {"schema": OBS_SCHEMA, "mode": mode}
    doc["trace_overhead"] = bench_trace_overhead(cell)

    if not args.smoke:
        # embed smoke-size baselines so CI runners compare like-for-like;
        # each sample is a FRESH subprocess (cold-process CI conditions),
        # and the baseline keeps the MAX overhead over the samples — the
        # conservative bound for a "got noticeably worse" gate.
        print("[obs_bench] measuring smoke baseline for CI "
              "(3 fresh subprocesses)")
        import subprocess
        import sys
        smoke_path = os.path.join(ROOT, "BENCH_obs.smoke.json")
        samples = []
        for _ in range(3):
            subprocess.run([sys.executable, os.path.abspath(__file__),
                            "--smoke"], check=True, cwd=ROOT)
            samples.append(json.load(open(smoke_path)))
        os.remove(smoke_path)
        overheads = [s["trace_overhead"]["acceptance"]
                      ["sampled_overhead_pct"] for s in samples]
        idents = [s["trace_overhead"]["bit_identical"] for s in samples]
        assert all(idents), "smoke subprocess broke bit-identity"
        doc["smoke_baseline"] = {
            "sampled_overhead_pct": max(overheads),
            "samples": overheads,
        }

    path = OBS_JSON if not args.smoke else \
        os.path.join(ROOT, "BENCH_obs.smoke.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[obs_bench] wrote {path}")
    print(json.dumps({"acceptance": doc["trace_overhead"]["acceptance"],
                      "overhead_pct": doc["trace_overhead"]["overhead_pct"]},
                     indent=2))

    if args.check:
        failures = run_check(doc, args.smoke, committed)
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
        print("[obs_bench] check OK — tracing is observation-only and "
              "sampled overhead is under the gate")


if __name__ == "__main__":
    main()
