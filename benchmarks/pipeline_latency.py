"""Paper §4.2 latency claim: a 3-stage pipeline (face detection -> quality
-> embedding) has end-to-end latency ~= sum of stage latencies + ~5%
handoff overhead; 3 x 30 ms sticks -> 95-100 ms per frame."""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible benchmark numbers

from repro.bus import BusParams, SharedBus
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import CapabilityRegistry, StreamEngine

STAGES = [("retinaface", 0.030, msg.IMAGE_FRAME, msg.FACE_CROPS),
          ("crfiqa", 0.030, msg.FACE_CROPS, msg.QUALITY),
          ("facenet", 0.030, msg.QUALITY, msg.EMBEDDING)]


def run() -> dict:
    reg = CapabilityRegistry()
    for i, (name, svc, cin, cout) in enumerate(STAGES):
        reg.insert(i, FnCartridge(
            name, lambda p, x: x, msg.MessageSpec(cin), msg.MessageSpec(cout),
            device=DeviceModel(service_s=svc)))
    bus = SharedBus(BusParams("usb3", bandwidth=400e6, base_overhead_s=1.2e-3,
                              arbitration_s=2e-4))
    eng = StreamEngine(reg, bus)
    eng.feed(200, interval_s=0.2)   # unloaded: isolate per-frame latency
    rep = eng.run(until=120)
    lat = rep.mean_latency()
    ideal = sum(s[1] for s in STAGES)
    overhead = lat / ideal - 1.0
    return {
        "stage_latencies_ms": [s[1] * 1e3 for s in STAGES],
        "ideal_sum_ms": round(ideal * 1e3, 1),
        "measured_e2e_ms": round(lat * 1e3, 2),
        "handoff_overhead_pct": round(overhead * 100, 2),
        "paper_band_ms": [95, 100],
        "in_paper_band": bool(0.095 <= lat <= 0.100),
        "frames": rep.frames_out,
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
