"""Benchmark entry point: one function per paper table/figure.

``python -m benchmarks.run`` prints a CSV summary line per benchmark plus
the full JSON payloads; exit code is non-zero if any paper-validation
check fails.
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible benchmark numbers

import json
import time

from benchmarks import (bus_scaling, chaos_bench, engine_bench, fabric_bench,
                        gallery_bench, hotswap, latency_bench, obs_bench,
                        pipeline_latency, power_bench, power_model,
                        roofline_report, secure_match, serve_bench)

BENCHES = [
    ("table1_bus_scaling", bus_scaling.run, "pass_pm1fps"),
    ("s4_2_pipeline_latency", pipeline_latency.run, "in_paper_band"),
    ("s4_2_hotswap", hotswap.run, "zero_loss"),
    ("s4_3_power_model", power_model.run, "in_band"),
    ("s4_3_power_governor", power_bench.run, "pass_power"),
    ("s3_encrypted_matching", secure_match.run, "identical_all"),
    ("identification_fastpath", gallery_bench.run, "pass_fastpath"),
    ("engine_core_events_per_sec", engine_bench.run, "pass_epoch_10x"),
    ("tail_latency_fastpath", latency_bench.run, "pass_tail"),
    ("multi_hub_fabric", fabric_bench.run, "pass_fabric"),
    ("chaos_fabric", chaos_bench.run, "pass_chaos"),
    ("trace_overhead", obs_bench.run, "pass_bit_identical"),
    ("fleet_frontdoor", serve_bench.run, "pass_bit_identical"),
    ("roofline_report", roofline_report.run, None),
]


def main() -> None:
    print("name,ms,check")
    payloads = {}
    failures = []
    for name, fn, check_key in BENCHES:
        t0 = time.perf_counter()
        out = fn()
        ms = (time.perf_counter() - t0) * 1e3
        ok = out.get(check_key, True) if check_key else True
        if not ok:
            failures.append(name)
        payloads[name] = out
        print(f"{name},{ms:.1f},{'PASS' if ok else 'FAIL'}")
    print(json.dumps(payloads, indent=2))
    if failures:
        raise SystemExit(f"benchmark validation failed: {failures}")


if __name__ == "__main__":
    main()
