"""Identification fast-path benchmark — the repo's tracked perf baseline.

One tracked artifact, written to the repo root:

* ``BENCH_gallery.json`` — throughput of the sharded/quantized
  ``SecureGallery.match`` fast path over a (N, dtype, shards) sweep,
  against the *pre-fast-path monolithic fp32 baseline* (per-call gallery
  decrypt + full normalize + bn=512 fp32 kernel — exactly what
  ``SecureGallery.match`` did before this PR), plus recall@1 of each fast
  path against the fp32 oracle.  The two-level ANN tier adds a
  (dtype, nprobe) sweep: its tracked contract is recall@1 >= 0.98 vs the
  fp32 exact oracle while scoring <= 1/10 of the gallery rows
  (``rows_scored_ratio`` = gallery rows / rows scored per query — the
  machine-portable speed lever; interpret-mode wall-clock on CPU is
  dominated by per-grid-step overhead and is reported but not tracked).

(The engine event-core microbench that used to live here moved to
``benchmarks/engine_bench.py``, which owns ``BENCH_engine.json``.)

The file embeds a ``smoke_baseline`` section measured at the ``--smoke``
sizes, so CI can re-run ``--smoke --check`` on any runner and compare
like-for-like ratios (speedups and recall are machine-portable; absolute
wall times are not).  ``--check`` exits non-zero if the committed
``BENCH_gallery.json`` is malformed or a tracked ratio regresses >20%.

Run:  PYTHONPATH=src python benchmarks/gallery_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible CI numbers

import argparse
import json
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GALLERY_JSON = os.path.join(ROOT, "BENCH_gallery.json")

GALLERY_SCHEMA = "champ.gallery_bench.v2"

FULL_CFG = dict(Q=256, D=512, k=5, n_sweep=(16384, 65536),
                shards=(1, 4), dtypes=("fp32", "bf16", "int8"),
                accept_n=65536, accept_shards=4, reps=2,
                ann_q=64, ann_dtypes=("fp32", "bf16", "int8"),
                ann_nprobe=(4, 8, 16), accept_nprobe=8, ann_max_frac=0.1)
SMOKE_CFG = dict(Q=64, D=256, k=5, n_sweep=(8192,),
                 shards=(1, 2), dtypes=("fp32", "int8"),
                 accept_n=8192, accept_shards=2, reps=3,
                 ann_q=64, ann_dtypes=("fp32", "int8"),
                 ann_nprobe=(4, 8), accept_nprobe=4, ann_max_frac=0.1)


# ---------------------------------------------------------------------------
# gallery matching
# ---------------------------------------------------------------------------
def _legacy_monolithic_match(store, q_raw, k):
    """The pre-fast-path hot loop, reproduced verbatim: protect queries,
    decrypt the whole gallery *per call*, normalize both sides, run the
    bn=512 fp32 kernel (``ops.gallery_match``), gather labels."""
    import jax.numpy as jnp
    from repro.kernels import ops as K
    q = store.rotation.protect(jnp.asarray(q_raw))
    g = store.protected_gallery()             # decrypts every call
    scores, idx = K.gallery_match(q, g, k=min(k, len(store)))
    labels = np.asarray(store._labels, object)[np.asarray(idx)]
    return labels, scores


def _time_call(fn, reps):
    import jax
    out = fn()                                 # warmup / compile / prep
    jax.block_until_ready(out[1])
    best = None
    for _ in range(reps):                      # best-of-N (wall-clock noise)
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out[1])
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best, out


def bench_gallery(cfg) -> dict:
    from repro.crypto import SecureGallery

    rng = np.random.default_rng(0)
    Q, D, k = cfg["Q"], cfg["D"], cfg["k"]
    out = {"config": {"Q": Q, "D": D, "k": k, "ann_q": cfg["ann_q"],
                      "ann_nprobe": list(cfg["ann_nprobe"])},
           "baseline": {}, "cells": [], "ann_cells": []}
    for N in cfg["n_sweep"]:
        gallery = rng.normal(size=(N, D)).astype(np.float32)
        labels = np.arange(N)
        queries = gallery[rng.integers(0, N, Q)] + \
            0.1 * rng.normal(size=(Q, D)).astype(np.float32)

        mono = SecureGallery(D, seed=3)
        mono.enroll(gallery, labels)
        base_s, (truth_labels, _) = _time_call(
            lambda: _legacy_monolithic_match(mono, queries, k), cfg["reps"])
        truth1 = truth_labels[:, 0].astype(np.int64)
        out["baseline"][str(N)] = {
            "path": "monolithic fp32 (per-call decrypt + normalize, bn=512)",
            "ms_per_call": round(base_s * 1e3, 1),
            "queries_per_sec": round(Q / base_s, 1),
        }

        for shards in cfg["shards"]:
            store = SecureGallery(D, seed=3, n_shards=shards)
            store.enroll(gallery, labels)
            for dtype in cfg["dtypes"]:
                store.seal()       # each dtype pays full decrypt+prep cost
                t0 = time.perf_counter()
                store.match(queries[:1], k=1, dtype=dtype)  # build prep
                prep_s = time.perf_counter() - t0
                dt_s, (lab, _) = _time_call(
                    lambda: store.match(queries, k, dtype=dtype),
                    cfg["reps"])
                recall1 = float(np.mean(
                    lab[:, 0].astype(np.int64) == truth1))
                out["cells"].append({
                    "N": N, "dtype": dtype, "shards": shards,
                    "ms_per_call": round(dt_s * 1e3, 1),
                    "queries_per_sec": round(Q / dt_s, 1),
                    "prep_ms": round(prep_s * 1e3, 1),
                    "recall_at_1": recall1,
                    "speedup_vs_fp32_monolithic": round(base_s / dt_s, 2),
                })

        # -- two-level ANN tier: (dtype, nprobe) sweep at accept_shards.
        # Tracked metrics are recall@1 vs the exact fp32 oracle and
        # rows_scored_ratio (gallery rows / rows scored per query) — both
        # machine-portable.  Wall-clock is reported for context only:
        # interpret-mode Pallas pays ~ms per grid step on CPU, so ANN
        # wall time here does NOT reflect the scored-rows saving.
        Qa = min(cfg["ann_q"], Q)
        qa, ta = queries[:Qa], truth1[:Qa]
        astore = SecureGallery(D, seed=3, n_shards=cfg["accept_shards"])
        astore.enroll(gallery, labels)
        t0 = time.perf_counter()
        astore.build_ann_index()
        build_s = time.perf_counter() - t0
        out.setdefault("ann_index", {})[str(N)] = {
            "n_cells": astore._ann_n_cells,
            "build_ms": round(build_s * 1e3, 1),
        }
        for dtype in cfg["ann_dtypes"]:
            for nprobe in cfg["ann_nprobe"]:
                astore.match(qa[:1], k=1, dtype=dtype,  # prep + compile
                             mode="ann", nprobe=nprobe)
                t0 = time.perf_counter()
                lab, _ = astore.match(qa, k, dtype=dtype,
                                      mode="ann", nprobe=nprobe)
                dt_s = time.perf_counter() - t0
                st = astore.last_match_stats
                out["ann_cells"].append({
                    "N": N, "dtype": dtype,
                    "shards": cfg["accept_shards"], "nprobe": nprobe,
                    "ms_per_call": round(dt_s * 1e3, 1),
                    "recall_at_1": float(np.mean(
                        lab[:, 0].astype(np.int64) == ta)),
                    "rows_scored_per_query": round(st["rows_scored"], 1),
                    "scan_fraction": round(st["scan_fraction"], 4),
                    "rows_scored_ratio": round(
                        st["rows_total"] / max(st["rows_scored"], 1.0), 1),
                })

    acc = [c for c in out["cells"]
           if c["N"] == cfg["accept_n"] and c["dtype"] == "int8"
           and c["shards"] == cfg["accept_shards"]][0]
    out["acceptance"] = {
        "cell": {kk: acc[kk] for kk in ("N", "dtype", "shards")},
        "int8_sharded_speedup": acc["speedup_vs_fp32_monolithic"],
        "recall_at_1": acc["recall_at_1"],
        "pass_speedup_1p5x": acc["speedup_vs_fp32_monolithic"] >= 1.5,
        "pass_recall_0p99": acc["recall_at_1"] >= 0.99,
    }

    acc_a = [c for c in out["ann_cells"]
             if c["N"] == cfg["accept_n"] and c["dtype"] == "int8"
             and c["nprobe"] == cfg["accept_nprobe"]][0]
    out["acceptance_ann"] = {
        "cell": {kk: acc_a[kk] for kk in ("N", "dtype", "shards", "nprobe")},
        "recall_at_1": acc_a["recall_at_1"],
        "scan_fraction": acc_a["scan_fraction"],
        "rows_scored_ratio": acc_a["rows_scored_ratio"],
        "max_scan_fraction": cfg["ann_max_frac"],
        "pass_recall_0p98": acc_a["recall_at_1"] >= 0.98,
        "pass_scan_frac": acc_a["scan_fraction"] <= cfg["ann_max_frac"],
    }
    return out


# ---------------------------------------------------------------------------
# schema validation + regression check
# ---------------------------------------------------------------------------
def validate_gallery(doc: dict):
    assert doc.get("schema") == GALLERY_SCHEMA, "bad/missing schema tag"
    assert doc.get("mode") in ("full", "smoke"), "bad mode"
    for section in ("config", "baseline", "cells", "acceptance",
                    "ann_cells", "acceptance_ann"):
        assert section in doc, f"missing section {section!r}"
    for c in doc["cells"]:
        for kk in ("N", "dtype", "shards", "queries_per_sec", "recall_at_1",
                   "speedup_vs_fp32_monolithic"):
            assert kk in c, f"cell missing {kk!r}"
    assert doc["ann_cells"], "empty ann_cells sweep"
    for c in doc["ann_cells"]:
        for kk in ("N", "dtype", "nprobe", "recall_at_1", "scan_fraction",
                   "rows_scored_ratio"):
            assert kk in c, f"ann cell missing {kk!r}"
    for kk in ("int8_sharded_speedup", "recall_at_1"):
        assert kk in doc["acceptance"], f"acceptance missing {kk!r}"
    for kk in ("recall_at_1", "scan_fraction", "rows_scored_ratio"):
        assert kk in doc["acceptance_ann"], f"acceptance_ann missing {kk!r}"


def load_committed():
    """Read + schema-validate the committed baseline.  Must be called
    BEFORE a full-mode run overwrites it, or the comparison is vacuous.
    Returns (gallery_doc, failures)."""
    try:
        committed_g = json.load(open(GALLERY_JSON))
        validate_gallery(committed_g)
    except Exception as e:  # malformed committed file is itself a failure
        return None, [f"committed BENCH_gallery.json malformed: {e}"]
    return committed_g, []


def run_check(fresh_gallery: dict, smoke: bool, committed_g: dict) -> list:
    """Compare a fresh run against the committed baseline; returns a list
    of failure strings (empty = pass)."""
    failures = []
    base_g = committed_g["smoke_baseline"] if smoke \
        else committed_g["acceptance"]
    got_sp = fresh_gallery["acceptance"]["int8_sharded_speedup"]
    want_sp = base_g["int8_sharded_speedup"]
    if got_sp < 0.8 * want_sp:
        failures.append(f"gallery speedup regressed >20%: "
                        f"{got_sp} vs baseline {want_sp}")
    if fresh_gallery["acceptance"]["recall_at_1"] < 0.99:
        failures.append(f"int8 recall@1 below 0.99: "
                        f"{fresh_gallery['acceptance']['recall_at_1']}")
    # ANN tier contract: recall floor + scored-rows bound are absolute
    # (machine-portable — no noise allowance), the scored-rows *ratio* is
    # additionally pinned against the committed baseline like the speedups.
    acc_a = fresh_gallery["acceptance_ann"]
    if acc_a["recall_at_1"] < 0.98:
        failures.append(f"ANN recall@1 below 0.98: {acc_a['recall_at_1']}")
    if acc_a["scan_fraction"] > acc_a["max_scan_fraction"]:
        failures.append(
            f"ANN scan_fraction {acc_a['scan_fraction']} exceeds "
            f"{acc_a['max_scan_fraction']} (>=10x fewer scored rows broken)")
    base_a = committed_g["smoke_baseline_ann"] if smoke \
        else committed_g["acceptance_ann"]
    got_ra, want_ra = acc_a["rows_scored_ratio"], base_a["rows_scored_ratio"]
    if got_ra < 0.8 * want_ra:
        failures.append(f"ANN rows_scored_ratio regressed >20%: "
                        f"{got_ra} vs baseline {want_ra}")
    return failures


def run() -> dict:
    """Validation-suite entry (``benchmarks/run.py``): smoke-size check that
    the fast path still beats the monolithic baseline with intact recall."""
    g = bench_gallery(SMOKE_CFG)
    return {
        "gallery_acceptance": g["acceptance"],
        "ann_acceptance": g["acceptance_ann"],
        "pass_fastpath": bool(g["acceptance"]["pass_speedup_1p5x"]
                              and g["acceptance"]["pass_recall_0p99"]
                              and g["acceptance_ann"]["pass_recall_0p98"]
                              and g["acceptance_ann"]["pass_scan_frac"]),
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; writes BENCH_gallery.smoke.json "
                         "instead of overwriting the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_gallery.json and fail on "
                         ">20% ratio regression")
    args = ap.parse_args()

    cfg = SMOKE_CFG if args.smoke else FULL_CFG
    mode = "smoke" if args.smoke else "full"
    committed_g = None
    if args.check:
        # snapshot the committed baseline BEFORE a full run overwrites it
        committed_g, failures = load_committed()
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
    print(f"[gallery_bench] mode={mode} sweep={cfg['n_sweep']} "
          f"dtypes={cfg['dtypes']} shards={cfg['shards']}")
    gallery_doc = {"schema": GALLERY_SCHEMA, "mode": mode}
    gallery_doc.update(bench_gallery(cfg))

    if not args.smoke:
        # embed smoke-size baselines so CI runners can compare like-for-like.
        # Each sample runs in a FRESH subprocess (the cold-process conditions
        # a CI `--smoke --check` run sees) and the committed baseline is the
        # MINIMUM ratio over the samples — a conservative lower bound, so a
        # >20% drop below it is a real regression, not wall-clock noise.
        print("[gallery_bench] measuring smoke baselines for CI "
              "(min of 3 fresh subprocesses)")
        import subprocess
        import sys
        g_samples, ga_samples = [], []
        sg_path = os.path.join(ROOT, "BENCH_gallery.smoke.json")
        for _ in range(3):
            subprocess.run([sys.executable, os.path.abspath(__file__),
                            "--smoke"], check=True, cwd=ROOT)
            smoke_g = json.load(open(sg_path))
            g_samples.append(smoke_g["acceptance"])
            ga_samples.append(smoke_g["acceptance_ann"])
        os.remove(sg_path)
        worst_g = min(g_samples, key=lambda a: a["int8_sharded_speedup"])
        gallery_doc["smoke_baseline"] = dict(
            worst_g, samples=[a["int8_sharded_speedup"] for a in g_samples])
        # ANN smoke baseline: scored-rows ratio is deterministic given the
        # config, but keep the same min-of-samples discipline as the rest
        worst_a = min(ga_samples, key=lambda a: a["rows_scored_ratio"])
        gallery_doc["smoke_baseline_ann"] = dict(
            worst_a, samples=[a["rows_scored_ratio"] for a in ga_samples])

    g_path = GALLERY_JSON if not args.smoke else \
        os.path.join(ROOT, "BENCH_gallery.smoke.json")
    with open(g_path, "w") as f:
        json.dump(gallery_doc, f, indent=2)
    print(f"[gallery_bench] wrote {g_path}")
    print(json.dumps({"gallery_acceptance": gallery_doc["acceptance"],
                      "ann_acceptance": gallery_doc["acceptance_ann"]},
                     indent=2))

    if args.check:
        failures = run_check(gallery_doc, args.smoke, committed_g)
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
        print("[gallery_bench] check OK — no tracked metric regressed")


if __name__ == "__main__":
    main()
