"""Chaos-fabric benchmark — the tracked zero-loss / recovery contract.

One tracked artifact, written to the repo root:

* ``BENCH_chaos.json`` — the canonical chaos scenario (two-stage
  detect->embed pipeline spanning two hubs, hedged shard dispatch; see
  ``repro.runtime.replication.build_chaos_engine``) swept over a seeded
  fault-storm grid: fault kind (lane crash, lane hang, transfer
  corruption, link flap, everything-at-once storm) x intensity x seed.
  Every cell must deliver **every** offered frame exactly once — zero
  loss and zero duplicates under any seeded storm is the hard contract,
  not a statistic — and the sweep tracks goodput retention (cell
  goodput / fault-free goodput) and p99 inflation as the degradation
  telemetry.

Acceptance:

* zero frame loss and exactly-once delivery in every cell;
* goodput retention >= 0.7 at the headline intensity (the full storm
  at the high rate);
* chaos machinery off == chaos machinery absent: with an empty
  ``FaultPlan`` the Table 1 broadcast FPS is **bit-identical** (exact
  float equality) to an engine built without any fault plan.

The committed file embeds a ``smoke_baseline`` so CI can re-run
``--smoke --check`` and compare retention like-for-like (>20%
regression or any frame loss fails).  All metrics are virtual-time
deterministic — identical on any machine.

Run:  PYTHONPATH=src python benchmarks/chaos_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible CI numbers

import argparse
import json

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHAOS_JSON = os.path.join(ROOT, "BENCH_chaos.json")

CHAOS_SCHEMA = "champ.chaos_bench.v1"

FULL_CFG = dict(n_bursts=150, seeds=(1, 2, 3),
                rates=(2.0, 6.0), corrupt_ps=(0.02, 0.08),
                table1_frames=200)
SMOKE_CFG = dict(n_bursts=60, seeds=(1,),
                 rates=(2.0, 6.0), corrupt_ps=(0.02, 0.08),
                 table1_frames=100)

# intensity index -> storm kwargs, parameterized by (rate, corrupt_p).
# "storm" is the headline: every fault kind at once.
KINDS = {
    "crash":     lambda r, p: dict(crash_rate=r),
    "hang":      lambda r, p: dict(hang_rate=r),
    "corrupt":   lambda r, p: dict(corrupt_p=p),
    "link_flap": lambda r, p: dict(link_down_rate=r, link_down_s=0.1),
    "storm":     lambda r, p: dict(crash_rate=r, hang_rate=0.5 * r,
                                   hub_loss_rate=0.3, link_down_rate=0.5 * r,
                                   link_down_s=0.1, corrupt_p=p),
}


def _quarantine():
    """Bench lease tuning: short leases so quarantined lanes rejoin
    within the measurement window instead of sitting out the run."""
    from repro.runtime.faults import QuarantinePolicy
    return QuarantinePolicy(lease_s=0.2, probation_s=0.2)


def _run_cell(plan, n_bursts: int):
    from repro.runtime import run_chaos
    return run_chaos(plan, quarantine=_quarantine(), n_bursts=n_bursts)


def _goodput(rep) -> float:
    """Delivered frames per second of *delivery* span — robust to
    trailing fault/reinstate events inflating sim_time after the last
    frame left the pipeline."""
    return rep.frames_out / max(rep.last_out_t, 1e-9)


def bench_storm_sweep(cfg) -> dict:
    """The (kind x intensity x seed) grid.  Each cell reports loss,
    duplicates, goodput retention vs the fault-free baseline, p99
    inflation, and the recovery counters that explain them."""
    from repro.runtime import chaos_lane_names
    from repro.runtime.faults import FaultPlan

    base = _run_cell(None, cfg["n_bursts"])
    base_goodput = _goodput(base)
    # the fault window covers the whole offered-load span
    horizon = max(base.last_out_t, 0.5)
    lanes = chaos_lane_names()

    out = {
        "baseline": {
            "frames": base.frames_out,
            "goodput_fps": round(base_goodput, 2),
            "p99_ms": round(base.p99() * 1e3, 2),
        },
        "cells": {},
    }
    all_zero_loss = True
    all_exactly_once = True
    for kind, mk in KINDS.items():
        for i, (rate, cp) in enumerate(zip(cfg["rates"], cfg["corrupt_ps"])):
            level = ("low", "high")[min(i, 1)]
            worst = None
            for seed in cfg["seeds"]:
                plan = FaultPlan.storm(
                    seed=seed, horizon_s=horizon, lanes=lanes,
                    hubs=(0, 1), links=((0, 1),), **mk(rate, cp))
                rep = _run_cell(plan, cfg["n_bursts"])
                lost = rep.frames_in - rep.frames_out
                dup = rep.faults["duplicates"]
                all_zero_loss &= (lost == 0)
                all_exactly_once &= (dup == 0)
                cell = {
                    "seed": seed,
                    "faults_injected": rep.faults["injected"],
                    "frames_lost": lost,
                    "duplicates": dup,
                    "goodput_retention": round(
                        _goodput(rep) / base_goodput, 4),
                    "p99_inflation": round(
                        rep.p99() / max(base.p99(), 1e-9), 2),
                    "recovery": {k: rep.faults[k] for k in
                                 ("hang_promoted", "redispatched", "retries",
                                  "corrupt_detected", "resends",
                                  "quarantined", "reinstated",
                                  "reroute_blocked")},
                }
                if (worst is None or cell["goodput_retention"]
                        < worst["goodput_retention"]):
                    worst = cell
            out["cells"][f"{kind}/{level}"] = worst
    out["all_zero_loss"] = all_zero_loss
    out["all_exactly_once"] = all_exactly_once
    return out


def bench_bit_identity(cfg) -> dict:
    """Chaos off must be chaos absent: an engine built with an *empty*
    FaultPlan replays the Table 1 broadcast experiment bit-identically
    (exact float equality, not a tolerance) to one built with no plan."""
    from repro.runtime import run_replicated
    from repro.runtime.faults import FaultPlan

    n = cfg["table1_frames"]
    plain = run_replicated("ncs2", 5, "broadcast", n)
    chaos = run_replicated("ncs2", 5, "broadcast", n,
                           fault_plan=FaultPlan())
    return {
        "workload": f"broadcast ncs2 x5, {n} frames (Table 1 shape)",
        "fps_no_plan": plain.throughput(),
        "fps_empty_plan": chaos.throughput(),
        "p99_no_plan": plain.p99(),
        "p99_empty_plan": chaos.p99(),
        "bit_identical": bool(
            plain.throughput() == chaos.throughput()
            and plain.p99() == chaos.p99()
            and plain.frames_out == chaos.frames_out),
    }


def _acceptance(sweep: dict, ident: dict) -> dict:
    head = sweep["cells"]["storm/high"]
    return {
        "scenario": "storm/high (all fault kinds, high rate, worst seed)",
        "all_zero_loss": sweep["all_zero_loss"],
        "all_exactly_once": sweep["all_exactly_once"],
        "headline_goodput_retention": head["goodput_retention"],
        "pass_retention_0p7": head["goodput_retention"] >= 0.7,
        "headline_p99_inflation": head["p99_inflation"],
        "bit_identical_fault_free": ident["bit_identical"],
        "pass_chaos": bool(sweep["all_zero_loss"]
                           and sweep["all_exactly_once"]
                           and head["goodput_retention"] >= 0.7
                           and ident["bit_identical"]),
    }


# ---------------------------------------------------------------------------
# schema validation + regression check
# ---------------------------------------------------------------------------
def validate_chaos(doc: dict):
    assert doc.get("schema") == CHAOS_SCHEMA, "bad/missing schema tag"
    assert doc.get("mode") in ("full", "smoke"), "bad mode"
    for section in ("storm_sweep", "bit_identity", "acceptance"):
        assert section in doc, f"missing section {section!r}"
    for cell in ("crash/high", "hang/high", "corrupt/high",
                 "link_flap/high", "storm/high"):
        assert cell in doc["storm_sweep"]["cells"], f"missing cell {cell!r}"
    for kk in ("all_zero_loss", "all_exactly_once",
               "headline_goodput_retention", "bit_identical_fault_free"):
        assert kk in doc["acceptance"], f"acceptance missing {kk!r}"
    if doc["mode"] == "full":
        assert "smoke_baseline" in doc, "missing smoke_baseline"
        assert "headline_goodput_retention" in doc["smoke_baseline"], \
            "smoke_baseline missing headline_goodput_retention"


def load_committed():
    try:
        doc = json.load(open(CHAOS_JSON))
        validate_chaos(doc)
    except Exception as e:
        return None, [f"committed BENCH_chaos.json malformed: {e}"]
    return doc, []


def run_check(fresh: dict, smoke: bool, committed: dict) -> list:
    failures = []
    acc = fresh["acceptance"]
    if not acc["all_zero_loss"]:
        failures.append("frame loss under seeded faults (zero-loss "
                        "contract broken)")
    if not acc["all_exactly_once"]:
        failures.append("duplicate delivery under seeded faults "
                        "(exactly-once contract broken)")
    if not acc["bit_identical_fault_free"]:
        failures.append("empty FaultPlan no longer bit-identical to "
                        "fault-free engine")
    base = committed["smoke_baseline"] if smoke else committed["acceptance"]
    got = acc["headline_goodput_retention"]
    want = base["headline_goodput_retention"]
    if got < 0.8 * want:
        failures.append(f"goodput retention regressed >20%: "
                        f"{got} vs baseline {want}")
    if not acc["pass_retention_0p7"]:
        failures.append(f"headline goodput retention below 0.7: {got}")
    return failures


def run() -> dict:
    """Validation-suite entry (``benchmarks/run.py``): smoke-size check
    that every seeded storm still delivers every frame exactly once."""
    sweep = bench_storm_sweep(SMOKE_CFG)
    ident = bench_bit_identity(SMOKE_CFG)
    acc = _acceptance(sweep, ident)
    return {"acceptance": acc, "pass_chaos": acc["pass_chaos"]}


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; writes BENCH_chaos.smoke.json "
                         "instead of overwriting the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_chaos.json and fail on "
                         "frame loss or >20% retention regression")
    args = ap.parse_args()

    cfg = SMOKE_CFG if args.smoke else FULL_CFG
    mode = "smoke" if args.smoke else "full"
    committed = None
    if args.check:
        committed, failures = load_committed()
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))

    print(f"[chaos_bench] mode={mode} bursts={cfg['n_bursts']} "
          f"seeds={cfg['seeds']} rates={cfg['rates']}")
    doc = {"schema": CHAOS_SCHEMA, "mode": mode}
    doc["storm_sweep"] = bench_storm_sweep(cfg)
    doc["bit_identity"] = bench_bit_identity(cfg)
    doc["acceptance"] = _acceptance(doc["storm_sweep"], doc["bit_identity"])

    if not args.smoke:
        # every metric is virtual-time deterministic, so the CI smoke
        # baseline is just the smoke-config run — no subprocess sampling
        print("[chaos_bench] measuring smoke baseline for CI")
        s_sweep = bench_storm_sweep(SMOKE_CFG)
        s_ident = bench_bit_identity(SMOKE_CFG)
        s_acc = _acceptance(s_sweep, s_ident)
        doc["smoke_baseline"] = {
            "headline_goodput_retention":
                s_acc["headline_goodput_retention"],
            "headline_p99_inflation": s_acc["headline_p99_inflation"],
        }

    if args.check:
        # check BEFORE writing: a failed check must not clobber the
        # committed baseline it was compared against
        failures = run_check(doc, args.smoke, committed)
        if failures:
            raise SystemExit("benchmark check failed: " + "; ".join(failures))
        print("[chaos_bench] check OK — no tracked contract regressed")

    path = CHAOS_JSON if not args.smoke else \
        os.path.join(ROOT, "BENCH_chaos.smoke.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[chaos_bench] wrote {path}")
    print(json.dumps(doc["acceptance"], indent=2))


if __name__ == "__main__":
    main()
