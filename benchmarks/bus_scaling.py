"""Paper Table 1: throughput scaling, 1-5 accelerators on the shared bus.

Reproduces the broadcast-load experiment on the calibrated discrete-event
bus simulator and validates each cell against the published FPS.
"""
from __future__ import annotations

from repro.bus import TABLE1, calibrated, simulate_broadcast_fps


def run() -> dict:
    rows = {}
    worst = 0.0
    for device, published in TABLE1.items():
        p = calibrated(device)
        sim = [simulate_broadcast_fps(p, n) for n in range(1, 6)]
        err = max(abs(a - b) for a, b in zip(sim, published))
        worst = max(worst, err)
        rows[device] = {
            "published_fps": published,
            "simulated_fps": [round(v, 2) for v in sim],
            "max_abs_err_fps": round(err, 2),
            "params": {"t_comp_ms": round(p.t_comp_s * 1e3, 2),
                       "t_x0_ms": round(p.base_overhead_s * 1e3, 3),
                       "arbitration_ms": round(p.arbitration_s * 1e3, 3)},
        }
    return {"table1": rows, "max_abs_err_fps": round(worst, 2),
            "pass_pm1fps": bool(worst <= 1.0)}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
