"""Paper Table 1: throughput scaling, 1-5 accelerators on the shared bus.

Two executions of the same experiment:

  * ``simulator`` — the closed-form discrete-event broadcast loop
    (``simulate_broadcast_fps``), the original calibration harness;
  * ``engine``    — the VDiSK ``StreamEngine`` itself, dispatching frames
    over a replicated lane group in ``broadcast`` mode (the §4.1 topology
    inside the real runtime).

Both must land on every published FPS cell within ±1; the engine run also
reports the ``shard`` (load-balanced) curve — what the same sticks deliver
when the goal is aggregate throughput instead of redundancy — and the
bus contention breakdown from the replicated run.
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible benchmark numbers

from repro.bus import TABLE1, calibrated, simulate_broadcast_fps
from repro.runtime import engine_shard_fps, run_replicated


def run() -> dict:
    rows = {}
    worst_sim = 0.0
    worst_eng = 0.0
    for device, published in TABLE1.items():
        p = calibrated(device)
        sim = [simulate_broadcast_fps(p, n) for n in range(1, 6)]
        eng_reports = [run_replicated(device, n, mode="broadcast")
                       for n in range(1, 6)]
        eng = [r.throughput() for r in eng_reports]
        shard = [engine_shard_fps(device, n) for n in range(1, 6)]
        err_sim = max(abs(a - b) for a, b in zip(sim, published))
        err_eng = max(abs(a - b) for a, b in zip(eng, published))
        worst_sim = max(worst_sim, err_sim)
        worst_eng = max(worst_eng, err_eng)
        rows[device] = {
            "published_fps": published,
            "simulated_fps": [round(v, 2) for v in sim],
            "engine_fps": [round(v, 2) for v in eng],
            "engine_shard_fps": [round(v, 2) for v in shard],
            "max_abs_err_fps": round(err_sim, 2),
            "max_abs_err_engine_fps": round(err_eng, 2),
            "bus_contention_n5": eng_reports[-1].bus,
            "params": {"t_comp_ms": round(p.t_comp_s * 1e3, 2),
                       "t_x0_ms": round(p.base_overhead_s * 1e3, 3),
                       "arbitration_ms": round(p.arbitration_s * 1e3, 3)},
        }
    return {"table1": rows,
            "max_abs_err_fps": round(worst_sim, 2),
            "max_abs_err_engine_fps": round(worst_eng, 2),
            "pass_pm1fps": bool(worst_sim <= 1.0 and worst_eng <= 1.0)}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
