"""Paper §4.2 hot-swap: removing the middle (quality) stage pauses ~0.5 s,
re-inserting pauses ~2 s (model reload), and no frames are lost."""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # reproducible benchmark numbers

from repro.bus import BusParams, SharedBus
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime import CapabilityRegistry, StreamEngine

SPEC = msg.MessageSpec(msg.IMAGE_FRAME)


def _cart(name, svc=0.030, load_s=1.5):
    return FnCartridge(name, lambda p, x: x, SPEC, SPEC,
                       device=DeviceModel(service_s=svc, load_s=load_s))


def run() -> dict:
    reg = CapabilityRegistry()
    for i, name in enumerate(["detect", "quality", "embed"]):
        reg.insert(i, _cart(name))
    eng = StreamEngine(reg, SharedBus(BusParams(
        "usb3", bandwidth=400e6, base_overhead_s=4e-4)))
    eng.feed(400, interval_s=0.05)
    eng.schedule_remove(5.0, slot=1)                 # paper: remove middle
    eng.schedule_insert(12.0, slot=1, cart=_cart("quality"))
    rep = eng.run(until=60)
    removes = [d for d in rep.downtime if "remove" in d[2]]
    inserts = [d for d in rep.downtime if "insert" in d[2]]
    t_rm = removes[0][1] - removes[0][0] if removes else None
    t_in = inserts[0][1] - inserts[0][0] if inserts else None
    return {
        "frames_in": rep.frames_in,
        "frames_out": rep.frames_out,
        "frames_lost": rep.lost,
        "remove_pause_s": round(t_rm, 2),
        "insert_pause_s": round(t_in, 2),
        "paper_remove_s": 0.5,
        "paper_insert_s": 2.0,
        "zero_loss": rep.lost == 0,
        "remove_in_band": bool(0.3 <= t_rm <= 0.8),
        "insert_in_band": bool(1.5 <= t_in <= 2.5),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
