from repro.bus.simulator import (BusParams, SharedBus, TABLE1, calibrated,
                                 calibrate_from_fps, simulate_broadcast_fps)
from repro.bus.fabric import (FabricRouter, Hub, InterHubLink, LinkParams,
                              uniform_fabric)
