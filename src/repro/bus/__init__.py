from repro.bus.simulator import (BusParams, SharedBus, TABLE1, calibrated,
                                 calibrate_from_fps, simulate_broadcast_fps)
