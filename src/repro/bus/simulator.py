"""Discrete-event shared-bus model (the CHAMP USB3 multi-drop bus).

The bus serializes transfers. Each transfer costs
    base_overhead + arbitration * (n_active_endpoints - 1) + bytes / bandwidth
where the arbitration term models host-side dispatch contention and USB
protocol overhead growing with the number of devices sharing the bus — the
mechanism behind Table 1's per-device FPS decline under broadcast load.

``calibrate_from_fps`` inverts the paper's own measurements: with serial
broadcast (device i's transfer starts after device i-1's) and parallel
on-device compute, the steady-state cycle for N devices is

    cycle(N) = t_comp + N * (t_x + arb * (N - 1))

Three published points (N = 1, 2, 5) pin (t_comp, t_x, arb) exactly; the
remaining table rows validate the fit (tests assert within +-1 FPS).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field


@dataclass
class BusParams:
    name: str
    bandwidth: float = 400e6       # effective B/s (USB3.1 Gen1 practical)
    base_overhead_s: float = 0.0   # per-transfer fixed cost (setup, driver)
    arbitration_s: float = 0.0     # extra cost per competing endpoint
    t_comp_s: float = 0.0          # device compute time (calibrated model)


def calibrate_from_fps(name: str, fps1: float, fps2: float, fps5: float,
                       frame_bytes: int = 150528,
                       bandwidth: float = 400e6) -> BusParams:
    """Solve cycle(N) = t_comp + N*t_x + arb*N*(N-1) through N=1,2,5."""
    c1, c2, c5 = 1.0 / fps1, 1.0 / fps2, 1.0 / fps5
    # c2 - c1 = t_x + 2*arb ; c5 - c1 = 4*t_x + 20*arb
    d2, d5 = c2 - c1, c5 - c1
    arb = (d5 - 4 * d2) / 12.0
    t_x = d2 - 2 * arb
    t_comp = c1 - t_x
    base = max(t_x - frame_bytes / bandwidth, 0.0)
    return BusParams(name=name, bandwidth=bandwidth, base_overhead_s=base,
                     arbitration_s=max(arb, 0.0), t_comp_s=max(t_comp, 0.0))


class SharedBus:
    """FIFO shared bus: transfers serialize; cost grows with contention.

    Contention is accounted explicitly so schedulers can see where bus
    time goes: ``wait_s`` is time transfers spent queued behind the bus
    (FIFO serialization), ``arbitration_s_total`` is protocol overhead
    attributable to the number of endpoints sharing the hub, and
    ``wire_s`` is pure payload time at the calibrated bandwidth.
    """

    def __init__(self, params: BusParams):
        self.p = params
        self.reset()

    def reset(self):
        self.free_at = 0.0
        self.bytes_moved = 0
        self.transfers = 0
        self.busy_s = 0.0
        self.wait_s = 0.0
        self.arbitration_s_total = 0.0
        self.wire_s = 0.0
        self.max_endpoints = 0
        self.suppressed_transfers = 0
        self.suppressed_bytes = 0

    def transfer(self, t_req: float, nbytes: int, n_endpoints: int = 1) -> float:
        """Schedule a transfer requested at ``t_req``; returns completion."""
        start = max(t_req, self.free_at)
        arb = self.p.arbitration_s * max(n_endpoints - 1, 0)
        wire = nbytes / self.p.bandwidth
        dur = self.p.base_overhead_s + arb + wire
        self.free_at = start + dur
        self.bytes_moved += nbytes
        self.transfers += 1
        self.busy_s += dur
        self.wait_s += start - t_req
        self.arbitration_s_total += arb
        self.wire_s += wire
        self.max_endpoints = max(self.max_endpoints, n_endpoints)
        return self.free_at

    def suppress(self, nbytes: int):
        """Account a handoff that was *not* performed: a hedged duplicate
        lost the race after being serviced, so its result never crosses the
        bus.  Suppression is what makes hedging cheap on a shared medium —
        these counters quantify the bus time the cancellation saved."""
        self.suppressed_transfers += 1
        self.suppressed_bytes += nbytes

    def stats(self) -> dict:
        """Contention breakdown of everything moved so far."""
        return {
            "bytes_moved": self.bytes_moved,
            "transfers": self.transfers,
            "busy_s": round(self.busy_s, 6),
            "wait_s": round(self.wait_s, 6),
            "arbitration_s": round(self.arbitration_s_total, 6),
            "wire_s": round(self.wire_s, 6),
            "max_endpoints": self.max_endpoints,
            "suppressed_transfers": self.suppressed_transfers,
            "suppressed_bytes": self.suppressed_bytes,
        }


# ---------------------------------------------------------------------------
# Table 1 broadcast experiment (the paper's only quantitative table)
# ---------------------------------------------------------------------------
def simulate_broadcast_fps(params: BusParams, n_devices: int,
                           frame_bytes: int = 150528,
                           n_frames: int = 200) -> float:
    """Event-driven replication of §4.1: every frame is sent to all N
    devices (serial transfers on the shared bus), all devices infer in
    parallel, next frame dispatches when the slowest finishes."""
    bus = SharedBus(params)
    t = 0.0
    done = 0.0
    for _ in range(n_frames):
        t = max(t, done - 0.0)  # closed loop: dispatch after previous barrier
        finishes = []
        for d in range(n_devices):
            arr = bus.transfer(t, frame_bytes, n_devices)
            finishes.append(arr + params.t_comp_s)
        done = max(finishes)
        t = done
    return n_frames / done


# Published Table 1 rows (FPS for 1..5 devices)
TABLE1 = {
    "ncs2": [15, 13, 10, 8, 6],
    "coral": [25, 22, 19, 17, 15],
}


def calibrated(name: str) -> BusParams:
    row = TABLE1[name]
    return calibrate_from_fps(name, row[0], row[1], row[4])
