"""Multi-hub bus fabric: hub-partitioned buses with host-side routing.

The paper's §4.1 experiment saturates a single USB3 multi-drop bus at
five accelerators: every endpoint shares ONE arbitration domain, so the
per-transfer cost grows with the total device count no matter how the
frames are dispatched.  Past that knee, faster devices do not help —
the topology is the bottleneck.  The fabric is the layer the paper's
"future improvements in bus protocols" points at: partition the devices
across several hubs, each with its *own* calibrated ``SharedBus``
(arbitration scales with the hub's endpoint count, not the fleet's),
and route between hubs through the host.

Three pieces:

  * ``Hub`` — one physical hub: a ``SharedBus`` arbitration domain with
    its own calibrated ``BusParams``.
  * ``InterHubLink`` — the discrete-event host-side channel between a
    hub pair (PCIe root / host-controller path + memcpy): FIFO
    serialized, its own bandwidth and per-transfer overhead, no
    arbitration term (point-to-point).  One full-duplex channel per
    unordered hub pair, created lazily.
  * ``FabricRouter`` — the host-side cost model.  A routed transfer is
    three serialized legs::

        route(src -> dst) = src-hub egress + inter-hub link + dst-hub ingress

    Local transfers (``src == dst``, or only one side given) collapse to
    a single hub-bus transfer, so a one-hub fabric is *identical* to the
    bare ``SharedBus`` — the engine swaps the router in where the bus
    sits today (same ``transfer`` / ``suppress`` / ``stats`` surface).

Suppression at the router.  PR 3's learning: cancelling a hedge loser
*before* its result transfer is what keeps hedging ~free on a shared
medium.  Cross-hub, the stakes are higher — a wasted result would burn
source-hub egress, link time, AND destination-hub ingress, and the
destination hub is where the winning traffic flows.  ``suppress``
therefore kills the route before any leg starts and books the savings
per domain (``suppressed_saved_s`` aggregates hub + link time).  With
``suppression=False`` the router *executes* the wasted route instead
(the loser's result crosses the fabric and is discarded at the host) —
the measurable baseline for what router-level suppression buys
(``benchmarks/fabric_bench.py`` tracks the p99 delta).

Hedge copies are charged to the *destination* hub's bus (ingress-only:
the host already buffers the frame it originally dispatched, so a
speculative re-send consumes no source-hub egress and no inter-hub
link) — otherwise speculative traffic would erode the source hub's
arbitration budget, exactly the failure mode the ROADMAP called out.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple, Union

from repro.bus.simulator import BusParams, SharedBus


@dataclass
class LinkParams:
    """Host-side routed channel between two hubs.  Defaults model a
    PCIe-root / DMA path: ~3x a hub bus's effective bandwidth and a
    small fixed per-routed-transfer host cost."""
    bandwidth: float = 1.2e9     # effective B/s of the host-side path
    overhead_s: float = 5e-5     # per-transfer routing cost (host CPU)


class InterHubLink:
    """FIFO point-to-point channel between one unordered hub pair.
    Transfers serialize; there is no arbitration term (nothing else
    shares the channel)."""

    def __init__(self, a: int, b: int, params: LinkParams):
        self.a, self.b = (a, b) if a <= b else (b, a)
        self.p = params
        self.reset()

    def reset(self):
        self.free_at = 0.0
        self.up = True
        self.downs = 0
        self.transfers = 0
        self.bytes_moved = 0
        self.busy_s = 0.0
        self.wait_s = 0.0
        self.wire_s = 0.0
        self.suppressed_transfers = 0
        self.suppressed_bytes = 0
        self.suppressed_s = 0.0

    def cost(self, nbytes: int) -> float:
        """Unloaded one-transfer cost (the suppression-savings estimate)."""
        return self.p.overhead_s + nbytes / self.p.bandwidth

    def transfer(self, t_req: float, nbytes: int) -> float:
        if not self.up:
            raise RuntimeError(
                f"link {self.a}<->{self.b} is down; the router must not "
                f"schedule transfers over a dead link")
        start = max(t_req, self.free_at)
        wire = nbytes / self.p.bandwidth
        dur = self.p.overhead_s + wire
        self.free_at = start + dur
        self.transfers += 1
        self.bytes_moved += nbytes
        self.busy_s += dur
        self.wait_s += start - t_req
        self.wire_s += wire
        return self.free_at

    def suppress(self, nbytes: int):
        """Account a routed transfer that never started (hedge loser
        killed at the router)."""
        self.suppressed_transfers += 1
        self.suppressed_bytes += nbytes
        self.suppressed_s += self.cost(nbytes)

    def stats(self) -> dict:
        return {
            "bytes_moved": self.bytes_moved,
            "transfers": self.transfers,
            "busy_s": round(self.busy_s, 6),
            "wait_s": round(self.wait_s, 6),
            "wire_s": round(self.wire_s, 6),
            "suppressed_transfers": self.suppressed_transfers,
            "suppressed_bytes": self.suppressed_bytes,
            "suppressed_s": round(self.suppressed_s, 6),
            "up": self.up,
            "downs": self.downs,
        }


class Hub:
    """One physical hub: its own ``SharedBus`` arbitration domain."""

    def __init__(self, hub_id: int, params: BusParams):
        self.hub_id = hub_id
        self.p = params
        self.bus = SharedBus(params)

    def reset(self):
        self.bus.reset()

    def local_cost(self, nbytes: int) -> float:
        """Unloaded, arbitration-free one-transfer cost on this hub."""
        return self.p.base_overhead_s + nbytes / self.p.bandwidth

    def stats(self) -> dict:
        return self.bus.stats()


LinkSpec = Union[LinkParams, Dict[Tuple[int, int], LinkParams], None]


class FabricRouter:
    """Host-side router over hub-partitioned buses.

    Drop-in for ``SharedBus`` at the ``StreamEngine`` boundary: the
    engine calls the same ``transfer(t, nbytes, n_endpoints)`` /
    ``suppress(nbytes)`` / ``stats()`` surface, optionally extended with
    ``src`` / ``dst`` hub ids (omitted or equal -> a local transfer on
    that hub; a one-hub router is bit-identical to its bare bus).
    ``n_endpoints`` / ``dst_endpoints`` are the *per-hub* endpoint
    counts — partitioning the arbitration domain is the whole point.
    """

    def __init__(self, hub_params: List[BusParams], link: LinkSpec = None,
                 suppression: bool = True):
        if not hub_params:
            raise ValueError("a fabric needs at least one hub")
        self.hubs = [Hub(i, p) for i, p in enumerate(hub_params)]
        if isinstance(link, dict):
            self._link_params = {tuple(sorted(k)): v for k, v in link.items()}
            self._default_link = LinkParams()
        else:
            self._link_params = {}
            self._default_link = link or LinkParams()
        self._links: Dict[Tuple[int, int], InterHubLink] = {}
        self.suppression = suppression
        self._reset_counters()

    def _reset_counters(self):
        self._down_links = 0      # reset() revives every link (lk.reset())
        self.cross_hub_transfers = 0
        self.suppressed_transfers = 0
        self.suppressed_bytes = 0
        self.suppressed_saved_s = 0.0
        self.wasted_transfers = 0
        self.wasted_bytes = 0

    def reset(self):
        for h in self.hubs:
            h.reset()
        for lk in self._links.values():
            lk.reset()
        self._reset_counters()

    # -- topology -------------------------------------------------------------
    @property
    def n_hubs(self) -> int:
        return len(self.hubs)

    def hub(self, hub_id: int) -> Hub:
        return self.hubs[hub_id]

    def link(self, a: int, b: int) -> InterHubLink:
        key = (a, b) if a <= b else (b, a)
        lk = self._links.get(key)
        if lk is None:
            lk = self._links[key] = InterHubLink(
                key[0], key[1],
                self._link_params.get(key, self._default_link))
        return lk

    # -- link fault state ------------------------------------------------------
    def set_link_state(self, a: int, b: int, up: bool):
        """Mark the ``a<->b`` link up or down.  While down, ``route_cost``
        over it is +inf (so cost-aware dispatch falls back to alternate
        hubs) and ``transfer`` refuses to schedule over it.  In-flight
        transfers are not interrupted: a link fault stops *new* routes."""
        self._route(a, b)
        if a == b:
            raise ValueError("a hub has no link to itself")
        lk = self.link(a, b)
        if lk.up != up:
            lk.up = up
            if not up:
                lk.downs += 1
                self._down_links += 1
            else:
                self._down_links -= 1

    def link_ok(self, a: Optional[int], b: Optional[int]) -> bool:
        """Is the route between these hubs usable?  Local routes (same
        hub, or a missing side) never traverse a link, so always True."""
        if a is None or b is None or a == b:
            return True
        key = (a, b) if a <= b else (b, a)
        lk = self._links.get(key)
        return lk is None or lk.up

    def has_down_links(self) -> bool:
        return self._down_links > 0

    def _route(self, src: Optional[int], dst: Optional[int]) -> Tuple[int, int]:
        """Normalize a (src, dst) pair: a missing side collapses to the
        other (host-local leg), both missing defaults to hub 0.  Hub ids
        are bounds-checked here — every transfer/suppress funnels through
        this, so a bad placement fails loudly instead of wrapping to the
        wrong hub (negative ids) or crashing with a bare IndexError."""
        if src is None:
            src = dst if dst is not None else 0
        if dst is None:
            dst = src
        n = len(self.hubs)
        if not (0 <= src < n and 0 <= dst < n):
            raise ValueError(f"unknown hub in route {src}->{dst}: "
                             f"this fabric has hubs 0..{n - 1}")
        return src, dst

    def route_cost(self, src: Optional[int] = None,
                   dst: Optional[int] = None, nbytes: int = 0,
                   t: Optional[float] = None) -> float:
        """Estimated seconds to route ``nbytes`` from ``src`` to ``dst``
        — the dispatch-time toll a fabric-aware ``pick_lane`` folds into
        its completion estimate.

        A local route is one hub-bus transfer; a cross-hub route sums
        its three legs (src egress + link + dst ingress).  With ``t``
        given, each leg also charges its *current FIFO backlog*
        (``free_at - t``): a hot link or saturated destination hub makes
        remote lanes look exactly as expensive as they are right now.
        Legs queue sequentially, so summing the waits is a (cheap,
        slightly pessimistic) upper estimate.  Pure query: no counters
        move, no lazy link is materialized.
        """
        s, d = self._route(src, dst)
        if s == d:
            h = self.hubs[s]
            c = h.local_cost(nbytes)
            if t is not None:
                c += max(h.bus.free_at - t, 0.0)
            return c
        if not self.link_ok(s, d):
            return float("inf")
        hs, hd = self.hubs[s], self.hubs[d]
        c = hs.local_cost(nbytes) + hd.local_cost(nbytes)
        key = (s, d) if s <= d else (d, s)
        lk = self._links.get(key)
        if lk is not None:
            c += lk.cost(nbytes)
            if t is not None:
                c += max(lk.free_at - t, 0.0)
        else:
            p = self._link_params.get(key, self._default_link)
            c += p.overhead_s + nbytes / p.bandwidth
        if t is not None:
            c += max(hs.bus.free_at - t, 0.0) + max(hd.bus.free_at - t, 0.0)
        return c

    def route_legs(self, src: Optional[int], dst: Optional[int],
                   nbytes: int) -> dict:
        """Per-leg nominal cost breakdown of a route, for transfer-span
        annotation (flight recorder).  Pure query like ``route_cost`` —
        no counters move, no lazy link materializes; FIFO waits are
        excluded (the span's own duration already includes them)."""
        s, d = self._route(src, dst)
        if s == d:
            return {"local_s": self.hubs[s].local_cost(nbytes)}
        key = (s, d) if s <= d else (d, s)
        lk = self._links.get(key)
        if lk is not None:
            link_s = lk.cost(nbytes)
        else:
            p = self._link_params.get(key, self._default_link)
            link_s = p.overhead_s + nbytes / p.bandwidth
        return {"egress_s": self.hubs[s].local_cost(nbytes),
                "link_s": link_s,
                "ingress_s": self.hubs[d].local_cost(nbytes)}

    # -- the SharedBus-compatible surface -------------------------------------
    @property
    def bytes_moved(self) -> int:
        return sum(h.bus.bytes_moved for h in self.hubs) + \
            sum(lk.bytes_moved for lk in self._links.values())

    def transfer(self, t_req: float, nbytes: int, n_endpoints: int = 1,
                 src: Optional[int] = None, dst: Optional[int] = None,
                 dst_endpoints: int = 1) -> float:
        """Route a transfer requested at ``t_req``; returns completion.
        Local routes are one hub-bus transfer; cross-hub routes serialize
        egress -> link -> ingress (each leg queues FIFO in its domain)."""
        s, d = self._route(src, dst)
        if s == d:
            return self.hubs[s].bus.transfer(t_req, nbytes, n_endpoints)
        t_egress = self.hubs[s].bus.transfer(t_req, nbytes, n_endpoints)
        t_link = self.link(s, d).transfer(t_egress, nbytes)
        t_ingress = self.hubs[d].bus.transfer(t_link, nbytes, dst_endpoints)
        self.cross_hub_transfers += 1
        return t_ingress

    def suppress(self, nbytes: int, src: Optional[int] = None,
                 dst: Optional[int] = None, t: Optional[float] = None,
                 n_endpoints: int = 1, dst_endpoints: int = 1):
        """Kill a routed handoff before any leg starts.

        With suppression enabled (the default) every domain on the route
        books what it saved: source-hub egress, and — the cross-hub
        stakes — link time plus destination-hub ingress.  Disabled, the
        wasted route is *executed* and charged (the loser's result
        crosses the fabric and is discarded at the host), which is the
        contention baseline the benchmark compares against."""
        if not self.suppression:
            # the wasted route really runs, so it needs a request time —
            # a SharedBus-shaped suppress(nbytes) call must not silently
            # book a phantom transfer
            if t is None:
                raise ValueError(
                    "suppression is disabled on this router: suppress() "
                    "executes the wasted route and needs the request "
                    "time t")
            self.wasted_transfers += 1
            self.wasted_bytes += nbytes
            self.transfer(t, nbytes, n_endpoints, src=src, dst=dst,
                          dst_endpoints=dst_endpoints)
            return
        s, d = self._route(src, dst)
        self.suppressed_transfers += 1
        self.suppressed_bytes += nbytes
        self.hubs[s].bus.suppress(nbytes)
        saved = self.hubs[s].local_cost(nbytes)
        if d != s:
            lk = self.link(s, d)
            lk.suppress(nbytes)
            self.hubs[d].bus.suppress(nbytes)
            saved += lk.cost(nbytes) + self.hubs[d].local_cost(nbytes)
        self.suppressed_saved_s += saved

    def stats(self) -> dict:
        """Aggregate ``SharedBus``-shaped stats plus per-hub and per-link
        breakdowns.  ``suppressed_transfers`` counts router-level
        suppressions once each (the per-domain ledgers in the breakdowns
        count every leg a suppression saved)."""
        hubs = {h.hub_id: h.stats() for h in self.hubs}
        links = {f"{lk.a}<->{lk.b}": lk.stats()
                 for _, lk in sorted(self._links.items())}
        return {
            "bytes_moved": self.bytes_moved,
            "transfers": sum(h.bus.transfers for h in self.hubs) +
            sum(lk.transfers for lk in self._links.values()),
            "busy_s": round(sum(h.bus.busy_s for h in self.hubs) +
                            sum(lk.busy_s for lk in self._links.values()), 6),
            "wait_s": round(sum(h.bus.wait_s for h in self.hubs) +
                            sum(lk.wait_s for lk in self._links.values()), 6),
            "arbitration_s": round(sum(h.bus.arbitration_s_total
                                       for h in self.hubs), 6),
            "wire_s": round(sum(h.bus.wire_s for h in self.hubs) +
                            sum(lk.wire_s for lk in self._links.values()), 6),
            "max_endpoints": max(h.bus.max_endpoints for h in self.hubs),
            "suppressed_transfers": self.suppressed_transfers,
            "suppressed_bytes": self.suppressed_bytes,
            "suppressed_saved_s": round(self.suppressed_saved_s, 6),
            "wasted_transfers": self.wasted_transfers,
            "wasted_bytes": self.wasted_bytes,
            "cross_hub_transfers": self.cross_hub_transfers,
            "down_links": self._down_links,
            "n_hubs": self.n_hubs,
            "hubs": hubs,
            "links": links,
        }


def uniform_fabric(params: BusParams, n_hubs: int,
                   link: Optional[LinkParams] = None,
                   suppression: bool = True) -> FabricRouter:
    """N identical hubs of the given calibration (the common topology:
    the same USB3 hub model, replicated)."""
    return FabricRouter(
        [replace(params, name=f"{params.name}_hub{i}")
         for i in range(n_hubs)],
        link=link, suppression=suppression)
