"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(peak: float, *, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * (s + 1.0) / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr


def constant(v: float):
    def lr(step):
        return jnp.full((), v, jnp.float32)
    return lr
