"""Blockwise-int8 quantized tensors for optimizer state / gradient compression.

A ``QTensor`` stores int8 values plus one fp32 scale per block of
``BLOCK`` elements along the flattened last axis — the standard 8-bit
optimizer-state layout (Dettmers et al.) adapted to pytrees: QTensor is a
registered pytree node, so it flows through jit/scan/sharding like an array.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

BLOCK = 128


@jax.tree_util.register_dataclass
@dataclass
class QTensor:
    q: jax.Array                     # int8, shape = orig padded to BLOCK
    scale: jax.Array                 # f32, shape = (*lead, n_blocks)
    shape: tuple = dataclasses.field(metadata=dict(static=True), default=())

    @property
    def dtype(self):
        return jnp.int8


def _pad_len(n: int) -> int:
    return (-n) % BLOCK


def quantize(x: jax.Array) -> QTensor:
    """Symmetric blockwise int8 quantization of an arbitrary-shape tensor."""
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.shape[0])
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q.reshape(-1), scale=scale[:, 0], shape=tuple(shape))


def dequantize(t: QTensor) -> jax.Array:
    blocks = t.q.reshape(-1, BLOCK).astype(jnp.float32) * t.scale[:, None]
    n = 1
    for s in t.shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(t.shape)


def zeros_like_q(x) -> QTensor:
    """Quantized zeros matching ``x``'s shape (x may be Spec-like w/ .shape)."""
    n = 1
    for s in x.shape:
        n *= s
    npad = n + _pad_len(n)
    return QTensor(
        q=jnp.zeros((npad,), jnp.int8),
        scale=jnp.zeros((npad // BLOCK,), jnp.float32),
        shape=tuple(x.shape),
    )
