from repro.optim.optimizers import (Optimizer, adamw, adafactor, for_config,
                                    clip_by_global_norm, global_norm,
                                    param_count)
from repro.optim.schedules import cosine_warmup, constant
from repro.optim.quant import QTensor, quantize, dequantize
