"""Int8 error-feedback gradient compression for the cross-``data`` reduce.

At 1000-node scale the gradient all-reduce over DCN is the dominant wire
cost; compressing to int8 with an error-feedback residual (1-bit SGD /
Deep-Gradient-Compression family) cuts it 2x vs bf16 while keeping
convergence (the residual re-injects quantization error next step).

Usage inside a train step (grads already averaged within a pod):
    cg, new_resid = compress_with_feedback(grads, resid)
    # ship cg across pods (the dry-run measures these bytes), then
    g = decompress(cg)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.quant import QTensor, dequantize, quantize


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress_with_feedback(grads, resid):
    """Returns (quantized grads pytree, new residual pytree)."""
    def leaf(g, r):
        gf = g.astype(jnp.float32) + r.astype(jnp.float32)
        q = quantize(gf)
        err = gf - dequantize(q)
        return q, err.astype(jnp.bfloat16)

    out = jax.tree.map(leaf, grads, resid)
    is2 = lambda x: isinstance(x, tuple) and len(x) == 2
    qs = jax.tree.map(lambda t: t[0], out, is_leaf=is2)
    rs = jax.tree.map(lambda t: t[1], out, is_leaf=is2)
    return qs, rs


def decompress(qgrads):
    return jax.tree.map(
        lambda q: dequantize(q),
        qgrads,
        is_leaf=lambda x: isinstance(x, QTensor),
    )
