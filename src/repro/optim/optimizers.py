"""Optimizers as (init, update) pairs over param pytrees.

Self-contained (no optax). Three memory tiers for 1000-node-scale training:
  adamw        fp32 m/v                         (< ~30 B params)
  adamw8       blockwise-int8 m/v               (mid-size, 4x state cut)
  adafactor    factored second moment, no mom.  (200 B+ giants)

All states mirror the param pytree so shardings propagate leaf-by-leaf
(ZeRO-style: state shards exactly like its param).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.quant import QTensor, dequantize, quantize, zeros_like_q


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple]  # (g, st, p, step)


def _tree_zeros(params, dtype):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), n


def _layer_mapped(core, *args):
    """Run a per-leaf update over axis 0 of stacked scanned-layer leaves.

    Optimizer math runs in f32; on a (L, E, d, f) stacked-MoE leaf the f32
    temporaries between reduction barriers would occupy several GiB per
    device. ``lax.map`` over the layer axis caps the live f32 working set
    at one layer slice (identical results — the update is layerwise).
    """
    p = args[-1]
    if getattr(p, "ndim", 0) >= 3 and p.shape[0] > 1 and not any(
            isinstance(a, QTensor) for a in args):
        return jax.lax.map(lambda xs: core(*xs), args)
    return core(*args)


# ---------------------------------------------------------------------------
# AdamW (fp32 or blockwise-int8 state)
# ---------------------------------------------------------------------------
def adamw(lr: Callable[[jax.Array], jax.Array], *, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1, clip=1.0, int8_state=False) -> Optimizer:
    def init(params):
        if int8_state:
            z = lambda p: zeros_like_q(p)
        else:
            z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        if clip:
            grads, gn = clip_by_global_norm(grads, clip)
        else:
            gn = global_norm(grads)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        lr_t = lr(step)

        def core(g, mf, vf, p):
            g = g.astype(jnp.float32)
            mf = b1 * mf + (1 - b1) * g
            vf = b2 * vf + (1 - b2) * g * g
            upd = (mf / c1) / (jnp.sqrt(vf / c2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)
            return new_p, mf, vf

        def leaf(g, m, v, p):
            mf = dequantize(m) if isinstance(m, QTensor) else m
            vf = dequantize(v) if isinstance(v, QTensor) else v
            new_p, mf, vf = _layer_mapped(core, g, mf, vf, p)
            if isinstance(m, QTensor):
                mf, vf = quantize(mf), quantize(vf)
            return new_p, mf, vf

        out = jax.tree.map(leaf, grads, state["m"], state["v"], params,
                           is_leaf=lambda x: isinstance(x, QTensor))
        is3 = lambda x: isinstance(x, tuple) and len(x) == 3
        new_p = jax.tree.map(lambda t3: t3[0], out, is_leaf=is3)
        new_m = jax.tree.map(lambda t3: t3[1], out, is_leaf=is3)
        new_v = jax.tree.map(lambda t3: t3[2], out, is_leaf=is3)
        return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gn, "lr": lr_t}

    return Optimizer("adamw8" if int8_state else "adamw", init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored 2nd moment, momentum-free) — giants' memory tier
# ---------------------------------------------------------------------------
def adafactor(lr: Callable[[jax.Array], jax.Array], *, decay=0.99, eps=1e-30,
              clip=1.0, weight_decay=0.0) -> Optimizer:
    """Factored AdamW-style update. 2-D+ leaves keep row/col second-moment
    factors (O(n+m) memory); 0/1-D leaves keep a full fp32 second moment."""

    def init(params):
        def z(p):
            if p.ndim >= 2:
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),      # row sums
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        if clip:
            grads, gn = clip_by_global_norm(grads, clip)
        else:
            gn = global_norm(grads)
        lr_t = lr(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** -0.8          # increasing-decay schedule
        beta = jnp.minimum(beta, decay)

        def core(g, f, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                r = beta * f["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * f["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rc = r / jnp.maximum(
                    jnp.mean(r, axis=-1, keepdims=True), 1e-30)
                vhat = rc[..., None] * c[..., None, :]
                nf = {"r": r, "c": c}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                vhat = v
                nf = {"v": v}
            upd = g / jnp.sqrt(vhat + 1e-30)
            # update clipping (Adafactor RMS trick)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms)
            upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)
            return new_p, nf

        def leaf(g, f, p):
            return _layer_mapped(core, g, f, p)

        out = jax.tree.map(leaf, grads, state["f"], params,
                           is_leaf=lambda x: isinstance(x, dict)
                           and set(x) <= {"r", "c", "v"})
        is2 = lambda x: isinstance(x, tuple) and len(x) == 2
        new_p = jax.tree.map(lambda t2: t2[0], out, is_leaf=is2)
        new_f = jax.tree.map(lambda t2: t2[1], out, is_leaf=is2)
        return new_p, {"f": new_f}, {"grad_norm": gn, "lr": lr_t}

    return Optimizer("adafactor", init, update)


def state_specs(opt: Optimizer, param_specs):
    """Spec pytree for the optimizer state (drives AOT structs + shardings).

    State leaves shard exactly like their parameter (ZeRO): same logical
    axes, reduced for adafactor's factored moments.
    """
    from repro.sharding import Spec, spec_map

    if opt.name in ("adamw", "adamw8"):
        f32 = lambda s: Spec(s.shape, s.axes, "zeros", jnp.float32)
        return {"m": spec_map(f32, param_specs), "v": spec_map(f32, param_specs)}
    if opt.name == "adafactor":
        def fact(s):
            if len(s.shape) >= 2:
                return {
                    "r": Spec(s.shape[:-1], s.axes[:-1], "zeros", jnp.float32),
                    "c": Spec(s.shape[:-2] + s.shape[-1:],
                              s.axes[:-2] + s.axes[-1:], "zeros", jnp.float32),
                }
            return {"v": Spec(s.shape, s.axes, "zeros", jnp.float32)}
        return {"f": spec_map(fact, param_specs)}
    raise ValueError(opt.name)


def for_config(cfg, lr_fn=None) -> Optimizer:
    """Memory-tier policy: giants get adafactor, the rest AdamW."""
    from repro.optim.schedules import cosine_warmup
    lr_fn = lr_fn or cosine_warmup(3e-4, warmup=100, total=10_000)
    n = param_count(cfg)
    if n >= 100e9:
        return adafactor(lr_fn)
    return adamw(lr_fn)


def param_count(cfg) -> float:
    """Closed-form parameter count from an ArchConfig (approximate, for
    policy decisions and MODEL_FLOPS)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "moe":
        attn = L * _mla_params(cfg)
        dense_ff = cfg.first_dense_layers * 3 * d * cfg.d_ff
        moe_layers = L - cfg.first_dense_layers
        per_exp = 3 * d * cfg.moe_d_ff
        routed = moe_layers * cfg.n_experts * per_exp
        shared = moe_layers * cfg.n_shared_experts * per_exp
        router = moe_layers * d * cfg.n_experts
        return emb + attn + dense_ff + routed + shared + router
    if cfg.family == "hybrid":
        # mamba blocks + one shared attn/mlp block (weight-tied)
        din = cfg.ssm_expand * d
        per_mamba = d * (2 * din + 2 * cfg.ssm_state) + din * d + din
        n_attn = 1
        attn = n_attn * (4 * d * d + 3 * d * cfg.d_ff)
        return emb + L * per_mamba + attn
    if cfg.family == "ssm":
        din = 2 * d
        per = d * din * 4 + din * d  # qkv/gates + out
        return emb + L * per
    dh = cfg.dh
    attn_p = d * (cfg.n_heads * dh) * 2 + d * (cfg.n_kv_heads * dh) * 2
    ff_mult = 3 if cfg.mlp_gated else 2
    ff = ff_mult * d * cfg.d_ff
    enc = 0
    if cfg.family == "audio":
        enc = cfg.encoder_layers * (attn_p + ff)
    return emb + L * (attn_p + ff) + enc


def _mla_params(cfg):
    d, H = cfg.d_model, cfg.n_heads
    qn, qr, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q = (d * cfg.q_lora_rank + cfg.q_lora_rank * H * (qn + qr)
         if cfg.q_lora_rank else d * H * (qn + qr))
    kv = d * (cfg.kv_lora_rank + qr) + cfg.kv_lora_rank * H * (qn + vd)
    o = H * vd * d
    return q + kv + o
