"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute term    = per-device dot FLOPs / peak bf16 FLOP/s
    memory term     = per-device HBM traffic / HBM bandwidth
    collective term = per-device collective bytes / ICI link bandwidth

All inputs are per-device because the analyzed HLO is the SPMD per-device
program; dividing by per-chip peaks is equivalent to the global/(chips*peak)
form. MODEL_FLOPS is the closed-form useful compute (6*N*D train,
2*N*D forward) — its ratio against compiled FLOPs exposes remat/dispatch
waste.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW
from repro.optim.optimizers import param_count


def active_param_count(cfg: ArchConfig) -> float:
    """Params touched per token (MoE: routed top-k only + shared)."""
    if cfg.family != "moe":
        return param_count(cfg)
    full = param_count(cfg)
    per_exp = 3 * cfg.d_model * cfg.moe_d_ff
    moe_layers = cfg.n_layers - cfg.first_dense_layers
    routed_all = moe_layers * cfg.n_experts * per_exp
    routed_active = moe_layers * cfg.experts_per_token * per_exp
    return full - routed_all + routed_active


def attn_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Closed-form useful attention FLOPs (score + context matmuls)."""
    B, S = shape.global_batch, shape.seq_len
    fam = cfg.family
    if fam == "gemma3":
        n_local = cfg.n_layers * cfg.local_global_pattern // (
            cfg.local_global_pattern + 1)
        layers = [(n_local, min(cfg.sliding_window, S)),
                  (cfg.n_layers - n_local, S)]
    elif fam == "hybrid":
        layers = [(cfg.n_layers // cfg.superblock, S)]  # shared attn blocks
    elif fam == "ssm":
        return 0.0  # mLSTM/sLSTM: linear recurrence, no S^2 term
    elif fam == "audio":
        # decoder self (causal) + decoder cross (full memory) + encoder
        # self (bidirectional) — for a 512-dim model these dominate params
        H, dh = cfg.n_heads, cfg.dh
        per = 2.0 * H * 2 * dh                     # score + context, per pair
        Se = cfg.encoder_len
        if shape.kind == "decode":
            pairs = B * cfg.n_layers * (S + Se)    # one query token
        else:
            pairs = B * cfg.n_layers * (S * S / 2 + S * Se) \
                + B * cfg.encoder_layers * Se * Se
        total = per * pairs
        if shape.kind == "train":
            total *= 3.0
        return total
    else:
        layers = [(cfg.n_layers, S)]
    H = cfg.n_heads
    if cfg.attn_kind == "mla":
        if shape.kind == "decode":
            # absorbed decode: scores vs (kvr + rope), context gather kvr
            dq = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            dv = cfg.kv_lora_rank
        else:
            dq = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            dv = cfg.v_head_dim
    else:
        dq = dv = cfg.dh
    total = 0.0
    for L, ctx in layers:
        if shape.kind == "decode":
            total += 2.0 * B * L * H * ctx * (dq + dv)
        else:
            avg = ctx / 2 if ctx >= S else ctx  # causal half vs window band
            total += 2.0 * B * S * L * H * avg * (dq + dv)
    if shape.kind == "train":
        total *= 3.0  # fwd + bwd
    return total


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    n = active_param_count(cfg)
    attn = attn_flops(cfg, shape)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len + attn
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len + attn
    # decode: one token per sequence through the whole model
    return 2.0 * n * shape.global_batch + attn


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float

    def to_dict(self):
        return self.__dict__.copy()


def derive(cfg: ArchConfig, shape: ShapeSpec, *, dot_flops_dev: float,
           traffic_bytes_dev: float, collective_bytes_dev: float,
           n_chips: int) -> Roofline:
    """``traffic_bytes_dev`` should be the matmul-boundary (dot) bytes —
    the TPU-faithful HBM traffic basis (see hlo_stats.dot_bytes)."""
    c = dot_flops_dev / PEAK_FLOPS_BF16
    m = traffic_bytes_dev / HBM_BW
    k = collective_bytes_dev / ICI_BW
    dom = max(("compute", c), ("memory", m), ("collective", k),
              key=lambda t: t[1])[0]
    mf = model_flops(cfg, shape)
    hlo_global = dot_flops_dev * n_chips
    return Roofline(
        compute_s=c, memory_s=m, collective_s=k, dominant=dom,
        model_flops=mf, hlo_flops_global=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
    )
