"""Production mesh construction (function, not constant — importing this
module must never touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: (data=16, model=16) single-pod, (pod=2, ...) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes exist, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# --- v5e hardware constants (roofline) --------------------------------------
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~3 links usable per direction on v5e torus)
DCN_BW = 25e9  # bytes/s per host effective cross-pod
VMEM_BYTES = 128 * 1024 * 1024
HBM_BYTES = 16e9  # v5e: 16 GB HBM per chip
