"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the kwargs for the lowered step:
  train:    {"batch": {tokens, labels [, patches | frames]}}
  prefill:  {"batch": {tokens [, patches | frames]}}
  decode:   {"token", "pos", "cache" [, extras inside cache]}

With ``mesh``+``rules`` given, shardings are attached to each struct so
``jax.jit(...).lower(**specs)`` picks them up directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import model as mdl
from repro.sharding import (RULE_SETS, Spec, logical_to_pspec, shape_dtype,
                            spec_map)

MODEL_DTYPE = jnp.bfloat16


def _sds(shape, dtype, axes, mesh, rules):
    if mesh is None or rules is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = logical_to_pspec(axes, rules, mesh, shape)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ArchConfig, S: int, B: int, *, with_labels: bool,
                mesh=None, rules=None):
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    b = {"tokens": _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules)}
    if with_labels:
        b["labels"] = _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules)
    if cfg.family == "vlm":
        b["patches"] = _sds((B, cfg.n_patches, cfg.vit_dim), MODEL_DTYPE,
                            ("batch", "seq", None), mesh, rules)
    if cfg.family == "audio":
        b["frames"] = _sds((B, cfg.encoder_len, cfg.d_model), MODEL_DTYPE,
                           ("batch", "frames", "embed"), mesh, rules)
    return b


def param_structs(cfg: ArchConfig, mesh=None, rules=None):
    specs = mdl.param_specs(cfg)
    if mesh is None or rules is None:
        return shape_dtype(specs, MODEL_DTYPE)
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    return spec_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype or MODEL_DTYPE,
            sharding=NamedSharding(
                mesh, logical_to_pspec(s.axes, rules, mesh, s.shape))),
        specs)


def cache_structs(cfg: ArchConfig, B: int, T: int, mesh=None, rules=None):
    specs = mdl.cache_specs(cfg, B, T)
    if mesh is None or rules is None:
        return shape_dtype(specs, MODEL_DTYPE)
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    return spec_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype or MODEL_DTYPE,
            sharding=NamedSharding(
                mesh, logical_to_pspec(s.axes, rules, mesh, s.shape))),
        specs)


def decode_specs(cfg: ArchConfig, shape: ShapeSpec, mesh=None, rules=None):
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    B, T = shape.global_batch, shape.seq_len
    return {
        "token": _sds((B, 1), jnp.int32, ("batch", "seq"), mesh, rules),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache_structs(cfg, B, T, mesh, rules),
    }


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh=None, rules=None):
    """Every model input for the step implied by ``shape.kind``."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape.seq_len, shape.global_batch,
                                     with_labels=True, mesh=mesh, rules=rules)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape.seq_len, shape.global_batch,
                                     with_labels=False, mesh=mesh, rules=rules)}
    if shape.kind == "decode":
        return decode_specs(cfg, shape, mesh, rules)
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Concrete batches (smoke tests / examples) — small shapes only
# ---------------------------------------------------------------------------
def make_batch(cfg: ArchConfig, S: int, B: int, key, with_labels=True):
    ks = jax.random.split(key, 4)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size, jnp.int32)}
    if with_labels:
        b["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size,
                                         jnp.int32)
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(ks[2], (B, cfg.n_patches, cfg.vit_dim),
                                         MODEL_DTYPE)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(ks[3], (B, cfg.encoder_len, cfg.d_model),
                                        MODEL_DTYPE)
    return b


def init_cache(cfg: ArchConfig, B: int, T: int):
    """Fresh (empty) cache. Attention ``pos`` slots get a large sentinel so
    unwritten entries are masked out (cpos <= pos fails)."""
    specs = mdl.cache_specs(cfg, B, T)

    def mk(path, s):
        dt = s.dtype or MODEL_DTYPE
        last = getattr(path[-1], "key", None) if path else None
        if last == "pos":
            return jnp.full(s.shape, 1 << 30, dt)
        if last == "m" and dt == jnp.float32:
            return jnp.full(s.shape, -1e30, dt)  # xlstm stabilizer
        return jnp.zeros(s.shape, dt)

    return jax.tree_util.tree_map_with_path(
        mk, specs, is_leaf=lambda x: isinstance(x, Spec))
