import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (no sharding
mismatch, no unsupported collective), prints ``memory_analysis`` (fits HBM)
and ``cost_analysis`` (FLOPs/bytes), and records the roofline terms parsed
out of the compiled HLO (see hlo_stats / roofline).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.launch import specs as sp
from repro.launch import steps as st
from repro.launch.hlo_stats import analyze
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.launch.roofline import derive
from repro.optim import for_config, param_count
from repro.optim.optimizers import state_specs
from repro.sharding import RULE_SETS, logical_to_pspec, spec_map, use_rules
from jax.sharding import NamedSharding


def pick_rules(cfg: cb.ArchConfig, shape: cb.ShapeSpec) -> str:
    """Sharding-rule policy per (arch, shape) — see DESIGN.md §5."""
    n = param_count(cfg)
    if shape.kind in ("train", "prefill"):
        return "fsdp" if n >= 2e9 else "tp"
    if shape.name == "long_500k":
        return "long"
    # decode_32k: cache time axis shards over "model" (flash-decode);
    # MoE archs additionally spread experts over the batch axes (EP)
    if cfg.family == "moe":
        return "decode_moe"
    return "decode"


def batch_shard_count(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def build_inputs(cfg, shape, mesh, rules):
    """ShapeDtypeStructs (with shardings) for the step function's args."""
    rule_map = RULE_SETS[rules]
    params = sp.param_structs(cfg, mesh, rule_map)
    if shape.kind == "train":
        opt = for_config(cfg)
        ospecs = state_specs(opt, __import__("repro.models.model",
                                             fromlist=["param_specs"]
                                             ).param_specs(cfg))
        ostructs = spec_map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, s.dtype or jnp.float32,
                sharding=NamedSharding(
                    mesh, logical_to_pspec(s.axes, rule_map, mesh, s.shape))),
            ospecs)
        batch = sp.input_specs(cfg, shape, mesh, rule_map)["batch"]
        step_struct = jax.ShapeDtypeStruct((), jnp.int32)
        return opt, (params, ostructs, batch, step_struct)
    if shape.kind == "prefill":
        batch = sp.input_specs(cfg, shape, mesh, rule_map)["batch"]
        return None, (params, batch)
    dec = sp.input_specs(cfg, shape, mesh, rule_map)
    return None, (params, dec["token"], dec["pos"], dec["cache"])


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, rules: str | None = None,
             cfg_overrides: dict | None = None) -> dict:
    cfg = cb.get(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = cb.SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if cfg_overrides:
        rec["cfg_overrides"] = cfg_overrides
    ok, why = cb.supports_shape(cfg, shape_name)
    if not ok:
        rec.update(status="skip", reason=why)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rules = rules or pick_rules(cfg, shape)
    rec["rules"] = rules
    try:
        opt, args = build_inputs(cfg, shape, mesh, rules)
        seq_shards = mesh.shape.get("model", 1) if rules == "fsdp_sp" else 1
        fn, donate, n_micro = st.step_fn_for(
            cfg, shape, opt, batch_shard_count(mesh), seq_shards=seq_shards)
        rec["n_micro"] = n_micro
        with use_rules(rules, mesh):
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        stats = analyze(hlo)
        rl = derive(cfg, shape,
                    dot_flops_dev=stats.dot_flops,
                    traffic_bytes_dev=stats.dot_bytes,
                    collective_bytes_dev=stats.collective_bytes,
                    n_chips=n_chips)
        per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        # XLA-CPU float normalization carries bf16 loop state as f32 (no
        # native bf16 on CPU); TPU keeps it bf16 — report both figures.
        adj_bytes = per_dev_bytes - stats.f32_upcast_carry_bytes
        rec.update(
            status="ok",
            t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            hlo_bytes=len(hlo),
            memory={
                "argument": mem.argument_size_in_bytes,
                "output": mem.output_size_in_bytes,
                "temp": mem.temp_size_in_bytes,
                "alias": mem.alias_size_in_bytes,
                "per_device_total": per_dev_bytes,
                "per_device_tpu_adjusted": adj_bytes,
                "fits_hbm": bool(adj_bytes <= HBM_BYTES),
                "fits_hbm_raw": bool(per_dev_bytes <= HBM_BYTES),
            },
            cost={"flops": cost.get("flops"),
                  "bytes_accessed": cost.get("bytes accessed")},
            hlo_stats=stats.to_dict(),
            roofline=rl.to_dict(),
        )
        if verbose:
            print(f"[{arch} x {shape_name} @ {rec['mesh']} rules={rules}] "
                  f"compile={t_compile:.0f}s "
                  f"mem/dev={per_dev_bytes/2**30:.2f}GiB "
                  f"adj={adj_bytes/2**30:.2f}GiB "
                  f"(arg={mem.argument_size_in_bytes/2**30:.2f} "
                  f"out={mem.output_size_in_bytes/2**30:.2f} "
                  f"tmp={mem.temp_size_in_bytes/2**30:.2f} "
                  f"alias={mem.alias_size_in_bytes/2**30:.2f}) "
                  f"fits={rec['memory']['fits_hbm']} "
                  f"terms(c/m/k)={rl.compute_s:.3e}/{rl.memory_s:.3e}/"
                  f"{rl.collective_s:.3e} dom={rl.dominant} "
                  f"useful={rl.useful_ratio:.2f}")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[{arch} x {shape_name} @ {rec['mesh']}] FAILED: "
                  f"{rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default=None,
                    help="override the sharding-rule policy (perf runs)")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. kv_cache_dtype=int8)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    cells = []
    archs = cb.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(cb.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    out = open(args.out, "a") if args.out else None
    n_ok = n_fail = n_skip = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, multi_pod=mp, rules=args.rules,
                       cfg_overrides=overrides or None)
        n_ok += rec["status"] == "ok"
        n_fail += rec["status"] == "error"
        n_skip += rec["status"] == "skip"
        if out:
            rec.pop("traceback", None) if rec["status"] != "error" else None
            out.write(json.dumps(rec) + "\n")
            out.flush()
    print(f"dry-run: {n_ok} ok / {n_skip} skip / {n_fail} FAILED "
          f"of {len(cells)}")
    if out:
        out.close()
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
