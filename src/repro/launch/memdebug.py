import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Memory autopsy for a dry-run cell: compile it and list the largest
result tensors in the optimized HLO (the buffers that dominate
``memory_analysis().temp_size``), grouped by op and computation.

Usage: python -m repro.launch.memdebug --arch X --shape Y [--rules R]
"""
import argparse
from collections import defaultdict

import jax

from repro.configs import base as cb
from repro.launch import dryrun as dr
from repro.launch import steps as st
import repro.launch.hlo_stats as H
from repro.launch.mesh import make_production_mesh
from repro.sharding import use_rules


def autopsy(arch: str, shape_name: str, rules: str | None = None,
            top: int = 30, min_bytes: float = 100e6):
    cfg = cb.get(arch)
    shape = cb.SHAPES[shape_name]
    mesh = make_production_mesh()
    rules = rules or dr.pick_rules(cfg, shape)
    opt, args = dr.build_inputs(cfg, shape, mesh, rules)
    fn, donate, nm = st.step_fn_for(cfg, shape, opt,
                                    dr.batch_shard_count(mesh))
    with use_rules(rules, mesh):
        c = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
    ma = c.memory_analysis()
    print(f"[{arch} x {shape_name} rules={rules}] "
          f"arg={ma.argument_size_in_bytes/2**30:.2f} "
          f"out={ma.output_size_in_bytes/2**30:.2f} "
          f"tmp={ma.temp_size_in_bytes/2**30:.2f} GiB")
    comps = H._parse_computations(c.as_text())
    comps.pop("__entry__", None)
    rows = []
    for cname, lines in comps.items():
        for ln in lines:
            ins = H._parse_instr(ln)
            if ins is None or ins.op == "parameter":
                continue
            b = H.shape_bytes(ins.result_type)
            if b >= min_bytes:
                rows.append((b, ins.op, ins.result_type.split("{")[0][:64],
                             cname[:40], ins.name[:36]))
    rows.sort(reverse=True)
    print(f"{'GiB':>6} {'op':14s} type")
    for b, op, t, cn, nm_ in rows[:top]:
        print(f"{b/2**30:6.2f} {op:14s} {t:66s} {cn} {nm_}")
    return c


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--rules", default=None)
    ap.add_argument("--top", type=int, default=30)
    args = ap.parse_args()
    autopsy(args.arch, args.shape, args.rules, args.top)


if __name__ == "__main__":
    main()
