"""End-to-end training driver (runs on CPU for the ~100M example; the same
code path drives the production mesh).

Features exercised here are the 1000-node checklist:
  * deterministic step-indexed data pipeline with prefetch
  * jit'd train step with microbatching + sharding rules
  * async checkpointing with atomic commit + restart-from-failure
  * elastic recovery: --simulate-failure kills a "node" mid-run; the
    controller re-meshes, restores the latest snapshot, and replays the
    stream with no sample loss/duplication.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import base as cb
from repro.data import DataConfig, Prefetcher, TokenStream
from repro.launch.steps import make_train_step
from repro.models import model as mdl
from repro.optim import adamw, cosine_warmup
from repro.sharding import init_params, use_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="crash+recover at this step (elastic demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = cb.smoke(args.arch) if args.smoke else cb.get(args.arch)
    opt = adamw(cosine_warmup(args.lr, warmup=20, total=args.steps),
                weight_decay=0.01)
    step_fn = jax.jit(make_train_step(cfg, opt, n_micro=args.n_micro),
                      donate_argnums=(0, 1))

    key = jax.random.PRNGKey(0)
    params = init_params(mdl.param_specs(cfg), key, jnp.float32)
    opt_state = opt.init(params)
    store = CheckpointStore(args.ckpt_dir)
    start = 0
    if args.resume and store.latest_step() is not None:
        start, state = store.restore({"p": params, "o": opt_state})
        params, opt_state = state["p"], state["o"]
        print(f"[train] resumed from step {start}")

    dcfg = DataConfig(seed=1, vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    pf = Prefetcher(TokenStream(dcfg), start_step=start)

    losses = []
    t0 = time.time()
    step = start
    try:
        while step < args.steps:
            i, batch = pf.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.int32(i))
            step = i + 1
            if args.simulate_failure and step == args.simulate_failure:
                raise RuntimeError("simulated node failure")
            if step % args.log_every == 0 or step == args.steps:
                l = float(metrics["loss"])
                losses.append(l)
                tok_s = (args.batch * args.seq * args.log_every
                         / max(time.time() - t0, 1e-9))
                t0 = time.time()
                print(f"[train] step {step:5d} loss {l:7.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"tok/s {tok_s:,.0f}")
            if step % args.ckpt_every == 0:
                store.save(step, {"p": params, "o": opt_state})
    except RuntimeError as e:
        if "simulated" not in str(e):
            raise
        pf.close()
        print(f"[train] {e} at step {step} — recovering from checkpoint")
        store.wait()
        rstep, state = store.restore({"p": params, "o": opt_state})
        params, opt_state = state["p"], state["o"]
        pf = Prefetcher(TokenStream(dcfg), start_step=rstep)
        print(f"[train] re-meshed + restored step {rstep}; replaying stream")
        while rstep < args.steps:
            i, batch = pf.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.int32(i))
            rstep = i + 1
            if rstep % args.log_every == 0 or rstep == args.steps:
                print(f"[train] step {rstep:5d} loss "
                      f"{float(metrics['loss']):7.4f} (post-recovery)")
        step = rstep
    finally:
        pf.close()
        store.wait()

    final = float(metrics["loss"])
    print(f"[train] done at step {step}; final loss {final:.4f}")
    return final


if __name__ == "__main__":
    main()
