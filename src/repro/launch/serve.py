"""Serving driver: a CHAMP biometric pipeline with real JAX payloads.

Builds the paper's flagship pipeline — face detection -> quality scoring ->
embedding extraction -> encrypted watchlist match — as VDiSK cartridges
whose payload compute is real (small CNN/MLP stand-ins for the RetinaFace/
CR-FIQA/FaceNet bitstreams), streams synthetic camera frames through it,
and exercises a live hot-swap.

Also provides batch LM serving (prefill + decode loop) for the
transformer archs via --mode lm.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bus import BusParams, SharedBus, calibrated
from repro.core import messages as msg
from repro.core.cartridge import Cartridge, DeviceModel, FnCartridge
from repro.crypto import SecureGallery
from repro.data import FrameStream
from repro.runtime import CapabilityRegistry, StreamEngine


# ---------------------------------------------------------------------------
# Biometric cartridges (real payload compute)
# ---------------------------------------------------------------------------
EMB_DIM = 128


def _conv_params(key, cin, cout):
    return jax.random.normal(key, (3, 3, cin, cout), jnp.float32) * 0.1


def make_detector(key):
    """'RetinaFace' stand-in: blob-center detector -> one crop per frame."""
    w = _conv_params(key, 3, 8)

    def fn(params, img):
        x = jax.lax.conv_general_dilated(
            img[None], params, (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        heat = jnp.mean(jax.nn.relu(x), axis=-1)[0]
        iy, ix = jnp.unravel_index(jnp.argmax(heat), heat.shape)
        cy, cx = iy * 2, ix * 2
        crop = jax.lax.dynamic_slice(
            img, (jnp.clip(cy - 32, 0, img.shape[0] - 64),
                  jnp.clip(cx - 32, 0, img.shape[1] - 64), 0), (64, 64, 3))
        return crop

    return FnCartridge("retinaface", fn, msg.MessageSpec(msg.IMAGE_FRAME),
                       msg.MessageSpec(msg.FACE_CROPS, (64, 64, 3)),
                       params=w, capability_id=2,
                       device=DeviceModel(service_s=0.030))


def make_quality(key):
    """'CR-FIQA' stand-in: sharpness-gated passthrough (score in meta)."""
    def fn(params, crop):
        g = jnp.mean(jnp.abs(jnp.diff(crop, axis=0))) + \
            jnp.mean(jnp.abs(jnp.diff(crop, axis=1)))
        return crop * jnp.clip(g * 10, 0.5, 1.5)

    return FnCartridge("crfiqa", fn, msg.MessageSpec(msg.FACE_CROPS),
                       msg.MessageSpec(msg.FACE_CROPS, (64, 64, 3)),
                       capability_id=3, device=DeviceModel(service_s=0.030))


def make_embedder(key):
    """'FaceNet' stand-in: conv + pool + linear -> L2-normalized embedding."""
    k1, k2 = jax.random.split(key)
    params = {"conv": _conv_params(k1, 3, 16),
              "lin": jax.random.normal(k2, (16 * 8 * 8, EMB_DIM),
                                       jnp.float32) * 0.05}

    def fn(p, crop):
        x = jax.lax.conv_general_dilated(
            crop[None], p["conv"], (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x)
        x = jax.image.resize(x, (1, 8, 8, 16), "linear").reshape(-1)
        e = x @ p["lin"]
        return e / jnp.maximum(jnp.linalg.norm(e), 1e-9)

    return FnCartridge("facenet", fn, msg.MessageSpec(msg.FACE_CROPS),
                       msg.MessageSpec(msg.EMBEDDING, (EMB_DIM,)),
                       params=params, capability_id=4,
                       device=DeviceModel(service_s=0.030))


class WatchlistCartridge(Cartridge):
    """Database cartridge: encrypted gallery + in-protected-space match.

    A *batched match stage*: when the engine drains a micro-batch of
    queued embedding frames, ``process_batch`` coalesces them into one
    ``SecureGallery.match`` call — a single gallery-match kernel dispatch
    per engine service cycle instead of one per frame.

    ``mode="ann"`` routes the coalesced batch through the two-level ANN
    tier (coarse centroid scan + probed-cell rescore, ``nprobe`` cells
    per query) — the planet-scale watchlist path; the gallery must have
    ``build_ann_index()`` called after enrollment.
    """

    capability_id = 9
    name = "watchlist_db"
    consumes = msg.MessageSpec(msg.EMBEDDING, (EMB_DIM,))
    produces = msg.MessageSpec(msg.MATCH_RESULT)

    def __init__(self, gallery: SecureGallery, *, mode: str = "exact",
                 nprobe: int = 8):
        super().__init__(device=DeviceModel(service_s=0.010, load_s=0.8))
        self.gallery = gallery
        self.mode = mode
        self.nprobe = nprobe
        self.stats["match_calls"] = 0

    def fn(self, params, emb):
        return emb  # jit side is identity; match below (host-side store)

    def process(self, m):
        return self.process_batch([m])[0]

    def process_batch(self, ms):
        live = [m for m in ms if m.payload is not None]
        if not live:
            return ms
        q = np.stack([np.asarray(m.payload) for m in live])   # (B, D)
        labels, scores = self.gallery.match(                  # one kernel call
            q, k=1, mode=self.mode, nprobe=self.nprobe)
        self.stats["match_calls"] += 1
        self.stats["processed"] += len(live)
        results = iter(zip(labels[:, 0], np.asarray(scores)[:, 0]))
        out = []
        for m in ms:
            if m.payload is None:
                out.append(m)
            else:
                lab, sc = next(results)
                out.append(m.with_payload({"label": lab, "score": float(sc)},
                                          msg.MATCH_RESULT))
        return out

    def load(self):
        self._loaded = True
        self._fn = lambda p, x: x
        return 0.0


def build_biometric_pipeline(seed=0, with_quality=True, n_shards=1,
                             match_dtype="fp32", match_mode="exact",
                             nprobe=8):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    reg = CapabilityRegistry()
    reg.insert(0, make_detector(ks[0]))
    if with_quality:
        reg.insert(1, make_quality(ks[1]))
    reg.insert(2, make_embedder(ks[2]))
    # one gallery shard per watchlist replica lane (cartridge scaling)
    gallery = SecureGallery(EMB_DIM, seed=7, n_shards=n_shards,
                            match_dtype=match_dtype)
    reg.insert(3, WatchlistCartridge(gallery, mode=match_mode,
                                     nprobe=nprobe))
    return reg, gallery


def run_biometric(n_frames=30, hotswap=True):
    reg, gallery = build_biometric_pipeline()
    # enroll: run a few frames through det->quality->embed offline
    det, qual, emb = (reg.slots[0].cartridge, reg.slots[1].cartridge,
                      reg.slots[2].cartridge)
    for c in (det, qual, emb):
        c.load()
    src = FrameStream(seed=3)
    enroll = []
    for i in range(10):
        crop = det._fn(det.params, jnp.asarray(src.frame_at(i)))
        crop = qual._fn(qual.params, crop)
        enroll.append(np.asarray(emb._fn(emb.params, crop)))
    gallery.enroll(np.stack(enroll), [f"subject{i}" for i in range(10)])

    eng = StreamEngine(reg, SharedBus(calibrated("ncs2")),
                       execute_payloads=True)
    eng.feed(n_frames, interval_s=0.12,
             payload_fn=lambda i: jnp.asarray(src.frame_at(i % 10)))
    if hotswap:
        eng.schedule_remove(1.0, slot=1)   # pull the quality cartridge live
    rep = eng.run(until=60)
    hits = sum(1 for _ in rep.latencies)
    print(f"[serve] frames={rep.frames_out}/{rep.frames_in} "
          f"lost={rep.lost} mean_latency={rep.mean_latency()*1e3:.1f}ms "
          f"downtime={rep.total_downtime():.2f}s")
    return rep


# ---------------------------------------------------------------------------
# LM serving (prefill + decode)
# ---------------------------------------------------------------------------
def run_lm(arch="tinyllama-1.1b", batch=2, prompt_len=32, gen=16):
    from repro.configs import base as cb
    from repro.launch import specs as sp
    from repro.models import model as mdl
    from repro.sharding import init_params

    cfg = cb.smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(mdl.param_specs(cfg), key, jnp.bfloat16)
    batch_d = sp.make_batch(cfg, prompt_len, batch, key, with_labels=False)
    T = prompt_len + gen

    last, cache = jax.jit(lambda p, b: mdl.prefill(p, cfg, b))(params, batch_d)
    cache_t = sp.init_cache(cfg, batch, T)

    def put(dst, src):
        if src.ndim == 0 or dst.shape == src.shape:
            return src.astype(dst.dtype)
        ax = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
              if a != b][0]
        sl = [slice(None)] * dst.ndim
        sl[ax] = slice(0, src.shape[ax])
        return dst.at[tuple(sl)].set(src.astype(dst.dtype))

    cache = jax.tree.map(put, cache_t, cache)
    step = jax.jit(lambda p, t, i, c: mdl.serve_step(p, cfg, t, i, c))
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    outs = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        tok, cache = step(params, tok, jnp.int32(prompt_len + i), cache)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(outs, axis=1)
    print(f"[serve-lm] {arch}: generated {gen}x{batch} tokens "
          f"({batch * (gen - 1) / dt:.1f} tok/s on CPU); "
          f"sample: {np.asarray(toks[0])[:12]}")
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["biometric", "lm"], default="biometric")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--frames", type=int, default=30)
    ap.add_argument("--no-hotswap", action="store_true")
    args = ap.parse_args(argv)
    if args.mode == "biometric":
        run_biometric(args.frames, hotswap=not args.no_hotswap)
    else:
        run_lm(args.arch)


if __name__ == "__main__":
    main()
