"""Serving driver: CHAMP fleet serving behind the multi-tenant front door.

Builds the paper's flagship pipeline — face detection -> quality scoring ->
embedding extraction -> encrypted watchlist match — as VDiSK cartridges
whose payload compute is real (small CNN/MLP stand-ins for the RetinaFace/
CR-FIQA/FaceNet bitstreams), and serves it three ways:

* ``--mode fleet`` (the canonical entry point): several tenants — live
  checkpoint operators with a latency SLO, recon feeds, archive
  backfill — share the box through the ``FrontDoor`` admission
  controller.  Each tenant screens against its *own* watchlist
  (tenant-scoped gallery views), the door sheds bulk work first under
  overload, and the run prints a per-tenant admission/SLO table.
* ``--mode biometric``: the single-operator scenario with a live
  hot-swap (the pre-fleet behaviour, unchanged).
* ``--mode lm``: batch LM serving (prefill + decode) for the
  transformer archs.
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # no TPU probing on CPU hosts

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bus import BusParams, SharedBus, calibrated
from repro.core import messages as msg
from repro.core.cartridge import Cartridge, DeviceModel, FnCartridge
from repro.crypto import SecureGallery
from repro.data import FrameStream
from repro.runtime import (CapabilityRegistry, FrontDoor, StreamEngine,
                           Tenant)


# ---------------------------------------------------------------------------
# Biometric cartridges (real payload compute)
# ---------------------------------------------------------------------------
EMB_DIM = 128


def _conv_params(key, cin, cout):
    return jax.random.normal(key, (3, 3, cin, cout), jnp.float32) * 0.1


def make_detector(key):
    """'RetinaFace' stand-in: blob-center detector -> one crop per frame."""
    w = _conv_params(key, 3, 8)

    def fn(params, img):
        x = jax.lax.conv_general_dilated(
            img[None], params, (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        heat = jnp.mean(jax.nn.relu(x), axis=-1)[0]
        iy, ix = jnp.unravel_index(jnp.argmax(heat), heat.shape)
        cy, cx = iy * 2, ix * 2
        crop = jax.lax.dynamic_slice(
            img, (jnp.clip(cy - 32, 0, img.shape[0] - 64),
                  jnp.clip(cx - 32, 0, img.shape[1] - 64), 0), (64, 64, 3))
        return crop

    return FnCartridge("retinaface", fn, msg.MessageSpec(msg.IMAGE_FRAME),
                       msg.MessageSpec(msg.FACE_CROPS, (64, 64, 3)),
                       params=w, capability_id=2,
                       device=DeviceModel(service_s=0.030))


def make_quality(key):
    """'CR-FIQA' stand-in: sharpness-gated passthrough (score in meta)."""
    def fn(params, crop):
        g = jnp.mean(jnp.abs(jnp.diff(crop, axis=0))) + \
            jnp.mean(jnp.abs(jnp.diff(crop, axis=1)))
        return crop * jnp.clip(g * 10, 0.5, 1.5)

    return FnCartridge("crfiqa", fn, msg.MessageSpec(msg.FACE_CROPS),
                       msg.MessageSpec(msg.FACE_CROPS, (64, 64, 3)),
                       capability_id=3, device=DeviceModel(service_s=0.030))


def make_embedder(key):
    """'FaceNet' stand-in: conv + pool + linear -> L2-normalized embedding."""
    k1, k2 = jax.random.split(key)
    params = {"conv": _conv_params(k1, 3, 16),
              "lin": jax.random.normal(k2, (16 * 8 * 8, EMB_DIM),
                                       jnp.float32) * 0.05}

    def fn(p, crop):
        x = jax.lax.conv_general_dilated(
            crop[None], p["conv"], (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x)
        x = jax.image.resize(x, (1, 8, 8, 16), "linear").reshape(-1)
        e = x @ p["lin"]
        return e / jnp.maximum(jnp.linalg.norm(e), 1e-9)

    return FnCartridge("facenet", fn, msg.MessageSpec(msg.FACE_CROPS),
                       msg.MessageSpec(msg.EMBEDDING, (EMB_DIM,)),
                       params=params, capability_id=4,
                       device=DeviceModel(service_s=0.030))


class WatchlistCartridge(Cartridge):
    """Database cartridge: encrypted gallery + in-protected-space match.

    A *batched match stage*: when the engine drains a micro-batch of
    queued embedding frames, ``process_batch`` coalesces them into one
    ``SecureGallery.match`` call — a single gallery-match kernel dispatch
    per engine service cycle instead of one per frame.

    ``mode="ann"`` routes the coalesced batch through the two-level ANN
    tier (coarse centroid scan + probed-cell rescore, ``nprobe`` cells
    per query) — the planet-scale watchlist path; the gallery must have
    ``build_ann_index()`` called after enrollment.

    ``tenant_scoped=True`` (fleet serving): frames are grouped by the
    tenant id they carry and each group matches only against that
    tenant's gallery view — one tenant's watchlist never serves
    another's match.  Frames without a tenant tag (or whose tenant has
    no enrolled rows) fall back to the shared fleet pool.
    """

    capability_id = 9
    name = "watchlist_db"
    consumes = msg.MessageSpec(msg.EMBEDDING, (EMB_DIM,))
    produces = msg.MessageSpec(msg.MATCH_RESULT)

    def __init__(self, gallery: SecureGallery, *, mode: str = "exact",
                 nprobe: int = 8, tenant_scoped: bool = False,
                 hit_threshold: float = 0.5):
        super().__init__(device=DeviceModel(service_s=0.010, load_s=0.8))
        self.gallery = gallery
        self.mode = mode
        self.nprobe = nprobe
        self.tenant_scoped = tenant_scoped
        self.hit_threshold = hit_threshold
        self.stats["match_calls"] = 0
        self.stats["hits"] = 0           # matches at/above hit_threshold

    def fn(self, params, emb):
        return emb  # jit side is identity; match below (host-side store)

    def process(self, m):
        return self.process_batch([m])[0]

    def _scope_of(self, m) -> object:
        """Which gallery view this frame screens against: its tenant's,
        or None (the shared pool) when untagged / not enrolled."""
        if not self.tenant_scoped:
            return None
        tenant = m.meta.get("tenant")
        if tenant is None or not self.gallery.has_tenant(tenant):
            return None
        return tenant

    def process_batch(self, ms):
        live = [m for m in ms if m.payload is not None]
        if not live:
            return ms
        # one gallery.match kernel dispatch per tenant scope in the
        # micro-batch (a single call when not tenant-scoped)
        groups: dict = {}
        for i, m in enumerate(live):
            groups.setdefault(self._scope_of(m), []).append(i)
        labels = [None] * len(live)
        scores = [0.0] * len(live)
        for tenant, idxs in groups.items():
            q = np.stack([np.asarray(live[i].payload) for i in idxs])
            lab, sc = self.gallery.match(q, k=1, mode=self.mode,
                                         nprobe=self.nprobe, tenant=tenant)
            sc = np.asarray(sc)
            self.stats["match_calls"] += 1
            for j, i in enumerate(idxs):
                labels[i] = lab[j, 0]
                scores[i] = float(sc[j, 0])
        self.stats["hits"] += sum(1 for s in scores
                                  if s >= self.hit_threshold)
        self.stats["processed"] += len(live)
        results = iter(zip(labels, scores))
        out = []
        for m in ms:
            if m.payload is None:
                out.append(m)
            else:
                lab, sc = next(results)
                out.append(m.with_payload({"label": lab, "score": sc},
                                          msg.MATCH_RESULT))
        return out

    def load(self):
        self._loaded = True
        self._fn = lambda p, x: x
        return 0.0


def build_biometric_pipeline(seed=0, with_quality=True, n_shards=1,
                             match_dtype="fp32", match_mode="exact",
                             nprobe=8, tenant_scoped=False):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    reg = CapabilityRegistry()
    reg.insert(0, make_detector(ks[0]))
    if with_quality:
        reg.insert(1, make_quality(ks[1]))
    reg.insert(2, make_embedder(ks[2]))
    # one gallery shard per watchlist replica lane (cartridge scaling)
    gallery = SecureGallery(EMB_DIM, seed=7, n_shards=n_shards,
                            match_dtype=match_dtype)
    reg.insert(3, WatchlistCartridge(gallery, mode=match_mode,
                                     nprobe=nprobe,
                                     tenant_scoped=tenant_scoped))
    return reg, gallery


def _pipeline_embed(reg, src, frame_ids):
    """Offline enrollment embeddings: the same det->quality->embed path
    the streamed frames take."""
    det, qual, emb = (reg.slots[0].cartridge, reg.slots[1].cartridge,
                      reg.slots[2].cartridge)
    for c in (det, qual, emb):
        c.load()
    out = []
    for i in frame_ids:
        crop = det._fn(det.params, jnp.asarray(src.frame_at(i)))
        crop = qual._fn(qual.params, crop)
        out.append(np.asarray(emb._fn(emb.params, crop)))
    return np.stack(out)


def run_biometric(n_frames=30, hotswap=True):
    reg, gallery = build_biometric_pipeline()
    # enroll: run a few frames through det->quality->embed offline
    src = FrameStream(seed=3)
    gallery.enroll(_pipeline_embed(reg, src, range(10)),
                   [f"subject{i}" for i in range(10)])

    eng = StreamEngine(reg, SharedBus(calibrated("ncs2")),
                       execute_payloads=True)
    eng.feed(n_frames, interval_s=0.12,
             payload_fn=lambda i: jnp.asarray(src.frame_at(i % 10)))
    if hotswap:
        eng.schedule_remove(1.0, slot=1)   # pull the quality cartridge live
    rep = eng.run(until=60)
    wl = reg.slots[3].cartridge.stats      # watchlist match-hit accounting
    print(f"[serve] frames={rep.frames_out}/{rep.frames_in} "
          f"lost={rep.lost} hits={wl['hits']} "
          f"mean_latency={rep.mean_latency()*1e3:.1f}ms "
          f"downtime={rep.total_downtime():.2f}s")
    return rep


# ---------------------------------------------------------------------------
# Fleet serving: multi-tenant admission through the front door
# ---------------------------------------------------------------------------
# the three conventional tiers: checkpoint operators screening live
# subjects (tight SLO, sheds last), recon feeds, archive backfill (bulk)
FLEET_TENANTS = (
    Tenant("field_ops", priority=0, weight=8.0, slo_s=0.5, queue_cap=64),
    Tenant("recon", priority=1, weight=3.0, queue_cap=128),
    Tenant("backfill", priority=2, weight=1.0, queue_cap=64),
)
# offered load per tenant, as a fraction of the pipeline's bottleneck
# rate; summing past 1.0 = deliberate overload (backfill sheds first)
FLEET_LOAD = {"field_ops": 0.2, "recon": 0.6, "backfill": 1.2}


def run_fleet(duration_s=3.0, load=None, hotswap=False):
    """The canonical fleet-serving entry point: the biometric pipeline
    behind the multi-tenant front door.  Each tenant enrolls its own
    watchlist (tenant-scoped gallery views) and streams frames at its
    offered rate; the door does weighted-fair admission with
    lowest-class shed, and the run prints the per-tenant ledger."""
    reg, gallery = build_biometric_pipeline(tenant_scoped=True)
    src = FrameStream(seed=3)
    # disjoint per-tenant watchlists from the shared frame bank: tenant
    # i's subjects are frames [10*i, 10*i+10)
    tenant_base = {}
    for i, t in enumerate(FLEET_TENANTS):
        base = 10 * i
        tenant_base[t.name] = base
        gallery.enroll(_pipeline_embed(reg, src, range(base, base + 10)),
                       [f"{t.name}/subject{j}" for j in range(10)],
                       tenant=t.name)

    fd = FrontDoor()
    for t in FLEET_TENANTS:
        fd.add_tenant(t)
    eng = StreamEngine(reg, SharedBus(calibrated("ncs2")),
                       execute_payloads=True, frontdoor=fd)
    # bottleneck stage service time sets the capacity the load fractions
    # scale from
    bottleneck_s = max(r.cartridge.device.service_s for r in reg.records())
    cap_fps = 1.0 / bottleneck_s
    for t in FLEET_TENANTS:
        rate = (load or FLEET_LOAD)[t.name] * cap_fps
        n = int(rate * duration_s)
        base = tenant_base[t.name]
        eng.feed_tenant(
            t.name, n, interval_s=1.0 / rate,
            payload_fn=lambda i, b=base: jnp.asarray(
                src.frame_at(b + i % 10)))
    if hotswap:
        eng.schedule_remove(1.0, slot=1)
    rep = eng.run(until=float("inf"))
    wl = reg.slots[3].cartridge.stats
    fdd = rep.frontdoor
    print(f"[serve-fleet] frames={rep.frames_out}/{rep.frames_in} "
          f"lost={rep.lost} hits={wl['hits']} "
          f"shed={fdd['shed']} credit={fdd['credit']:.2f}")
    for name, t in fdd["tenants"].items():
        print(f"  {name:10s} [{t['class']:11s}] offered={t['offered']:4d} "
              f"admitted={t['admitted']:4d} shed={t['shed']:4d} "
              f"goodput={t['goodput']:.2f} p99={t['latency'].get('p99', 0.0) * 1e3:7.1f}ms "
              f"slo_miss={t['slo_miss']}")
    return rep


# ---------------------------------------------------------------------------
# LM serving (prefill + decode)
# ---------------------------------------------------------------------------
def run_lm(arch="tinyllama-1.1b", batch=2, prompt_len=32, gen=16):
    from repro.configs import base as cb
    from repro.launch import specs as sp
    from repro.models import model as mdl
    from repro.sharding import init_params

    cfg = cb.smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(mdl.param_specs(cfg), key, jnp.bfloat16)
    batch_d = sp.make_batch(cfg, prompt_len, batch, key, with_labels=False)
    T = prompt_len + gen

    last, cache = jax.jit(lambda p, b: mdl.prefill(p, cfg, b))(params, batch_d)
    cache_t = sp.init_cache(cfg, batch, T)

    def put(dst, src):
        if src.ndim == 0 or dst.shape == src.shape:
            return src.astype(dst.dtype)
        ax = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
              if a != b][0]
        sl = [slice(None)] * dst.ndim
        sl[ax] = slice(0, src.shape[ax])
        return dst.at[tuple(sl)].set(src.astype(dst.dtype))

    cache = jax.tree.map(put, cache_t, cache)
    step = jax.jit(lambda p, t, i, c: mdl.serve_step(p, cfg, t, i, c))
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    outs = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        tok, cache = step(params, tok, jnp.int32(prompt_len + i), cache)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(outs, axis=1)
    print(f"[serve-lm] {arch}: generated {gen}x{batch} tokens "
          f"({batch * (gen - 1) / dt:.1f} tok/s on CPU); "
          f"sample: {np.asarray(toks[0])[:12]}")
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["fleet", "biometric", "lm"],
                    default="fleet")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--frames", type=int, default=30)
    ap.add_argument("--duration", type=float, default=3.0,
                    help="fleet mode: seconds of offered traffic")
    ap.add_argument("--no-hotswap", action="store_true")
    args = ap.parse_args(argv)
    if args.mode == "fleet":
        run_fleet(args.duration)
    elif args.mode == "biometric":
        run_biometric(args.frames, hotswap=not args.no_hotswap)
    else:
        run_lm(args.arch)


if __name__ == "__main__":
    main()
