"""Static analysis of post-optimization HLO text — the dry-run "profiler".

``compiled.cost_analysis()`` on the CPU backend (a) reports *per-device*
numbers and (b) counts ``while`` bodies **once**, ignoring trip counts
(calibrated empirically). Scan-over-layers therefore under-reports FLOPs by
~n_layers. This module re-derives the three roofline inputs from HLO text:

  * dot FLOPs          — every ``dot`` op's 2*batch*M*K*N, x loop trip count
  * HBM traffic        — operand+result bytes of top-level instructions
                         (fusion internals excluded), x trip count
  * collective bytes   — operand bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute,
                         x trip count, split by type

Loop trip counts come from XLA's ``backend_config known_trip_count`` on
``while`` ops (exact for scan), with a condition-constant fallback.
All numbers are per-device (HLO here is the SPMD per-device program).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops that move no HBM bytes of their own (control flow passes by alias)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "add-dependency", "partition-id", "replica-id",
             "iota", "while", "conditional", "call", "custom-call",
             "optimization-barrier", "broadcast", "reshape"}


def shape_dims(type_str: str):
    """[(dtype, [dims])] for every array in an HLO type string."""
    return [(dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _SHAPE_RE.findall(type_str)]


def _nbytes(dt: str, dims) -> int:
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt]


def shape_bytes(type_str: str) -> int:
    return sum(_nbytes(dt, dims) for dt, dims in shape_dims(type_str))


@dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list
    raw: str


@dataclass
class HloStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: dict = field(default_factory=dict)
    collective_count: int = 0
    f32_upcast_carry_bytes: int = 0
    top_collectives: list = field(default_factory=list)
    top_dots: list = field(default_factory=list)

    def to_dict(self):
        return {
            "dot_flops": self.dot_flops,
            "dot_bytes": self.dot_bytes,
            "traffic_bytes": self.traffic_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_type": dict(self.collective_by_type),
            "collective_count": self.collective_count,
            "f32_upcast_carry_bytes": int(self.f32_upcast_carry_bytes),
            "top_collectives": self.top_collectives[:12],
            "top_dots": self.top_dots[:12],
        }


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")


def _parse_instr(ln: str):
    """Manual scan: '%name = <type> <op>(<operands>), attrs...'.
    Handles tuple types containing /*index=N*/ comments and '='."""
    m = _NAME_RE.match(ln)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    # result type: either a (possibly nested) tuple or an array type token
    if i < len(ln) and ln[i] == "(":
        depth, j = 1, i + 1
        while j < len(ln) and depth:
            if ln[j] == "(":
                depth += 1
            elif ln[j] == ")":
                depth -= 1
            j += 1
        rtype = ln[i:j]
        i = j
    else:
        mt = re.match(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?", ln[i:])
        if not mt:
            return None
        rtype = mt.group(0)
        i += mt.end()
    mo = re.match(r"\s*([a-z][\w\-]*)\(", ln[i:])
    if not mo:
        return None
    op = mo.group(1)
    i += mo.end()
    depth, j = 1, i
    while j < len(ln) and depth:
        if ln[j] == "(":
            depth += 1
        elif ln[j] == ")":
            depth -= 1
        j += 1
    operands = [o.strip().lstrip("%") for o in ln[i:j - 1].split(",")
                if o.strip()]
    return Instr(name, rtype, op, operands, ln)


def _parse_computations(hlo: str):
    comps, name, lines = {}, None, []
    for ln in hlo.splitlines():
        if name is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{\s*$", ln)
            if m:
                name, lines = m.group(2), []
                if m.group(1):
                    comps["__entry__"] = m.group(2)
            continue
        if ln.startswith("}"):
            comps[name] = lines
            name = None
            continue
        lines.append(ln)
    return comps


def _instrs(lines):
    out = []
    for ln in lines:
        ins = _parse_instr(ln)
        if ins is not None:
            out.append(ins)
    return out


def _trip_from_backend_config(ln: str) -> int | None:
    m = re.search(r'known_trip_count[":{\s]+n["\s:]+(\d+)', ln)
    return int(m.group(1)) if m else None


def _trip_from_condition(cond_lines) -> int:
    best = 1
    for ln in cond_lines or []:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(instr: Instr, sym: dict) -> float:
    """2 * prod(batch) * prod(lhs_free) * prod(K) * prod(rhs_free)."""
    if len(instr.operands) < 2:
        return 0.0
    lhs_t = sym.get(instr.operands[0])
    rhs_t = sym.get(instr.operands[1])
    if not lhs_t or not rhs_t:
        return 0.0
    lhs = shape_dims(lhs_t)
    rhs = shape_dims(rhs_t)
    if not lhs or not rhs:
        return 0.0
    ldims, rdims = lhs[0][1], rhs[0][1]

    def _get(attr):
        m = re.search(attr + r"=\{([0-9,]*)\}", instr.raw)
        return [int(x) for x in m.group(1).split(",") if x] if m else []

    lc, rc = _get("lhs_contracting_dims"), _get("rhs_contracting_dims")
    lb, rb = _get("lhs_batch_dims"), _get("rhs_batch_dims")
    pb = 1
    for d in lb:
        pb *= ldims[d] if d < len(ldims) else 1
    k = 1
    for d in lc:
        k *= ldims[d] if d < len(ldims) else 1
    lf = 1
    for i, d in enumerate(ldims):
        if i not in lc and i not in lb:
            lf *= d
    rf = 1
    for i, d in enumerate(rdims):
        if i not in rc and i not in rb:
            rf *= d
    return 2.0 * pb * lf * k * rf


def analyze(hlo: str) -> HloStats:
    comps = _parse_computations(hlo)
    entry = comps.pop("__entry__", None)
    parsed = {c: _instrs(lines) for c, lines in comps.items()}
    if entry is None:
        entry = max(parsed, key=lambda c: len(parsed[c])) if parsed else None

    # call graph with loop multipliers. Computations reached only through
    # fusion/to_apply edges are "fused contexts": their instructions run
    # inside a fused kernel and move no HBM bytes of their own (dots and
    # collectives still count).
    mult: dict = defaultdict(float)
    mult[entry] = 1.0
    real: set = {entry}
    frontier = [entry]
    visited = set()
    while frontier:
        c = frontier.pop()
        if c in visited or c not in parsed:
            continue
        visited.add(c)
        c_real = c in real
        for ins in parsed[c]:
            if ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.raw)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.raw)
                trip = _trip_from_backend_config(ins.raw)
                if trip is None and mc:
                    trip = _trip_from_condition(comps.get(mc.group(1)))
                trip = trip or 1
                for mm in (mb, mc):
                    if mm:
                        callee = mm.group(1)
                        mult[callee] = max(mult[callee], mult[c] * trip)
                        if c_real:
                            real.add(callee)
                        frontier.append(callee)
            else:
                is_fusion_edge = ins.op in ("fusion", "reduce", "sort", "map",
                                            "scatter", "reduce-window",
                                            "select-and-scatter", "all-reduce",
                                            "reduce-scatter")
                for attr in ("calls", "to_apply", "branch_computations"):
                    for m in re.finditer(
                            attr + r"=\{?%?([\w.\-]+(?:,\s*%[\w.\-]+)*)\}?",
                            ins.raw):
                        for callee in re.split(r",\s*%?", m.group(1)):
                            callee = callee.lstrip("%")
                            if callee in parsed:
                                mult[callee] = max(mult[callee], mult[c])
                                if c_real and not is_fusion_edge and \
                                        attr != "to_apply":
                                    real.add(callee)
                                frontier.append(callee)

    # per-computation root info for in-place fusion accounting:
    # list of (elem_bytes, is_dus, update_bytes) per root tuple element.
    fusion_root_info = {}
    for cname, instrs in parsed.items():
        by_name = {i.name: i for i in instrs}
        root = next((i for i in instrs
                     if i.raw.lstrip().startswith("ROOT")), None)
        if root is None:
            continue
        elems = root.operands if root.op == "tuple" else [root.name]
        info = []
        for e in elems:
            ins_e = by_name.get(e)
            # look through bitcast/copy/convert wrappers
            hops = 0
            while ins_e is not None and ins_e.op in (
                    "bitcast", "copy", "convert", "transpose") and hops < 4:
                ins_e = by_name.get(ins_e.operands[0]) if ins_e.operands \
                    else None
                hops += 1
            if ins_e is None:
                info.append((0, False, 0))
                continue
            eb = shape_bytes(ins_e.result_type)
            if ins_e.op == "dynamic-update-slice" and len(ins_e.operands) >= 2:
                upd = by_name.get(ins_e.operands[1])
                ub = shape_bytes(upd.result_type) if upd else 0
                info.append((eb, True, ub))
            else:
                info.append((eb, False, 0))
        fusion_root_info[cname] = info
    stats = HloStats(collective_by_type=defaultdict(float))
    dots, colls = [], []
    for cname, instrs in parsed.items():
        m_c = mult.get(cname, 0.0)
        if m_c == 0.0:
            continue  # unreachable (dead) computation
        sym = {i.name: i.result_type for i in instrs}
        # parameters appear as "%p = f32[..] parameter(0)" — already in sym
        for ins in instrs:
            base = ins.op.replace("-start", "")
            if base in _COLL_KINDS and not ins.op.endswith("-done"):
                b = sum(shape_bytes(sym.get(o, o)) for o in ins.operands)
                stats.collective_bytes += b * m_c
                stats.collective_by_type[base] += b * m_c
                stats.collective_count += 1
                colls.append((base, b, m_c, cname, ins.name))
            if ins.op == "dot":
                f = _dot_flops(ins, sym)
                stats.dot_flops += f * m_c
                # matmul-boundary HBM traffic: operands + result. On TPU
                # elementwise chains fuse into dot prologues/epilogues, so
                # this is the tight memory-roofline basis (weights +
                # activations streamed per use); bf16-equivalent for f32
                # operands the CPU backend upcast from bf16.
                db = shape_bytes(ins.result_type)
                by_name_local = {i.name: i for i in instrs}
                for o in ins.operands[:2]:
                    t = sym.get(o, "")
                    b = shape_bytes(t)
                    if t.startswith("f32"):
                        b //= 2   # CPU float-normalization upcast
                    # look through converts: an int8-sourced operand
                    # streams from HBM at int8 width on TPU (the upcast
                    # fuses into the matmul read)
                    src = by_name_local.get(o)
                    hops = 0
                    while src is not None and hops < 5:
                        if src.op in ("convert", "copy", "bitcast",
                                      "transpose", "fusion", "reshape",
                                      "get-tuple-element",
                                      "optimization-barrier"):
                            ot = [sym.get(x, "") for x in src.operands]
                            if any(x.startswith(("s8", "u8")) for x in ot):
                                b = min(b, shape_bytes(t) // 2)
                                break
                            src = by_name_local.get(src.operands[0]) \
                                if src.operands else None
                            hops += 1
                        else:
                            break
                    db += b
                stats.dot_bytes += db * m_c
                dots.append((f, m_c, cname, ins.name))
            # HBM traffic: top-level ops in *real* computations move
            # operands + result. In-place patterns must not be charged at
            # full buffer size (else scan accumulators blow up as O(L^2)):
            #  - dynamic-update-slice reads/writes only the update region;
            #  - fusions pass accumulated buffers through aliased
            #    operand/result pairs (greedy size-match removal).
            if cname in real and ins.op not in _FREE_OPS \
                    and base not in _COLL_KINDS:
                res_b = shape_bytes(ins.result_type)
                op_bytes = [shape_bytes(sym.get(o, o)) for o in ins.operands]
                if ins.op == "dynamic-update-slice":
                    upd = shape_bytes(sym.get(ins.operands[1], "")) \
                        if len(ins.operands) > 1 else 0
                    b = 2 * upd
                elif ins.op == "dynamic-slice":
                    b = 2 * res_b
                elif ins.op == "fusion":
                    m = re.search(r"calls=%?([\w.\-]+)", ins.raw)
                    info = fusion_root_info.get(m.group(1)) if m else None
                    if info:
                        op_rem = list(op_bytes)
                        b = 0
                        for eb, is_dus, ub in info:
                            if is_dus:
                                b += 2 * ub          # update region r/w
                                if eb in op_rem:     # aliased accumulator
                                    op_rem.remove(eb)
                            else:
                                b += eb              # fresh output write
                        b += sum(op_rem)             # operand reads
                    else:
                        b = res_b + sum(op_bytes)
                else:
                    b = res_b + sum(op_bytes)
                stats.traffic_bytes += b * m_c

    # CPU-backend artifact: XLA-CPU float normalization upcasts bf16 loop
    # state to f32 (CPU has no native bf16 ALU), doubling the carried KV
    # cache / grad accumulators in memory_analysis. TPU executes bf16
    # natively so these buffers would stay bf16. Detect: f32 while-carry
    # elements >= 64 MiB whose init-tuple producer (within 3 hops) is a
    # convert from bf16; report half their bytes (f32 -> bf16 delta).
    for cname, instrs in parsed.items():
        if cname not in real:
            continue
        by_name = {i.name: i for i in instrs}
        for ins in instrs:
            if ins.op != "while" or not ins.operands:
                continue
            init = by_name.get(ins.operands[0])
            if init is None or init.op != "tuple":
                continue
            elems = shape_dims(ins.result_type)
            for idx, (dt, dims) in enumerate(elems):
                if dt != "f32" or idx >= len(init.operands):
                    continue
                b = _nbytes(dt, dims)
                if b < 64 * 2**20:
                    continue
                src = by_name.get(init.operands[idx])
                hops = 0
                is_upcast = False
                while src is not None and hops < 3:
                    if src.op == "convert" or "convert" in src.name:
                        ops_t = [
                            by_name[o].result_type if o in by_name else ""
                            for o in src.operands]
                        if any(t.startswith("bf16") for t in ops_t):
                            is_upcast = True
                            break
                    src = by_name.get(src.operands[0]) if src.operands \
                        else None
                    hops += 1
                if is_upcast:
                    stats.f32_upcast_carry_bytes += b // 2

    colls.sort(key=lambda t: -t[1] * t[2])
    dots.sort(key=lambda t: -t[0] * t[1])
    stats.top_collectives = [
        {"kind": k, "bytes": b, "mult": m, "comp": c, "name": n}
        for k, b, m, c, n in colls[:20]]
    stats.top_dots = [
        {"flops": f, "mult": m, "comp": c, "name": n}
        for f, m, c, n in dots[:20]]
    stats.collective_by_type = dict(stats.collective_by_type)
    return stats
