"""Step functions lowered by the dry-run and executed by train.py/serve.py.

``make_train_step`` builds a full production step: microbatched gradient
accumulation (scan), global-norm clipping, optimizer update, metrics. The
microbatch count is auto-chosen so the remat'd activation working set fits
v5e HBM next to params + optimizer state.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import model as mdl
from repro.optim import Optimizer, param_count
from repro.launch.mesh import HBM_BYTES


# ---------------------------------------------------------------------------
# Microbatch policy
# ---------------------------------------------------------------------------
def auto_microbatches(cfg: ArchConfig, B: int, S: int, batch_shards: int,
                      target_bytes: float = 3.0e9,
                      seq_shards: int = 1) -> int:
    """Smallest power-of-2 microbatch count s.t. the per-device scan-carry
    activation footprint (B_local*S*d per layer, bf16) fits ``target_bytes``.

    Capped so each microbatch still divides over the batch-sharded axis.
    ``seq_shards`` > 1 models sequence-parallel carries (fsdp_sp rules).
    """
    n_micro, cap = 1, max(B // batch_shards, 1)
    while n_micro < cap:
        b_local = max(B // batch_shards // n_micro, 1)
        act = cfg.n_layers * b_local * (S // seq_shards) \
            * cfg.d_model * 2 * 1.5
        if act <= target_bytes:
            break
        n_micro *= 2
    return n_micro


def grad_accum_dtype(cfg: ArchConfig):
    """fp32 accumulation when it fits; bf16 for 100B+ giants (memory)."""
    return jnp.bfloat16 if param_count(cfg) >= 100e9 else jnp.float32


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, opt: Optimizer, *, n_micro: int = 1):
    accum_dt = grad_accum_dtype(cfg)

    def loss_fn(params, batch):
        return mdl.loss_fn(params, cfg, batch)

    def train_step(params, opt_state, batch, step):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dt), params)

            def body(carry, micro):
                acc, loss_acc = carry
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, micro)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(accum_dt), acc, g)
                return (acc, loss_acc + l), m

            (grads, loss), ms = jax.lax.scan(body, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)

        new_params, new_state, om = opt.update(grads, opt_state, params, step)
        metrics = dict(metrics, loss=loss, **om)
        return new_params, new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return mdl.prefill(params, cfg, batch)
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, token, pos, cache):
        return mdl.serve_step(params, cfg, token, pos, cache)
    return serve_step


def step_fn_for(cfg: ArchConfig, shape: ShapeSpec, opt: Optimizer | None,
                batch_shards: int, seq_shards: int = 1):
    """(callable, donate_argnums, n_micro) for the step ``shape.kind`` implies."""
    if shape.kind == "train":
        n_micro = auto_microbatches(cfg, shape.global_batch, shape.seq_len,
                                    batch_shards, seq_shards=seq_shards)
        return (make_train_step(cfg, opt, n_micro=n_micro), (0, 1), n_micro)
    if shape.kind == "prefill":
        return make_prefill_step(cfg), (), 1
    if shape.kind == "decode":
        return make_serve_step(cfg), (3,), 1
    raise ValueError(shape.kind)
