"""Pallas TPU kernel: blocked cosine top-k gallery matching.

The Database cartridge's hot path: score Q protected query templates
against an N-row protected gallery and keep the top-k matches per query.

TPU adaptation (vs. the GPU "matmul then sort" idiom): the gallery streams
through VMEM in (BN, D) tiles feeding the MXU per (BQ, BN) score block; a
running (BQ, k) top-k accumulator lives in VMEM scratch across the
sequential gallery-block grid dimension, merged with each new score block
by k unrolled max/argmax passes (k is small and static — no sort, and the
(Q, N) score matrix never round-trips HBM).

Grid: (Q/BQ, N/BN); the gallery dimension iterates fastest (sequential on
TPU), the accumulator resets at j == 0 and flushes at j == last.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3.0e38


def _match_kernel(q_ref, g_ref, scores_ref, idx_ref, acc_s, acc_i, *,
                  k: int, bn: int, n_gallery: int):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_s[...] = jnp.full(acc_s.shape, NEG, acc_s.dtype)
        acc_i[...] = jnp.zeros(acc_i.shape, acc_i.dtype)

    q = q_ref[...]                                   # (BQ, D)
    g = g_ref[...]                                   # (BN, D)
    s = jax.lax.dot_general(
        q, g, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (BQ, BN)
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < n_gallery, s, NEG)           # mask tail padding

    # merge carry and block: k unrolled max/argmax passes
    cs = jnp.concatenate([acc_s[...], s], axis=1)    # (BQ, k+BN)
    ci = jnp.concatenate([acc_i[...], col], axis=1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, cs.shape, 1)
    for slot in range(k):
        a = jnp.argmax(cs, axis=1)                   # (BQ,)
        m = jnp.max(cs, axis=1)
        acc_s[:, slot] = m
        acc_i[:, slot] = jnp.take_along_axis(ci, a[:, None], axis=1)[:, 0]
        cs = jnp.where(lanes == a[:, None], NEG, cs)

    @pl.when(j == nj - 1)
    def _flush():
        scores_ref[...] = acc_s[...]
        idx_ref[...] = acc_i[...]


def gallery_match_pallas(q: jax.Array, g: jax.Array, *, k: int = 5,
                         bq: int = 128, bn: int = 512,
                         interpret: bool = False):
    """q: (Q, D) normalized queries; g: (N, D) normalized gallery rows.
    Returns (scores (Q, k) f32, idx (Q, k) i32), scores descending."""
    Q, D = q.shape
    N = g.shape[0]
    bq = min(bq, max(Q, 8))
    bn = min(bn, max(N, 8))
    Qp = -(-Q // bq) * bq
    Np = -(-N // bn) * bn
    qp = jnp.pad(q.astype(jnp.float32), ((0, Qp - Q), (0, 0)))
    gp = jnp.pad(g.astype(jnp.float32), ((0, Np - N), (0, 0)))
    kernel = functools.partial(_match_kernel, k=k, bn=bn, n_gallery=N)
    scores, idx = pl.pallas_call(
        kernel,
        grid=(Qp // bq, Np // bn),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, gp)
    return scores[:Q], idx[:Q]
