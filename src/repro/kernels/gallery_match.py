"""Pallas TPU kernel family: blocked cosine top-k gallery matching.

The Database cartridge's hot path: score Q protected query templates
against an N-row protected gallery and keep the top-k matches per query.

TPU adaptation (vs. the GPU "matmul then sort" idiom): the gallery streams
through VMEM in (BN, D) tiles feeding the MXU per (BQ, BN) score block; a
running (BQ, k) top-k accumulator lives in VMEM scratch across the
sequential gallery-block grid dimension, merged with each new score block
by k unrolled max/argmax passes (k is small and static — no sort, and the
(Q, N) score matrix never round-trips HBM).

Dtype family (identification fast path):

  * fp32  — the parity oracle path (``kernels/ref.py``).
  * bf16  — gallery tiles stored/streamed as bf16, cast to f32 at the MXU
            boundary (fp32 accumulation); halves VMEM + bus traffic.
  * int8  — symmetric per-row quantized gallery (``quantize_gallery``)
            plus an f32 scale column; tiles stream at 1/4 the f32 bytes
            and scores accumulate in fp32, dequantized per gallery row.

Block schedule: the gallery grid dimension is sequential ("arbitrary"
semantics) so Pallas double-buffers the (BN, D) tile fetch against the
MXU pass.  Default BN is storage-dtype-aware (``_DEF_BN``): one tile is
kept ~2-4 MiB at D=512 so two in-flight tiles plus the query tile fit
VMEM — the narrower the storage dtype, the larger the tile and the fewer
grid steps for the same gallery.

``fuse_norm=True`` L2-normalizes the query tile in-kernel (queries never
round-trip through a separate normalization op); the gallery is expected
pre-normalized at enrollment time by the caller.

Edge cases: ``k > N`` is clamped to the gallery size — the trailing
``k - N`` output columns are sentinel-filled (score ``NEG``, index
``-1``); ``Q < 8`` and ``N`` not a multiple of ``BN`` are handled by
zero-padding with tail-column masking.

Grid: (Q/BQ, N/BN); the gallery dimension iterates fastest (sequential on
TPU), the accumulator resets at j == 0 and flushes at j == last.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3.0e38

# Storage-dtype-aware default gallery tile height: sized so one (BN, 512)
# tile stays ~2-4 MiB and double-buffers comfortably within a 16 MiB VMEM
# budget alongside the query tile and the (BQ, k) accumulator.
_DEF_BN = {"float32": 2048, "bfloat16": 4096, "int8": 8192}


def _default_bn(g_dtype) -> int:
    return _DEF_BN.get(jnp.dtype(g_dtype).name, 512)


def _match_kernel(*refs, k: int, bn: int, n_gallery: int,
                  fuse_norm: bool, quantized: bool):
    if quantized:
        q_ref, g_ref, gs_ref, scores_ref, idx_ref, acc_s, acc_i = refs
    else:
        q_ref, g_ref, scores_ref, idx_ref, acc_s, acc_i = refs
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_s[...] = jnp.full(acc_s.shape, NEG, acc_s.dtype)
        acc_i[...] = jnp.zeros(acc_i.shape, acc_i.dtype)

    # tiles stream in storage dtype; the MXU boundary casts to f32 so the
    # MAC (and the top-k carry) always accumulates in fp32
    q = q_ref[...].astype(jnp.float32)               # (BQ, D)
    if fuse_norm:
        q = q * jax.lax.rsqrt(
            jnp.maximum(jnp.sum(q * q, axis=-1, keepdims=True), 1e-18))
    g = g_ref[...].astype(jnp.float32)               # (BN, D)
    s = jax.lax.dot_general(
        q, g, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (BQ, BN)
    if quantized:
        # symmetric per-row dequantization of the gallery contribution
        s = s * gs_ref[...][:, 0][None, :]
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < n_gallery, s, NEG)           # mask tail padding

    # merge carry and block: k unrolled max/argmax passes
    cs = jnp.concatenate([acc_s[...], s], axis=1)    # (BQ, k+BN)
    ci = jnp.concatenate([acc_i[...], col], axis=1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, cs.shape, 1)
    for slot in range(k):
        a = jnp.argmax(cs, axis=1)                   # (BQ,)
        m = jnp.max(cs, axis=1)
        acc_s[:, slot] = m
        acc_i[:, slot] = jnp.take_along_axis(ci, a[:, None], axis=1)[:, 0]
        cs = jnp.where(lanes == a[:, None], NEG, cs)

    @pl.when(j == nj - 1)
    def _flush():
        scores_ref[...] = acc_s[...]
        idx_ref[...] = acc_i[...]


def _launch(q, g, g_scale, *, k: int, bq: int, bn, fuse_norm: bool,
            interpret: bool):
    Q, D = q.shape
    N = g.shape[0]
    if N == 0:
        raise ValueError("gallery_match: empty gallery")
    k_eff = max(1, min(k, N))                        # clamp k > N
    bq = min(bq, max(Q, 8))
    bn = bn if bn is not None else _default_bn(g.dtype)
    bn = min(bn, max(N, 8))
    Qp = -(-Q // bq) * bq
    Np = -(-N // bn) * bn
    qp = jnp.pad(q, ((0, Qp - Q), (0, 0)))           # storage dtype kept
    gp = jnp.pad(g, ((0, Np - N), (0, 0)))
    quantized = g_scale is not None
    inputs = [qp, gp]
    in_specs = [
        pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
        pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
    ]
    if quantized:
        gsp = jnp.pad(g_scale.astype(jnp.float32).reshape(-1, 1),
                      ((0, Np - N), (0, 0)))
        inputs.append(gsp)
        in_specs.append(pl.BlockSpec((bn, 1), lambda i, j: (j, 0)))
    kernel = functools.partial(_match_kernel, k=k_eff, bn=bn, n_gallery=N,
                               fuse_norm=fuse_norm, quantized=quantized)
    scores, idx = pl.pallas_call(
        kernel,
        grid=(Qp // bq, Np // bn),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k_eff), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k_eff), jnp.float32),
            pltpu.VMEM((bq, k_eff), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*inputs)
    scores, idx = scores[:Q], idx[:Q]
    if k_eff < k:                                    # k > N sentinels
        scores = jnp.pad(scores, ((0, 0), (0, k - k_eff)),
                         constant_values=NEG)
        idx = jnp.pad(idx, ((0, 0), (0, k - k_eff)), constant_values=-1)
    return scores, idx


def gallery_match_pallas(q: jax.Array, g: jax.Array, *, k: int = 5,
                         bq: int = 128, bn=None, fuse_norm: bool = False,
                         interpret: bool = False):
    """q: (Q, D) queries; g: (N, D) gallery rows (both normalized unless
    ``fuse_norm`` handles the queries in-kernel).  Storage dtype of ``g``
    (f32 or bf16) picks the tile schedule; accumulation is always fp32.
    Returns (scores (Q, k) f32, idx (Q, k) i32), scores descending; when
    ``k > N`` the trailing columns hold sentinel score/index (NEG, -1)."""
    if g.dtype == jnp.bfloat16:
        q = q.astype(jnp.bfloat16)
    else:
        q = q.astype(jnp.float32)
        g = g.astype(jnp.float32)
    return _launch(q, g, None, k=k, bq=bq, bn=bn, fuse_norm=fuse_norm,
                   interpret=interpret)


def gallery_match_quant_pallas(q: jax.Array, g_q: jax.Array,
                               g_scale: jax.Array, *, k: int = 5,
                               bq: int = 128, bn=None,
                               fuse_norm: bool = False,
                               interpret: bool = False):
    """int8 fast path: ``g_q`` (N, D) int8 symmetric per-row quantized
    gallery with f32 ``g_scale`` (N,); queries stay f32 (only the large
    operand is quantized).  Scores are fp32-accumulated then dequantized
    per gallery row, so ordering matches the dequantized-f32 oracle."""
    assert g_q.dtype == jnp.int8, g_q.dtype
    return _launch(q.astype(jnp.float32), g_q, g_scale, k=k, bq=bq, bn=bn,
                   fuse_norm=fuse_norm, interpret=interpret)


def quantize_gallery(g: jax.Array):
    """Symmetric per-row int8 quantization: returns (values (N, D) int8,
    scale (N,) f32) with ``values * scale[:, None] ~= g``."""
    g = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_gallery(g_q: jax.Array, g_scale: jax.Array) -> jax.Array:
    """Inverse of ``quantize_gallery`` (the int8 parity oracle input)."""
    return g_q.astype(jnp.float32) * g_scale[:, None].astype(jnp.float32)
