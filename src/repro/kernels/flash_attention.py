"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Prefill hot path. Grid (B*H, Sq/BQ, Sk/BK) with the key dimension
innermost (sequential on TPU): running max / denominator / output
accumulators live in VMEM scratch across key blocks. GQA reads the
kv-head via the BlockSpec index map (h // G) — kv heads are never
materialized per-q-head in HBM. Causal and sliding-window masks skip
fully-masked key blocks entirely (``pl.when`` around the block body), so
compiled FLOPs follow the actual mask occupancy.

VMEM tiling: q/k/v tiles are (BQ|BK, D) with D the full head dim —
hardware-aligned for the MXU when D in {64, 128, 192, 256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  scale, causal, window, bq, bk, sq, sk):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_s[...] = jnp.full(m_s.shape, NEG, m_s.dtype)
        l_s[...] = jnp.zeros(l_s.shape, l_s.dtype)
        acc_s[...] = jnp.zeros(acc_s.shape, acc_s.dtype)

    # causal / window block skipping (compile-time grid, runtime predicate)
    q_lo = qi * bq
    k_lo = kj * bk
    needed = jnp.bool_(True)
    if causal:
        needed &= k_lo <= q_lo + bq - 1
    if window:
        needed &= k_lo + bk - 1 >= q_lo - window + 1

    @pl.when(needed)
    def _block():
        q = q_ref[0, 0]                              # (BQ, D)
        k = k_ref[0, 0]                              # (BK, D)
        v = v_ref[0, 0]                              # (BK, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < sk
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(kj == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_s[...] /
                       jnp.maximum(l_s[...], 1e-20)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, scale=None,
                           bq=256, bk=256, interpret=False):
    """q: (B, H, Sq, D); k/v: (B, Kh, Sk, D[v]). Returns (B, H, Sq, Dv)."""
    B, H, Sq, D = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    Sqp = -(-Sq // bq) * bq
    Skp = -(-Sk // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, sq=Sq, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sqp // bq, Skp // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda bh, i, j: (bh // H, bh % H, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda bh, i, j, G=G, H=H:
                         (bh // H, (bh % H) // G, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv),
                         lambda bh, i, j, G=G, H=H:
                         (bh // H, (bh % H) // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv),
                               lambda bh, i, j: (bh // H, bh % H, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]
