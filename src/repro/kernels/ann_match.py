"""Pallas TPU kernel family: two-level ANN gallery matching (IVF-style).

The planet-scale identification path: exact brute-force scan is linear in
N, so a 10^7-10^8 identity watchlist blows the latency budget no matter
how many replica cartridges shard it.  This module splits the match into
two levels so only a small, query-dependent fraction of the gallery is
ever scored:

  level 1 — **coarse centroid scan**: queries vs the K-row centroid
      codebook (trained by ``kmeans_lite``), keep the top-c cells per
      query.  This is a dense cosine top-k at codebook scale, so it
      reuses the blocked ``gallery_match`` launcher — same storage-dtype
      family (fp32 / bf16 / int8 per-row quantized, fp32 accumulation),
      same fused query normalization.

  level 2 — **exact rescore inside the probed cells**: the gallery is
      stored cell-major, each cell padded to a fixed ``L`` rows, as a
      (K*L, D) array in the storage dtype.  A scalar-prefetch kernel
      (``PrefetchScalarGridSpec``) walks grid (Q, c): the prefetched
      (Q, c) probe table drives the BlockSpec index map, so each grid
      step DMA's exactly one (L, D) cell tile — the cells a query did
      not probe never leave HBM.  Scores accumulate in fp32; pad rows
      (row >= cell_len) and invalid probes (cell id -1) are masked to
      the ``NEG`` sentinel; a running (1, k) top-k accumulator merges
      across the sequential probe dimension exactly like the dense
      kernel merges across gallery blocks.

The rescore kernel returns *padded positions* (cell * L + row) — the
caller owns the padded-position -> gallery-row mapping (``CellLayout``
keeps it), which is how the sharded ``SecureGallery`` translates to
global identity ids.

Exactness contract: within the probed cells the rescore is the same
fp32-accumulated cosine as the dense kernel, so recall loss comes only
from probe selection (tracked in ``BENCH_gallery.json``: recall@1 >=
0.98 vs the fp32 oracle at <= 1/10 of the gallery rows scored).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gallery_match import (NEG, dequantize_gallery,
                                         gallery_match_pallas,
                                         gallery_match_quant_pallas,
                                         quantize_gallery)

__all__ = ["NEG", "CellLayout", "kmeans_lite", "assign_cells",
           "build_cell_layout", "centroid_topc_pallas",
           "cell_rescore_pallas"]


# ---------------------------------------------------------------------------
# level 1 — coarse centroid scan (dense top-c at codebook scale)
# ---------------------------------------------------------------------------
def centroid_topc_pallas(q: jax.Array, centroids: jax.Array,
                         c_scale: Optional[jax.Array] = None, *, c: int,
                         bq: int = 128, bn=None, fuse_norm: bool = True,
                         interpret: bool = False):
    """Top-``c`` probe selection: q (Q, D) vs centroids (K, D) in the
    centroid storage dtype (f32 / bf16, or int8 + per-row ``c_scale``).
    Returns (scores (Q, c) f32, cell ids (Q, c) i32); when ``c > K`` the
    trailing columns hold the (NEG, -1) sentinels — i.e. invalid probes,
    which the rescore kernel masks."""
    if c_scale is not None:
        return gallery_match_quant_pallas(q, centroids, c_scale, k=c, bq=bq,
                                          bn=bn, fuse_norm=fuse_norm,
                                          interpret=interpret)
    return gallery_match_pallas(q, centroids, k=c, bq=bq, bn=bn,
                                fuse_norm=fuse_norm, interpret=interpret)


# ---------------------------------------------------------------------------
# level 2 — exact rescore restricted to the probed cells
# ---------------------------------------------------------------------------
def _rescore_kernel(ids_ref, lens_ref, q_ref, cell_ref, *rest, k: int,
                    L: int, fuse_norm: bool, quantized: bool):
    if quantized:
        scale_ref, scores_ref, pos_ref, acc_s, acc_p = rest
    else:
        scores_ref, pos_ref, acc_s, acc_p = rest
    i = pl.program_id(0)                             # query
    j = pl.program_id(1)                             # probe slot
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_s[...] = jnp.full(acc_s.shape, NEG, acc_s.dtype)
        acc_p[...] = jnp.full(acc_p.shape, -1, acc_p.dtype)

    cid = ids_ref[i, j]                              # probed cell (or -1)
    # clamp for the length lookup; validity is enforced via masking below
    n_valid = jnp.where(cid < 0, 0,
                        lens_ref[jnp.maximum(cid, 0)])

    q = q_ref[...].astype(jnp.float32)               # (1, D)
    if fuse_norm:
        q = q * jax.lax.rsqrt(
            jnp.maximum(jnp.sum(q * q, axis=-1, keepdims=True), 1e-18))
    g = cell_ref[...].astype(jnp.float32)            # (L, D) one cell tile
    s = jax.lax.dot_general(
        q, g, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (1, L)
    if quantized:
        s = s * scale_ref[...][:, 0][None, :]        # per-row dequant
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(row < n_valid, s, NEG)             # pad rows + dead probes
    pos = jnp.where(row < n_valid,
                    jnp.maximum(cid, 0) * L + row, -1)

    # merge carry and cell block: k unrolled max/argmax passes
    cs = jnp.concatenate([acc_s[...], s], axis=1)    # (1, k+L)
    cp = jnp.concatenate([acc_p[...], pos], axis=1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, cs.shape, 1)
    for slot in range(k):
        a = jnp.argmax(cs, axis=1)
        m = jnp.max(cs, axis=1)
        acc_s[:, slot] = m
        # an unfilled slot (every candidate already consumed / masked)
        # carries the -1 sentinel, not a stale position
        acc_p[:, slot] = jnp.where(
            m <= NEG / 2, -1,
            jnp.take_along_axis(cp, a[:, None], axis=1)[:, 0])
        cs = jnp.where(lanes == a[:, None], NEG, cs)

    @pl.when(j == nj - 1)
    def _flush():
        scores_ref[...] = acc_s[...]
        pos_ref[...] = acc_p[...]


def cell_rescore_pallas(q: jax.Array, cells: jax.Array,
                        cell_ids: jax.Array, cell_lens: jax.Array,
                        cell_scale: Optional[jax.Array] = None, *,
                        k: int = 5, L: int, fuse_norm: bool = True,
                        interpret: bool = False):
    """Exact rescore of q (Q, D) against its probed cells only.

    ``cells``: (K*L, D) padded cell-major gallery in the storage dtype
    (f32 / bf16, or int8 with f32 ``cell_scale`` (K*L,)); ``cell_ids``:
    (Q, c) i32 probe table from the coarse scan (-1 = no probe);
    ``cell_lens``: (K,) i32 valid rows per cell.  Returns (scores (Q, k)
    f32, padded positions (Q, k) i32) with (NEG, -1) sentinels for
    unfilled slots; positions are ``cell * L + row`` in the padded
    layout.  Grid (Q, c) with the probe dimension sequential: the
    scalar-prefetched probe table drives the cell-tile index map, so an
    unprobed cell is never fetched.
    """
    Q, D = q.shape
    _, c = cell_ids.shape
    K = cell_lens.shape[0]
    assert cells.shape[0] == K * L, (cells.shape, K, L)
    quantized = cell_scale is not None
    if quantized:
        assert cells.dtype == jnp.int8, cells.dtype
        qp = q.astype(jnp.float32)
    elif cells.dtype == jnp.bfloat16:
        qp = q.astype(jnp.bfloat16)
    else:
        qp = q.astype(jnp.float32)

    ids = cell_ids.astype(jnp.int32)
    lens = cell_lens.astype(jnp.int32)

    # index maps see the prefetched scalars after the grid indices; an
    # invalid probe (-1) clamps to tile 0 and is masked inside the kernel
    def _cell_map(i, j, ids_ref, lens_ref):
        return (jnp.maximum(ids_ref[i, j], 0), 0)

    in_specs = [
        pl.BlockSpec((1, D), lambda i, j, ids_ref, lens_ref: (i, 0)),
        pl.BlockSpec((L, D), _cell_map),
    ]
    inputs = [qp, cells]
    if quantized:
        in_specs.append(pl.BlockSpec((L, 1), _cell_map))
        inputs.append(cell_scale.astype(jnp.float32).reshape(-1, 1))
    kernel = functools.partial(_rescore_kernel, k=k, L=L,
                               fuse_norm=fuse_norm, quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q, c),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j, ids_ref, lens_ref: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j, ids_ref, lens_ref: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
    )
    scores, pos = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ids, lens, *inputs)
    return scores, pos


# ---------------------------------------------------------------------------
# codebook training + cell layout (host side, enrollment time)
# ---------------------------------------------------------------------------
def kmeans_lite(x: np.ndarray, n_cells: int, *, iters: int = 6,
                seed: int = 0) -> np.ndarray:
    """Spherical k-means-lite: train an (n_cells, D) L2-normalized
    centroid codebook over L2-normalized rows ``x``.  Deterministic
    (seeded row-sample init); an emptied cell keeps its previous
    centroid so the codebook never collapses.  Host-side numpy — this
    runs once per codebook at enrollment time, not in the match path."""
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    n_cells = max(1, min(n_cells, n))
    rng = np.random.default_rng(seed)
    cent = x[rng.choice(n, n_cells, replace=False)].copy()
    for _ in range(iters):
        assign = np.argmax(x @ cent.T, axis=1)
        for cell in range(n_cells):
            rows = x[assign == cell]
            if len(rows):
                m = rows.sum(axis=0)
                norm = np.linalg.norm(m)
                if norm > 1e-9:
                    cent[cell] = m / norm
    return cent


def assign_cells(x: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """Nearest-centroid (cosine) cell id per row — the incremental-enroll
    path: new rows join existing cells, the codebook is never retrained."""
    xn = np.asarray(x, np.float32)
    xn = xn / np.maximum(np.linalg.norm(xn, axis=-1, keepdims=True), 1e-9)
    return np.argmax(xn @ np.asarray(centroids, np.float32).T,
                     axis=1).astype(np.int32)


@dataclass
class CellLayout:
    """Padded cell-major physical layout of one gallery shard.

    ``perm``: (N,) shard-row id at each occupied padded slot, cell-major;
    ``pos_to_row``: (K*L,) shard-row id per padded position (-1 = pad);
    ``cell_lens``: (K,) occupancy; ``L``: pad width (max cell size,
    rounded up to a multiple of 8 so cell tiles stay sublane-aligned).
    """
    perm: np.ndarray
    pos_to_row: np.ndarray
    cell_lens: np.ndarray
    L: int

    @property
    def n_cells(self) -> int:
        return len(self.cell_lens)


def build_cell_layout(assign: np.ndarray, n_cells: int) -> CellLayout:
    """Group shard rows by cell id into the padded cell-major layout the
    rescore kernel streams.  O(N log N) host-side repack; stable within a
    cell (rows keep enrollment order, so in-cell score ties break toward
    the earliest-enrolled row, same as the dense kernel)."""
    assign = np.asarray(assign, np.int64)
    cell_lens = np.bincount(assign, minlength=n_cells).astype(np.int32)
    L = max(8, int(-(-max(1, cell_lens.max(initial=1)) // 8) * 8))
    perm = np.argsort(assign, kind="stable").astype(np.int64)
    pos_to_row = np.full(n_cells * L, -1, np.int64)
    starts = np.concatenate([[0], np.cumsum(cell_lens)[:-1]])
    for cell in range(n_cells):
        rows = perm[starts[cell]:starts[cell] + cell_lens[cell]]
        pos_to_row[cell * L:cell * L + len(rows)] = rows
    return CellLayout(perm=perm, pos_to_row=pos_to_row,
                      cell_lens=cell_lens, L=L)


def pack_cells(gn: np.ndarray, layout: CellLayout) -> np.ndarray:
    """Scatter normalized shard rows (N, D) into the (K*L, D) padded
    cell-major array (pad rows zero — masked in-kernel via cell_lens)."""
    out = np.zeros((layout.n_cells * layout.L, gn.shape[1]), np.float32)
    occ = layout.pos_to_row >= 0
    out[occ] = np.asarray(gn, np.float32)[layout.pos_to_row[occ]]
    return out


def pack_cells_quant(gn: np.ndarray, layout: CellLayout):
    """int8 packed cells: symmetric per-row quantization of the packed
    array (pad rows quantize to zeros with the minimum scale, and are
    masked by the kernel anyway)."""
    packed = pack_cells(gn, layout)
    q8, scale = quantize_gallery(jnp.asarray(packed))
    return np.asarray(q8), np.asarray(scale)
