"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` —
the kernel body runs in Python per grid step, which validates BlockSpec
indexing and accumulator logic against the pure-jnp oracles in ref.py.
On TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gallery_match import gallery_match_pallas
from repro.kernels.mamba2_ssd import mamba2_ssd_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("k",))
def gallery_match(q, g, *, k: int = 5):
    """Cosine top-k of queries (Q,D) against gallery (N,D): normalizes,
    then runs the blocked Pallas matcher."""
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    gn = g / jnp.maximum(jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-9)
    return gallery_match_pallas(qn.astype(jnp.float32),
                                gn.astype(jnp.float32), k=k,
                                interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,S,D); k/v: (B,Kh,S,Dv)."""
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=_on_cpu())


@jax.jit
def mamba2_ssd(x, dt, A, B, C):
    """Chunk-parallel SSD scan; see mamba2_ssd.py."""
    return mamba2_ssd_pallas(x, dt, A, B, C, interpret=_on_cpu())
