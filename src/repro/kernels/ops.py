"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` —
the kernel body runs in Python per grid step, which validates BlockSpec
indexing and accumulator logic against the pure-jnp oracles in ref.py.
On TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ann_match import (cell_rescore_pallas,
                                     centroid_topc_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gallery_match import (gallery_match_pallas,
                                         gallery_match_quant_pallas,
                                         quantize_gallery)
from repro.kernels.mamba2_ssd import mamba2_ssd_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("k",))
def gallery_match(q, g, *, k: int = 5):
    """Cosine top-k of queries (Q,D) against gallery (N,D): normalizes,
    then runs the blocked Pallas matcher.  This is the fp32 parity-oracle
    path and keeps the original (pre-fast-path) bn=512 block schedule."""
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    gn = g / jnp.maximum(jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-9)
    return gallery_match_pallas(qn.astype(jnp.float32),
                                gn.astype(jnp.float32), k=k, bn=512,
                                interpret=_on_cpu())


# -- identification fast path -------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "bq", "bn"))
def gallery_match_fused(q, gn, *, k: int = 5, bq: int = 256, bn=None):
    """Fast path vs a *pre-normalized* gallery (f32 or bf16 storage):
    query L2 normalization is fused into the kernel, so raw queries go
    straight in without a separate normalization op."""
    return gallery_match_pallas(q, gn, k=k, bq=bq, bn=bn, fuse_norm=True,
                                interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn"))
def gallery_match_quant(q, g_q, g_scale, *, k: int = 5, bq: int = 256,
                        bn=None):
    """int8 fast path vs a pre-normalized, per-row-quantized gallery
    (``quantize_gallery``); fused query normalization, fp32 accumulation."""
    return gallery_match_quant_pallas(q, g_q, g_scale, k=k, bq=bq, bn=bn,
                                      fuse_norm=True, interpret=_on_cpu())


@jax.jit
def prepare_gallery_quant(gn):
    """Enrollment-time int8 preparation of a normalized gallery."""
    return quantize_gallery(gn)


# -- two-level ANN fast path --------------------------------------------------
@functools.partial(jax.jit, static_argnames=("c", "bq", "bn"))
def centroid_topc(q, centroids, *, c: int, bq: int = 256, bn=None):
    """Coarse probe selection: raw queries vs the (K, D) codebook (f32 or
    bf16 storage), fused query normalization; returns top-``c`` cell ids."""
    return centroid_topc_pallas(q, centroids, c=c, bq=bq, bn=bn,
                                fuse_norm=True, interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("c", "bq", "bn"))
def centroid_topc_quant(q, c_q, c_scale, *, c: int, bq: int = 256, bn=None):
    """int8-codebook coarse scan (per-row quantized centroids)."""
    return centroid_topc_pallas(q, c_q, c_scale, c=c, bq=bq, bn=bn,
                                fuse_norm=True, interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("k", "L"))
def cell_rescore(q, cells, cell_ids, cell_lens, *, k: int, L: int):
    """Exact rescore of each query against its probed cells only (f32 or
    bf16 packed cell-major storage); returns padded positions."""
    return cell_rescore_pallas(q, cells, cell_ids, cell_lens, k=k, L=L,
                               fuse_norm=True, interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("k", "L"))
def cell_rescore_quant(q, cells_q, cell_scale, cell_ids, cell_lens, *,
                       k: int, L: int):
    """int8 packed-cell rescore (per-row quantized, fp32 accumulation)."""
    return cell_rescore_pallas(q, cells_q, cell_ids, cell_lens, cell_scale,
                               k=k, L=L, fuse_norm=True,
                               interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,S,D); k/v: (B,Kh,S,Dv)."""
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=_on_cpu())


@jax.jit
def mamba2_ssd(x, dt, A, B, C):
    """Chunk-parallel SSD scan; see mamba2_ssd.py."""
    return mamba2_ssd_pallas(x, dt, A, B, C, interpret=_on_cpu())
