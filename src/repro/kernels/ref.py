"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gallery_match_ref(q, g, *, k: int = 5):
    """q: (Q, D), g: (N, D) — cosine top-k by full matmul + top_k.

    Mirrors the Pallas kernel's ``k > N`` contract: k is clamped to the
    gallery size and the trailing columns hold sentinels (-3e38, -1).
    """
    s = q.astype(jnp.float32) @ g.astype(jnp.float32).T
    k_eff = max(1, min(k, g.shape[0]))
    scores, idx = jax.lax.top_k(s, k_eff)
    if k_eff < k:
        scores = jnp.pad(scores, ((0, 0), (0, k - k_eff)),
                         constant_values=-3.0e38)
        idx = jnp.pad(idx, ((0, 0), (0, k - k_eff)), constant_values=-1)
    return scores, idx.astype(jnp.int32)


def gallery_match_quant_ref(q, g_q, g_scale, *, k: int = 5):
    """int8-path oracle: match against the dequantized gallery in f32."""
    g = g_q.astype(jnp.float32) * g_scale[:, None].astype(jnp.float32)
    return gallery_match_ref(q, g, k=k)


def centroid_topc_ref(q, centroids, *, c: int):
    """Coarse-scan oracle: top-``c`` cells by cosine (same contract as
    ``gallery_match_ref`` — ``c > K`` pads with (-3e38, -1) sentinels)."""
    return gallery_match_ref(q, centroids, k=c)


def cell_rescore_ref(q, cells, cell_ids, cell_lens, *, k: int, L: int):
    """Rescore oracle in the padded cell-major layout: score q (Q, D)
    against the (K*L, D) packed array, mask pad rows (row >= cell_len)
    and every position outside each query's probed cells, then top-k.
    Returns (scores (Q, k) f32, padded positions (Q, k) i32) with
    (-3e38, -1) sentinels for unfilled slots."""
    q = q.astype(jnp.float32)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    s = qn @ cells.astype(jnp.float32).T                  # (Q, K*L)
    K = cell_lens.shape[0]
    pos_cell = jnp.arange(K * L, dtype=jnp.int32) // L
    pos_row = jnp.arange(K * L, dtype=jnp.int32) % L
    occupied = pos_row < cell_lens[pos_cell]              # (K*L,)
    probed = jnp.any(cell_ids[:, :, None] == pos_cell[None, None, :],
                     axis=1)                              # (Q, K*L)
    live = probed & occupied[None, :]
    s = jnp.where(live, s, -3.0e38)
    scores, pos = jax.lax.top_k(s, k)
    dead = scores <= -3.0e38 / 2
    return (jnp.where(dead, -3.0e38, scores),
            jnp.where(dead, -1, pos).astype(jnp.int32))


def ann_match_ref(q, gn, centroids, assign, *, nprobe: int, k: int):
    """End-to-end two-level oracle against the *flat* shard gallery:
    probe the top-``nprobe`` cells per query, then exact top-k restricted
    to gallery rows assigned to a probed cell.  Returns (scores, row ids)
    with (-3e38, -1) sentinels when fewer than k rows were probed."""
    q = q.astype(jnp.float32)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    _, cells = centroid_topc_ref(qn, centroids, c=nprobe)
    probed = jnp.any(assign[None, None, :] == cells[:, :, None],
                     axis=1)                              # (Q, N)
    s = qn @ gn.astype(jnp.float32).T
    s = jnp.where(probed, s, -3.0e38)
    scores, idx = jax.lax.top_k(s, min(k, gn.shape[0]))
    if scores.shape[1] < k:
        pad = k - scores.shape[1]
        scores = jnp.pad(scores, ((0, 0), (0, pad)),
                         constant_values=-3.0e38)
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=-1)
    dead = scores <= -3.0e38 / 2
    return (jnp.where(dead, -3.0e38, scores),
            jnp.where(dead, -1, idx).astype(jnp.int32))


def flash_attention_ref(q, k, v, *, causal=True, scale=None, window=0):
    """q: (B,H,Sq,D), k/v: (B,Kh,Sk,D[v]). Plain softmax attention, f32."""
    B, H, Sq, D = q.shape
    Kh = k.shape[1]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Kh, G, Sq, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    Sk = k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)   # right-aligned
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -2e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, v.shape[-1])


def mamba2_ssd_ref(x, dt, A, B, C, D=None, *, init_state=None):
    """Sequential SSD recurrence (Mamba-2), the exactness oracle.

    x: (Bt, L, H, P)  dt: (Bt, L, H)  A: (H,)  B,C: (Bt, L, N)
    state: (Bt, H, P, N); y[t] = C[t] . state[t]  (+ D*x skip).
    """
    Bt, L, H, P = x.shape
    N = B.shape[-1]
    st = init_state if init_state is not None else jnp.zeros(
        (Bt, H, P, N), jnp.float32)

    def step(st, args):
        xt, dtt, Bt_, Ct = args  # (Bt,H,P), (Bt,H), (Bt,N), (Bt,N)
        dA = jnp.exp(dtt * A[None, :])                      # (Bt,H)
        dBx = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt_)
        st = st * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", st, Ct)
        return st, y

    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          B.swapaxes(0, 1).astype(jnp.float32),
          C.swapaxes(0, 1).astype(jnp.float32))
    st, ys = jax.lax.scan(step, st, xs)
    y = ys.swapaxes(0, 1)                                   # (Bt,L,H,P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y, st
