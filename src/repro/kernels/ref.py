"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gallery_match_ref(q, g, *, k: int = 5):
    """q: (Q, D), g: (N, D) — cosine top-k by full matmul + top_k.

    Mirrors the Pallas kernel's ``k > N`` contract: k is clamped to the
    gallery size and the trailing columns hold sentinels (-3e38, -1).
    """
    s = q.astype(jnp.float32) @ g.astype(jnp.float32).T
    k_eff = max(1, min(k, g.shape[0]))
    scores, idx = jax.lax.top_k(s, k_eff)
    if k_eff < k:
        scores = jnp.pad(scores, ((0, 0), (0, k - k_eff)),
                         constant_values=-3.0e38)
        idx = jnp.pad(idx, ((0, 0), (0, k - k_eff)), constant_values=-1)
    return scores, idx.astype(jnp.int32)


def gallery_match_quant_ref(q, g_q, g_scale, *, k: int = 5):
    """int8-path oracle: match against the dequantized gallery in f32."""
    g = g_q.astype(jnp.float32) * g_scale[:, None].astype(jnp.float32)
    return gallery_match_ref(q, g, k=k)


def flash_attention_ref(q, k, v, *, causal=True, scale=None, window=0):
    """q: (B,H,Sq,D), k/v: (B,Kh,Sk,D[v]). Plain softmax attention, f32."""
    B, H, Sq, D = q.shape
    Kh = k.shape[1]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Kh, G, Sq, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    Sk = k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)   # right-aligned
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -2e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, v.shape[-1])


def mamba2_ssd_ref(x, dt, A, B, C, D=None, *, init_state=None):
    """Sequential SSD recurrence (Mamba-2), the exactness oracle.

    x: (Bt, L, H, P)  dt: (Bt, L, H)  A: (H,)  B,C: (Bt, L, N)
    state: (Bt, H, P, N); y[t] = C[t] . state[t]  (+ D*x skip).
    """
    Bt, L, H, P = x.shape
    N = B.shape[-1]
    st = init_state if init_state is not None else jnp.zeros(
        (Bt, H, P, N), jnp.float32)

    def step(st, args):
        xt, dtt, Bt_, Ct = args  # (Bt,H,P), (Bt,H), (Bt,N), (Bt,N)
        dA = jnp.exp(dtt * A[None, :])                      # (Bt,H)
        dBx = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt_)
        st = st * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", st, Ct)
        return st, y

    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          B.swapaxes(0, 1).astype(jnp.float32),
          C.swapaxes(0, 1).astype(jnp.float32))
    st, ys = jax.lax.scan(step, st, xs)
    y = ys.swapaxes(0, 1)                                   # (Bt,L,H,P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y, st
