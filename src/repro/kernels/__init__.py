from repro.kernels import ann_match, ops, ref  # noqa
