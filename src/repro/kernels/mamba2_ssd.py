"""Pallas TPU kernel: chunked Mamba-2 SSD (state-space dual) scan.

The hybrid-arch (zamba2) hot path. The sequential recurrence
    state_t = exp(dt_t A) state_{t-1} + dt_t x_t B_t^T ;  y_t = C_t state_t
is evaluated chunk-parallel (Dao & Gu SSD): within a chunk of length c the
quadratic form  y_intra = (C B^T o L) (dt * x)  runs on the MXU, and the
running (P, N) state carries across chunks in VMEM scratch — one grid
step per (batch*head, chunk), chunk dimension sequential.

TPU adaptation: the GPU implementation tiles warps over the (c, c)
attention-like matrix; here the natural mapping is one (c, N) x (N, c)
MXU matmul per chunk with f32 accumulation in scratch, P and N padded to
lane multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_s,
                *, chunk: int):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        state_s[...] = jnp.zeros(state_s.shape, state_s.dtype)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (c,)
    A = a_ref[0, 0]                                  # scalar (this head)
    B = b_ref[0].astype(jnp.float32)                 # (c, N)
    C = c_ref[0].astype(jnp.float32)                 # (c, N)

    a = dt * A                                       # (c,) log-decay
    cum = jnp.cumsum(a)                              # (c,)
    seg = cum[:, None] - cum[None, :]                # sum_{u in (s, t]} a_u
    t_ge_s = (jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
              >= jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1))
    L = jnp.where(t_ge_s, jnp.exp(seg), 0.0)         # (c, c) decay mask
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (c, c)
    xdt = x * dt[:, None]                            # (c, P)
    y = jax.lax.dot((G * L).astype(xdt.dtype), xdt,
                    preferred_element_type=jnp.float32)          # (c, P)

    # inter-chunk: contribution of the carried state
    st = state_s[...]                                # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, st, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (c, P)

    # state update: st_new = exp(sum a) st + sum_t exp(sum_{u>t} a) dBx_t
    total = cum[-1]
    w = jnp.exp(total - cum)                         # (c,)
    dBx = jax.lax.dot_general(xdt * w[:, None], B,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_s[...] = jnp.exp(total) * st + dBx

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(j == nj - 1)
    def _flush():
        st_ref[0, 0] = state_s[...].astype(st_ref.dtype)


def mamba2_ssd_pallas(x, dt, A, B, C, *, chunk: int = 256,
                      interpret: bool = False):
    """x: (Bt, L, H, P); dt: (Bt, L, H); A: (H,); B, C: (Bt, L, N).
    Returns (y (Bt, L, H, P) f32-accumulated, state (Bt, H, P, N) f32)."""
    Bt, Lx, H, P = x.shape
    N = B.shape[-1]
    c = min(chunk, Lx)
    assert Lx % c == 0, (Lx, c)
    kernel = functools.partial(_ssd_kernel, chunk=c)
    y, st = pl.pallas_call(
        kernel,
        grid=(Bt * H, Lx // c),
        in_specs=[
            pl.BlockSpec((1, c, 1, P),
                         lambda bh, j, H=H: (bh // H, j, bh % H, 0)),
            pl.BlockSpec((1, c, 1),
                         lambda bh, j, H=H: (bh // H, j, bh % H)),
            pl.BlockSpec((1, 1), lambda bh, j, H=H: (bh % H, 0)),
            pl.BlockSpec((1, c, N), lambda bh, j, H=H: (bh // H, j, 0)),
            pl.BlockSpec((1, c, N), lambda bh, j, H=H: (bh // H, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, 1, P),
                         lambda bh, j, H=H: (bh // H, j, bh % H, 0)),
            pl.BlockSpec((1, 1, P, N),
                         lambda bh, j, H=H: (bh // H, bh % H, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, Lx, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(H, 1), B, C)
    return y, st
