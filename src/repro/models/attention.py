"""Attention variants: GQA (full / sliding-window / cross) and MLA.

Modes:
  fwd(..., cache=None)        train / prefill over a full sequence. When
                              ``want_cache`` the per-layer cache is returned.
  step(..., cache, pos)       single-token decode against a cache.

Long sequences use a kv-chunked online-softmax ("flash") path whose body is
checkpointed, so fwd+bwd memory stays O(S * chunk). Sliding-window layers use
an exact banded (loop-free) path. MLA decode uses the absorbed-matmul trick
(toggled by ``absorb``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.sharding import Spec, shard
from repro.models.layers import apply_rope, rms_norm

NEG = -2.0e38
FLASH_CHUNK = 1024


def _auto_q_chunk(B, H, Sq, kc, budget=64 * 1024 * 1024):
    """Largest power-of-two q chunk whose f32 score tile (B, H, qc, kc)
    stays under ``budget`` bytes per device (mesh-aware)."""
    from repro.sharding import current_mesh_and_rules
    mesh, _ = current_mesh_and_rules()
    devs = mesh.size if mesh is not None else 1
    qc = Sq
    while qc > 1024 and B * H * qc * kc * 4 // devs > budget:
        qc //= 2
    return qc if qc < Sq else 0


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
def gqa_specs(cfg, d=None):
    d = d or cfg.d_model
    dh, H, Kh = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        "wq": Spec((d, H, dh), ("embed", "heads", "head_dim")),
        "wk": Spec((d, Kh, dh), ("embed", "kv_heads", "head_dim")),
        "wv": Spec((d, Kh, dh), ("embed", "kv_heads", "head_dim")),
        "wo": Spec((H, dh, d), ("heads", "head_dim", "embed")),
    }


def mla_specs(cfg):
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        "wdq": Spec((d, qr), ("embed", "q_lora")),
        "q_ln": Spec((qr,), ("q_lora",), "zeros"),
        "wuq": Spec((qr, H, dn + dr), ("q_lora", "heads", "head_dim")),
        "wdkv": Spec((d, kvr + dr), ("embed", "kv_lora")),
        "kv_ln": Spec((kvr,), ("kv_lora",), "zeros"),
        "wuk": Spec((kvr, H, dn), ("kv_lora", "heads", "head_dim")),
        "wuv": Spec((kvr, H, dv), ("kv_lora", "heads", "head_dim")),
        "wo": Spec((H, dv, d), ("heads", "head_dim", "embed")),
    }


def cache_spec_gqa(cfg, B, T, window=0):
    dh, Kh = cfg.dh, cfg.n_kv_heads
    W = min(window, T) if window else T
    ax = ("cache_batch", "cache_seq", "kv_heads", "head_dim")
    sx = ("cache_batch", "cache_seq", "kv_heads")
    if cfg.kv_cache_dtype == "int8":
        # per-(token, head) symmetric int8 rows; scales fold into scores
        # and probs at use, so the dequantized cache never materializes
        return {
            "k": Spec((B, W, Kh, dh), ax, "zeros", jnp.int8),
            "k_s": Spec((B, W, Kh), sx, "zeros", jnp.float32),
            "v": Spec((B, W, Kh, dh), ax, "zeros", jnp.int8),
            "v_s": Spec((B, W, Kh), sx, "zeros", jnp.float32),
            "pos": Spec((B, W), sx[:2], "zeros", jnp.int32),
        }
    return {
        "k": Spec((B, W, Kh, dh), ax, "zeros"),
        "v": Spec((B, W, Kh, dh), ax, "zeros"),
        "pos": Spec((B, W), sx[:2], "zeros", jnp.int32),
    }


def cache_spec_mla(cfg, B, T):
    if cfg.kv_cache_dtype == "int8":
        return {
            "ckv": Spec((B, T, cfg.kv_lora_rank),
                        ("cache_batch", "cache_seq", "kv_lora"), "zeros",
                        jnp.int8),
            "ckv_s": Spec((B, T), ("cache_batch", "cache_seq"), "zeros",
                          jnp.float32),
            "krope": Spec((B, T, cfg.qk_rope_head_dim),
                          ("cache_batch", "cache_seq", "head_dim"), "zeros"),
            "pos": Spec((B, T), ("cache_batch", "cache_seq"), "zeros",
                        jnp.int32),
        }
    return {
        "ckv": Spec((B, T, cfg.kv_lora_rank), ("cache_batch", "cache_seq", "kv_lora"), "zeros"),
        "krope": Spec((B, T, cfg.qk_rope_head_dim), ("cache_batch", "cache_seq", "head_dim"), "zeros"),
        "pos": Spec((B, T), ("cache_batch", "cache_seq"), "zeros", jnp.int32),
    }


def _quant_rows(x):
    """Symmetric int8 over the last axis. x: (..., D) -> (int8, f32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------
def _grouped_scores(q, k, out_dtype=jnp.float32):
    """q: (B,Sq,H,D), k: (B,Sk,Kh,D) -> (B, Kh, G, Sq, Sk) in f32.

    ``out_dtype=bf16`` emits a bf16-result dot (still f32-accumulated on
    the MXU) and upcasts after: decode uses it so the KV cache is consumed
    by a bf16 op — otherwise XLA-CPU's float normalization upcasts the
    *entire carried cache* to f32 across the layer scan (2x HBM).
    """
    B, Sq, H, D = q.shape
    Kh = k.shape[2]
    G = H // Kh
    q = q.reshape(B, Sq, Kh, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=out_dtype)
    return s.astype(jnp.float32)


def _apply_probs(p, v):
    """p: (B,Kh,G,Sq,Sk) f32, v: (B,Sk,Kh,D) -> (B,Sq,H,D)."""
    B, Kh, G, Sq, Sk = p.shape
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, Kh * G, v.shape[-1])


def plain_attention(q, k, v, mask, scale):
    s = _grouped_scores(q, k) * scale
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return _apply_probs(p, v)


def flash_attention_jnp(q, k, v, scale, causal=True, chunk=FLASH_CHUNK,
                        q_offset=0, q_chunk=0):
    """kv- and q-chunked online-softmax attention.
    q: (B,Sq,H,D), k/v: (B,Sk,Kh,D[v]).

    Exact; executes the full Sq x Sk rectangle with masking (the causal
    skip is a recorded perf-iteration). Body is checkpointed -> residency
    O(q_chunk * chunk) per (batch, head) in fwd+bwd. q chunking runs as a
    sequential lax.map so only one q block's score tile is ever live.
    """
    B, Sq, H, D = q.shape
    if q_chunk == 0:
        q_chunk = _auto_q_chunk(B, H, Sq, chunk)
    if 0 < q_chunk < Sq and Sq % q_chunk == 0:
        nq = Sq // q_chunk
        qs = q.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)

        def one(args):
            qi, off = args
            return flash_attention_jnp(qi, k, v, scale, causal=causal,
                                       chunk=chunk,
                                       q_offset=off, q_chunk=-1)

        offs = q_offset + q_chunk * jnp.arange(nq)
        outs = jax.lax.map(one, (qs, offs))
        return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, v.shape[-1])
    Sk0 = k.shape[1]
    Kh = k.shape[2]
    Dv = v.shape[-1]          # may differ from D (MLA: qk 192 vs v 128)
    G = H // Kh
    pad = (-Sk0) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sk = k.shape[1]
    nc = Sk // chunk
    qf = q.reshape(B, Sq, Kh, G, D)
    kc = k.reshape(B, nc, chunk, Kh, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, Kh, Dv).transpose(1, 0, 2, 3, 4)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kj,
                       preferred_element_type=jnp.float32) * scale
        kpos = j * chunk + jnp.arange(chunk)
        if causal:
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG)
        if pad:
            s = jnp.where(kpos[None, :] < Sk0, s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Kh, G, Sq), NEG, jnp.float32)
    l0 = jnp.zeros((B, Kh, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Kh, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0),
        (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def banded_attention(q, k, v, scale, window):
    """Exact sliding-window causal attention, loop-free.

    Chunks of size ``window``; each q-chunk attends [prev chunk | own chunk]
    with the exact (q-k) < window band mask. q,k,v: (B,S,*,D), S % window == 0.
    """
    B, S, H, D = q.shape
    Kh = k.shape[2]
    W = window
    nc = S // W
    qc = q.reshape(B, nc, W, H, D)
    kc = k.reshape(B, nc, W, Kh, D)
    vc = v.reshape(B, nc, W, Kh, D)
    zk = jnp.zeros_like(kc[:, :1])
    kprev = jnp.concatenate([zk, kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kc], axis=2)  # (B, nc, 2W, Kh, D)
    v2 = jnp.concatenate([vprev, vc], axis=2)
    G = H // Kh
    qg = qc.reshape(B, nc, W, Kh, G, D)
    s = jnp.einsum("bnqkgd,bnskd->bnkgqs", qg, k2,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(W)[:, None]
    kpos = jnp.arange(2 * W)[None, :] - W
    band = (qpos >= kpos) & (qpos - kpos < W)  # (W, 2W)
    first = jnp.arange(nc) == 0  # first chunk has no prev
    valid = band[None, :, :] & ((kpos[None] >= 0) | ~first[:, None, None])
    s = jnp.where(valid[None, :, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnkgqs,bnskd->bnqkgd", p.astype(v2.dtype), v2)
    return o.reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------
def _qkv(p, x, cfg, theta, pos):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    if theta:
        q = apply_rope(q, pos, theta)
        k = apply_rope(k, pos, theta)
    return q, k, v


def gqa_fwd(p, x, cfg, *, theta, window=0, causal=True, want_cache=False):
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q, k, v = _qkv(p, x, cfg, theta, pos)
    scale = cfg.dh ** -0.5
    if window and S > window and S % window == 0:
        o = banded_attention(q, k, v, scale, window)
    elif causal and S >= 2048 and S % FLASH_CHUNK == 0:
        o = flash_attention_jnp(q, k, v, scale, causal=True)
    else:
        if causal:
            m = pos[:, None] >= pos[None, :]
            if window:
                m &= pos[:, None] - pos[None, :] < window
            mask = m[None, None, None]
        else:
            mask = jnp.ones((1, 1, 1, S, S), bool)
        o = plain_attention(q, k, v, mask, scale)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    y = shard(y, "batch", "seq", "embed")
    cache = None
    if want_cache:
        if window and window < S:
            # keep the last `window` positions (ring layout, oldest first)
            kk, vv = k[:, S - window:], v[:, S - window:]
            cpos = jnp.broadcast_to(pos[S - window:], (B, window))
            roll = (-S) % window  # align ring slot = position % window
            kk = jnp.roll(kk, roll, axis=1)
            vv = jnp.roll(vv, roll, axis=1)
            cpos = jnp.roll(cpos, roll, axis=1)
        else:
            kk, vv = k, v
            cpos = jnp.broadcast_to(pos, (B, S))
        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quant_rows(kk)
            vq, vs = _quant_rows(vv)
            cache = {"k": kq, "k_s": ks, "v": vq, "v_s": vs,
                     "pos": cpos.astype(jnp.int32)}
        else:
            cache = {"k": kk, "v": vv, "pos": cpos.astype(jnp.int32)}
        # barrier: without it XLA keeps the rope'd keys in f32 (the flash
        # dot's operand precision) and stacks the scan's cache output as a
        # full-depth f32 buffer next to the bf16 one
        cache = jax.lax.optimization_barrier(cache)
    return y, cache


def gqa_step(p, x, cfg, cache, pos, *, theta, window=0):
    """x: (B,1,d). cache k/v: (B,T,Kh,D) (T=window for local layers)."""
    # barrier: stops XLA hoisting a bf16->f32 convert of the *entire
    # stacked* cache out of the decode layer scan (2x cache memory)
    cache = jax.lax.optimization_barrier(cache)
    B = x.shape[0]
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    posv = jnp.full((B, 1), pos, jnp.int32)
    if theta:
        q = apply_rope(q, posv, theta)
        k = apply_rope(k, posv, theta)
    T = cache["k"].shape[1]
    slot = (pos % T) if window else jnp.minimum(pos, T - 1)
    int8_kv = "k_s" in cache
    if int8_kv:
        kq, ks = _quant_rows(k)
        vq, vs = _quant_rows(v)
        ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_s"], ks, (0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        cvs = jax.lax.dynamic_update_slice(cache["v_s"], vs, (0, slot, 0))
        new_cache = {"k": ck, "k_s": cks, "v": cv, "v_s": cvs}
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
    cpos = jax.lax.dynamic_update_slice(cache["pos"], posv, (0, slot))
    new_cache["pos"] = cpos
    valid = (cpos <= pos)
    if window:
        valid &= cpos > pos - window
    if int8_kv:
        # int8 dot; per-row scale folds into scores: (q . k_q) * k_s
        s = _grouped_scores(q, ck.astype(q.dtype), out_dtype=q.dtype)
        s = s * cks.transpose(0, 2, 1)[:, :, None, None, :]
    else:
        s = _grouped_scores(q, ck, out_dtype=ck.dtype)
    s = s * (cfg.dh ** -0.5)
    # flash-decode: keep scores sharded along the cache time axis (decode
    # rules put cache_seq on "model"; long-context rules put it on "data")
    s = shard(s, "cache_batch", None, None, None, "cache_seq")
    s = jnp.where(valid[:, None, None, None, :], s, NEG)
    pr = jax.nn.softmax(s, axis=-1)
    if int8_kv:
        # fold v scales into the probabilities: sum_t (p_t v_s_t) v_q_t
        prv = pr * cvs.transpose(0, 2, 1)[:, :, None, None, :]
        o = _apply_probs(prv, cv.astype(q.dtype))
    else:
        o = _apply_probs(pr, cv)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA layer (DeepSeek V2/V3)
# ---------------------------------------------------------------------------
def _mla_qkv_latent(p, x, cfg, pos):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", h, p["wdq"]), p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])  # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    ckv_full = jnp.einsum("bsd,dr->bsr", h, p["wdkv"])
    ckv, k_rope = ckv_full[..., : cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank:]
    ckv = rms_norm(ckv, p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_fwd(p, x, cfg, *, want_cache=False):
    B, S, _ = x.shape
    pos = jnp.arange(S)
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(p, x, cfg, pos)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, cfg.n_heads, dr))],
        axis=-1)
    scale = (dn + dr) ** -0.5
    if S >= 2048 and S % FLASH_CHUNK == 0:
        o = flash_attention_jnp(q, k, v, scale, causal=True)
    else:
        mask = (pos[:, None] >= pos[None, :])[None, None, None]
        o = plain_attention(q, k, v, mask, scale)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    y = shard(y, "batch", "seq", "embed")
    cache = None
    if want_cache:
        if cfg.kv_cache_dtype == "int8":
            cq, cs = _quant_rows(ckv)
            cache = {"ckv": cq, "ckv_s": cs, "krope": k_rope,
                     "pos": jnp.broadcast_to(pos, (B, S)).astype(jnp.int32)}
        else:
            cache = {"ckv": ckv, "krope": k_rope,
                     "pos": jnp.broadcast_to(pos, (B, S)).astype(jnp.int32)}
        cache = jax.lax.optimization_barrier(cache)  # see gqa_fwd
    return y, cache


def mla_step(p, x, cfg, cache, pos, *, absorb=True):
    cache = jax.lax.optimization_barrier(cache)  # see gqa_step
    B = x.shape[0]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(p, x, cfg, posv)
    T = cache["ckv"].shape[1]
    int8_kv = "ckv_s" in cache
    if int8_kv:
        cq, cs = _quant_rows(ckv)
        cckv = jax.lax.dynamic_update_slice(cache["ckv"], cq, (0, pos, 0))
        ccs = jax.lax.dynamic_update_slice(cache["ckv_s"], cs, (0, pos))
    else:
        cckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, pos, 0))
        ccs = None
    ckr = jax.lax.dynamic_update_slice(cache["krope"], k_rope, (0, pos, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], posv, (0, pos))
    valid = cpos <= pos  # (B,T)
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = (dn + dr) ** -0.5
    if absorb:
        # scores = (q_nope W_uk^T) . ckv + q_rope . k_rope
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])  # (B,1,H,kvr)
        lat = cckv.astype(x.dtype) if int8_kv else cckv
        # bf16-result dots keep the carried latent cache bf16 (see
        # _grouped_scores); scores upcast to f32 for the softmax
        s = jnp.einsum("bshr,btr->bhst", q_lat, lat,
                       preferred_element_type=lat.dtype).astype(jnp.float32)
        if int8_kv:
            s = s * ccs[:, None, None, :]    # fold row scales into scores
        s += jnp.einsum("bshk,btk->bhst", q_rope, ckr,
                        preferred_element_type=ckr.dtype).astype(jnp.float32)
        s = shard(s, "cache_batch", None, None, "cache_seq")  # flash-decode
        s = jnp.where(valid[:, None, None, :], s * scale, NEG)
        pr = jax.nn.softmax(s, axis=-1)
        if int8_kv:
            pr = pr * ccs[:, None, None, :]  # fold scales into the combine
        ctx = jnp.einsum("bhst,btr->bshr", pr.astype(x.dtype),
                         lat if int8_kv else cckv)
        o = jnp.einsum("bshr,rhk->bshk", ctx, p["wuv"])  # (B,1,H,dv)
    else:
        lat = cckv.astype(x.dtype) * ccs[..., None].astype(x.dtype) \
            if int8_kv else cckv
        k_nope = jnp.einsum("btr,rhk->bthk", lat, p["wuk"])
        v = jnp.einsum("btr,rhk->bthk", lat, p["wuv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(ckr[:, :, None, :], k_nope.shape[:3] + (dr,))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        s = jnp.einsum("bshk,bthk->bhst", q, k, preferred_element_type=jnp.float32)
        s = jnp.where(valid[:, None, None, :], s * scale, NEG)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,bthk->bshk", pr.astype(v.dtype), v)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    new_cache = {"ckv": cckv, "krope": ckr, "pos": cpos}
    if int8_kv:
        new_cache["ckv_s"] = ccs
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------
def cross_specs(cfg):
    return gqa_specs(cfg)


def cross_fwd(p, x, memory_kv, cfg):
    """x: (B,S,d); memory_kv: dict k/v (B,Se,Kh,D) precomputed."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    Sq, Sk = x.shape[1], memory_kv["k"].shape[1]
    if Sq * Sk >= 1 << 21:
        # chunked path: unblocked cross scores at 4k x 1.5k x B x H are
        # multi-GiB f32 (the whisper-train memory hog)
        o = flash_attention_jnp(q, memory_kv["k"], memory_kv["v"],
                                cfg.dh ** -0.5, causal=False, chunk=512)
    else:
        mask = jnp.ones((1, 1, 1, Sq, Sk), bool)
        o = plain_attention(q, memory_kv["k"], memory_kv["v"], mask,
                            cfg.dh ** -0.5)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_memory(p, memory, cfg):
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    return {"k": k, "v": v}
