"""Shared primitives: norms, RoPE, gated MLP, embeddings, loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import Spec, shard


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def group_norm_heads(x, w, eps=1e-6):
    """Per-head group norm over the last dim. x: (..., H, D)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(dh: int, theta: float):
    return theta ** (-jnp.arange(0, dh, 2, dtype=jnp.float32) / dh)


def apply_rope(x, pos, theta: float):
    """x: (B, S, H, D); pos: (B, S) or (S,) int positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (D/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if angles.ndim == 2:  # (S, D/2) -> broadcast batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_pos_emb(S: int, d: int, offset=0):
    pos = jnp.arange(offset, offset + S, dtype=jnp.float32)[:, None]
    inv = 1e4 ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------
def mlp_specs(d: int, ff: int):
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        "w_gate": Spec((d, ff), ("embed", "mlp")),
        "w_up": Spec((d, ff), ("embed", "mlp")),
        "w_down": Spec((ff, d), ("mlp", "embed")),
    }


def mlp_fwd(p, x, act="silu", eps=1e-6):
    h = rms_norm(x, p["ln"], eps)
    g = jnp.einsum("bsd,df->bsf", h, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", h, p["w_up"])
    g = shard(act_fn(act)(g) * u, "batch", "seq", "mlp")
    return shard(jnp.einsum("bsf,fd->bsd", g, p["w_down"]), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------
def embed_specs(vocab: int, d: int, tie: bool):
    # the token table's model dim uses its own logical axis ("embed_table",
    # never data-sharded): a token gather from a 2-axis-sharded table makes
    # SPMD replicate the whole table per lookup. vocab-sharding alone keeps
    # the table at V*d/model_parallel bytes with an efficient masked gather.
    s = {"tok": Spec((vocab, d), ("vocab", "embed_table"))}
    if not tie:
        s["head"] = Spec((d, vocab), ("embed", "vocab"))
    return s


def embed(p, tokens, d):
    x = jnp.take(p["tok"], tokens, axis=0) * jnp.sqrt(float(d)).astype(jnp.bfloat16)
    return shard(x, "batch", "seq", "embed")


def unembed(p, x):
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard(logits, "batch", "seq", "vocab")


def softmax_xent(logits, labels, mask=None):
    """Mean next-token CE in f32. logits: (B,S,V); labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def softmax_xent_fused(embed_p, x, labels, mask=None, chunk=512):
    """Fused unembed + CE that never materializes (B, S, V) logits.

    Scans over sequence chunks; per chunk computes logits (B, c, V_shard)
    for logZ (vocab-sharded logsumexp) and the label log-likelihood via a
    gather of label *columns* of the head matrix (an embedding-style
    lookup — no full-vocab tensor is ever indexed). The chunk body is
    checkpointed so backward recomputes chunk logits instead of storing
    them. This is the big-vocab memory lever (129k-vocab models would
    otherwise spend GBs/device on one logits tensor).
    """
    W = embed_p.get("head")
    if W is None:
        W = embed_p["tok"].T                       # (d, V)
    B, S, d = x.shape
    c = min(chunk, S)
    nc = S // c
    rem = S - nc * c

    def chunk_loss(xc, lc, mc):
        logits = jnp.einsum("bsd,dv->bsv", xc, W)
        logits = shard(logits, "batch", "seq", "vocab").astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)          # (B, c)
        # label log-likelihood via one-hot product on the chunk logits —
        # SPMD-friendly on a vocab-sharded tensor (a take/gather on the
        # 2D-sharded head matrix forces full rematerialization instead)
        oh = jax.nn.one_hot(lc, logits.shape[-1], dtype=logits.dtype)
        oh = shard(oh, "batch", "seq", "vocab")
        ll = jnp.sum(logits * oh, axis=-1)
        nll = logz - ll
        m = mc.astype(jnp.float32)
        return jnp.sum(nll * m), jnp.sum(m)

    if mask is None:
        mask = jnp.ones_like(labels)

    tot = jnp.zeros((), jnp.float32)
    cnt = jnp.zeros((), jnp.float32)
    if nc:
        xs = x[:, : nc * c].reshape(B, nc, c, d).swapaxes(0, 1)
        ls = labels[:, : nc * c].reshape(B, nc, c).swapaxes(0, 1)
        ms = mask[:, : nc * c].reshape(B, nc, c).swapaxes(0, 1)

        def body(acc, args):
            t, n = acc
            dt, dn = jax.checkpoint(chunk_loss)(*args)
            return (t + dt, n + dn), None

        (tot, cnt), _ = jax.lax.scan(body, (tot, cnt), (xs, ls, ms))
    if rem:
        dt, dn = chunk_loss(x[:, nc * c:], labels[:, nc * c:],
                            mask[:, nc * c:])
        tot, cnt = tot + dt, cnt + dn
    return tot / jnp.maximum(cnt, 1.0)
