"""Mixture-of-Experts with expert parallelism (DeepSeek V2/V3 style).

Expert weights are sharded over the "model" mesh axis (EP). Inside a
``jax.shard_map`` region each device keeps only its local experts; routing
is computed redundantly (router is tiny), assignments to local experts are
sorted and packed into a static-capacity (E_local, C, d) buffer, run as
batched einsums (the TPU megablox/gmm pattern — compiled FLOPs scale with
*active* experts only), and partial outputs are combined with one psum
over "model" — the same volume as a dense TP FFN all-reduce, replacing the
GPU all-to-all. A separate decode-EP path spreads experts over the
batch-sharded axes for serving (gather tokens -> compute -> psum-scatter).

Capacity-factor semantics: tokens beyond C = load*cf per expert drop
(cf >= n_experts reproduces dropless behaviour exactly, used by tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import (
    Spec,
    current_mesh_and_rules,
    logical_to_pspec,
    shard,
)
from repro.models.layers import act_fn, rms_norm


def moe_specs(cfg):
    d, E, fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s = {
        "ln": Spec((d,), ("embed",), "zeros"),
        "router": Spec((d, E), ("embed", "experts"), "small", jnp.float32),
        "w_gate": Spec((E, d, fe), ("experts", "embed", "expert_mlp")),
        "w_up": Spec((E, d, fe), ("experts", "embed", "expert_mlp")),
        "w_down": Spec((E, fe, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.expert_weights_dtype == "int8":
        # weight-only quantized serving: int8 matrices + per-output-column
        # f32 scales (folded in after the matmul) — halves the dominant
        # HBM stream of MoE decode
        for w in ("w_gate", "w_up", "w_down"):
            s[w] = Spec(s[w].shape, s[w].axes, "normal", jnp.int8)
        s["s_gate"] = Spec((E, fe), ("experts", "expert_mlp"), "ones",
                           jnp.float32)
        s["s_up"] = Spec((E, fe), ("experts", "expert_mlp"), "ones",
                         jnp.float32)
        s["s_down"] = Spec((E, d), ("experts", "embed"), "ones", jnp.float32)
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        s.update(
            sh_gate=Spec((d, fs), ("embed", "mlp")),
            sh_up=Spec((d, fs), ("embed", "mlp")),
            sh_down=Spec((fs, d), ("mlp", "embed")),
        )
    return s


def _route(h2d, router, k):
    """h2d: (T, d). Returns topk weights (T,k) f32, ids (T,k) i32, aux loss."""
    logits = h2d.astype(jnp.float32) @ router  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    # load-balancing aux: E * sum_e f_e * p_e
    E = gates.shape[-1]
    p_e = jnp.mean(gates, axis=0)
    ind = jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32)
    f_e = jnp.mean(ind, axis=0)
    aux = E * jnp.sum(f_e * p_e)
    return topw, topi, aux


def _capacity(T: int, k: int, E_total: int, cf: float) -> int:
    """Static per-expert slot count: expected load x capacity factor,
    rounded up to a multiple of 8 (TPU lane alignment)."""
    c = int(-(-T * k * cf // E_total))
    return max(-(-c // 8) * 8, 8)


def _expert_compute(xf, topw, topi, w_gate, w_up, w_down, e_lo, E_local, act,
                    E_total=None, cf=1.25, scales=None):
    """Run assignments routed to experts [e_lo, e_lo+E_local).

    Capacity-based grouped matmul (the TPU megablox pattern): assignments
    are sorted by local expert, packed into an (E_local, C, d) buffer with
    C static slots per expert, and pushed through batched einsums, so
    compiled FLOPs are proportional to *active* experts. Overflow beyond C
    is dropped (standard capacity-factor semantics; cf >= E gives exact
    dropless behaviour for tests).

    xf: (T, d); topw/topi: (T, k). Returns (T, d) partial output.
    """
    T, k = topi.shape
    d = xf.shape[-1]
    E_total = E_total or E_local
    C = _capacity(T, k, E_total, cf)
    flat_e = topi.reshape(-1)
    flat_w = topw.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), k)
    local = (flat_e >= e_lo) & (flat_e < e_lo + E_local)
    le = jnp.where(local, flat_e - e_lo, E_local)  # overflow bucket = E_local
    order = jnp.argsort(le, stable=True)
    le_s, tok_s, w_s = le[order], tok[order], flat_w[order]
    counts = jnp.bincount(le_s, length=E_local + 1)[:E_local]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    # slot -> source-token index arithmetic: the (T*k, d) assignment
    # expansion never materializes (it is ~T*k x d f32 in fwd+bwd —
    # gigabytes); only the (E_local*C, d) packed buffer touches memory.
    slots = jnp.arange(E_local * C)
    e_arr, p_arr = slots // C, slots % C
    pos = jnp.minimum(starts[e_arr] + p_arr, T * k - 1)
    valid = p_arr < jnp.minimum(counts[e_arr], C)
    src_tok = jnp.where(valid, tok_s[pos], T)          # T = zero pad row
    slot_w = jnp.where(valid, w_s[pos], 0.0)
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])
    pk = xpad[src_tok].reshape(E_local, C, d)
    if scales is not None:
        # weight-only int8: dot in bf16 against the int8 matrix, fold the
        # per-output-column scale into the result (dequantized weights
        # never materialize)
        sg, su, sd = scales
        g = jnp.einsum("ecd,edf->ecf", pk, w_gate.astype(pk.dtype))
        g = g * sg[:, None, :].astype(g.dtype)
        u = jnp.einsum("ecd,edf->ecf", pk, w_up.astype(pk.dtype))
        u = u * su[:, None, :].astype(u.dtype)
        h = act_fn(act)(g) * u
        o = jnp.einsum("ecf,efd->ecd", h, w_down.astype(pk.dtype))
        o = o * sd[:, None, :].astype(o.dtype)
    else:
        g = jnp.einsum("ecd,edf->ecf", pk, w_gate)
        u = jnp.einsum("ecd,edf->ecf", pk, w_up)
        h = act_fn(act)(g) * u
        o = jnp.einsum("ecf,efd->ecd", h, w_down)
    o = o.reshape(E_local * C, d)
    o = o * slot_w[:, None].astype(o.dtype)
    y = jnp.zeros((T + 1, d), o.dtype).at[src_tok].add(o)
    return y[:T].astype(xf.dtype)


def _resolve_axes(rules, mesh, key):
    """Mesh axes a logical axis maps to (only those present in the mesh)."""
    m = rules.get(key) if rules else None
    flat = [a for a in (m if isinstance(m, (tuple, list)) else (m,))
            if a is not None and mesh is not None and a in mesh.axis_names]
    return tuple(flat)


def moe_fwd(p, x, cfg):
    """x: (B,S,d) -> (y, aux_loss)."""
    B, S, d = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    mesh, rules = current_mesh_and_rules()
    E, k = cfg.n_experts, cfg.experts_per_token

    ep_axes = _resolve_axes(rules, mesh, "experts") if mesh is not None else ()
    batch_axes = _resolve_axes(rules, mesh, "batch") if mesh is not None else ()
    ep_size = 1
    for a in ep_axes:
        ep_size *= mesh.shape[a]
    if ep_axes and set(ep_axes) & set(batch_axes) and E % ep_size == 0:
        # ---- decode EP: experts spread over the batch-sharded axes ----
        y, aux = _moe_decode_ep(p, h, cfg, mesh, rules, ep_axes)
    elif (
        mesh is not None
        and "model" in mesh.axis_names
        and E % mesh.shape["model"] == 0
    ):
        # FSDP rules may shard expert weights along expert_mlp over "data";
        # force the gathered layout at use point (per-layer all-gather).
        wg = shard(p["w_gate"], "experts", None, None)
        wu = shard(p["w_up"], "experts", None, None)
        wd = shard(p["w_down"], "experts", None, None)
        x_spec = logical_to_pspec(("batch", "seq", "embed"), rules, mesh, h.shape)
        w_spec = P("model", None, None)

        def local_fn(hl, router, wg, wu, wd):
            Bl, Sl, _ = hl.shape
            hf = hl.reshape(Bl * Sl, d)
            topw, topi, aux = _route(hf, router, k)
            El = wg.shape[0]
            e_lo = jax.lax.axis_index("model") * El
            y = _expert_compute(hf, topw, topi, wg, wu, wd, e_lo, El, cfg.act,
                                E_total=E, cf=cfg.capacity_factor)
            y = jax.lax.psum(y, "model")
            return y.reshape(Bl, Sl, d), aux

        y, aux = jax.shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(x_spec, P(), w_spec, w_spec, w_spec),
            out_specs=(x_spec, P()),
            check_vma=False,
        )(h, p["router"], wg, wu, wd)
    else:
        hf = h.reshape(B * S, d)
        topw, topi, aux = _route(hf, p["router"], k)
        sc = (p["s_gate"], p["s_up"], p["s_down"]) \
            if cfg.expert_weights_dtype == "int8" else None
        y = _expert_compute(
            hf, topw, topi, p["w_gate"], p["w_up"], p["w_down"], 0, E,
            cfg.act, E_total=E, cf=cfg.capacity_factor, scales=sc
        )
        y = y.reshape(B, S, d)

    if cfg.n_shared_experts:
        g = jnp.einsum("bsd,df->bsf", h, p["sh_gate"])
        u = jnp.einsum("bsd,df->bsf", h, p["sh_up"])
        y = y + jnp.einsum("bsf,fd->bsd", act_fn(cfg.act)(g) * u, p["sh_down"])
    return shard(y, "batch", "seq", "embed"), aux


def _moe_decode_ep(p, h, cfg, mesh, rules, ep_axes):
    """EP where experts live on the batch-sharded axes (decode serving).

    Each EP shard all-gathers the (tiny) token batch across EP axes, runs
    its local experts (hidden dim TP-sharded over "model"), then
    psum-scatters outputs back to the owning batch shards — one gather +
    one scatter replaces the GPU all-to-all pair.
    """
    B, S, d = h.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    x_spec = logical_to_pspec(("batch", "seq", "embed"), rules, mesh, h.shape)
    tp = "model" if "model" in mesh.axis_names else None
    w_in_spec = P(ep_axes, None, tp)     # (E, d, f)
    w_out_spec = P(ep_axes, tp, None)    # (E, f, d)
    El = E // _prod(mesh.shape[a] for a in ep_axes)

    int8_w = cfg.expert_weights_dtype == "int8"
    s_in_spec = P(ep_axes, tp) if int8_w else P()
    s_out_spec = P(ep_axes, None) if int8_w else P()

    def local_fn(hl, router, wg, wu, wd, sg, su, sd):
        Bl, Sl, _ = hl.shape
        hg = hl
        for a in reversed(ep_axes):
            hg = jax.lax.all_gather(hg, a, axis=0, tiled=True)
        hf = hg.reshape(-1, d)
        topw, topi, aux = _route(hf, router, k)
        e_lo = _linear_index(ep_axes, mesh) * El
        y = _expert_compute(hf, topw, topi, wg, wu, wd, e_lo, El, cfg.act,
                            E_total=E, cf=cfg.capacity_factor,
                            scales=(sg, su, sd) if int8_w else None)
        if tp is not None and wg.shape[-1] != cfg.moe_d_ff:
            y = jax.lax.psum(y, tp)
        y = y.reshape(hg.shape)
        for a in ep_axes:
            y = jax.lax.psum_scatter(y, a, scatter_dimension=0, tiled=True)
        return y, aux  # identical on every shard (same gathered tokens)

    dummy = jnp.zeros((), jnp.float32)
    y, aux = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(), w_in_spec, w_in_spec, w_out_spec,
                  s_in_spec, s_in_spec, s_out_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(h, p["router"], p["w_gate"], p["w_up"], p["w_down"],
      p.get("s_gate", dummy), p.get("s_up", dummy), p.get("s_down", dummy))
    return y, aux


def quantize_expert_weights(moe_params):
    """Convert one MoE subtree's bf16 expert weights to the int8 layout
    (per-output-column symmetric scales). Inverse of nothing — serving
    conversion; pair with cfg.expert_weights_dtype='int8'."""
    out = dict(moe_params)
    for w, s, axis in (("w_gate", "s_gate", 1), ("w_up", "s_up", 1),
                       ("w_down", "s_down", 1)):
        m = moe_params[w].astype(jnp.float32)     # (E, in, out)
        amax = jnp.max(jnp.abs(m), axis=axis)     # (E, out)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(m / scale[:, None, :]), -127, 127)
        out[w] = q.astype(jnp.int8)
        out[s] = scale
    return out


def _prod(it):
    r = 1
    for v in it:
        r *= v
    return r


def _linear_index(axes, mesh):
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx
