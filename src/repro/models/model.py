"""Unified model: composes attention/MoE/SSM/xLSTM blocks per ArchConfig.

Families and their superblock layouts (scan-over-superblocks everywhere):
  dense   1 x {attn(GQA), mlp}                      tinyllama/codeqwen/starcoder2
  gemma3  6 x {attn} + 6 x {mlp}  (5 local + 1 global per superblock)
  moe     {attn(MLA), moe}; `first_dense_layers` unrolled prefix with dense mlp
  hybrid  6 x {mamba} + one weight-tied shared {attn, mlp} applied per superblock
  ssm     5 x {mlstm} + 1 x {slstm} per superblock
  vlm     dense backbone + patch-embedding projector (frontend stub)
  audio   enc-dec: encoder (bidir attn) + decoder (self + cross)

Entry points (all pure functions of (params, cfg, ...)):
  loss_fn        train loss (CE + MoE aux [+ MTP])
  forward        logits over a full sequence (prefill path, optional caches)
  prefill        run a prompt, return (last-token logits, cache)
  decode_step    one token through the cache -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import Spec, shard, spec_map
from repro.models import layers as L
from repro.models import attention as A
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models import moe as M


# ---------------------------------------------------------------------------
# Spec helpers
# ---------------------------------------------------------------------------
def stack_specs(tree, n: int):
    """Prepend a scanned 'layers' axis of size n to every Spec leaf."""
    return spec_map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype), tree
    )


def _mlp_specs(cfg, d=None):
    d = d or cfg.d_model
    if cfg.mlp_gated:
        return L.mlp_specs(d, cfg.d_ff)
    # non-gated (starcoder2 / whisper style)
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        "w_up": Spec((d, cfg.d_ff), ("embed", "mlp")),
        "w_down": Spec((cfg.d_ff, d), ("mlp", "embed")),
    }


def _mlp_fwd(p, x, cfg):
    if "w_gate" in p:
        return L.mlp_fwd(p, x, cfg.act, cfg.norm_eps)
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    u = L.act_fn(cfg.act)(jnp.einsum("bsd,df->bsf", h, p["w_up"]))
    u = shard(u, "batch", "seq", "mlp")
    return shard(jnp.einsum("bsf,fd->bsd", u, p["w_down"]), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Superblock param/cache specs
# ---------------------------------------------------------------------------
def _superblock_specs(cfg):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"attn": A.gqa_specs(cfg), "mlp": _mlp_specs(cfg)}
    if fam == "gemma3":
        return {
            "attn": stack_specs(A.gqa_specs(cfg), cfg.superblock),
            "mlp": stack_specs(_mlp_specs(cfg), cfg.superblock),
        }
    if fam == "moe":
        return {"attn": A.mla_specs(cfg), "moe": M.moe_specs(cfg)}
    if fam == "hybrid":
        return {"mamba": stack_specs(S.mamba2_specs(cfg), cfg.superblock)}
    if fam == "ssm":
        return {
            "m": stack_specs(X.mlstm_specs(cfg), cfg.superblock - 1),
            "s": X.slstm_specs(cfg),
        }
    if fam == "audio":  # decoder superblock
        return {
            "self": A.gqa_specs(cfg),
            "cross": A.cross_specs(cfg),
            "mlp": _mlp_specs(cfg),
        }
    raise ValueError(fam)


def param_specs(cfg) -> Any:
    d = cfg.d_model
    p = {"embed": L.embed_specs(cfg.vocab_size, d, cfg.tie_embeddings),
         "final_ln": Spec((d,), ("embed",), "zeros")}
    nsb = cfg.n_superblocks
    p["blocks"] = stack_specs(_superblock_specs(cfg), nsb)
    if cfg.family == "moe" and cfg.first_dense_layers:
        p["prefix"] = {
            f"l{i}": {"attn": A.mla_specs(cfg), "mlp": _mlp_specs(cfg)}
            for i in range(cfg.first_dense_layers)
        }
    if cfg.family == "hybrid":
        p["shared"] = {"attn": A.gqa_specs(cfg), "mlp": _mlp_specs(cfg)}
    if cfg.family == "vlm":
        dv = cfg.vit_dim
        p["projector"] = {
            "ln": Spec((dv,), ("embed",), "zeros"),
            "w1": Spec((dv, d), ("embed", "embed2")),
            "w2": Spec((d, d), ("embed", "embed2")),
        }
    if cfg.family == "audio":
        enc = {"attn": A.gqa_specs(cfg), "mlp": _mlp_specs(cfg)}
        p["encoder"] = stack_specs(enc, cfg.encoder_layers)
        p["enc_ln"] = Spec((d,), ("embed",), "zeros")
    if cfg.mtp:
        p["mtp"] = {
            "proj": Spec((2 * d, d), ("embed", "embed2")),
            "attn": A.mla_specs(cfg),
            "mlp": _mlp_specs(cfg),
            "ln": Spec((d,), ("embed",), "zeros"),
        }
    return p


def cache_specs(cfg, B: int, T: int) -> Any:
    fam = cfg.family
    nsb = cfg.n_superblocks
    if fam in ("dense", "vlm"):
        c = stack_specs({"attn": A.cache_spec_gqa(cfg, B, T)}, nsb)
    elif fam == "gemma3":
        c = stack_specs({
            "local": stack_specs(
                A.cache_spec_gqa(cfg, B, T, window=cfg.sliding_window),
                cfg.superblock - 1),
            "global": A.cache_spec_gqa(cfg, B, T),
        }, nsb)
    elif fam == "moe":
        c = {"scan": stack_specs({"attn": A.cache_spec_mla(cfg, B, T)}, nsb)}
        if cfg.first_dense_layers:
            c["prefix"] = {
                f"l{i}": A.cache_spec_mla(cfg, B, T)
                for i in range(cfg.first_dense_layers)
            }
    elif fam == "hybrid":
        c = stack_specs({
            "mamba": stack_specs(S.mamba2_cache_spec(cfg, B), cfg.superblock),
            "shared": A.cache_spec_gqa(cfg, B, T),
        }, nsb)
    elif fam == "ssm":
        c = stack_specs({
            "m": stack_specs(X.mlstm_cache_spec(cfg, B), cfg.superblock - 1),
            "s": X.slstm_cache_spec(cfg, B),
        }, nsb)
    elif fam == "audio":
        c = {
            "dec": stack_specs({"self": A.cache_spec_gqa(cfg, B, T)}, nsb),
            "cross": stack_specs(
                {"k": Spec((B, cfg.encoder_len, cfg.n_kv_heads, cfg.dh),
                           ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                           "zeros"),
                 "v": Spec((B, cfg.encoder_len, cfg.n_kv_heads, cfg.dh),
                           ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                           "zeros")}, nsb),
        }
    else:
        raise ValueError(fam)
    return c


# ---------------------------------------------------------------------------
# Superblock forward bodies
# ---------------------------------------------------------------------------
def _sb_fwd(cfg, x, bp, shared, want_cache):
    """One superblock over a full sequence. Returns (x, cache, aux)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    if fam in ("dense", "vlm"):
        y, c = A.gqa_fwd(bp["attn"], x, cfg, theta=cfg.rope_theta,
                         window=cfg.sliding_window, want_cache=want_cache)
        x = x + y
        x = x + _mlp_fwd(bp["mlp"], x, cfg)
        return x, ({"attn": c} if want_cache else None), aux
    if fam == "gemma3":
        locals_, glob = [], None
        for i in range(cfg.superblock):
            ap = jax.tree.map(lambda t: t[i], bp["attn"])
            mp = jax.tree.map(lambda t: t[i], bp["mlp"])
            is_global = i == cfg.superblock - 1
            theta = cfg.rope_theta_global if is_global else cfg.rope_theta
            win = 0 if is_global else cfg.sliding_window
            y, c = A.gqa_fwd(ap, x, cfg, theta=theta, window=win,
                             want_cache=want_cache)
            x = x + y
            x = x + _mlp_fwd(mp, x, cfg)
            if want_cache:
                (locals_.append(c) if not is_global else None)
                glob = c if is_global else glob
        cache = None
        if want_cache:
            cache = {"local": jax.tree.map(lambda *t: jnp.stack(t), *locals_),
                     "global": glob}
        return x, cache, aux
    if fam == "moe":
        y, c = A.mla_fwd(bp["attn"], x, cfg, want_cache=want_cache)
        x = x + y
        y, aux = M.moe_fwd(bp["moe"], x, cfg)
        x = x + y
        return x, ({"attn": c} if want_cache else None), aux
    if fam == "hybrid":
        mcs = []
        for i in range(cfg.superblock):
            mp = jax.tree.map(lambda t: t[i], bp["mamba"])
            y, c = S.mamba2_fwd(mp, x, cfg, want_cache=want_cache)
            x = x + y
            if want_cache:
                mcs.append(c)
        y, c = A.gqa_fwd(shared["attn"], x, cfg, theta=cfg.rope_theta,
                         want_cache=want_cache)
        x = x + y
        x = x + _mlp_fwd(shared["mlp"], x, cfg)
        cache = None
        if want_cache:
            cache = {"mamba": jax.tree.map(lambda *t: jnp.stack(t), *mcs),
                     "shared": c}
        return x, cache, aux
    if fam == "ssm":
        mcs = []
        for i in range(cfg.superblock - 1):
            mp = jax.tree.map(lambda t: t[i], bp["m"])
            y, c = X.mlstm_fwd(mp, x, cfg, want_cache=want_cache)
            x = x + y
            if want_cache:
                mcs.append(c)
        y, c = X.slstm_fwd(bp["s"], x, cfg, want_cache=want_cache)
        x = x + y
        cache = None
        if want_cache:
            cache = {"m": jax.tree.map(lambda *t: jnp.stack(t), *mcs), "s": c}
        return x, cache, aux
    if fam == "audio":
        memory_kv = shared  # dict k/v per superblock (already sliced)
        y, c = A.gqa_fwd(bp["self"], x, cfg, theta=0.0, want_cache=want_cache)
        x = x + y
        x = x + A.cross_fwd(bp["cross"], x, memory_kv, cfg)
        x = x + _mlp_fwd(bp["mlp"], x, cfg)
        return x, ({"self": c} if want_cache else None), aux
    raise ValueError(fam)


def _sb_step(cfg, x, bp, shared, cache, pos):
    """One superblock for one decode token. Returns (x, new_cache)."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        y, c = A.gqa_step(bp["attn"], x, cfg, cache["attn"], pos,
                          theta=cfg.rope_theta, window=cfg.sliding_window)
        x = x + y
        x = x + _mlp_fwd(bp["mlp"], x, cfg)
        return x, {"attn": c}
    if fam == "gemma3":
        lc, gc = [], None
        for i in range(cfg.superblock):
            ap = jax.tree.map(lambda t: t[i], bp["attn"])
            mp = jax.tree.map(lambda t: t[i], bp["mlp"])
            is_global = i == cfg.superblock - 1
            theta = cfg.rope_theta_global if is_global else cfg.rope_theta
            win = 0 if is_global else cfg.sliding_window
            ci = cache["global"] if is_global else jax.tree.map(
                lambda t: t[i], cache["local"])
            y, c = A.gqa_step(ap, x, cfg, ci, pos, theta=theta, window=win)
            x = x + y
            x = x + _mlp_fwd(mp, x, cfg)
            (lc.append(c) if not is_global else None)
            gc = c if is_global else gc
        return x, {"local": jax.tree.map(lambda *t: jnp.stack(t), *lc),
                   "global": gc}
    if fam == "moe":
        y, c = A.mla_step(bp["attn"], x, cfg, cache["attn"], pos)
        x = x + y
        y, _ = M.moe_fwd(bp["moe"], x, cfg)
        x = x + y
        return x, {"attn": c}
    if fam == "hybrid":
        mcs = []
        for i in range(cfg.superblock):
            mp = jax.tree.map(lambda t: t[i], bp["mamba"])
            ci = jax.tree.map(lambda t: t[i], cache["mamba"])
            y, c = S.mamba2_step(mp, x, cfg, ci)
            x = x + y
            mcs.append(c)
        y, c = A.gqa_step(shared["attn"], x, cfg, cache["shared"], pos,
                          theta=cfg.rope_theta)
        x = x + y
        x = x + _mlp_fwd(shared["mlp"], x, cfg)
        return x, {"mamba": jax.tree.map(lambda *t: jnp.stack(t), *mcs),
                   "shared": c}
    if fam == "ssm":
        mcs = []
        for i in range(cfg.superblock - 1):
            mp = jax.tree.map(lambda t: t[i], bp["m"])
            ci = jax.tree.map(lambda t: t[i], cache["m"])
            y, c = X.mlstm_step(mp, x, cfg, ci)
            x = x + y
            mcs.append(c)
        y, c = X.slstm_step(bp["s"], x, cfg, cache["s"])
        x = x + y
        return x, {"m": jax.tree.map(lambda *t: jnp.stack(t), *mcs), "s": c}
    if fam == "audio":
        y, c = A.gqa_step(bp["self"], x, cfg, cache["self"], pos, theta=0.0)
        x = x + y
        x = x + A.cross_fwd(bp["cross"], x, shared, cfg)
        x = x + _mlp_fwd(bp["mlp"], x, cfg)
        return x, {"self": c}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Whole-model forward
# ---------------------------------------------------------------------------
def _remat(fn, cfg):
    if not cfg.remat:
        return fn
    policy = getattr(jax.checkpoint_policies, cfg.remat_policy, None)
    return jax.checkpoint(fn, policy=policy)


def _inject_inputs(params, cfg, batch):
    """Token embedding + modality stubs. Returns x (B,S,d) and pos offset."""
    x = L.embed(params["embed"], batch["tokens"], cfg.d_model)
    if cfg.family == "vlm" and "patches" in batch:
        pp = params["projector"]
        h = L.rms_norm(batch["patches"], pp["ln"], cfg.norm_eps)
        h = jax.nn.gelu(jnp.einsum("bpd,de->bpe", h, pp["w1"]))
        h = jnp.einsum("bpd,de->bpe", h, pp["w2"]).astype(x.dtype)
        n = h.shape[1]
        x = jnp.concatenate([h, x[:, n:]], axis=1)  # patches replace prefix
    if cfg.family == "audio":
        x = x + L.sinusoid_pos_emb(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    return x


def forward(params, cfg, batch, *, want_cache=False, return_hidden=False):
    """Full-sequence forward. Returns (logits | hidden, aux, cache|None).

    ``return_hidden`` skips the unembed projection — the train loss fuses
    unembed+CE chunkwise (softmax_xent_fused) so (B,S,V) logits never
    materialize.
    """
    x = _inject_inputs(params, cfg, batch)
    cross_kv = None
    if cfg.family == "audio":
        frames = batch["frames"]
        h = frames + L.sinusoid_pos_emb(frames.shape[1], cfg.d_model).astype(
            frames.dtype)[None]

        def ebody(h, ep):
            y, _ = A.gqa_fwd(ep["attn"], h, cfg, theta=0.0, causal=False)
            h = h + y
            h = h + _mlp_fwd(ep["mlp"], h, cfg)
            return h, None

        h, _ = jax.lax.scan(_remat(ebody, cfg), h, params["encoder"])
        memory = L.rms_norm(h, params["enc_ln"], cfg.norm_eps)

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.family == "moe" and cfg.first_dense_layers:
        for i in range(cfg.first_dense_layers):
            bp = params["prefix"][f"l{i}"]
            y, _ = A.mla_fwd(bp["attn"], x, cfg, want_cache=False)
            x = x + y
            x = x + _mlp_fwd(bp["mlp"], x, cfg)

    shared = params.get("shared")

    def body(carry, bp):
        x, aux = carry
        sh = shared
        if cfg.family == "audio":
            sh = A.cross_memory(bp["cross"], memory, cfg)
        x, cache, a = _sb_fwd(cfg, x, bp, sh, want_cache)
        # sequence-parallel boundary: under "fsdp_sp" rules the carry (the
        # dominant activation buffer) is seq-sharded over "model"
        x = shard(x, "batch", "act_seq", "embed")
        return (x, aux + a), cache

    (x, aux_total), caches = jax.lax.scan(
        _remat(body, cfg), (x, aux_total), params["blocks"])
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = x if return_hidden else L.unembed(params["embed"], x)
    cache = None
    if want_cache:
        if cfg.family == "moe":
            cache = {"scan": caches}
            if cfg.first_dense_layers:
                # prefix caches: recompute cheaply (prefix is tiny)
                pc = {}
                xi = _inject_inputs(params, cfg, batch)
                for i in range(cfg.first_dense_layers):
                    bp = params["prefix"][f"l{i}"]
                    y, c = A.mla_fwd(bp["attn"], xi, cfg, want_cache=True)
                    xi = xi + y
                    xi = xi + _mlp_fwd(bp["mlp"], xi, cfg)
                    pc[f"l{i}"] = c
                cache["prefix"] = pc
        elif cfg.family == "audio":
            def mk_kv(_, dp):
                return None, A.cross_memory(dp["cross"], memory, cfg)
            _, cross = jax.lax.scan(mk_kv, None, params["blocks"])
            cache = {"dec": caches, "cross": cross}
        else:
            cache = caches
    return logits, aux_total, cache


def loss_fn(params, cfg, batch):
    x, aux, _ = forward(params, cfg, batch, return_hidden=True)
    mask = batch.get("mask")
    ce = L.softmax_xent_fused(params["embed"], x[:, :-1],
                              batch["labels"][:, 1:],
                              None if mask is None else mask[:, 1:])
    loss = ce + cfg.router_aux_coef * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp:
        mtp_loss = _mtp_loss(params, cfg, batch)
        loss = loss + 0.3 * mtp_loss
        metrics["mtp"] = mtp_loss
    return loss, metrics


def _mtp_loss(params, cfg, batch):
    """DeepSeek-V3 multi-token prediction: depth-1 extra head."""
    mp = params["mtp"]
    x = L.embed(params["embed"], batch["tokens"], cfg.d_model)
    # combine hidden (approximated by embedding of t_{s+1}) with stream
    h = jnp.concatenate([x[:, :-1], x[:, 1:]], axis=-1)
    h = jnp.einsum("bsd,de->bse", h, mp["proj"])
    y, _ = A.mla_fwd(mp["attn"], h, cfg)
    h = h + y
    h = h + _mlp_fwd(mp["mlp"], h, cfg)
    h = L.rms_norm(h, mp["ln"], cfg.norm_eps)
    return L.softmax_xent_fused(params["embed"], h[:, :-1],
                                batch["labels"][:, 2:])


def prefill(params, cfg, batch):
    # unembed ONLY the last position: full-sequence logits at 32k x 92k
    # vocab would be tens of GiB of f32 that serving never reads
    x, _, cache = forward(params, cfg, batch, want_cache=True,
                          return_hidden=True)
    logits = L.unembed(params["embed"], x[:, -1:])
    return logits[:, -1], cache


def decode_step(params, cfg, token, pos, cache):
    """token: (B,1) int32; pos: scalar int32. Returns (logits (B,V), cache)."""
    x = L.embed(params["embed"], token, cfg.d_model)
    if cfg.family == "audio":
        # learned-free sinusoid at position `pos`
        d = cfg.d_model
        inv = 1e4 ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
        ang = pos.astype(jnp.float32) * inv
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
        x = x + pe.astype(x.dtype)[None, None]

    if cfg.family == "moe" and cfg.first_dense_layers:
        new_prefix = {}
        for i in range(cfg.first_dense_layers):
            bp = params["prefix"][f"l{i}"]
            y, c = A.mla_step(bp["attn"], x, cfg, cache["prefix"][f"l{i}"], pos)
            x = x + y
            x = x + _mlp_fwd(bp["mlp"], x, cfg)
            new_prefix[f"l{i}"] = c

    shared = params.get("shared")
    scan_cache = cache
    if cfg.family == "moe":
        scan_cache = cache["scan"]
    elif cfg.family == "audio":
        scan_cache = cache["dec"]

    if cfg.family == "audio":
        def abody(x, bp_ci_cr):
            bp, ci, cr = bp_ci_cr
            x, cnew = _sb_step(cfg, x, bp, cr, ci, pos)
            return x, cnew
        x, new_scan = jax.lax.scan(abody, x,
                                   (params["blocks"], scan_cache, cache["cross"]))
    else:
        def body(x, bp_ci):
            bp, ci = bp_ci
            x, cnew = _sb_step(cfg, x, bp, shared, ci, pos)
            return x, cnew
        x, new_scan = jax.lax.scan(body, x, (params["blocks"], scan_cache))

    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    if cfg.family == "moe":
        new_cache = {"scan": new_scan}
        if cfg.first_dense_layers:
            new_cache["prefix"] = new_prefix
    elif cfg.family == "audio":
        new_cache = {"dec": new_scan, "cross": cache["cross"]}
    else:
        new_cache = new_scan
    return logits, new_cache


def serve_step(params, cfg, token, pos, cache):
    """Greedy decode of one token — the unit lowered for decode_* shapes."""
    logits, cache = decode_step(params, cfg, token, pos, cache)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return nxt, cache


class Model:
    """Thin OO wrapper used by cartridges/runtime."""

    def __init__(self, cfg):
        self.cfg = cfg

    def param_specs(self):
        return param_specs(self.cfg)

    def cache_specs(self, B, T):
        return cache_specs(self.cfg, B, T)

    def init(self, key):
        from repro.sharding import init_params
        return init_params(self.param_specs(), key, jnp.bfloat16)

    loss_fn = staticmethod(loss_fn)
    forward = staticmethod(forward)
    prefill = staticmethod(prefill)
    decode_step = staticmethod(decode_step)
    serve_step = staticmethod(serve_step)
