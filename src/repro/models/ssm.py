"""Mamba2 (SSD) blocks — zamba2's backbone.

Training/prefill uses the chunkwise SSD algorithm (quadratic within a chunk,
linear state recurrence across chunks); decode is the O(1)-state recurrent
step. State layout: h (B, H, P, N) with P=headdim, N=ssm_state.

The cross-chunk state recurrence is the compute hot-spot the `ssd_scan`
Pallas kernel targets; this module calls the jnp reference path (identical
math) so the model is kernel-independent on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import Spec, shard
from repro.models.layers import rms_norm, act_fn

CHUNK = 256


def mamba2_specs(cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_headdim
    N, K = cfg.ssm_state, cfg.ssm_conv
    conv_ch = di + 2 * N  # x, B, C go through the causal conv
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        # in_proj -> [z (di), xBC (conv_ch), dt (H)]
        "w_in": Spec((d, 2 * di + 2 * N + H), ("embed", "inner")),
        "conv_w": Spec((K, conv_ch), ("conv", "inner"), "small"),
        "conv_b": Spec((conv_ch,), ("inner",), "zeros"),
        "A_log": Spec((H,), ("ssm_heads",), "ones", jnp.float32),
        "D": Spec((H,), ("ssm_heads",), "ones", jnp.float32),
        "dt_bias": Spec((H,), ("ssm_heads",), "zeros", jnp.float32),
        "out_ln": Spec((di,), ("inner",), "zeros"),
        "w_out": Spec((di, d), ("inner", "embed")),
    }


def mamba2_cache_spec(cfg, B):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_headdim
    N, K = cfg.ssm_state, cfg.ssm_conv
    conv_ch = di + 2 * N
    return {
        "conv": Spec((B, K - 1, conv_ch), ("cache_batch", "conv", "inner"), "zeros"),
        "h": Spec((B, H, cfg.ssm_headdim, N),
                  ("cache_batch", "ssm_heads", "head_dim", "state"), "zeros",
                  jnp.float32),
    }


def _split_in(p, x, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_headdim
    N = cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,de->bse", rms_norm(x, p["ln"], cfg.norm_eps), p["w_in"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv. xbc: (B,S,C); w: (K,C). Returns (B,S,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for k in range(K):  # K=4: unrolled taps beat a conv op for this shape
        out = out + pad[:, k: k + xbc.shape[1]] * w[k]
    return jax.nn.silu(out + b)


def ssd_chunked(xh, dt, A, Bm, Cm, h0=None, chunk=CHUNK):
    """Chunkwise SSD. xh:(B,S,H,P) dt:(B,S,H) A:(H,) Bm/Cm:(B,S,N).

    Returns (y (B,S,H,P) same dtype as xh, h_final (B,H,P,N) f32).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    dtf = dt.astype(jnp.float32)
    a = dtf * A  # (B,S,H) log-decay (A negative)
    xc = (xh.astype(jnp.float32) * dtf[..., None]).reshape(Bsz, nc, chunk, H, P)
    ac = a.reshape(Bsz, nc, chunk, H)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, N)

    cum = jnp.cumsum(ac, axis=2)  # (B,nc,L,H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Lq,Lk,H)
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (quadratic in chunk)
    sc = jnp.einsum("bnqc,bnkc->bnqk", Cc, Bc)
    y_in = jnp.einsum("bnqk,bnqkh,bnkhp->bnqhp", sc, decay, xc)

    # per-chunk input->state and chunk decays
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,L,H)
    chunk_states = jnp.einsum("bnkc,bnkh,bnkhp->bnhpc", Bc, decay_to_end, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    # inter-chunk state recurrence (the ssd_scan kernel target)
    def step(h, xs):
        st, dc = xs
        h_new = h * dc[:, :, None, None] + st
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    hN, h_prevs = jax.lax.scan(
        step, h0,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N) state entering chunk

    # state -> output within each chunk
    y_st = jnp.einsum("bnqc,bnqh,bnhpc->bnqhp", Cc, jnp.exp(cum), h_prevs)
    y = (y_in + y_st).reshape(Bsz, S, H, P).astype(xh.dtype)
    return y, hN


def mamba2_fwd(p, x, cfg, *, want_cache=False):
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_headdim
    P, N = cfg.ssm_headdim, cfg.ssm_state
    z, xbc, dt = _split_in(p, x, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = xbc[..., :di], xbc[..., di: di + N], xbc[..., di + N:]
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, P)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)
    chunk = min(CHUNK, S)
    y, hN = ssd_chunked(xh, dtf, A, Bm, Cm, chunk=chunk)
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["out_ln"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    out = shard(out, "batch", "seq", "embed")
    cache = None
    if want_cache:
        K = cfg.ssm_conv
        conv_tail_in = jnp.einsum(
            "bsd,de->bse", rms_norm(x[:, S - (K - 1):], p["ln"], cfg.norm_eps),
            p["w_in"])[..., di: di + di + 2 * N]
        cache = {"conv": conv_tail_in, "h": hN}
    return out, cache


def mamba2_step(p, x, cfg, cache):
    """x: (B,1,d). cache: {conv (B,K-1,C), h (B,H,P,N)}."""
    B = x.shape[0]
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_headdim
    P, N, K = cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv
    z, xbc_new, dt = _split_in(p, x, cfg)
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B,K,C)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])[:, None]
    xs, Bm, Cm = conv_out[..., :di], conv_out[..., di: di + N], conv_out[..., di + N:]
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dtf * A)  # (B,H)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)  # (B,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    h = cache["h"] * a[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xh, Bv, dtf)
    y = jnp.einsum("bhpn,bn->bhp", h, Cv) + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_ln"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return out, {"conv": window[:, 1:], "h": h}
