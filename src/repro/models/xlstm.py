"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, block-diagonal recurrence) — xlstm-1.3b's backbone.

mLSTM trains with a stabilized chunkwise linear-attention form (exponential
input gate, sigmoid-in-log-space forget gate, running max stabilizer m).
Decode is the O(1) recurrent update on C (B,H,K,V) / n (B,H,K) / m (B,H).

sLSTM is inherently sequential: a lax.scan over time with per-head
block-diagonal recurrent weights, exponential gating and the same m
stabilizer. Cache is (c, n, m, h_prev).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import Spec, shard
from repro.models.layers import rms_norm, group_norm_heads, act_fn

CHUNK = 256
PROJ = 2  # mLSTM up-projection factor


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_specs(cfg):
    d = cfg.d_model
    di = PROJ * d
    H = cfg.n_heads
    dh = di // H
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        "w_up": Spec((d, 2 * di), ("embed", "inner")),  # -> [x_path, z_gate]
        "wq": Spec((di, H, dh), ("inner", "heads", "head_dim")),
        "wk": Spec((di, H, dh), ("inner", "heads", "head_dim")),
        "wv": Spec((di, H, dh), ("inner", "heads", "head_dim")),
        "w_if": Spec((di, 2 * H), ("inner", "heads"), "small"),  # i,f pre-acts
        "b_if": Spec((2 * H,), ("heads",), "zeros", jnp.float32),
        "out_gn": Spec((H, dh), ("heads", "head_dim"), "ones"),
        "w_down": Spec((di, d), ("inner", "embed")),
    }


def mlstm_cache_spec(cfg, B):
    di = PROJ * cfg.d_model
    H = cfg.n_heads
    dh = di // H
    return {
        "C": Spec((B, H, dh, dh), ("cache_batch", "ssm_heads", "head_dim", "state"),
                  "zeros", jnp.float32),
        "n": Spec((B, H, dh), ("cache_batch", "ssm_heads", "head_dim"), "zeros",
                  jnp.float32),
        "m": Spec((B, H), ("cache_batch", "ssm_heads"), "zeros", jnp.float32),
    }


def _mlstm_qkvif(p, x, cfg):
    B, S, d = x.shape
    di = PROJ * d
    H = cfg.n_heads
    dh = di // H
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h, p["w_up"])
    xp, z = up[..., :di], up[..., di:]
    q = jnp.einsum("bsi,ihk->bshk", xp, p["wq"]) * (dh ** -0.5)
    k = jnp.einsum("bsi,ihk->bshk", xp, p["wk"])
    v = jnp.einsum("bsi,ihk->bshk", xp, p["wv"])
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "heads", None)
    v = shard(v, "batch", "seq", "heads", None)
    gates = jnp.einsum("bsi,ig->bsg", xp, p["w_if"]).astype(jnp.float32) + p["b_if"]
    ig, fg = gates[..., :H], gates[..., H:]  # (B,S,H) log-space pre-acts
    logf = -jax.nn.softplus(-fg)  # log sigmoid(f)
    return xp, z, q, k, v, ig, logf


def mlstm_chunked(q, k, v, ig, logf, state=None, chunk=CHUNK):
    """Stabilized chunkwise mLSTM. q/k/v: (B,S,H,D); ig/logf: (B,S,H) f32.

    Returns (y (B,S,H,D), (C,n,m) final state). Matches the recurrent form:
      m_t = max(logf_t + m_{t-1}, ig_t)
      C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(ig_t - m_t) k_t v_t^T
      n_t likewise; y_t = C_t^T q_t / max(|n_t.q_t|, 1)
    """
    B, S, H, D = q.shape
    nc = S // chunk
    assert S % chunk == 0
    qc = q.astype(jnp.float32).reshape(B, nc, chunk, H, D)
    kc = k.astype(jnp.float32).reshape(B, nc, chunk, H, D)
    vc = v.astype(jnp.float32).reshape(B, nc, chunk, H, D)
    igc = ig.reshape(B, nc, chunk, H)
    lfc = logf.reshape(B, nc, chunk, H)
    cumf = jnp.cumsum(lfc, axis=2)  # (B,nc,L,H) sum of logf up to & incl t

    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]

    def body(carry, xs):
        C, n, m = carry
        qj, kj, vj, igj, cfj, lfj = xs  # (B,L,H,*) / (B,L,H)
        # per-step stabilizer: m_t = cf_t + max(m_in, max_{s<=t}(ig_s - cf_s))
        m_t = cfj + jnp.maximum(
            m[:, None],
            jax.lax.cummax(igj - cfj, axis=1))  # (B,L,H)
        # intra-chunk weights: exp(cf_t - cf_s + ig_s - m_t), causal
        logw = (cfj[:, :, None] - cfj[:, None, :] + igj[:, None, :]
                - m_t[:, :, None])  # (B,Lq,Ls,H)
        w = jnp.where(causal[None, :, :, None], jnp.exp(logw), 0.0)
        s = jnp.einsum("bqhd,bshd->bqsh", qj, kj)
        y_in = jnp.einsum("bqsh,bqsh,bshd->bqhd", s, w, vj)
        # carry contribution: exp(cf_t + m_in - m_t) * (q_t . C_in)
        wc = jnp.exp(cfj + m[:, None] - m_t)  # (B,L,H)
        y_c = jnp.einsum("bqhd,bhdk->bqhk", qj, C) * wc[..., None]
        # normalizer n_t = sum_s w k_s + wc * n_in ; denom = max(|n.q|, e^-m)
        n_t = jnp.einsum("bqsh,bshd->bqhd", w, kj) + n[:, None] * wc[..., None]
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bqhd,bqhd->bqh", n_t, qj)), jnp.exp(-m_t))
        y = (y_in + y_c) / denom[..., None]
        # chunk-end state: m_end = cf_L + max(m_in, max_s(ig_s - cf_s))
        m_end = cfj[:, -1] + jnp.maximum(m, jnp.max(igj - cfj, axis=1))
        wk_end = jnp.exp(cfj[:, -1][:, None] - cfj + igj - m_end[:, None])
        fw = jnp.exp(cfj[:, -1] + m - m_end)
        C_new = (C * fw[..., None, None]
                 + jnp.einsum("blh,blhd,blhk->bhdk", wk_end, kj, vj))
        n_new = n * fw[..., None] + jnp.einsum("blh,blhd->bhd", wk_end, kj)
        return (C_new, n_new, m_end), y

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), igc.transpose(1, 0, 2, 3),
          cumf.transpose(1, 0, 2, 3), lfc.transpose(1, 0, 2, 3))
    (C, n, m), ys = jax.lax.scan(body, (C0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    return y.astype(q.dtype), (C, n, m)


def mlstm_fwd(p, x, cfg, *, want_cache=False):
    B, S, d = x.shape
    di = PROJ * d
    xp, z, q, k, v, ig, logf = _mlstm_qkvif(p, x, cfg)
    chunk = min(CHUNK, S)
    y, (C, n, m) = mlstm_chunked(q, k, v, ig, logf, chunk=chunk)
    y = group_norm_heads(y, p["out_gn"], cfg.norm_eps)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_down"])
    out = shard(out, "batch", "seq", "embed")
    cache = {"C": C, "n": n, "m": m} if want_cache else None
    return out, cache


def mlstm_step(p, x, cfg, cache):
    B = x.shape[0]
    d = cfg.d_model
    di = PROJ * d
    xp, z, q, k, v, ig, logf = _mlstm_qkvif(p, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]  # (B,H,D)
    ig, logf = ig[:, 0], logf[:, 0]  # (B,H)
    C, n, m = cache["C"].astype(jnp.float32), cache["n"], cache["m"]
    m_new = jnp.maximum(logf + m, ig)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(ig - m_new)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    C = C * fw[..., None, None] + jnp.einsum("bhd,bhk->bhdk", kf, vf) * iw[..., None, None]
    n = n * fw[..., None] + kf * iw[..., None]
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)), jnp.exp(-m_new))
    y = jnp.einsum("bhd,bhdk->bhk", qf, C) / denom[..., None]
    y = y[:, None].astype(x.dtype)  # (B,1,H,D)
    y = group_norm_heads(y, p["out_gn"], cfg.norm_eps)
    y = y.reshape(B, 1, di) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_down"])
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_specs(cfg):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    return {
        "ln": Spec((d,), ("embed",), "zeros"),
        "w_gates": Spec((d, 4 * d), ("embed", "inner")),  # i,f,z,o inputs
        "r_gates": Spec((H, dh, 4 * dh), ("ssm_heads", "head_dim", "inner"), "small"),
        "b_gates": Spec((4 * d,), ("inner",), "zeros", jnp.float32),
        "out_gn": Spec((H, dh), ("heads", "head_dim"), "ones"),
        # post-block gated FFN (4/3 factor, GELU) per xLSTM paper
        "ffn_ln": Spec((d,), ("embed",), "zeros"),
        "ffn_up": Spec((d, (4 * d) // 3 * 2), ("embed", "mlp")),
        "ffn_down": Spec(((4 * d) // 3, d), ("mlp", "embed")),
    }


def slstm_cache_spec(cfg, B):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    zz = lambda shp, ax: Spec(shp, ax, "zeros", jnp.float32)
    return {
        "c": zz((B, H, dh), ("cache_batch", "ssm_heads", "head_dim")),
        "n": zz((B, H, dh), ("cache_batch", "ssm_heads", "head_dim")),
        "m": zz((B, H, dh), ("cache_batch", "ssm_heads", "head_dim")),
        "hp": zz((B, H, dh), ("cache_batch", "ssm_heads", "head_dim")),
    }


def _slstm_cell(p, xg, state, H, dh):
    """One timestep. xg: (B, 4d) input pre-acts; state: (c,n,m,hp) (B,H,dh)."""
    c, n, m, hp = state
    B = xg.shape[0]
    rec = jnp.einsum("bhd,hdg->bhg", hp.astype(p["r_gates"].dtype), p["r_gates"])
    g = xg.reshape(B, H, 4 * dh).astype(jnp.float32) + rec.astype(jnp.float32)
    ii, ff, zz, oo = jnp.split(g, 4, axis=-1)  # (B,H,dh) each
    m_new = jnp.maximum(ff + m, ii)  # exp forget gating, stabilized
    iw = jnp.exp(ii - m_new)
    fw = jnp.exp(ff + m - m_new)
    c = fw * c + iw * jnp.tanh(zz)
    n = fw * n + iw
    h = jax.nn.sigmoid(oo) * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h)


def _slstm_scan(xg, r_gates, H, dh):
    """The sequential recurrence over time. xg: (B,S,4d) f32 pre-acts."""
    B = xg.shape[0]
    z0 = jnp.zeros((B, H, dh), jnp.float32)
    state0 = (z0, z0, jnp.full_like(z0, -1e30), z0)

    def step(st, xt):
        st2 = _slstm_cell({"r_gates": r_gates}, xt, st, H, dh)
        return st2, st2[3]

    return jax.lax.scan(step, state0, xg.transpose(1, 0, 2))


def slstm_fwd(p, x, cfg, *, want_cache=False):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    hn = rms_norm(x, p["ln"], cfg.norm_eps)
    xg = jnp.einsum("bsd,dg->bsg", hn, p["w_gates"]).astype(jnp.float32) + p["b_gates"]

    from repro.sharding import current_mesh_and_rules, logical_to_pspec
    mesh, rules = current_mesh_and_rules()
    if mesh is not None and rules is not None:
        # run the whole recurrence as one batch-parallel shard_map region:
        # the region is per-sample independent, and crucially the
        # cotangent psum for the (replicated) recurrent weights happens
        # ONCE at the region boundary — not once per timestep, which is
        # what an unannotated scan compiles to (a ~1 MB all-reduce per
        # step x 4096 steps x n_micro was xlstm's dominant roofline term).
        from jax.sharding import PartitionSpec as P
        xg_spec = logical_to_pspec(("batch", "seq", None), rules, mesh,
                                   xg.shape)
        st_spec = logical_to_pspec(("batch", None, None), rules, mesh,
                                   (B, H, dh))
        hs_spec = logical_to_pspec((None, "batch", None, None), rules, mesh,
                                   (S, B, H, dh))
        (c, n, m, hp), hs = jax.shard_map(
            lambda a, r: _slstm_scan(a, r, H, dh),
            mesh=mesh,
            in_specs=(xg_spec, P()),
            out_specs=((st_spec,) * 4, hs_spec),
            check_vma=False,
        )(xg, p["r_gates"])
    else:
        (c, n, m, hp), hs = _slstm_scan(xg, p["r_gates"], H, dh)
    y = hs.transpose(1, 0, 2, 3)  # (B,S,H,dh)
    y = group_norm_heads(y.astype(x.dtype), p["out_gn"], cfg.norm_eps)
    y = y.reshape(B, S, d)
    # gated FFN
    f = rms_norm(x + y, p["ffn_ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", f, p["ffn_up"])
    half = up.shape[-1] // 2
    y2 = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(up[..., :half]) * up[..., half:],
                    p["ffn_down"])
    out = y + y2
    cache = {"c": c, "n": n, "m": m, "hp": hp} if want_cache else None
    return out, cache


def slstm_step(p, x, cfg, cache):
    B = x.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    hn = rms_norm(x, p["ln"], cfg.norm_eps)
    xg = jnp.einsum("bsd,dg->bsg", hn, p["w_gates"]).astype(jnp.float32) + p["b_gates"]
    st = (cache["c"], cache["n"], cache["m"], cache["hp"])
    c, n, m, h = _slstm_cell(p, xg[:, 0], st, H, dh)
    y = group_norm_heads(h[:, None].astype(x.dtype), p["out_gn"], cfg.norm_eps)
    y = y.reshape(B, 1, d)
    f = rms_norm(x + y, p["ffn_ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", f, p["ffn_up"])
    half = up.shape[-1] // 2
    y2 = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(up[..., :half]) * up[..., half:],
                    p["ffn_down"])
    return y + y2, {"c": c, "n": n, "m": m, "hp": h}
