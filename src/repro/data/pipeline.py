"""Deterministic, shard-aware streaming data pipeline.

Requirements at 1000-node scale:
  * deterministic resume — batch t is a pure function of (seed, step), so a
    restarted/re-meshed job replays the exact stream with no state files;
  * shard-awareness — each data-parallel rank draws only its slice;
  * prefetch — a background thread keeps a bounded queue of ready batches
    (the host-side analogue of VDiSK's streaming-mode buffering).

Sources are synthetic (token LM streams and frame streams for the
biometric pipelines) — the substrate the paper assumes, built in JAX/numpy.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    seq_len: int = 512
    global_batch: int = 8
    n_shards: int = 1
    shard: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class TokenStream:
    """Synthetic LM stream: step-indexed, deterministic, shardable.

    Tokens follow a skewed unigram distribution with short-range structure
    (next token correlated with previous) so models actually learn and
    loss curves are meaningful in examples/tests.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        ss = np.random.SeedSequence([c.seed, step, c.shard])
        rng = np.random.default_rng(ss)
        B, S, V = c.local_batch, c.seq_len, c.vocab_size
        base = rng.zipf(1.5, size=(B, S + 1)).astype(np.int64)
        tok = np.minimum(base, V - 1).astype(np.int32)
        # short-range structure: token t+1 echoes token t half the time
        echo = rng.random((B, S)) < 0.5
        for i in range(1, S + 1):
            tok[:, i] = np.where(echo[:, i - 1], (tok[:, i - 1] + 1) % V,
                                 tok[:, i])
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FrameStream:
    """Synthetic camera frames (H, W, 3) for the biometric pipelines."""

    def __init__(self, seed: int = 0, hw=(224, 224)):
        self.seed, self.hw = seed, hw

    def frame_at(self, i: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, i]))
        h, w = self.hw
        yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
        cx, cy = rng.uniform(0.2, 0.8, 2) * (w, h)
        r = rng.uniform(0.1, 0.3) * min(h, w)
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * r * r)))
        img = rng.normal(0.5, 0.1, (h, w, 3)).astype(np.float32)
        img += blob[..., None] * rng.uniform(0.3, 0.8, 3).astype(np.float32)
        return np.clip(img, 0, 1)


class Prefetcher:
    """Bounded background prefetch over any step-indexed source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.source.batch_at(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        while not self.q.empty():
            self.q.get_nowait()
        self._thread.join(timeout=2)
