"""Logical-axis sharding: rules map logical tensor axes -> mesh axes.

MaxText-style indirection: model code annotates tensors with *logical* axis
names ("embed", "heads", ...); a rule set picks the physical mesh axes. This
lets the same model run under tensor-parallel (TP), fully-sharded (FSDP), or
single-host rules without touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axis vocabulary
# ---------------------------------------------------------------------------
#   layers     scan dimension over (super)blocks       -> never sharded
#   batch      global batch                            -> (pod, data)
#   seq        sequence (activations)                  -> None (or "data" SP)
#   cache_seq  KV-cache time axis                      -> None / "data"
#   embed      d_model                                 -> None (TP) / fsdp
#   vocab      vocabulary                              -> model
#   heads      query heads                             -> model
#   kv_heads   kv heads                                -> model (capped)
#   head_dim   per-head dim                            -> None
#   mlp        ffn hidden                              -> model
#   experts    MoE experts                             -> model (EP)
#   expert_mlp per-expert ffn hidden                   -> None
#   q_lora / kv_lora   MLA latents                     -> None
#   conv, state, ssm_heads, inner  SSM internals       -> model where safe

Rules = Mapping[str, Any]

TP_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": None,   # residual-stream seq axis at superblock boundaries
    "cache_seq": None,
    "cache_batch": ("pod", "data"),
    "embed": None,
    "embed_table": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "q_lora": None,
    "kv_lora": None,
    "conv": None,
    "state": None,
    "ssm_heads": "model",
    "inner": "model",
    "layers": None,
    "frames": None,
}

# FSDP: weights additionally sharded along their "embed"/"expert_mlp" axis over
# the data axis (ZeRO-3); XLA inserts per-layer all-gathers inside the scan.
FSDP_RULES: Rules = dict(
    TP_RULES,
    embed="data",
    expert_mlp="data",
    q_lora="data",
    kv_lora="data",
    head_dim=None,
)

# Long-context serving: shard the KV-cache time axis over "data" (sequence
# parallelism over the cache) because batch=1 cannot use the data axis.
LONG_CONTEXT_RULES: Rules = dict(
    TP_RULES,
    cache_seq="data",
    cache_batch=None,
    batch=None,
)

# Decode serving (32k context): the KV-cache time axis shards over "model"
# (flash-decode style: each model shard scores its cache chunk; softmax
# stats + context psum are tiny) so 128 concurrent 32k caches fit HBM.
DECODE_RULES: Rules = dict(
    TP_RULES,
    cache_seq="model",
)

# MoE decode serving: additionally spread routed experts over ("pod","data")
# (EP) with the per-expert ffn hidden dim over "model" (intra-expert TP).
# Token batch stays on ("pod","data") too; moe_fwd gathers tokens across EP
# shards and reduce-scatters outputs back (the TPU analogue of the GPU
# all-to-all).
DECODE_MOE_RULES: Rules = dict(
    DECODE_RULES,
    experts=("pod", "data"),
    expert_mlp="model",
)

# Sequence-parallel training: the residual stream (and therefore the
# scan-over-layers carry that dominates activation memory) shards its seq
# axis over "model" between superblocks; blocks gather what they need
# (Megatron-SP adapted to scan + logical axes). Attention/MoE internals
# keep their existing annotations ("seq" -> None), so XLA inserts the
# boundary gathers automatically.
FSDP_SP_RULES: Rules = dict(FSDP_RULES, act_seq="model")

RULE_SETS = {
    "tp": TP_RULES,
    "fsdp": FSDP_RULES,
    "fsdp_sp": FSDP_SP_RULES,
    "long": LONG_CONTEXT_RULES,
    "decode": DECODE_RULES,
    "decode_moe": DECODE_MOE_RULES,
}

_state = threading.local()


def _current_rules() -> Rules | None:
    return getattr(_state, "rules", None)


def _current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_rules(rules: Rules | str, mesh: Mesh | None = None):
    """Activate a rule set for model code traced inside this context."""
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    prev = (_current_rules(), _current_mesh())
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def logical_to_pspec(
    axes: Sequence[str | None],
    rules: Rules,
    mesh: Mesh | None = None,
    shape: Sequence[int] | None = None,
) -> P:
    """Map logical axes -> PartitionSpec. Drops mesh axes that do not exist in
    ``mesh`` and shardings that do not divide ``shape`` evenly."""
    parts = []
    used: set = set()
    names = set(mesh.axis_names) if mesh is not None else None
    for i, ax in enumerate(axes):
        m = rules.get(ax) if ax is not None else None
        flat = [a for a in (m if isinstance(m, (tuple, list)) else (m,)) if a is not None]
        if names is not None:
            flat = [a for a in flat if a in names]
        # never map two logical axes onto the same mesh axis in one pspec
        flat = [a for a in flat if a not in used]
        if flat and shape is not None and mesh is not None:
            sz = int(np.prod([mesh.shape[a] for a in flat]))
            if shape[i] % sz != 0:
                flat = []
        if not flat:
            parts.append(None)
        else:
            used.update(flat)
            parts.append(tuple(flat) if len(flat) > 1 else flat[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain activation sharding by logical axes. No-op w/o active rules."""
    rules, mesh = _current_rules(), _current_mesh()
    if rules is None or mesh is None:
        return x
    spec = logical_to_pspec(axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh_and_rules():
    return _current_mesh(), _current_rules()


# ---------------------------------------------------------------------------
# Param specs: shape/dtype/logical-axes triples driving init, AOT and sharding
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | small
    dtype: Any = None  # default: model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=lambda x: isinstance(x, Spec))


def shape_dtype(tree, default_dtype) -> Any:
    return spec_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype), tree
    )


def shardings(tree, mesh: Mesh, rules: Rules | str):
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    return spec_map(
        lambda s: NamedSharding(mesh, logical_to_pspec(s.axes, rules, mesh, s.shape)),
        tree,
    )


def init_params(tree, key: jax.Array, default_dtype) -> Any:
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, Spec)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        dt = spec.dtype or default_dtype
        if spec.init == "zeros":
            out.append(jax.numpy.zeros(spec.shape, dt))
        elif spec.init == "ones":
            out.append(jax.numpy.ones(spec.shape, dt))
        else:
            scale = 0.02 if spec.init == "normal" else 0.006
            fan_in_axis = 0
            out.append(
                (jax.random.normal(k, spec.shape, jax.numpy.float32) * scale).astype(dt)
            )
    return jax.tree.unflatten(treedef, out)
