"""Secure gallery store — the paper's Database/Storage cartridge brain.

Holds a biometric gallery (N templates + identity labels) where templates
live in the protected (rotated) space and the backing arrays are encrypted
at rest with the Threefry stream cipher. Matching happens entirely in
protected space via the ``gallery_match`` kernel (cosine top-k); raw
embeddings never exist inside the store.

The store also "defines the necessary matching calculation for the
template type it stores" (paper fig. 2): `match()` is the store's own
calculation, parameterized by template kind.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.templates import (KeyedRotation, decrypt_array,
                                    encrypt_array)


class SecureGallery:
    def __init__(self, dim: int, *, seed: int = 7, template_kind: str =
                 "face_embedding"):
        self.dim = dim
        self.template_kind = template_kind
        self.rotation = KeyedRotation(dim, seed)
        self._cipher_key = jax.random.PRNGKey(seed ^ 0x5EC2E7)
        self._enc_templates: Optional[dict] = None  # encrypted at rest
        self._labels: list = []
        self._n = 0

    # -- enrollment ------------------------------------------------------------
    def enroll(self, raw_templates: np.ndarray, labels):
        """raw (N, dim) embeddings -> protected + encrypted at rest."""
        prot = np.asarray(self.rotation.protect(jnp.asarray(raw_templates)))
        if self._enc_templates is not None:
            prev = decrypt_array(self._cipher_key, self._enc_templates)
            prot = np.concatenate([prev, prot], axis=0)
        self._enc_templates = encrypt_array(self._cipher_key,
                                            prot.astype(np.float32))
        self._labels = list(self._labels) + list(labels)
        self._n = len(self._labels)

    def __len__(self):
        return self._n

    # -- matching ----------------------------------------------------------------
    def protected_gallery(self) -> jax.Array:
        assert self._enc_templates is not None, "empty gallery"
        return jnp.asarray(decrypt_array(self._cipher_key,
                                         self._enc_templates))

    def match(self, raw_queries: jax.Array, k: int = 5):
        """Match raw query embeddings; returns (labels, scores).

        Queries are protected with the same rotation, then matched in
        protected space (cosine is invariant under the shared rotation).
        """
        from repro.kernels import ops as K
        q = self.rotation.protect(jnp.asarray(raw_queries))
        g = self.protected_gallery()
        scores, idx = K.gallery_match(q, g, k=min(k, self._n))
        labels = np.asarray(self._labels, object)[np.asarray(idx)]
        return labels, scores

    # -- revocation --------------------------------------------------------------
    def rekey(self, new_seed: int):
        """Cancellable biometrics: re-protect the gallery under a new key."""
        g = np.asarray(self.protected_gallery())
        raw = np.asarray(self.rotation.unprotect(jnp.asarray(g)))
        self.rotation = KeyedRotation(self.dim, new_seed)
        self._cipher_key = jax.random.PRNGKey(new_seed ^ 0x5EC2E7)
        prot = np.asarray(self.rotation.protect(jnp.asarray(raw)))
        self._enc_templates = encrypt_array(self._cipher_key,
                                            prot.astype(np.float32))
