"""Secure gallery store — the paper's Database/Storage cartridge brain.

Holds a biometric gallery (N templates + identity labels) where templates
live in the protected (rotated) space and the backing arrays are encrypted
at rest with the Threefry stream cipher. Matching happens entirely in
protected space via the ``gallery_match`` kernel family (cosine top-k);
raw embeddings never exist inside the store.

The store also "defines the necessary matching calculation for the
template type it stores" (paper fig. 2): `match()` is the store's own
calculation, parameterized by template kind.

Identification fast path (sharded + quantized). The protected gallery is
held as ``n_shards`` independently encrypted shards — one per lane-group
replica in the engine topology, the software analogue of the paper's
"plug another cartridge in" capacity scaling: a slot with N replicas
searches an N×-larger gallery at the per-shard latency, and ``match``
merges the per-shard top-k into a global top-k.  Each shard keeps a
*prepared* match-time view (decrypt once → L2-normalize → optionally
bf16-cast or int8 per-row quantize with scales), built lazily and
invalidated by ``enroll``/``rekey``/``reshard``; ``seal()`` drops the
plaintext views so only the encrypted-at-rest blobs remain resident.
Match dtypes: ``"fp32"`` (oracle), ``"bf16"``, ``"int8"``.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.templates import (KeyedRotation, decrypt_array,
                                    encrypt_array)

MATCH_DTYPES = ("fp32", "bf16", "int8")


class SecureGallery:
    def __init__(self, dim: int, *, seed: int = 7, template_kind: str =
                 "face_embedding", n_shards: int = 1,
                 match_dtype: str = "fp32"):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if match_dtype not in MATCH_DTYPES:
            raise ValueError(f"match_dtype must be one of {MATCH_DTYPES}")
        self.dim = dim
        self.template_kind = template_kind
        self.match_dtype = match_dtype
        self.rotation = KeyedRotation(dim, seed)
        self._cipher_key = jax.random.PRNGKey(seed ^ 0x5EC2E7)
        # per-shard encrypted blobs + the global row ids each shard holds
        self._shards: List[Optional[dict]] = [None] * n_shards
        self._shard_ids: List[np.ndarray] = [
            np.empty((0,), np.int64) for _ in range(n_shards)]
        self._prep: List[dict] = [{} for _ in range(n_shards)]
        self._labels: list = []
        self._n = 0

    # -- enrollment ------------------------------------------------------------
    def enroll(self, raw_templates: np.ndarray, labels):
        """raw (N, dim) embeddings -> protected + encrypted at rest,
        distributed across shards (least-full first, so replica lanes stay
        balanced as the watchlist grows)."""
        prot = np.asarray(self.rotation.protect(jnp.asarray(raw_templates)))
        prot = prot.astype(np.float32)
        n_new = prot.shape[0]
        gids = np.arange(self._n, self._n + n_new, dtype=np.int64)
        order = np.argsort([len(ids) for ids in self._shard_ids],
                           kind="stable")
        splits = np.array_split(np.arange(n_new), self.n_shards)
        for shard, rows in zip(order, splits):
            if len(rows) == 0:
                continue
            self._append_to_shard(int(shard), prot[rows], gids[rows])
        self._labels = list(self._labels) + list(labels)
        self._n = len(self._labels)

    def _append_to_shard(self, s: int, prot: np.ndarray, gids: np.ndarray):
        if self._shards[s] is not None:
            prev = decrypt_array(self._cipher_key, self._shards[s])
            prot = np.concatenate([prev, prot], axis=0)
        self._shards[s] = encrypt_array(self._cipher_key, prot)
        self._shard_ids[s] = np.concatenate([self._shard_ids[s], gids])
        self._prep[s] = {}                         # plaintext view is stale

    def __len__(self):
        return self._n

    @property
    def n_shards(self) -> int:
        return len(self._shards)

    def shard_sizes(self) -> List[int]:
        return [len(ids) for ids in self._shard_ids]

    # -- matching ----------------------------------------------------------------
    def protected_gallery(self) -> jax.Array:
        """All protected templates, in global enrollment order (compat)."""
        assert self._n > 0, "empty gallery"
        out = np.empty((self._n, self.dim), np.float32)
        for s in range(self.n_shards):
            if len(self._shard_ids[s]):
                out[self._shard_ids[s]] = decrypt_array(
                    self._cipher_key, self._shards[s])
        return jnp.asarray(out)

    def _prepare(self, s: int, dtype: str) -> dict:
        """Decrypt-once match-time view of shard ``s`` for ``dtype``:
        pre-normalized rows, plus the int8 values/scales for the quantized
        path.  This is the enrollment-side half of the fused kernel entry
        (queries are normalized in-kernel; the gallery is normalized here)."""
        prep = self._prep[s]
        if "gn" not in prep:
            g = jnp.asarray(decrypt_array(self._cipher_key, self._shards[s]))
            prep["gn"] = g / jnp.maximum(
                jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-9)
        if dtype == "bf16" and "gn_bf16" not in prep:
            prep["gn_bf16"] = prep["gn"].astype(jnp.bfloat16)
        if dtype == "int8" and "q8" not in prep:
            from repro.kernels import ops as K
            q8, scale = K.prepare_gallery_quant(prep["gn"])
            prep["q8"], prep["scale"] = q8, scale
        return prep

    def seal(self):
        """Drop every plaintext match-time view; only the encrypted-at-rest
        shard blobs stay resident (next ``match`` re-prepares)."""
        self._prep = [{} for _ in self._shards]

    def _match_shard(self, s: int, q: jax.Array, k: int, dtype: str):
        from repro.kernels import ops as K
        prep = self._prepare(s, dtype)
        if dtype == "int8":
            return K.gallery_match_quant(q, prep["q8"], prep["scale"], k=k)
        gn = prep["gn_bf16"] if dtype == "bf16" else prep["gn"]
        return K.gallery_match_fused(q, gn, k=k)

    def match(self, raw_queries: jax.Array, k: int = 5,
              dtype: Optional[str] = None):
        """Match raw query embeddings; returns (labels, scores).

        Queries are protected with the same rotation, then matched in
        protected space (cosine is invariant under the shared rotation).
        Each shard is searched independently (one kernel call per shard,
        i.e. per replica lane) and the per-shard top-k merge to a global
        top-k; ``dtype`` selects the score path (default: the store's
        ``match_dtype``).
        """
        assert self._n > 0, "empty gallery"
        dtype = dtype or self.match_dtype
        if dtype not in MATCH_DTYPES:
            raise ValueError(f"dtype must be one of {MATCH_DTYPES}")
        k = min(k, self._n)
        q = self.rotation.protect(jnp.asarray(raw_queries))
        shard_scores, shard_gids = [], []
        for s in range(self.n_shards):
            n_s = len(self._shard_ids[s])
            if n_s == 0:
                continue
            ks = min(k, n_s)
            scores, idx = self._match_shard(s, q, ks, dtype)
            shard_scores.append(np.asarray(scores))
            shard_gids.append(self._shard_ids[s][np.asarray(idx)])
        all_s = np.concatenate(shard_scores, axis=1)       # (Q, sum ks)
        all_g = np.concatenate(shard_gids, axis=1)
        if len(shard_scores) > 1:                          # top-k merge
            top = np.argsort(-all_s, axis=1, kind="stable")[:, :k]
            all_s = np.take_along_axis(all_s, top, axis=1)
            all_g = np.take_along_axis(all_g, top, axis=1)
        labels = np.asarray(self._labels, object)[all_g]
        return labels, jnp.asarray(all_s)

    # -- topology ----------------------------------------------------------------
    def reshard(self, n_shards: int):
        """Re-split the gallery across ``n_shards`` shards (mirror the lane
        group gaining/losing a replica cartridge)."""
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self._n == 0:
            self._shards = [None] * n_shards
            self._shard_ids = [np.empty((0,), np.int64)
                               for _ in range(n_shards)]
            self._prep = [{} for _ in range(n_shards)]
            return
        full = np.asarray(self.protected_gallery())
        gids = np.arange(self._n, dtype=np.int64)
        self._shards = [None] * n_shards
        self._shard_ids = [np.empty((0,), np.int64) for _ in range(n_shards)]
        self._prep = [{} for _ in range(n_shards)]
        for s, rows in enumerate(np.array_split(gids, n_shards)):
            if len(rows):
                self._append_to_shard(s, full[rows], rows)

    # -- revocation --------------------------------------------------------------
    def rekey(self, new_seed: int):
        """Cancellable biometrics: re-protect the gallery under a new key."""
        assert self._n > 0, "empty gallery"
        raws = []
        for s in range(self.n_shards):
            if len(self._shard_ids[s]):
                g = decrypt_array(self._cipher_key, self._shards[s])
                raws.append(np.asarray(
                    self.rotation.unprotect(jnp.asarray(g))))
            else:
                raws.append(None)
        self.rotation = KeyedRotation(self.dim, new_seed)
        self._cipher_key = jax.random.PRNGKey(new_seed ^ 0x5EC2E7)
        for s, raw in enumerate(raws):
            if raw is None:
                continue
            prot = np.asarray(self.rotation.protect(jnp.asarray(raw)))
            self._shards[s] = encrypt_array(self._cipher_key,
                                            prot.astype(np.float32))
            self._prep[s] = {}
