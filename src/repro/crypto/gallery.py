"""Secure gallery store — the paper's Database/Storage cartridge brain.

Holds a biometric gallery (N templates + identity labels) where templates
live in the protected (rotated) space and the backing arrays are encrypted
at rest with the Threefry stream cipher. Matching happens entirely in
protected space via the ``gallery_match`` kernel family (cosine top-k);
raw embeddings never exist inside the store.

The store also "defines the necessary matching calculation for the
template type it stores" (paper fig. 2): `match()` is the store's own
calculation, parameterized by template kind.

Identification fast path (sharded + quantized). The protected gallery is
held as ``n_shards`` independently encrypted shards — one per lane-group
replica in the engine topology, the software analogue of the paper's
"plug another cartridge in" capacity scaling: a slot with N replicas
searches an N×-larger gallery at the per-shard latency, and ``match``
merges the per-shard top-k into a global top-k.  Each shard keeps a
*prepared* match-time view (decrypt once → L2-normalize → optionally
bf16-cast or int8 per-row quantize with scales), built lazily and
invalidated by ``enroll``/``rekey``/``reshard``; ``seal()`` drops the
plaintext views so only the encrypted-at-rest blobs remain resident.
Match dtypes: ``"fp32"`` (oracle), ``"bf16"``, ``"int8"``.

Planet-scale tier (two-level ANN).  Exact per-shard scan is linear in N;
``build_ann_index()`` trains one global spherical-k-means codebook (K
cells, encrypted at rest like everything else) and assigns every row to
a cell, and ``match(mode="ann", nprobe=c)`` scores only K centroids plus
the rows of each query's top-c cells (``kernels/ann_match``: coarse
centroid scan → exact rescore in the probed cells, both storage-dtype
aware).  Index maintenance is **incremental**: ``enroll`` assigns new
rows to existing cells (never retrains), ``rekey`` rotates the codebook
through the key change (cosine geometry is rotation-invariant, so
assignments survive), and ``reshard`` only re-packs the per-shard
physical layouts — ``ann_stats["trainings"]`` stays at one unless
``build_ann_index`` is called again explicitly.  ``last_match_stats``
reports rows scored vs rows resident, the tracked ≤1/10 contract.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.templates import (KeyedRotation, decrypt_array,
                                    encrypt_array)

MATCH_DTYPES = ("fp32", "bf16", "int8")
MATCH_MODES = ("exact", "ann")


def _deficit_alloc(sizes: np.ndarray, n_new: int) -> np.ndarray:
    """How many of ``n_new`` rows each shard gets so final sizes are as
    level as possible *without moving existing rows*: water-fill the
    smallest shards up to a common level, remainder to the smallest
    results first (stable by shard id, so allocation is deterministic)."""
    sizes = np.asarray(sizes, np.int64)
    if n_new <= 0:
        return np.zeros(len(sizes), np.int64)
    lo, hi = int(sizes.min()), int(sizes.max()) + n_new
    while lo < hi:                     # max level T reachable with n_new
        mid = (lo + hi + 1) // 2
        if int(np.maximum(mid - sizes, 0).sum()) <= n_new:
            lo = mid
        else:
            hi = mid - 1
    alloc = np.maximum(lo - sizes, 0)
    rem = n_new - int(alloc.sum())
    if rem:
        order = np.argsort(sizes + alloc, kind="stable")
        alloc[order[:rem]] += 1
    return alloc


class SecureGallery:
    def __init__(self, dim: int, *, seed: int = 7, template_kind: str =
                 "face_embedding", n_shards: int = 1,
                 match_dtype: str = "fp32"):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if match_dtype not in MATCH_DTYPES:
            raise ValueError(f"match_dtype must be one of {MATCH_DTYPES}")
        self.dim = dim
        self.template_kind = template_kind
        self.match_dtype = match_dtype
        self.rotation = KeyedRotation(dim, seed)
        self._cipher_key = jax.random.PRNGKey(seed ^ 0x5EC2E7)
        # per-shard encrypted blobs + the global row ids each shard holds
        self._shards: List[Optional[dict]] = [None] * n_shards
        self._shard_ids: List[np.ndarray] = [
            np.empty((0,), np.int64) for _ in range(n_shards)]
        self._prep: List[dict] = [{} for _ in range(n_shards)]
        self._labels: list = []
        self._n = 0
        # multi-tenant isolation: every row carries its enrolling
        # tenant's code (gid-indexed, so tags survive reshard/failover
        # exactly like the ANN assignment); code 0 = the untagged /
        # fleet-operator pool.  match(tenant=...) scopes scoring to that
        # tenant's rows — one tenant's watchlist never serves another's
        # match
        self._tenant_codes: dict = {None: 0}
        self._tenant_names: list = [None]
        self._tenant_tags = np.empty((0,), np.int32)
        # two-level ANN tier: encrypted global codebook + per-gid cell
        # assignment (ints, not biometric data); physical packed layouts
        # live in the per-shard _prep caches
        self._ann_blob: Optional[dict] = None      # encrypted (K, D) f32
        self._ann_codebook: Optional[np.ndarray] = None   # decrypt-once
        self._ann_assign = np.empty((0,), np.int32)       # gid -> cell
        self._ann_n_cells = 0
        self.ann_stats = {"trainings": 0, "assign_calls": 0, "packs": 0}
        self.failovers = 0                 # shard rebuilds after lane death
        self.last_match_stats: dict = {}
        # optional FlightRecorder: failovers/ANN trainings emit instants
        # at tracer.clock() (the gallery has no clock of its own)
        self.tracer = None

    # -- enrollment ------------------------------------------------------------
    def _tenant_code(self, tenant, create: bool = False) -> int:
        code = self._tenant_codes.get(tenant)
        if code is None:
            if not create:
                raise KeyError(f"unknown tenant {tenant!r}: no rows "
                               "enrolled under that name")
            code = len(self._tenant_names)
            self._tenant_codes[tenant] = code
            self._tenant_names.append(tenant)
        return code

    def has_tenant(self, tenant) -> bool:
        """True when ``tenant`` has enrolled rows to match against."""
        code = self._tenant_codes.get(tenant)
        return code is not None and bool((self._tenant_tags == code).any())

    def tenant_rows(self) -> dict:
        """Enrolled row count per tenant (None = the untagged pool)."""
        out = {}
        for name, code in self._tenant_codes.items():
            n = int((self._tenant_tags == code).sum())
            if n or name is None:
                out[name] = n
        return out

    def enroll(self, raw_templates: np.ndarray, labels, tenant=None):
        """raw (N, dim) embeddings -> protected + encrypted at rest,
        distributed across shards by *deficit* (each shard receives
        enough rows to level the sizes — ``np.array_split`` over the
        least-full order ignored existing imbalance, so uneven
        enroll/reshard sequences skewed per-replica latency).
        ``tenant`` tags the rows for scoped matching (None = the shared
        fleet pool)."""
        prot = np.asarray(self.rotation.protect(jnp.asarray(raw_templates)))
        prot = prot.astype(np.float32)
        n_new = prot.shape[0]
        gids = np.arange(self._n, self._n + n_new, dtype=np.int64)
        code = self._tenant_code(tenant, create=True)
        self._tenant_tags = np.concatenate(
            [self._tenant_tags, np.full(n_new, code, np.int32)])
        if self._ann_blob is not None and n_new:
            # incremental index maintenance: new rows join existing cells
            # (nearest centroid in protected space); the codebook is NOT
            # retrained — ann_stats["trainings"] must not move here
            from repro.kernels.ann_match import assign_cells
            new_cells = assign_cells(prot, self._codebook())
            self._ann_assign = np.concatenate([self._ann_assign, new_cells])
            self.ann_stats["assign_calls"] += 1
        alloc = _deficit_alloc([len(ids) for ids in self._shard_ids], n_new)
        offsets = np.concatenate([[0], np.cumsum(alloc)])
        for shard in range(self.n_shards):
            rows = np.arange(offsets[shard], offsets[shard + 1])
            if len(rows) == 0:
                continue
            self._append_to_shard(int(shard), prot[rows], gids[rows])
        self._labels = list(self._labels) + list(labels)
        self._n = len(self._labels)

    def _append_to_shard(self, s: int, prot: np.ndarray, gids: np.ndarray):
        if self._shards[s] is not None:
            prev = decrypt_array(self._cipher_key, self._shards[s])
            prot = np.concatenate([prev, prot], axis=0)
        self._shards[s] = encrypt_array(self._cipher_key, prot)
        self._shard_ids[s] = np.concatenate([self._shard_ids[s], gids])
        self._prep[s] = {}                         # plaintext view is stale

    def __len__(self):
        return self._n

    @property
    def n_shards(self) -> int:
        return len(self._shards)

    def shard_sizes(self) -> List[int]:
        return [len(ids) for ids in self._shard_ids]

    # -- matching ----------------------------------------------------------------
    def protected_gallery(self) -> jax.Array:
        """All protected templates, in global enrollment order (compat)."""
        assert self._n > 0, "empty gallery"
        out = np.empty((self._n, self.dim), np.float32)
        for s in range(self.n_shards):
            if len(self._shard_ids[s]):
                out[self._shard_ids[s]] = decrypt_array(
                    self._cipher_key, self._shards[s])
        return jnp.asarray(out)

    def _prepare(self, s: int, dtype: str) -> dict:
        """Decrypt-once match-time view of shard ``s`` for ``dtype``:
        pre-normalized rows, plus the int8 values/scales for the quantized
        path.  This is the enrollment-side half of the fused kernel entry
        (queries are normalized in-kernel; the gallery is normalized here)."""
        prep = self._prep[s]
        if "gn" not in prep:
            g = jnp.asarray(decrypt_array(self._cipher_key, self._shards[s]))
            prep["gn"] = g / jnp.maximum(
                jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-9)
        if dtype == "bf16" and "gn_bf16" not in prep:
            prep["gn_bf16"] = prep["gn"].astype(jnp.bfloat16)
        if dtype == "int8" and "q8" not in prep:
            from repro.kernels import ops as K
            q8, scale = K.prepare_gallery_quant(prep["gn"])
            prep["q8"], prep["scale"] = q8, scale
        return prep

    def seal(self):
        """Drop every plaintext match-time view — including the decrypted
        ANN codebook and packed cell layouts; only the encrypted-at-rest
        blobs stay resident (next ``match`` re-prepares)."""
        self._prep = [{} for _ in self._shards]
        self._ann_codebook = None

    def _tenant_shard_rows(self, s: int, code: int) -> np.ndarray:
        """Shard-local row indices belonging to a tenant (cached in the
        shard's prep view, so invalidation follows the same
        enroll/rekey/reshard lifecycle as the decrypted arrays)."""
        cache = self._prep[s].setdefault("tenant_rows", {})
        rows = cache.get(code)
        if rows is None:
            rows = cache[code] = np.nonzero(
                self._tenant_tags[self._shard_ids[s]] == code)[0]
        return rows

    def _match_shard(self, s: int, q: jax.Array, k: int, dtype: str,
                     rows: Optional[np.ndarray] = None):
        """Exact top-k over one shard; ``rows`` restricts scoring to a
        tenant's subset view (the int8 path subsets the per-row
        quantized values/scales directly — per-row quantization makes
        the subset bit-identical to quantizing the subset).  Returned
        indices are shard-local."""
        from repro.kernels import ops as K
        prep = self._prepare(s, dtype)
        if dtype == "int8":
            q8, scale = prep["q8"], prep["scale"]
            if rows is not None:
                q8, scale = q8[rows], scale[rows]
            scores, idx = K.gallery_match_quant(q, q8, scale, k=k)
        else:
            gn = prep["gn_bf16"] if dtype == "bf16" else prep["gn"]
            if rows is not None:
                gn = gn[rows]
            scores, idx = K.gallery_match_fused(q, gn, k=k)
        if rows is not None:
            idx = rows[np.asarray(idx)]
        return scores, idx

    # -- two-level ANN tier ------------------------------------------------------
    def build_ann_index(self, *, n_cells: Optional[int] = None,
                        iters: int = 6, seed: int = 0):
        """Train the global centroid codebook (spherical k-means-lite over
        every row) and assign each row to a cell.  The one expensive,
        explicit operation — everything after it (enroll/rekey/reshard)
        maintains the index incrementally."""
        assert self._n > 0, "empty gallery"
        from repro.kernels.ann_match import assign_cells, kmeans_lite
        gn = np.empty((self._n, self.dim), np.float32)
        for s in range(self.n_shards):
            if len(self._shard_ids[s]):
                gn[self._shard_ids[s]] = np.asarray(self._prepare(s, "fp32")
                                                    ["gn"])
        if n_cells is None:
            n_cells = max(1, int(round(float(np.sqrt(self._n)))))
        n_cells = max(1, min(n_cells, self._n))
        codebook = kmeans_lite(gn, n_cells, iters=iters, seed=seed)
        self._ann_n_cells = codebook.shape[0]
        self._ann_blob = encrypt_array(self._cipher_key, codebook)
        self._ann_codebook = codebook
        self._ann_assign = assign_cells(gn, codebook)
        self.ann_stats["trainings"] += 1
        if self.tracer is not None:
            self.tracer.instant("gallery.ann_train", self.tracer.clock(),
                                track="gallery", rows=self._n,
                                n_cells=self._ann_n_cells)
        for s in range(self.n_shards):             # packed layouts are stale
            self._prep[s].pop("ann", None)
            self._prep[s].pop("tenant_ann", None)

    @property
    def ann_indexed(self) -> bool:
        return self._ann_blob is not None

    def _codebook(self) -> np.ndarray:
        """Decrypt-once cached codebook (dropped by ``seal``)."""
        if self._ann_codebook is None:
            self._ann_codebook = decrypt_array(self._cipher_key,
                                               self._ann_blob)
        return self._ann_codebook

    def _prepare_ann(self, s: int, dtype: str,
                     code: Optional[int] = None) -> dict:
        """Padded cell-major physical view of shard ``s`` for ``dtype``,
        built lazily from the prepared (decrypt-once) view + the global
        assignment — an *affected-shard-only* repack, never a retrain.
        With a tenant ``code``, the layout and packed arrays cover only
        that tenant's rows (``ann["rows"]`` maps back to shard-local)."""
        from repro.kernels.ann_match import build_cell_layout
        prep = self._prepare(s, dtype)
        if code is None:
            if "ann" not in prep:
                assign = self._ann_assign[self._shard_ids[s]]
                prep["ann"] = {"layout": build_cell_layout(
                    assign, self._ann_n_cells)}
                self.ann_stats["packs"] += 1
            ann = prep["ann"]
        else:
            ann = prep.setdefault("tenant_ann", {}).setdefault(code, {})
            if "layout" not in ann:
                rows = self._tenant_shard_rows(s, code)
                ann["rows"] = rows
                assign = self._ann_assign[self._shard_ids[s][rows]]
                ann["layout"] = build_cell_layout(assign, self._ann_n_cells)
                self.ann_stats["packs"] += 1
        layout = ann["layout"]
        gn = np.asarray(prep["gn"])
        if code is not None:
            gn = gn[ann["rows"]]
        if dtype == "int8" and "q8" not in ann:
            from repro.kernels.ann_match import pack_cells_quant
            ann["q8"], ann["scale"] = pack_cells_quant(gn, layout)
        elif dtype in ("fp32", "bf16") and "packed" not in ann:
            from repro.kernels.ann_match import pack_cells
            ann["packed"] = pack_cells(gn, layout)
        if dtype == "bf16" and "packed_bf16" not in ann:
            ann["packed_bf16"] = jnp.asarray(ann["packed"]).astype(
                jnp.bfloat16)
        return ann

    def _coarse_scan(self, q: jax.Array, nprobe: int, dtype: str):
        """Query-vs-codebook probe selection in the match dtype (the
        codebook is small, so its quantized forms are derived on the
        fly from the decrypt-once cache)."""
        from repro.kernels import ops as K
        codebook = self._codebook()
        if dtype == "int8":
            from repro.kernels.ann_match import quantize_gallery
            c8, cs = quantize_gallery(jnp.asarray(codebook))
            return K.centroid_topc_quant(q, c8, cs, c=nprobe)
        cents = jnp.asarray(codebook)
        if dtype == "bf16":
            cents = cents.astype(jnp.bfloat16)
        return K.centroid_topc(q, cents, c=nprobe)

    def _match_shard_ann(self, s: int, q: jax.Array, cell_ids: jax.Array,
                         k: int, dtype: str, code: Optional[int] = None):
        """Exact rescore of shard ``s`` restricted to the probed cells
        (and, with a tenant ``code``, to that tenant's rows); returns
        (scores, global ids, rows_scored) with -1 ids on unfilled
        slots."""
        from repro.kernels import ops as K
        ann = self._prepare_ann(s, dtype, code)
        layout = ann["layout"]
        lens = jnp.asarray(layout.cell_lens)
        if dtype == "int8":
            scores, pos = K.cell_rescore_quant(
                q, jnp.asarray(ann["q8"]), jnp.asarray(ann["scale"]),
                cell_ids, lens, k=k, L=layout.L)
        else:
            packed = ann["packed_bf16"] if dtype == "bf16" \
                else jnp.asarray(ann["packed"])
            scores, pos = K.cell_rescore(q, packed, cell_ids, lens,
                                         k=k, L=layout.L)
        pos = np.asarray(pos)
        rows = np.where(pos >= 0,
                        layout.pos_to_row[np.clip(pos, 0, None)], -1)
        if code is not None:          # subset-local -> shard-local rows
            rows = np.where(rows >= 0,
                            ann["rows"][np.clip(rows, 0, None)], -1)
        gids = np.where(rows >= 0,
                        self._shard_ids[s][np.clip(rows, 0, None)], -1)
        ids = np.asarray(cell_ids)
        # average gallery rows rescored per query in this shard
        scored = float(layout.cell_lens[ids.clip(0)][ids >= 0].sum()
                       / max(ids.shape[0], 1))
        return np.asarray(scores), gids, scored

    # -- matching entry ----------------------------------------------------------
    def match(self, raw_queries: jax.Array, k: int = 5,
              dtype: Optional[str] = None, *, mode: str = "exact",
              nprobe: int = 8, tenant=None):
        """Match raw query embeddings; returns (labels, scores).

        Queries are protected with the same rotation, then matched in
        protected space (cosine is invariant under the shared rotation).
        ``mode="exact"``: each shard is searched in full (one kernel call
        per shard, i.e. per replica lane).  ``mode="ann"``: one coarse
        scan against the global codebook picks each query's top-``nprobe``
        cells, then every shard rescores only the probed cells — rows
        scored per query drops from N to ~K + nprobe·N/K (tracked in
        ``last_match_stats``).  Per-shard top-k merge to a global top-k
        breaks score ties by **global id**, so results are invariant to
        the shard topology; ``dtype`` selects the score path (default:
        the store's ``match_dtype``).

        ``tenant`` scopes the search to rows enrolled under that tenant
        (per-tenant shard views: one tenant's watchlist never serves
        another's match).  ``tenant=None`` searches the whole gallery —
        the fleet-operator view, and the pre-tenancy behaviour.
        """
        assert self._n > 0, "empty gallery"
        dtype = dtype or self.match_dtype
        if dtype not in MATCH_DTYPES:
            raise ValueError(f"dtype must be one of {MATCH_DTYPES}")
        if mode not in MATCH_MODES:
            raise ValueError(f"mode must be one of {MATCH_MODES}")
        if mode == "ann" and not self.ann_indexed:
            raise ValueError("ANN index not built — call "
                             "build_ann_index() before match(mode='ann')")
        code = None
        n_scope = self._n
        if tenant is not None:
            code = self._tenant_code(tenant)
            n_scope = int((self._tenant_tags == code).sum())
            if n_scope == 0:
                raise ValueError(f"tenant {tenant!r} has no enrolled rows")
        k = min(k, n_scope)
        q = self.rotation.protect(jnp.asarray(raw_queries))
        centroid_rows = 0
        cell_rows = 0
        if mode == "ann":
            nprobe = max(1, min(nprobe, self._ann_n_cells))
            _, cell_ids = self._coarse_scan(q, nprobe, dtype)
            centroid_rows = self._ann_n_cells
        shard_scores, shard_gids = [], []
        for s in range(self.n_shards):
            rows = None
            n_s = len(self._shard_ids[s])
            if code is not None and n_s:
                rows = self._tenant_shard_rows(s, code)
                n_s = len(rows)
            if n_s == 0:
                continue
            ks = min(k, n_s)
            if mode == "ann":
                scores, gids, scored = self._match_shard_ann(
                    s, q, cell_ids, ks, dtype, code)
                cell_rows += scored
            else:
                scores, idx = self._match_shard(s, q, ks, dtype, rows)
                scores = np.asarray(scores)
                gids = self._shard_ids[s][np.asarray(idx)]
                cell_rows += n_s          # exact: the whole scope scored
            shard_scores.append(scores)
            shard_gids.append(gids)
        all_s = np.concatenate(shard_scores, axis=1)       # (Q, sum ks)
        all_g = np.concatenate(shard_gids, axis=1)
        if len(shard_scores) > 1 or mode == "ann":         # top-k merge
            # primary key: score desc; tie-break: global id asc — equal
            # scores order identically for every reshard() topology
            # (sentinel slots sink: NEG scores with id -1)
            sort_g = np.where(all_g < 0, np.iinfo(np.int64).max, all_g)
            top = np.lexsort((sort_g, -all_s), axis=1)[:, :k]
            all_s = np.take_along_axis(all_s, top, axis=1)
            all_g = np.take_along_axis(all_g, top, axis=1)
        self.last_match_stats = {
            "mode": mode, "dtype": dtype, "rows_total": self._n,
            "centroid_rows": centroid_rows, "cell_rows": cell_rows,
            "rows_scored": centroid_rows + cell_rows,
            "scan_fraction": (centroid_rows + cell_rows) / self._n,
        }
        if tenant is not None:
            self.last_match_stats["tenant"] = tenant
            self.last_match_stats["tenant_rows"] = n_scope
        label_arr = np.asarray(self._labels, object)
        labels = np.where(all_g >= 0, label_arr[np.clip(all_g, 0, None)],
                          None)
        return labels, jnp.asarray(all_s)

    # -- topology ----------------------------------------------------------------
    def failover_shard(self, dead: int, into: Optional[int] = None) -> int:
        """A replica lane died: absorb its shard into a survivor.

        The rebuild reads the dead shard's *encrypted-at-rest* blob —
        never a decrypted ``_prep`` view — so failover works after
        ``seal()`` and a crashed lane's plaintext working set is never
        the recovery source.  Global row ids ride along, so the ANN
        codebook and per-gid cell assignments survive untouched (the
        absorbing shard's packed layout rebuilds lazily on its next ANN
        match).  The dead shard stays in the topology as an empty slot —
        matching a lane group running one replica short until the
        operator reshards.  Returns the absorbing shard's index."""
        if not 0 <= dead < self.n_shards:
            raise ValueError(f"no shard {dead}; this gallery has "
                             f"{self.n_shards}")
        if self.n_shards < 2:
            raise ValueError("cannot fail over a single-shard gallery: "
                             "no surviving shard to absorb into")
        if into is None:
            survivors = [s for s in range(self.n_shards) if s != dead]
            into = min(survivors,
                       key=lambda s: (len(self._shard_ids[s]), s))
        elif into == dead or not 0 <= into < self.n_shards:
            raise ValueError(f"bad failover target {into} for dead "
                             f"shard {dead}")
        if self._shards[dead] is not None and len(self._shard_ids[dead]):
            prot = decrypt_array(self._cipher_key, self._shards[dead])
            self._append_to_shard(into, np.asarray(prot),
                                  self._shard_ids[dead])
        self._shards[dead] = None
        self._shard_ids[dead] = np.empty((0,), np.int64)
        self._prep[dead] = {}
        self.failovers += 1
        if self.tracer is not None:
            self.tracer.instant("gallery.failover", self.tracer.clock(),
                                track="gallery", dead=dead, into=into,
                                rows=int(len(self._shard_ids[into])))
        return into

    def metrics(self) -> dict:
        """Scalar counters for the ``gallery.*`` registry namespace:
        topology, failovers, ANN maintenance, and the last match's scan
        accounting (rows_scored / scan_fraction)."""
        out = {"rows": self._n, "shards": self.n_shards,
               "failovers": self.failovers,
               "ann": dict(self.ann_stats)}
        if len(self._tenant_names) > 1:
            out["tenants"] = {str(name): n for name, n
                              in self.tenant_rows().items()
                              if name is not None}
        if self.last_match_stats:
            out["match"] = dict(self.last_match_stats)
        return out

    def reshard(self, n_shards: int):
        """Re-split the gallery across ``n_shards`` shards (mirror the lane
        group gaining/losing a replica cartridge).  The ANN codebook and
        per-row cell assignments survive untouched — only the per-shard
        packed layouts are rebuilt (lazily, on next ANN match)."""
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self._n == 0:
            self._shards = [None] * n_shards
            self._shard_ids = [np.empty((0,), np.int64)
                               for _ in range(n_shards)]
            self._prep = [{} for _ in range(n_shards)]
            return
        full = np.asarray(self.protected_gallery())
        gids = np.arange(self._n, dtype=np.int64)
        self._shards = [None] * n_shards
        self._shard_ids = [np.empty((0,), np.int64) for _ in range(n_shards)]
        self._prep = [{} for _ in range(n_shards)]
        for s, rows in enumerate(np.array_split(gids, n_shards)):
            if len(rows):
                self._append_to_shard(s, full[rows], rows)

    # -- revocation --------------------------------------------------------------
    def rekey(self, new_seed: int):
        """Cancellable biometrics: re-protect the gallery under a new key.
        The ANN codebook rides the rotation change (cosine geometry is
        rotation-invariant), so cell assignments — and recall — survive
        without retraining or reassignment."""
        assert self._n > 0, "empty gallery"
        raws = []
        for s in range(self.n_shards):
            if len(self._shard_ids[s]):
                g = decrypt_array(self._cipher_key, self._shards[s])
                raws.append(np.asarray(
                    self.rotation.unprotect(jnp.asarray(g))))
            else:
                raws.append(None)
        raw_codebook = None
        if self._ann_blob is not None:
            raw_codebook = np.asarray(self.rotation.unprotect(
                jnp.asarray(self._codebook())))
        self.rotation = KeyedRotation(self.dim, new_seed)
        self._cipher_key = jax.random.PRNGKey(new_seed ^ 0x5EC2E7)
        for s, raw in enumerate(raws):
            if raw is None:
                continue
            prot = np.asarray(self.rotation.protect(jnp.asarray(raw)))
            self._shards[s] = encrypt_array(self._cipher_key,
                                            prot.astype(np.float32))
            self._prep[s] = {}
        if raw_codebook is not None:
            codebook = np.asarray(self.rotation.protect(
                jnp.asarray(raw_codebook))).astype(np.float32)
            self._ann_blob = encrypt_array(self._cipher_key, codebook)
            self._ann_codebook = codebook
