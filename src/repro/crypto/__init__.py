from repro.crypto.templates import (KeyedRotation, cosine_scores,
                                    encrypt_bytes, decrypt_bytes,
                                    encrypt_array, decrypt_array)
from repro.crypto.gallery import SecureGallery
