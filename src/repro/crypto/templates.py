"""Cryptographically protected biometric templates (paper §3.1/§3.2).

The paper's database cartridge stores galleries encrypted and matches
templates "under encryption" with VDiSK's template-privacy layer. Two
complementary mechanisms, both pure JAX:

1. ``KeyedRotation`` — a secret orthogonal transform Q (seeded QR of a
   Threefry-generated Gaussian). Protected templates t' = Q t preserve
   inner products and norms *exactly*, so cosine-similarity matching (the
   FaceNet cartridge contract) runs directly on protected templates
   without revealing the raw embedding basis. This is the standard
   random-orthogonal-projection template-protection scheme and is the
   "homomorphic for cosine matching" property the paper invokes.
   Revocability: re-key by drawing a new Q (cancellable biometrics).

2. ``stream_cipher`` — Threefry counter-mode XOR cipher for templates and
   metadata at rest on the storage cartridge (byte-exact decrypt).

Key hygiene: keys are jax PRNG keys derived from a device secret +
gallery id; rotating either revokes every stored template.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# 1. Cosine-preserving keyed rotation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class KeyedRotation:
    dim: int
    seed: int

    def _q(self) -> jax.Array:
        g = jax.random.normal(jax.random.PRNGKey(self.seed),
                              (self.dim, self.dim), jnp.float32)
        q, r = jnp.linalg.qr(g)
        # fix signs so Q is unique given the seed (deterministic re-keying)
        return q * jnp.sign(jnp.diag(r))[None, :]

    def protect(self, t: jax.Array) -> jax.Array:
        """t: (..., dim) raw templates -> protected templates."""
        return jnp.einsum("...d,de->...e", t.astype(jnp.float32), self._q())

    def unprotect(self, tp: jax.Array) -> jax.Array:
        return jnp.einsum("...e,de->...d", tp.astype(jnp.float32), self._q())


def cosine_scores(queries: jax.Array, gallery: jax.Array) -> jax.Array:
    """(Q,d) x (N,d) -> (Q,N) cosine similarity (works on protected or raw
    templates identically when both sides share the same KeyedRotation)."""
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
    gn = gallery / jnp.maximum(
        jnp.linalg.norm(gallery, axis=-1, keepdims=True), 1e-9)
    return qn @ gn.T


# ---------------------------------------------------------------------------
# 2. Threefry counter-mode stream cipher (encryption at rest)
# ---------------------------------------------------------------------------
def _keystream(key: jax.Array, n_words: int) -> jax.Array:
    """n_words of uint32 keystream from the jax Threefry PRNG."""
    return jax.random.bits(key, (n_words,), jnp.uint32)


def encrypt_bytes(key: jax.Array, data: bytes) -> np.ndarray:
    buf = np.frombuffer(data, np.uint8)
    pad = (-len(buf)) % 4
    buf = np.pad(buf, (0, pad))
    words = buf.view(np.uint32)
    ks = np.asarray(_keystream(key, len(words)))
    enc = (words ^ ks).view(np.uint8)
    return np.concatenate([enc, np.array([pad], np.uint8)])


def decrypt_bytes(key: jax.Array, blob: np.ndarray) -> bytes:
    pad = int(blob[-1])
    words = blob[:-1].view(np.uint32)
    ks = np.asarray(_keystream(key, len(words)))
    dec = (words ^ ks).view(np.uint8)
    return dec[: len(dec) - pad].tobytes()


def encrypt_array(key: jax.Array, x: np.ndarray) -> dict:
    blob = encrypt_bytes(key, np.ascontiguousarray(x).tobytes())
    return {"blob": blob, "shape": x.shape, "dtype": str(x.dtype)}


def decrypt_array(key: jax.Array, enc: dict) -> np.ndarray:
    raw = decrypt_bytes(key, enc["blob"])
    return np.frombuffer(raw, enc["dtype"]).reshape(enc["shape"]).copy()
