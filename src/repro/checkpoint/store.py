"""Sharded, async checkpointing with atomic commit + restart-from-failure.

Production shape: each host writes only the array shards it owns (here:
the process-local slice of every leaf), snapshots are written to a temp
directory and committed by atomic rename, a manifest records the step and
pytree structure, and saves run on a background thread so the train loop
never blocks (double-buffered: at most one in-flight save).

Restore picks the newest *committed* step — a crash mid-save can never
corrupt the restore point (the paper's hot-swap resilience, applied to
training state).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointStore:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, *, block: bool = False):
        """Snapshot ``tree`` at ``step``. Async by default; at most one save
        in flight (joins the previous one first — double buffering)."""
        self.wait()
        # device_get under the caller (values captured before training moves on)
        flat = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        t = threading.Thread(target=self._write, args=(step, flat),
                             daemon=True)
        t.start()
        self._thread = t
        if block:
            self.wait()

    def _write(self, step: int, flat: dict):
        tmp = os.path.join(self.root, f".tmp-{step}-{os.getpid()}")
        final = os.path.join(self.root, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shards.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(flat),
                       "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self.save_count += 1
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). Returns (step, tree)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.root}")
        path = os.path.join(self.root, f"step_{step:010d}", "shards.npz")
        data = np.load(path)
        flat_like = _flatten(like)
        missing = set(flat_like) - set(data.files)
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}")
        leaves, treedef = jax.tree_util.tree_flatten(like)
        keys = list(_flatten(like).keys())
        vals = [jax.numpy.asarray(data[k]) for k in keys]
        return step, jax.tree_util.tree_unflatten(treedef, vals)
