"""whisper-base [arXiv:2212.04356; unverified] — enc-dec, conv frontend STUB
(input_specs supplies (B, 1500, 512) frame embeddings), sinusoid positions."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
    rope_theta=0.0, act="gelu", mlp_gated=False, is_encdec=True,
    encoder_layers=6, encoder_len=1500, tie_embeddings=True,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, encoder_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                          encoder_len=24, remat=False)
