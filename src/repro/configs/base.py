"""Architecture config schema + registry.

Every assigned architecture gets one module in this package defining
``CONFIG`` (exact published numbers) and ``smoke()`` (a reduced config of the
same family for CPU tests). ``get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention variant ---
    attn_kind: str = "gqa"  # gqa | mla
    rope_theta: float = 1e4
    sliding_window: int = 0  # 0 -> full attention
    local_global_pattern: int = 0  # e.g. 5 -> 5 local : 1 global (gemma3)
    rope_theta_global: float = 0.0  # gemma3 global layers

    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-expert hidden
    first_dense_layers: int = 0
    router_aux_coef: float = 0.001
    mtp: bool = False  # deepseek-v3 multi-token prediction head
    capacity_factor: float = 1.25  # per-expert slots = load * cf (cf>=E exact)

    # --- SSM / hybrid ---
    block_kind: str = "attn"  # attn | mamba | xlstm
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0  # zamba2: shared attn block period
    slstm_every: int = 0  # xlstm: sLSTM block period

    # --- encoder/decoder, modality stubs ---
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_len: int = 1500  # whisper frame count after conv stub
    n_patches: int = 0  # internvl2 prepended patch embeddings

    # --- misc ---
    norm_eps: float = 1e-6
    act: str = "silu"
    mlp_gated: bool = True
    vit_dim: int = 0  # vlm patch-embedding dim (frontend stub output)
    norm_kind: str = "rms"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bf16"  # "int8": quantized serving cache (2x HBM)
    expert_weights_dtype: str = "bf16"  # "int8": weight-only quant (serving)
    remat: bool = True
    # full remat by default: inside scan-over-layers only the (B,S,d) carry
    # is saved; "dots_with_no_batch_dims_saveable" keeps every projection
    # output alive across 40-60 layers (tens of GiB/device at 4k x 256).
    remat_policy: str = "nothing_saveable"
    superblock: int = 1  # layers per scan step (heterogeneous patterns)
    notes: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_superblocks(self) -> int:
        core = self.n_layers - self.first_dense_layers
        assert core % self.superblock == 0, (self.name, core, self.superblock)
        return core // self.superblock

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM-family arch gets the same 4 shape specs.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "zamba2-2.7b",
    "codeqwen1.5-7b",
    "gemma3-12b",
    "starcoder2-15b",
    "tinyllama-1.1b",
    "deepseek-v2-236b",
    "deepseek-v3-671b",
    "internvl2-26b",
    "whisper-base",
    "xlstm-1.3b",
]


def _module(name: str):
    return importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def smoke(name: str) -> ArchConfig:
    return _module(name).smoke()


def supports_shape(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run cell; else reason for skip."""
    if shape == "long_500k":
        sub_quadratic = cfg.block_kind in ("mamba", "xlstm") or (
            cfg.local_global_pattern > 0
        )
        if not sub_quadratic:
            return False, "pure full-attention arch: 500k decode is skipped per brief"
    return True, ""
