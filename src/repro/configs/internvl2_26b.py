"""internvl2-26b [arXiv:2404.16821; hf] — InternViT frontend (STUB: 256
precomputed patch embeddings of dim 3200) + InternLM2-20B-class backbone."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92553,
    rope_theta=1e6, n_patches=256, vit_dim=3200,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                          d_ff=256, vocab_size=256, n_patches=8, vit_dim=32,
                          remat=False)
