"""gemma3-12b [hf:google/gemma-3-*-pt; unverified] — 5 local : 1 global.

head_dim derived from the brief's d_model/n_heads = 240 (the HF release uses
256; the brief's numbers take precedence). Local layers: sliding window 1024,
theta 10k. Global layers: full attention, theta 1M.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="gemma3", n_layers=48, d_model=3840,
    n_heads=16, n_kv_heads=8, d_ff=15360, vocab_size=262144,
    rope_theta=1e4, rope_theta_global=1e6,
    sliding_window=1024, local_global_pattern=5, superblock=6,
    act="gelu", tie_embeddings=True,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=160, vocab_size=256, sliding_window=8,
                          remat=False)
