"""starcoder2-15b [arXiv:2402.19173; hf] — GQA kv=4, RoPE, non-gated GELU."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=4, d_ff=24576, vocab_size=49152,
    rope_theta=1e5, act="gelu", mlp_gated=False,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                          d_ff=256, vocab_size=256, remat=False)
