"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B] — qwen1.5-arch, MHA (kv=H)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=13440, vocab_size=92416,
    rope_theta=1e6,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=192, vocab_size=256, remat=False)
