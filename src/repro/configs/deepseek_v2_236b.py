"""deepseek-v2-236b [arXiv:2405.04434; hf] — MLA kv_lora=512, 2 shared +
160 routed experts top-6, first layer dense (d_ff 12288; per-expert 1536)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=12288, vocab_size=102400,
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, experts_per_token=6,
    moe_d_ff=1536, first_dense_layers=1, rope_theta=1e4,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=256, q_lora_rank=32,
                          kv_lora_rank=32, qk_nope_head_dim=16,
                          qk_rope_head_dim=8, v_head_dim=16, n_experts=8,
                          experts_per_token=2, moe_d_ff=64,
                          first_dense_layers=1, remat=False,
                          capacity_factor=16.0)  # dropless at smoke scale
