"""deepseek-v3-671b [arXiv:2412.19437; hf] — MLA, 1 shared + 256 routed
top-8, first 3 layers dense (d_ff 18432; per-expert 2048), MTP depth-1."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, d_ff=18432, vocab_size=129280,
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    n_experts=256, n_shared_experts=1, experts_per_token=8,
    moe_d_ff=2048, first_dense_layers=3, mtp=True, rope_theta=1e4,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=256, q_lora_rank=32,
                          kv_lora_rank=32, qk_nope_head_dim=16,
                          qk_rope_head_dim=8, v_head_dim=16, n_experts=8,
                          experts_per_token=2, moe_d_ff=64,
                          first_dense_layers=1, remat=False,
                          capacity_factor=16.0)  # dropless at smoke scale
