"""tinyllama-1.1b [arXiv:2401.02385; hf] — llama2-arch small."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=5632, vocab_size=32000,
    rope_theta=1e4,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=160, vocab_size=256, remat=False)
