"""zamba2-2.7b [arXiv:2411.15242; hf] — Mamba2 backbone + weight-tied shared
attention block applied every 6 mamba layers (9 applications over 54 layers).

d_ff=10240 is the shared block's MLP. ssm: expand 2 (d_inner 5120),
headdim 64 (80 ssm heads), state 64, conv 4. The per-application LoRA on the
shared block from the paper is omitted (DESIGN.md §7).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000,
    rope_theta=1e4, block_kind="mamba", ssm_state=64, ssm_expand=2,
    ssm_headdim=64, ssm_conv=4, attn_every=6, superblock=6,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=256, ssm_state=16,
                          ssm_headdim=16, attn_every=2, superblock=2,
                          remat=False)
