"""xlstm-1.3b [arXiv:2405.04517; unverified] — mLSTM + sLSTM blocks.

Superblock of 6 = 5 mLSTM (matrix memory, chunkwise-parallel) + 1 sLSTM
(scalar memory, sequential scan). d_ff=0 per the brief: projections live
inside the blocks (mLSTM up-factor 2; sLSTM carries a 4/3 gated FFN).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    block_kind="xlstm", slstm_every=6, superblock=6,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                          vocab_size=256, slstm_every=2, superblock=2,
                          remat=False)
