"""Capability cartridges: self-describing, hot-swappable AI stages.

A ``Cartridge`` binds (1) a typed consume/produce contract, (2) a jitted JAX
compute fn with its params, (3) a *device model* (service time, bytes moved,
power) used by the bus simulator and power accounting, and (4) lifecycle
hooks (load/warmup = the paper's "reloading the model on the stick", which
dominates the 2 s re-insert pause).

``capability_id`` mirrors the paper's predefined per-function codes.
"""
from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import messages as msg


@dataclass
class DeviceModel:
    """Calibrated accelerator model (per NCS2/Coral/cartridge type)."""
    name: str = "ncs2"
    service_s: float = 1 / 15.0  # per-frame compute time at batch 1
    host_overhead_s: float = 0.004  # per-transfer host CPU dispatch cost
    power_w: float = 1.8  # draw while running (paper §4.3: 1-2 W)
    idle_w: float = 0.3
    load_s: float = 1.5  # model (re)load on insert — bulk of the 2 s pause
    # Marginal service cost of each extra frame in a micro-batch, as a
    # fraction of service_s (activations stream through the on-stick model
    # back-to-back, so per-frame dispatch overhead amortizes).  1.0 = no
    # batching benefit.
    batch_marginal: float = 0.7
    # Heavy-tail service jitter: with probability ``jitter_p`` a service
    # cycle stalls to ``jitter_mult x`` its nominal time (USB re-enumeration
    # hiccups, on-stick thermal throttling — the stragglers that hedged
    # dispatch exists to absorb).  The draw is a deterministic hash of
    # (lane, frame seq), so simulations stay replayable.  Defaults off:
    # calibrated Table 1 devices are jitter-free.
    jitter_p: float = 0.0
    jitter_mult: float = 10.0
    # Thermal calibration (§4.3 power governor).  ``therm_tau_s`` is the
    # stick's thermal time constant: the smoothing horizon over which the
    # governor estimates a hub's electrical draw (enclosure heat mass —
    # a bare USB stick in free air settles within ~a second).
    # ``min_duty`` is the deepest duty cycle throttling may impose before
    # the governor parks the hub instead: below it the per-frame latency
    # stretch stops being worth the trickle of throughput.
    therm_tau_s: float = 1.0
    min_duty: float = 0.2


class Cartridge:
    """Base class. Subclasses set contract + fn; instances are hot-swappable."""

    capability_id: int = 0
    name: str = "cartridge"
    consumes: msg.MessageSpec = msg.MessageSpec(msg.IMAGE_FRAME)
    produces: msg.MessageSpec = msg.MessageSpec(msg.IMAGE_FRAME)

    def __init__(self, params: Any = None, device: Optional[DeviceModel] = None,
                 name: Optional[str] = None):
        self.params = params
        self.device = device or DeviceModel()
        if name:
            self.name = name
        self._fn = None
        self._loaded = False
        self._clone_seq = 0
        self.stats = {"processed": 0, "busy_s": 0.0}

    # -- lifecycle ----------------------------------------------------------
    def load(self) -> float:
        """Flash/compile the cartridge. Returns load time (s)."""
        t0 = time.perf_counter()
        self._fn = jax.jit(self.fn)
        self.warmup()
        self._loaded = True
        return time.perf_counter() - t0

    def unload(self):
        self._fn = None
        self._loaded = False

    def warmup(self):
        ex = self.example_input()
        if ex is not None:
            jax.block_until_ready(self._fn(self.params, ex))

    def example_input(self):
        sh = self.consumes.shape
        if sh is None or any(s is None for s in sh):
            return None
        dt = self.consumes.dtype or np.float32
        return np.zeros(sh, dt)

    # -- replication ---------------------------------------------------------
    def clone(self, name: Optional[str] = None,
              device: Optional[DeviceModel] = None) -> "Cartridge":
        """A replica of this cartridge on another physical device.

        Shares the (immutable) params and compiled fn — the same bitstream
        flashed onto a second stick — but carries its own identity,
        runtime stats, and **its own DeviceModel copy**: two sticks never
        share a calibration record, so per-device mutation (thermal
        state, calibration drift) cannot silently alias across sibling
        lanes.  Pass ``device`` to flash it onto a *different*
        accelerator type (heterogeneous lane group: e.g. an NCS2 primary
        with Coral replicas); the contract stays identical, only the
        calibrated service model changes, and the engine's weighted
        dispatcher uses it as each lane's seed estimate.

        Auto-names are deterministic *per parent* (``name#r1``,
        ``name#r2``, ...), not drawn from a process-global counter, so
        the engine's crc32(lane, seq) jitter draws replay identically
        no matter what else the process cloned first.
        """
        self._clone_seq += 1
        rep = copy.copy(self)
        rep.stats = {"processed": 0, "busy_s": 0.0}
        rep._clone_seq = 0             # the replica numbers its own clones
        rep.name = name or f"{self.name}#r{self._clone_seq}"
        rep.device = copy.copy(device if device is not None else self.device)
        return rep

    # -- compute ------------------------------------------------------------
    def fn(self, params, x):  # override
        raise NotImplementedError

    def process(self, m: msg.Message) -> msg.Message:
        assert self._loaded, f"{self.name}: process() before load()"
        t0 = time.perf_counter()
        out = jax.block_until_ready(self._fn(self.params, m.payload))
        self.stats["busy_s"] += time.perf_counter() - t0
        self.stats["processed"] += 1
        return m.with_payload(out, self.produces.kind)

    def process_batch(self, ms: list) -> list:
        """Service one engine micro-batch.  Default is frame-at-a-time;
        batched stage types (e.g. the watchlist match stage) override this
        to coalesce the whole batch into a single kernel dispatch."""
        return [self.process(m) if m.payload is not None else m for m in ms]

    # -- handshake (paper §3.2: capability ID + data format) -----------------
    def handshake(self) -> dict:
        return {
            "capability_id": self.capability_id,
            "name": self.name,
            "consumes": self.consumes,
            "produces": self.produces,
            "device": self.device.name,
        }

    def __repr__(self):
        return (f"<{type(self).__name__} {self.name} "
                f"{self.consumes.describe()}->{self.produces.describe()}>")


class FnCartridge(Cartridge):
    """Wrap an arbitrary (params, x) -> y JAX fn as a cartridge."""

    def __init__(self, name, fn, consumes, produces, params=None,
                 capability_id=99, device=None):
        self._user_fn = fn
        self.capability_id = capability_id
        super().__init__(params=params, device=device, name=name)
        self.consumes = consumes
        self.produces = produces

    def fn(self, params, x):
        return self._user_fn(params, x)


class PassThrough(Cartridge):
    """VDiSK's bridge stage: inserted when a removed cartridge's gap is
    type-compatible (paper §2.3: 'receives a default pass-through')."""

    capability_id = 0
    name = "bridge"

    def __init__(self, spec: msg.MessageSpec):
        super().__init__()
        self.consumes = spec
        self.produces = spec

    def fn(self, params, x):
        return x

    def load(self) -> float:
        self._fn = lambda p, x: x
        self._loaded = True
        return 0.0

    def process(self, m: msg.Message) -> msg.Message:
        self.stats["processed"] += 1
        return m
