"""Typed message contracts between cartridges (the CHAMP bus framing).

Every payload traveling the bus is a ``Message``: a sequence-numbered, typed
pytree. Cartridges advertise ``consumes``/``produces`` as ``MessageSpec``s;
VDiSK type-checks chains at registration time (paper §3.2: "a common protocol
for data exchange ... framing for messages ... tagged with metadata about
type and size").
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Tuple

import jax
import numpy as np

# Canonical message kinds (paper §3.2 cartridge list)
IMAGE_FRAME = "image_frame"          # (H, W, 3) uint8/float
BBOXES = "bboxes"                    # (N, 5) [x0,y0,x1,y1,score]
FACE_CROPS = "face_crops"            # (N, h, w, 3)
EMBEDDING = "embedding"              # (N, D) float
QUALITY = "quality"                  # (N,) float
MATCH_RESULT = "match_result"        # (N, k) ids + scores
TOKENS = "tokens"                    # (S,) int32 (document/NLP cartridges)
LOGITS = "logits"
ENCRYPTED_BLOB = "encrypted_blob"


@dataclass(frozen=True)
class MessageSpec:
    """A typed port: message kind + array schema (None entries = wildcard)."""
    kind: str
    shape: Optional[Tuple[Optional[int], ...]] = None
    dtype: Any = None

    def accepts(self, other: "MessageSpec") -> bool:
        if self.kind != other.kind:
            return False
        if self.shape is not None and other.shape is not None:
            if len(self.shape) != len(other.shape):
                return False
            for a, b in zip(self.shape, other.shape):
                if a is not None and b is not None and a != b:
                    return False
        if self.dtype is not None and other.dtype is not None:
            if np.dtype(self.dtype) != np.dtype(other.dtype):
                return False
        return True

    def describe(self) -> str:
        return f"{self.kind}{list(self.shape) if self.shape else ''}"


@dataclass
class Message:
    """One bus message. ``payload`` is a pytree of arrays."""
    kind: str
    seq: int
    payload: Any
    meta: dict = field(default_factory=dict)
    t_created: float = 0.0

    def nbytes(self) -> int:
        total = 0
        for x in jax.tree.leaves(self.payload):
            if hasattr(x, "shape") and hasattr(x, "dtype"):
                total += int(np.prod(x.shape) * np.dtype(x.dtype).itemsize)
            elif isinstance(x, (bytes, str)):
                total += len(x)
            else:
                total += 8
        return total

    def with_payload(self, payload, kind=None) -> "Message":
        return dataclasses.replace(self, payload=payload,
                                   kind=kind or self.kind)


class TypeError_(Exception):
    """Chain type mismatch (named to avoid shadowing builtins)."""
