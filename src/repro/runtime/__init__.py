from repro.runtime.registry import CapabilityRegistry, SlotRecord
from repro.runtime.engine import StreamEngine, EngineReport, validate_chain
from repro.runtime.health import HealthMonitor
from repro.runtime.elastic import ElasticController, largest_mesh
