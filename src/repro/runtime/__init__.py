from repro.runtime.registry import CapabilityRegistry, SlotRecord
from repro.runtime.engine import (ENGINE_CORES, StreamEngine, EngineReport,
                                  validate_chain)
from repro.runtime.events import HeapEventQueue, ListEventQueue
from repro.runtime.lanestate import LaneStateBank, MeterBank, SoABank
from repro.runtime.faults import (FaultEvent, FaultPlan, QuarantinePolicy,
                                  RetryPolicy, frame_checksum)
from repro.runtime.frontdoor import FrontDoor, Tenant, class_name
from repro.runtime.metrics import StreamingHistogram
from repro.runtime.power import PowerGovernor
from repro.runtime.trace import FlightRecorder, MetricsRegistry, jsonable
from repro.runtime.replication import (FLEET_SPLIT, FLEET_TENANTS,
                                       build_battery_engine,
                                       build_chaos_engine,
                                       build_cross_hub_hedge_engine,
                                       build_fabric_engine,
                                       build_fleet_engine,
                                       build_lane_sweep_engine,
                                       build_mixed_engine,
                                       build_replicated_engine,
                                       build_routed_pipeline_engine,
                                       chaos_lane_names,
                                       engine_broadcast_fps,
                                       engine_shard_fps,
                                       fabric_shard_fps,
                                       fleet_capacity_fps,
                                       make_inference_cartridge,
                                       run_battery,
                                       run_chaos,
                                       run_fabric,
                                       run_fleet_sweep,
                                       run_replicated)
from repro.runtime.health import HealthMonitor, QuarantineLedger, quantile
from repro.runtime.elastic import ElasticController, largest_mesh
