"""Capability registry — VDiSK's view of what is plugged into the bus.

Mirrors the paper's §3.2 handshake: on insertion a cartridge reports its
capability ID and data format; the registry records it and notifies
listeners (the engine rebuilds its pipeline routing on these events, the
way VDiSK reacts to USB attach/detach + Zeroconf announcements).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.cartridge import Cartridge


@dataclass
class SlotRecord:
    slot: int
    cartridge: Cartridge
    handshake: dict
    inserted_at: float = 0.0


class CapabilityRegistry:
    def __init__(self):
        self.slots: Dict[int, SlotRecord] = {}
        self._listeners: List[Callable[[str, SlotRecord], None]] = []

    # -- discovery events ----------------------------------------------------
    def insert(self, slot: int, cart: Cartridge, t: float = 0.0) -> SlotRecord:
        if slot in self.slots:
            raise ValueError(f"slot {slot} occupied by "
                             f"{self.slots[slot].cartridge.name}")
        rec = SlotRecord(slot, cart, cart.handshake(), inserted_at=t)
        self.slots[slot] = rec
        for fn in self._listeners:
            fn("insert", rec)
        return rec

    def remove(self, slot: int, t: float = 0.0) -> SlotRecord:
        rec = self.slots.pop(slot)
        for fn in self._listeners:
            fn("remove", rec)
        return rec

    def subscribe(self, fn: Callable[[str, SlotRecord], None]):
        self._listeners.append(fn)

    # -- queries --------------------------------------------------------------
    def chain(self) -> List[Cartridge]:
        """Cartridges in physical slot order (the paper's default pipeline)."""
        return [self.slots[s].cartridge for s in sorted(self.slots)]

    def find(self, capability_id: int) -> Optional[Cartridge]:
        for rec in self.slots.values():
            if rec.handshake["capability_id"] == capability_id:
                return rec.cartridge
        return None
