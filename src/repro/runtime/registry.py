"""Capability registry — VDiSK's view of what is plugged into the bus.

Mirrors the paper's §3.2 handshake: on insertion a cartridge reports its
capability ID and data format; the registry records it and notifies
listeners (the engine rebuilds its pipeline routing on these events, the
way VDiSK reacts to USB attach/detach + Zeroconf announcements).

A slot is a *lane group*: it may hold several replica cartridges of the
same capability (the paper's §4.1 broadcast experiment plugs up to five
identical accelerators into one hub).  ``SlotRecord.replicas`` lists every
physical device backing the slot; ``SlotRecord.cartridge`` stays the
primary replica for backward compatibility.  ``mode`` selects how the
engine dispatches over the replicas:

  * ``"shard"``     — frames are load-balanced across replicas
                      (throughput scaling);
  * ``"broadcast"`` — every frame goes to every replica (Table 1's
                      redundant-inference experiment).

Replicas need not be the same accelerator type: a slot may mix e.g. one
NCS2 with two Corals (heterogeneous lane group) as long as every replica
speaks the primary's contract.  The engine's weighted dispatcher reads
each replica's ``DeviceModel`` as its service-time seed, so a slow stick
carries proportionally less of the slot's load instead of gating it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.cartridge import Cartridge

DISPATCH_MODES = ("shard", "broadcast")


@dataclass
class SlotRecord:
    slot: int
    cartridge: Cartridge              # primary replica (compat accessor)
    handshake: dict
    inserted_at: float = 0.0
    mode: str = "shard"
    replicas: List[Cartridge] = field(default_factory=list)

    def __post_init__(self):
        if not self.replicas:
            self.replicas = [self.cartridge]

    def devices(self) -> List[str]:
        """Accelerator type of each replica lane, in lane order."""
        return [c.device.name for c in self.replicas]

    def heterogeneous(self) -> bool:
        """True when the slot mixes accelerator types (or calibrations)."""
        return len({(c.device.name, c.device.service_s)
                    for c in self.replicas}) > 1


def _compatible_replica(primary: Cartridge, cart: Cartridge) -> bool:
    """A replica must speak the primary's exact contract (same capability,
    interchangeable consume/produce specs) or the dispatcher could route a
    frame to a device that cannot process it."""
    return (cart.capability_id == primary.capability_id
            and cart.consumes.accepts(primary.consumes)
            and primary.consumes.accepts(cart.consumes)
            and cart.produces.accepts(primary.produces)
            and primary.produces.accepts(cart.produces))


class CapabilityRegistry:
    def __init__(self):
        self.slots: Dict[int, SlotRecord] = {}
        self._listeners: List[Callable[[str, SlotRecord], None]] = []

    # -- discovery events ----------------------------------------------------
    def insert(self, slot: int, cart: Cartridge, t: float = 0.0,
               mode: str = "shard") -> SlotRecord:
        if slot in self.slots:
            raise ValueError(f"slot {slot} occupied by "
                             f"{self.slots[slot].cartridge.name}")
        if mode not in DISPATCH_MODES:
            raise ValueError(f"unknown dispatch mode {mode!r}")
        rec = SlotRecord(slot, cart, cart.handshake(), inserted_at=t,
                         mode=mode)
        self.slots[slot] = rec
        for fn in self._listeners:
            fn("insert", rec)
        return rec

    def remove(self, slot: int, t: float = 0.0) -> SlotRecord:
        rec = self.slots.pop(slot)
        for fn in self._listeners:
            fn("remove", rec)
        return rec

    def add_replica(self, slot: int, cart: Cartridge,
                    t: float = 0.0) -> SlotRecord:
        """Plug an additional device of the slot's capability into the hub."""
        rec = self.slots[slot]
        for other in self.slots.values():
            if cart in other.replicas:
                raise ValueError(
                    f"{cart.name} is already plugged into slot "
                    f"{other.slot}; clone() it for another physical device")
        if not _compatible_replica(rec.cartridge, cart):
            raise ValueError(
                f"replica {cart.name} incompatible with slot {slot} "
                f"({rec.cartridge.name}: "
                f"{rec.cartridge.consumes.describe()}->"
                f"{rec.cartridge.produces.describe()})")
        rec.replicas.append(cart)
        for fn in self._listeners:
            fn("add_replica", rec)
        return rec

    def remove_replica(self, slot: int, cart: Optional[Cartridge] = None,
                       t: float = 0.0) -> SlotRecord:
        """Unplug one replica.  Removing the last replica removes the slot
        (equivalent to ``remove``, with its bridge/halt consequences)."""
        rec = self.slots[slot]
        victim = cart if cart is not None else rec.replicas[-1]
        if victim not in rec.replicas:
            raise ValueError(f"{victim.name} not a replica of slot {slot}")
        if len(rec.replicas) == 1:
            return self.remove(slot, t)
        rec.replicas.remove(victim)
        if rec.cartridge is victim:          # promote a surviving replica
            rec.cartridge = rec.replicas[0]
            rec.handshake = rec.cartridge.handshake()
        for fn in self._listeners:
            fn("remove_replica", rec)
        return rec

    def subscribe(self, fn: Callable[[str, SlotRecord], None]):
        self._listeners.append(fn)

    # -- queries --------------------------------------------------------------
    def chain(self) -> List[Cartridge]:
        """Primary cartridges in physical slot order (the paper's default
        pipeline; replicas share the primary's contract)."""
        return [self.slots[s].cartridge for s in sorted(self.slots)]

    def records(self) -> List[SlotRecord]:
        """Slot records in physical slot order (one per lane group)."""
        return [self.slots[s] for s in sorted(self.slots)]

    def n_replicas(self, slot: int) -> int:
        return len(self.slots[slot].replicas)

    def slot_devices(self, slot: int) -> List[str]:
        """Per-lane accelerator types backing a slot (dispatch telemetry)."""
        return self.slots[slot].devices()

    def n_endpoints(self) -> int:
        """Total physical devices on the bus (arbitration contention)."""
        return sum(len(r.replicas) for r in self.slots.values())

    def find(self, capability_id: int) -> Optional[Cartridge]:
        for rec in self.slots.values():
            if rec.handshake["capability_id"] == capability_id:
                return rec.cartridge
        return None
