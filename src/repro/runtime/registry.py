"""Capability registry — VDiSK's view of what is plugged into the bus.

Mirrors the paper's §3.2 handshake: on insertion a cartridge reports its
capability ID and data format; the registry records it and notifies
listeners (the engine rebuilds its pipeline routing on these events, the
way VDiSK reacts to USB attach/detach + Zeroconf announcements).

A slot is a *lane group*: it may hold several replica cartridges of the
same capability (the paper's §4.1 broadcast experiment plugs up to five
identical accelerators into one hub).  ``SlotRecord.replicas`` lists every
physical device backing the slot; ``SlotRecord.cartridge`` stays the
primary replica for backward compatibility.  ``mode`` selects how the
engine dispatches over the replicas:

  * ``"shard"``     — frames are load-balanced across replicas
                      (throughput scaling);
  * ``"broadcast"`` — every frame goes to every replica (Table 1's
                      redundant-inference experiment).

Replicas need not be the same accelerator type: a slot may mix e.g. one
NCS2 with two Corals (heterogeneous lane group) as long as every replica
speaks the primary's contract.  The engine's weighted dispatcher reads
each replica's ``DeviceModel`` as its service-time seed, so a slow stick
carries proportionally less of the slot's load instead of gating it.

Hub placement (multi-hub fabric).  Each physical device plugs into one
hub of the bus fabric; ``insert`` / ``add_replica`` take a ``hub`` id
(default: hub 0 / the primary's hub) and the registry tracks the
device -> hub map, so lane groups can *span* hubs and the engine's
router can charge each transfer to the right arbitration domain
(``n_endpoints`` contention is per hub, not fleet-wide).

Quorum broadcast.  A ``broadcast`` slot may carry ``quorum=k``: the
engine decides each frame at the k-th replica completion instead of the
slowest, suppressing the stragglers' result handoffs — Table 1
redundancy at shard-like tails.  ``quorum=None`` (or ``k >= N``) is the
paper's full-barrier semantics, bit-identical to Table 1.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.cartridge import Cartridge

DISPATCH_MODES = ("shard", "broadcast")


@dataclass
class SlotRecord:
    slot: int
    cartridge: Cartridge              # primary replica (compat accessor)
    handshake: dict
    inserted_at: float = 0.0
    mode: str = "shard"
    replicas: List[Cartridge] = field(default_factory=list)
    quorum: Optional[int] = None      # broadcast: first k of N results win

    def __post_init__(self):
        if not self.replicas:
            self.replicas = [self.cartridge]

    def devices(self) -> List[str]:
        """Accelerator type of each replica lane, in lane order."""
        return [c.device.name for c in self.replicas]

    def heterogeneous(self) -> bool:
        """True when the slot mixes accelerator types (or calibrations)."""
        return len({(c.device.name, c.device.service_s)
                    for c in self.replicas}) > 1


def _compatible_replica(primary: Cartridge, cart: Cartridge) -> bool:
    """A replica must speak the primary's exact contract (same capability,
    interchangeable consume/produce specs) or the dispatcher could route a
    frame to a device that cannot process it."""
    return (cart.capability_id == primary.capability_id
            and cart.consumes.accepts(primary.consumes)
            and primary.consumes.accepts(cart.consumes)
            and cart.produces.accepts(primary.produces)
            and primary.produces.accepts(cart.produces))


class CapabilityRegistry:
    def __init__(self):
        self.slots: Dict[int, SlotRecord] = {}
        self._listeners: List[Callable[[str, SlotRecord], None]] = []
        self._hub_of: Dict[int, int] = {}    # id(cartridge) -> hub id
        self._hub_counts: Dict[int, int] = {}  # hub id -> plugged devices
        self._failed: set = set()            # id(cartridge), powered off
        self._failed_on: Dict[int, int] = {}  # hub id -> failed devices

    def _hub_plug(self, cart: Cartridge, hub: int):
        self._hub_of[id(cart)] = hub
        self._hub_counts[hub] = self._hub_counts.get(hub, 0) + 1

    def _hub_unplug(self, cart: Cartridge):
        if id(cart) in self._failed:         # unplugging clears fault state
            self.set_failed(cart, False)
        hub = self._hub_of.pop(id(cart), None)
        if hub is not None:
            n = self._hub_counts.get(hub, 0) - 1
            if n > 0:
                self._hub_counts[hub] = n
            else:
                self._hub_counts.pop(hub, None)

    # -- discovery events ----------------------------------------------------
    def insert(self, slot: int, cart: Cartridge, t: float = 0.0,
               mode: str = "shard", hub: int = 0,
               quorum: Optional[int] = None) -> SlotRecord:
        if slot in self.slots:
            raise ValueError(f"slot {slot} occupied by "
                             f"{self.slots[slot].cartridge.name}")
        if mode not in DISPATCH_MODES:
            raise ValueError(f"unknown dispatch mode {mode!r}")
        if quorum is not None:
            if mode != "broadcast":
                raise ValueError("quorum only applies to broadcast slots")
            if quorum < 1:
                raise ValueError(f"quorum must be >= 1, got {quorum}")
        rec = SlotRecord(slot, cart, cart.handshake(), inserted_at=t,
                         mode=mode, quorum=quorum)
        self.slots[slot] = rec
        self._hub_plug(cart, hub)
        for fn in self._listeners:
            fn("insert", rec)
        return rec

    def remove(self, slot: int, t: float = 0.0) -> SlotRecord:
        rec = self.slots.pop(slot, None)
        if rec is None:
            raise ValueError(
                f"slot {slot} is not occupied; plugged slots: "
                f"{sorted(self.slots) or 'none'}")
        for cart in rec.replicas:
            self._hub_unplug(cart)
        for fn in self._listeners:
            fn("remove", rec)
        return rec

    def add_replica(self, slot: int, cart: Cartridge,
                    t: float = 0.0, hub: Optional[int] = None) -> SlotRecord:
        """Plug an additional device of the slot's capability into a hub
        (default: the primary's hub; pass ``hub=`` to span the fabric)."""
        rec = self.slots[slot]
        for other in self.slots.values():
            if cart in other.replicas:
                raise ValueError(
                    f"{cart.name} is already plugged into slot "
                    f"{other.slot}; clone() it for another physical device")
        if not _compatible_replica(rec.cartridge, cart):
            raise ValueError(
                f"replica {cart.name} incompatible with slot {slot} "
                f"({rec.cartridge.name}: "
                f"{rec.cartridge.consumes.describe()}->"
                f"{rec.cartridge.produces.describe()})")
        rec.replicas.append(cart)
        self._hub_plug(cart, hub if hub is not None
                       else self.hub_of(rec.cartridge))
        for fn in self._listeners:
            fn("add_replica", rec)
        return rec

    def remove_replica(self, slot: int, cart: Optional[Cartridge] = None,
                       t: float = 0.0) -> SlotRecord:
        """Unplug one replica.  Removing the last replica removes the slot
        (equivalent to ``remove``, with its bridge/halt consequences)."""
        rec = self.slots.get(slot)
        if rec is None:
            raise ValueError(
                f"slot {slot} is not occupied; plugged slots: "
                f"{sorted(self.slots) or 'none'}")
        victim = cart if cart is not None else rec.replicas[-1]
        if victim not in rec.replicas:
            raise ValueError(f"{victim.name} not a replica of slot {slot}")
        if len(rec.replicas) == 1:
            return self.remove(slot, t)
        rec.replicas.remove(victim)
        self._hub_unplug(victim)
        if rec.cartridge is victim:          # promote a surviving replica
            rec.cartridge = rec.replicas[0]
            rec.handshake = rec.cartridge.handshake()
        for fn in self._listeners:
            fn("remove_replica", rec)
        return rec

    def subscribe(self, fn: Callable[[str, SlotRecord], None]):
        self._listeners.append(fn)

    # -- queries --------------------------------------------------------------
    def chain(self) -> List[Cartridge]:
        """Primary cartridges in physical slot order (the paper's default
        pipeline; replicas share the primary's contract)."""
        return [self.slots[s].cartridge for s in sorted(self.slots)]

    def records(self) -> List[SlotRecord]:
        """Slot records in physical slot order (one per lane group)."""
        return [self.slots[s] for s in sorted(self.slots)]

    def n_replicas(self, slot: int) -> int:
        return len(self.slots[slot].replicas)

    def slot_devices(self, slot: int) -> List[str]:
        """Per-lane accelerator types backing a slot (dispatch telemetry)."""
        return self.slots[slot].devices()

    def n_endpoints(self) -> int:
        """Total *powered* devices on the bus (arbitration contention).
        A crashed or powered-off device stops arbitrating, so failed
        lanes are excluded — chaos runs see contention relax exactly as
        real hardware would."""
        return sum(len(r.replicas) for r in self.slots.values()) \
            - len(self._failed)

    # -- fault state (chaos fabric) -------------------------------------------
    def set_failed(self, cart: Cartridge, failed: bool = True):
        """Mark a plugged device failed (crashed / hub power loss) or
        recovered.  Failed devices stay *plugged* — the slot still owns
        them and reinstatement is cheap — but they leave the arbitration
        counts: a dead stick neither drives nor arbitrates the bus."""
        key = id(cart)
        hub = self._hub_of.get(key)
        if hub is None:
            raise ValueError(f"{cart.name} is not plugged in")
        if failed and key not in self._failed:
            self._failed.add(key)
            self._failed_on[hub] = self._failed_on.get(hub, 0) + 1
        elif not failed and key in self._failed:
            self._failed.discard(key)
            n = self._failed_on.get(hub, 1) - 1
            if n > 0:
                self._failed_on[hub] = n
            else:
                self._failed_on.pop(hub, None)

    def is_failed(self, cart: Cartridge) -> bool:
        return id(cart) in self._failed

    def n_failed(self) -> int:
        return len(self._failed)

    # -- hub placement (multi-hub fabric) -------------------------------------
    def hub_of(self, cart: Cartridge) -> int:
        """Which fabric hub a device is plugged into (default hub 0)."""
        return self._hub_of.get(id(cart), 0)

    def n_endpoints_on(self, hub: int) -> int:
        """Powered devices sharing one hub's arbitration domain — the
        contention count a hub-partitioned fabric charges per transfer.
        O(1): the engine asks for this several times per handoff."""
        return self._hub_counts.get(hub, 0) - self._failed_on.get(hub, 0)

    def hubs(self) -> List[int]:
        """Hub ids with at least one plugged device, sorted."""
        return sorted(self._hub_counts)

    def find(self, capability_id: int) -> Optional[Cartridge]:
        for rec in self.slots.values():
            if rec.handshake["capability_id"] == capability_id:
                return rec.cartridge
        return None
