"""Replicated-lane helpers: drive Table 1 (§4.1) through the StreamEngine.

The paper's only quantitative result is near-linear FPS scaling from one
to five identical accelerators sharing a USB3 bus.  ``engine_broadcast_fps``
reproduces that experiment *inside* the VDiSK runtime: one lane group in
``broadcast`` mode with N replica cartridges whose service time is the
calibrated device compute time, on a bus calibrated from the published
rows.  ``engine_shard_fps`` runs the same hardware in ``shard`` mode —
the throughput-scaling configuration the paper motivates but does not
measure — so benchmarks can report both curves side by side.
"""
from __future__ import annotations

from typing import Union

from repro.bus.simulator import BusParams, SharedBus, calibrated
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime.engine import EngineReport, StreamEngine
from repro.runtime.registry import CapabilityRegistry

FRAME_BYTES = 150528        # 224x224x3 uint8, the paper's imagenet frame


def _params(device: Union[str, BusParams]) -> BusParams:
    return calibrated(device) if isinstance(device, str) else device


def make_inference_cartridge(params: BusParams, name: str = None,
                             capability_id: int = 7) -> FnCartridge:
    """An identity-compute cartridge whose device model carries the
    calibrated on-stick inference time."""
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    return FnCartridge(
        name or f"{params.name}_infer", lambda p, x: x, spec, spec,
        capability_id=capability_id,
        device=DeviceModel(name=params.name, service_s=params.t_comp_s))


def build_replicated_engine(device: Union[str, BusParams], n_devices: int,
                            mode: str = "broadcast",
                            queue_cap: int = 8) -> StreamEngine:
    """One lane group holding ``n_devices`` replicas of the calibrated
    inference cartridge, all sharing one calibrated bus."""
    p = _params(device)
    reg = CapabilityRegistry()
    primary = make_inference_cartridge(p)
    reg.insert(0, primary, mode=mode)
    for i in range(1, n_devices):
        reg.add_replica(0, primary.clone(f"{primary.name}#r{i}"))
    return StreamEngine(reg, SharedBus(p), queue_cap=queue_cap)


def run_replicated(device: Union[str, BusParams], n_devices: int,
                   mode: str = "broadcast", n_frames: int = 200,
                   frame_bytes: int = FRAME_BYTES) -> EngineReport:
    """Stream a closed-loop burst through the replicated engine."""
    eng = build_replicated_engine(device, n_devices, mode=mode)
    # interval 0 = frames always available (the experiment is closed-loop:
    # the next frame dispatches as soon as the devices can take it)
    eng.feed(n_frames, interval_s=0.0, frame_bytes=frame_bytes)
    return eng.run(until=float("inf"))


def engine_broadcast_fps(device: Union[str, BusParams], n_devices: int,
                         n_frames: int = 200) -> float:
    """Per-device FPS when every frame is broadcast to all replicas —
    the Table 1 measurement, engine-driven."""
    return run_replicated(device, n_devices, "broadcast",
                          n_frames).throughput()


def engine_shard_fps(device: Union[str, BusParams], n_devices: int,
                     n_frames: int = 200) -> float:
    """Aggregate FPS when frames are load-balanced across replicas."""
    return run_replicated(device, n_devices, "shard", n_frames).throughput()
