"""Replicated-lane helpers: drive Table 1 (§4.1) through the StreamEngine.

The paper's only quantitative result is near-linear FPS scaling from one
to five identical accelerators sharing a USB3 bus.  ``engine_broadcast_fps``
reproduces that experiment *inside* the VDiSK runtime: one lane group in
``broadcast`` mode with N replica cartridges whose service time is the
calibrated device compute time, on a bus calibrated from the published
rows.  ``engine_shard_fps`` runs the same hardware in ``shard`` mode —
the throughput-scaling configuration the paper motivates but does not
measure — so benchmarks can report both curves side by side.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Union

from repro.bus.fabric import FabricRouter, LinkParams
from repro.bus.simulator import BusParams, SharedBus, calibrated
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime.engine import EngineReport, StreamEngine
from repro.runtime.frontdoor import FrontDoor, Tenant
from repro.runtime.registry import CapabilityRegistry

FRAME_BYTES = 150528        # 224x224x3 uint8, the paper's imagenet frame


def _params(device: Union[str, BusParams]) -> BusParams:
    return calibrated(device) if isinstance(device, str) else device


def make_inference_cartridge(params: BusParams, name: str = None,
                             capability_id: int = 7) -> FnCartridge:
    """An identity-compute cartridge whose device model carries the
    calibrated on-stick inference time."""
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    return FnCartridge(
        name or f"{params.name}_infer", lambda p, x: x, spec, spec,
        capability_id=capability_id,
        device=DeviceModel(name=params.name, service_s=params.t_comp_s))


def _device_model(d: Union[str, BusParams, DeviceModel]) -> DeviceModel:
    """Normalize a device spec: a calibrated name/BusParams becomes a
    DeviceModel carrying the on-stick inference time; a DeviceModel passes
    through (the hook for jittered / degraded straggler lanes)."""
    if isinstance(d, DeviceModel):
        return d
    p = _params(d)
    return DeviceModel(name=p.name, service_s=p.t_comp_s)


def build_replicated_engine(device: Union[str, BusParams], n_devices: int,
                            mode: str = "broadcast",
                            queue_cap: int = 8,
                            quorum: Optional[int] = None,
                            **engine_kw) -> StreamEngine:
    """One lane group holding ``n_devices`` replicas of the calibrated
    inference cartridge, all sharing one calibrated bus.  ``quorum=k``
    (broadcast only) decides each frame at the k-th replica completion.
    ``engine_kw`` passes through to ``StreamEngine`` (dispatch=, hedge=,
    ...)."""
    p = _params(device)
    reg = CapabilityRegistry()
    primary = make_inference_cartridge(p)
    reg.insert(0, primary, mode=mode, quorum=quorum)
    for i in range(1, n_devices):
        reg.add_replica(0, primary.clone(f"{primary.name}#r{i}"))
    return StreamEngine(reg, SharedBus(p), queue_cap=queue_cap, **engine_kw)


def build_mixed_engine(devices: list, mode: str = "shard",
                       queue_cap: int = 8,
                       bus: Union[str, BusParams, None] = None,
                       **engine_kw) -> StreamEngine:
    """A heterogeneous lane group: one slot whose replicas mix accelerator
    types (e.g. ``["ncs2", "coral", "coral"]``), or hand-built
    ``DeviceModel``s for straggler scenarios (slow sticks, jitter).

    All lanes share one bus — calibrated from ``bus`` (default: the first
    calibrated device in the list, else a generic USB3 hub).  The weighted
    dispatcher seeds each lane's EWMA from its own DeviceModel, so a
    mixed group load-balances by service time from the first frame.
    """
    if not devices:
        raise ValueError("need at least one device")
    devs = [_device_model(d) for d in devices]
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    primary = FnCartridge(f"{devs[0].name}_infer", lambda p, x: x,
                          spec, spec, capability_id=7, device=devs[0])
    reg.insert(0, primary, mode=mode)
    for i, dv in enumerate(devs[1:], 1):
        reg.add_replica(0, primary.clone(f"{dv.name}#m{i}", device=dv))
    if bus is None:
        cal = next((d for d in devices
                    if isinstance(d, (str, BusParams))), None)
        bp = _params(cal) if cal is not None else \
            BusParams("mixed_hub", base_overhead_s=1e-4, arbitration_s=2e-4)
    else:
        bp = _params(bus)
    return StreamEngine(reg, SharedBus(bp), queue_cap=queue_cap,
                        **engine_kw)


def run_replicated(device: Union[str, BusParams], n_devices: int,
                   mode: str = "broadcast", n_frames: int = 200,
                   frame_bytes: int = FRAME_BYTES,
                   quorum: Optional[int] = None,
                   **engine_kw) -> EngineReport:
    """Stream a closed-loop burst through the replicated engine."""
    eng = build_replicated_engine(device, n_devices, mode=mode,
                                  quorum=quorum, **engine_kw)
    # interval 0 = frames always available (the experiment is closed-loop:
    # the next frame dispatches as soon as the devices can take it)
    eng.feed(n_frames, interval_s=0.0, frame_bytes=frame_bytes)
    return eng.run(until=float("inf"))


def engine_broadcast_fps(device: Union[str, BusParams], n_devices: int,
                         n_frames: int = 200,
                         quorum: Optional[int] = None) -> float:
    """Per-device FPS when every frame is broadcast to all replicas —
    the Table 1 measurement, engine-driven.  ``quorum=k`` relaxes the
    full barrier to first-k-of-N."""
    return run_replicated(device, n_devices, "broadcast",
                          n_frames, quorum=quorum).throughput()


def engine_shard_fps(device: Union[str, BusParams], n_devices: int,
                     n_frames: int = 200, **engine_kw) -> float:
    """Aggregate FPS when frames are load-balanced across replicas."""
    return run_replicated(device, n_devices, "shard", n_frames,
                          **engine_kw).throughput()


# ---------------------------------------------------------------------------
# Multi-hub fabric topologies (the layer past the single-bus saturation knee)
# ---------------------------------------------------------------------------
def _hub_bus_params(i: int, specs: list, bus: Union[str, BusParams, None],
                    fleet_default: Union[str, BusParams, None]) -> BusParams:
    """One hub's calibration: explicit ``bus``, else the hub's first
    calibrated device spec, else the fleet-wide default (so an empty hub
    pre-provisioned for hot-plug matches its siblings), else a generic
    USB3 hub."""
    cal = bus if bus is not None else next(
        (d for d in specs if isinstance(d, (str, BusParams))),
        fleet_default)
    p = _params(cal) if cal is not None else \
        BusParams("hub", base_overhead_s=1e-4, arbitration_s=2e-4)
    return dataclasses.replace(p, name=f"{p.name}_hub{i}")


def build_fabric_engine(topology: List[list], mode: str = "shard",
                        queue_cap: int = 8,
                        bus: Union[str, BusParams, None] = None,
                        link: Optional[LinkParams] = None,
                        suppression: bool = True,
                        quorum: Optional[int] = None,
                        power_budget_w=None,
                        **engine_kw) -> StreamEngine:
    """One lane group whose replicas span a multi-hub bus fabric.

    ``topology`` is one device-spec list per hub — calibrated names,
    ``BusParams``, or hand-built ``DeviceModel``s, exactly like
    ``build_mixed_engine`` — e.g. ``[["ncs2"] * 4, ["ncs2"] * 4]`` is two
    four-stick hubs (an empty list pre-provisions a hub for later
    hot-plug).  Each hub gets its own calibrated ``SharedBus`` (so
    arbitration scales with the hub's endpoint count, not the fleet's)
    and the engine routes handoffs through a ``FabricRouter`` with
    ``link`` parameters on every inter-hub channel.
    ``suppression=False`` makes the router *execute* hedge losers'
    routed handoffs instead of killing them (the contention baseline).

    ``power_budget_w`` caps each hub's electrical draw (§4.3: the
    battery budget): a scalar applies the same cap to every hub, a
    ``{hub: watts}`` dict caps hubs individually, ``None`` meters
    energy without enforcement.  See ``repro.runtime.power``.
    """
    if not topology or not any(topology):
        raise ValueError("need at least one hub with at least one device")
    fleet_default = next((d for specs in topology for d in specs
                          if isinstance(d, (str, BusParams))), None)
    fabric = FabricRouter(
        [_hub_bus_params(i, specs, bus, fleet_default)
         for i, specs in enumerate(topology)],
        link=link, suppression=suppression)
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    primary = None
    for h, specs in enumerate(topology):
        for j, dspec in enumerate(specs):
            dv = _device_model(dspec)
            if primary is None:
                primary = FnCartridge(f"{dv.name}_infer", lambda p, x: x,
                                      spec, spec, capability_id=7, device=dv)
                reg.insert(0, primary, mode=mode, hub=h, quorum=quorum)
            else:
                reg.add_replica(0, primary.clone(f"{dv.name}#h{h}r{j}",
                                                 device=dv), hub=h)
    return StreamEngine(reg, fabric, queue_cap=queue_cap,
                        power_budget_w=power_budget_w, **engine_kw)


def run_fabric(topology: List[list], mode: str = "shard",
               n_frames: int = 200, frame_bytes: int = FRAME_BYTES,
               **kw) -> EngineReport:
    """Closed-loop burst through a fabric engine (fabric counterpart of
    ``run_replicated``)."""
    eng = build_fabric_engine(topology, mode=mode, **kw)
    eng.feed(n_frames, interval_s=0.0, frame_bytes=frame_bytes)
    return eng.run(until=float("inf"))


def fabric_shard_fps(device: Union[str, BusParams], n_hubs: int,
                     devices_per_hub: int, n_frames: int = 200,
                     **kw) -> float:
    """Aggregate shard FPS of ``n_hubs`` hubs x ``devices_per_hub``
    identical calibrated sticks — the headline the fabric exists for:
    at equal device count, partitioned hubs beat the saturated single
    bus because each hub arbitrates only its own endpoints."""
    return run_fabric([[device] * devices_per_hub] * n_hubs,
                      mode="shard", n_frames=n_frames, **kw).throughput()


# ---------------------------------------------------------------------------
# Power-governed scenarios (§4.3 battery budgets + fabric-aware dispatch)
# ---------------------------------------------------------------------------
def build_battery_engine(power_budget_w=None, n_devices: int = 4,
                         device: Union[str, BusParams, DeviceModel] = "ncs2",
                         n_hubs: int = 1, **engine_kw) -> StreamEngine:
    """The §4.3 battery kit: ``n_hubs`` hubs of ``n_devices`` calibrated
    sticks each, shard mode, under a per-hub watt budget.  The canonical
    budget-sweep workload — shared by ``benchmarks/power_bench.py`` (the
    tracked FPS/p99-vs-watt-cap curve in ``BENCH_power.json``), the
    power test suite, and ``examples/power_budget.py``, so the
    invariants the tests pin are measured on the exact workload the
    benchmark reports.

    At ncs2 calibration one 4-stick hub draws ~7.2 W flat out against a
    1.2 W idle floor, so caps between ~2.5 and ~6.5 W exercise the
    throttle band and caps below ~2.4 W force park/duty cycling."""
    return build_fabric_engine([[device] * n_devices] * n_hubs,
                               mode="shard",
                               power_budget_w=power_budget_w, **engine_kw)


def run_battery(power_budget_w=None, n_frames: int = 200,
                frame_bytes: int = FRAME_BYTES, **kw) -> EngineReport:
    """Closed-loop burst through the battery kit (the budget-sweep
    measurement: FPS/p99/average-watts at one cap)."""
    eng = build_battery_engine(power_budget_w, **kw)
    eng.feed(n_frames, interval_s=0.0, frame_bytes=frame_bytes)
    return eng.run(until=float("inf"))


def build_routed_pipeline_engine(route_aware: bool = True,
                                 n_bursts: int = 150,
                                 load: float = 0.85,
                                 service_s: float = 0.012,
                                 **engine_kw) -> StreamEngine:
    """The canonical fabric-aware-dispatch scenario — a two-stage
    pipeline whose BOTH stages span two hubs, with a deliberately slow
    inter-hub link, shared by ``benchmarks/power_bench.py`` (the
    cross-hub traffic-share comparison in ``BENCH_power.json``) and the
    test suite.

    Every detect->embed handoff must pick a destination lane: hub-blind
    dispatch (``route_aware=False``, the pre-PR ``pick_lane``) chases
    the shortest queue across the fabric and keeps paying
    egress + link + ingress for marginal wins; fabric-aware dispatch
    folds the router's current route cost (including the link's FIFO
    backlog) into the estimate, so traffic stays hub-local unless the
    local queue really is worth the toll."""
    fast = DeviceModel(name="coral", service_s=service_s)
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    det = FnCartridge("detect", lambda p, x: x, spec, spec,
                      capability_id=7, device=fast)
    reg.insert(0, det, mode="shard", hub=0)
    reg.add_replica(0, det.clone("detect#h0r1", device=fast), hub=0)
    reg.add_replica(0, det.clone("detect#h1r0", device=fast), hub=1)
    reg.add_replica(0, det.clone("detect#h1r1", device=fast), hub=1)
    emb = FnCartridge("embed", lambda p, x: x, spec, spec,
                      capability_id=8, device=fast)
    reg.insert(1, emb, mode="shard", hub=0)
    reg.add_replica(1, emb.clone("embed#h0r1", device=fast), hub=0)
    reg.add_replica(1, emb.clone("embed#h1r0", device=fast), hub=1)
    reg.add_replica(1, emb.clone("embed#h1r1", device=fast), hub=1)
    fabric = FabricRouter(
        [BusParams("hub0", bandwidth=400e6, base_overhead_s=1e-4,
                   arbitration_s=1e-4),
         BusParams("hub1", bandwidth=400e6, base_overhead_s=1e-4,
                   arbitration_s=1e-4)],
        # the hot link the ROADMAP called out: ~5 ms per routed frame
        link=LinkParams(bandwidth=30e6, overhead_s=3e-4))
    eng = StreamEngine(reg, fabric, route_aware=route_aware, **engine_kw)
    # bursty arrivals at `load` x the detect stage's aggregate capacity:
    # queues form, so the dispatcher actually faces local-vs-remote calls
    period = 5 / (load * (4 / service_s))
    for i in range(n_bursts):
        eng.feed(5, interval_s=0.0, t0=i * period)
    return eng


# ---------------------------------------------------------------------------
# Chaos fabric scenarios (deterministic fault injection, PR 7)
# ---------------------------------------------------------------------------
def chaos_lane_names() -> List[str]:
    """The deterministic cart names of the canonical chaos scenario's
    lanes, in lane order — the targets a ``FaultPlan.storm`` draws crash
    and hang victims from."""
    return ["detect", "detect#h0r1", "detect#h1r0", "detect#h1r1",
            "embed", "embed#h0r1", "embed#h1r0", "embed#h1r1"]


def build_chaos_engine(fault_plan=None, retry=None, quarantine=None,
                       n_bursts: int = 150, load: float = 0.7,
                       service_s: float = 0.012,
                       **engine_kw) -> StreamEngine:
    """The canonical fault-injection scenario — shared by
    ``benchmarks/chaos_bench.py`` (the zero-loss / goodput-retention
    contract in ``BENCH_chaos.json``) and the chaos test suite, so the
    invariants the tests pin are measured on the exact workload the
    benchmark reports.

    Same shape as the routed pipeline: a two-stage detect->embed
    pipeline with both stages spanning two hubs (2 lanes per stage per
    hub), hedged dispatch, bursty arrivals at moderate load so every
    recovery path gets headroom to act.  The topology gives every fault
    kind something to survive: a lane crash leaves three siblings, a
    hub power loss leaves the whole pipeline alive on the other hub,
    and a link-down forces reroute-or-hold on cross-hub handoffs.
    """
    fast = DeviceModel(name="coral", service_s=service_s)
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    det = FnCartridge("detect", lambda p, x: x, spec, spec,
                      capability_id=7, device=fast)
    reg.insert(0, det, mode="shard", hub=0)
    reg.add_replica(0, det.clone("detect#h0r1", device=fast), hub=0)
    reg.add_replica(0, det.clone("detect#h1r0", device=fast), hub=1)
    reg.add_replica(0, det.clone("detect#h1r1", device=fast), hub=1)
    emb = FnCartridge("embed", lambda p, x: x, spec, spec,
                      capability_id=8, device=fast)
    reg.insert(1, emb, mode="shard", hub=0)
    reg.add_replica(1, emb.clone("embed#h0r1", device=fast), hub=0)
    reg.add_replica(1, emb.clone("embed#h1r0", device=fast), hub=1)
    reg.add_replica(1, emb.clone("embed#h1r1", device=fast), hub=1)
    fabric = FabricRouter(
        [BusParams("hub0", bandwidth=400e6, base_overhead_s=1e-4,
                   arbitration_s=1e-4),
         BusParams("hub1", bandwidth=400e6, base_overhead_s=1e-4,
                   arbitration_s=1e-4)],
        link=LinkParams(bandwidth=120e6, overhead_s=2e-4))
    eng = StreamEngine(reg, fabric, hedge=True,
                       fault_plan=fault_plan, retry=retry,
                       quarantine=quarantine, **engine_kw)
    period = 5 / (load * (4 / service_s))
    for i in range(n_bursts):
        eng.feed(5, interval_s=0.0, t0=i * period)
    return eng


def run_chaos(fault_plan=None, retry=None, quarantine=None,
              n_bursts: int = 150, **kw) -> EngineReport:
    """Run the canonical chaos scenario to quiescence and return its
    report.  ``until=inf`` lets every retry, quarantine lease, and
    reinstatement play out, so a zero-loss plan delivers all
    ``5 * n_bursts`` frames by the time the queue drains."""
    eng = build_chaos_engine(fault_plan, retry=retry, quarantine=quarantine,
                             n_bursts=n_bursts, **kw)
    return eng.run(until=float("inf"))


def build_lane_sweep_engine(n_lanes: int, service_s: float = 2e-4,
                            queue_cap: int = 8, **engine_kw) -> StreamEngine:
    """A fleet-scale dispatch stressor: ONE shard group of ``n_lanes``
    identical lanes on a near-free bus, so simulated events/sec is
    dominated by the engine's per-event bookkeeping — exactly what
    ``benchmarks/engine_bench.py`` sweeps to compare the heap and epoch
    cores at 100/1k/10k lanes.

    The registry is built *before* the engine so the whole fleet costs
    one rebuild, and the bus is a bare ``SharedBus`` with microsecond
    overheads: at 10k lanes a realistic USB model would serialize on
    arbitration and hide the dispatch cost being measured."""
    if n_lanes < 1:
        raise ValueError("need at least one lane")
    dev = DeviceModel(name="sweep", service_s=service_s)
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    primary = FnCartridge("sweep", lambda p, x: x, spec, spec,
                          capability_id=7, device=dev)
    reg.insert(0, primary, mode="shard")
    for i in range(1, n_lanes):
        reg.add_replica(0, primary.clone(f"sweep#r{i}", device=dev))
    bus = SharedBus(BusParams("sweep", base_overhead_s=1e-5))
    return StreamEngine(reg, bus, queue_cap=queue_cap, **engine_kw)


def build_cross_hub_hedge_engine(suppression: bool = True,
                                 n_bursts: int = 120,
                                 load: float = 0.45,
                                 **engine_kw) -> StreamEngine:
    """The canonical cross-hub hedging scenario — shared by
    ``benchmarks/fabric_bench.py`` (the tracked suppression-on/off p99
    comparison in ``BENCH_fabric.json``) and the test suite, so the
    invariants the tests pin are measured on the exact workload the
    benchmark reports.

    Two jittery Coral-class lanes on hub 0, two clean ones plus the
    post-processing stage on hub 1, slow hub buses at near-critical
    load, bursty arrivals: stalls on hub 0 hedge onto hub 1 (cross-hub
    backup copies, charged ingress-only to hub 1), and loser results
    would route hub 0 -> link -> hub 1 if the router did not suppress
    them."""
    svc = 0.012
    jit = DeviceModel(name="coral_hot", service_s=svc,
                      jitter_p=0.12, jitter_mult=20.0)
    fast = DeviceModel(name="coral", service_s=svc)
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    infer = FnCartridge("infer", lambda p, x: x, spec, spec,
                        capability_id=7, device=jit)
    reg.insert(0, infer, mode="shard", hub=0)
    reg.add_replica(0, infer.clone("infer#j1", device=jit), hub=0)
    reg.add_replica(0, infer.clone("infer#f0", device=fast), hub=1)
    reg.add_replica(0, infer.clone("infer#f1", device=fast), hub=1)
    reg.insert(1, FnCartridge("post", lambda p, x: x, spec, spec,
                              capability_id=8,
                              device=DeviceModel(name="post",
                                                 service_s=0.002)),
               mode="shard", hub=1)
    fabric = FabricRouter(
        [BusParams("hub0", bandwidth=60e6, base_overhead_s=3e-4,
                   arbitration_s=3e-4),
         BusParams("hub1", bandwidth=60e6, base_overhead_s=3e-4,
                   arbitration_s=3e-4)],
        link=LinkParams(bandwidth=120e6, overhead_s=2e-4),
        suppression=suppression)
    eng = StreamEngine(reg, fabric, hedge=True, hedge_quantile=0.8,
                       **engine_kw)
    period = 5 / (load * (4 / svc))
    for i in range(n_bursts):
        eng.feed(5, interval_s=0.0, t0=i * period)
    return eng


# ---------------------------------------------------------------------------
# fleet front door (multi-tenant serving) — the canonical scenario shared
# by benchmarks/serve_bench.py, tests/test_frontdoor.py and
# examples/fleet_serving.py, so the invariants the tests pin are measured
# on the exact workload the benchmark reports
# ---------------------------------------------------------------------------
FLEET_LANES = 8             # one shard group of identical fleet lanes
FLEET_SERVICE_S = 0.012     # per-frame service time -> ~666 fps nominal

# the three conventional priority tiers (paper applications): checkpoint
# operators screening live subjects (tight SLO, sheds last), recon feeds,
# and archive backfill (bulk: first to shed under overload)
FLEET_TENANTS = (
    Tenant("field_ops", priority=0, weight=8.0, slo_s=0.25, queue_cap=64),
    Tenant("recon", priority=1, weight=3.0, queue_cap=128),
    Tenant("backfill", priority=2, weight=1.0, queue_cap=256),
)
# offered-load split across the tiers for the overload sweep
FLEET_SPLIT = {"field_ops": 0.10, "recon": 0.30, "backfill": 0.60}


def fleet_capacity_fps(n_lanes: int = FLEET_LANES,
                       service_s: float = FLEET_SERVICE_S) -> float:
    return n_lanes / service_s


def build_fleet_engine(n_lanes: int = FLEET_LANES,
                       service_s: float = FLEET_SERVICE_S,
                       tenants=FLEET_TENANTS, queue_cap: int = 8,
                       headroom: float = 0.95, **engine_kw):
    """One shard group of identical lanes behind a multi-tenant front
    door.  Returns ``(engine, frontdoor)``; feed tenants with
    ``engine.feed_tenant(name, ...)``."""
    dev = DeviceModel(name="fleet", service_s=service_s)
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    primary = FnCartridge("fleet", lambda p, x: x, spec, spec,
                          capability_id=7, device=dev)
    reg.insert(0, primary, mode="shard")
    for i in range(1, n_lanes):
        reg.add_replica(0, primary.clone(f"fleet#r{i}", device=dev))
    fd = FrontDoor(headroom=headroom)
    for t in tenants:
        fd.add_tenant(t)
    bus = SharedBus(BusParams("fleet", base_overhead_s=1e-5))
    eng = StreamEngine(reg, bus, queue_cap=queue_cap, frontdoor=fd,
                       **engine_kw)
    return eng, fd


def run_fleet_sweep(overload: float, duration_s: float = 20.0,
                    split=None, **build_kw) -> EngineReport:
    """Sustained offered load at ``overload`` x nominal capacity, divided
    across the tenant tiers by ``split``, each tenant arriving at its own
    even interval.  Arrivals stop at ``duration_s``; the run continues
    until the admitted backlog drains."""
    eng, fd = build_fleet_engine(**build_kw)
    cap = fleet_capacity_fps(build_kw.get("n_lanes", FLEET_LANES),
                             build_kw.get("service_s", FLEET_SERVICE_S))
    for name, frac in (split or FLEET_SPLIT).items():
        rate = overload * cap * frac
        if rate <= 0.0:
            continue
        eng.feed_tenant(name, int(rate * duration_s), interval_s=1.0 / rate)
    return eng.run(until=float("inf"))
