"""Replicated-lane helpers: drive Table 1 (§4.1) through the StreamEngine.

The paper's only quantitative result is near-linear FPS scaling from one
to five identical accelerators sharing a USB3 bus.  ``engine_broadcast_fps``
reproduces that experiment *inside* the VDiSK runtime: one lane group in
``broadcast`` mode with N replica cartridges whose service time is the
calibrated device compute time, on a bus calibrated from the published
rows.  ``engine_shard_fps`` runs the same hardware in ``shard`` mode —
the throughput-scaling configuration the paper motivates but does not
measure — so benchmarks can report both curves side by side.
"""
from __future__ import annotations

from typing import Union

from repro.bus.simulator import BusParams, SharedBus, calibrated
from repro.core import messages as msg
from repro.core.cartridge import DeviceModel, FnCartridge
from repro.runtime.engine import EngineReport, StreamEngine
from repro.runtime.registry import CapabilityRegistry

FRAME_BYTES = 150528        # 224x224x3 uint8, the paper's imagenet frame


def _params(device: Union[str, BusParams]) -> BusParams:
    return calibrated(device) if isinstance(device, str) else device


def make_inference_cartridge(params: BusParams, name: str = None,
                             capability_id: int = 7) -> FnCartridge:
    """An identity-compute cartridge whose device model carries the
    calibrated on-stick inference time."""
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    return FnCartridge(
        name or f"{params.name}_infer", lambda p, x: x, spec, spec,
        capability_id=capability_id,
        device=DeviceModel(name=params.name, service_s=params.t_comp_s))


def _device_model(d: Union[str, BusParams, DeviceModel]) -> DeviceModel:
    """Normalize a device spec: a calibrated name/BusParams becomes a
    DeviceModel carrying the on-stick inference time; a DeviceModel passes
    through (the hook for jittered / degraded straggler lanes)."""
    if isinstance(d, DeviceModel):
        return d
    p = _params(d)
    return DeviceModel(name=p.name, service_s=p.t_comp_s)


def build_replicated_engine(device: Union[str, BusParams], n_devices: int,
                            mode: str = "broadcast",
                            queue_cap: int = 8, **engine_kw) -> StreamEngine:
    """One lane group holding ``n_devices`` replicas of the calibrated
    inference cartridge, all sharing one calibrated bus.  ``engine_kw``
    passes through to ``StreamEngine`` (dispatch=, hedge=, ...)."""
    p = _params(device)
    reg = CapabilityRegistry()
    primary = make_inference_cartridge(p)
    reg.insert(0, primary, mode=mode)
    for i in range(1, n_devices):
        reg.add_replica(0, primary.clone(f"{primary.name}#r{i}"))
    return StreamEngine(reg, SharedBus(p), queue_cap=queue_cap, **engine_kw)


def build_mixed_engine(devices: list, mode: str = "shard",
                       queue_cap: int = 8,
                       bus: Union[str, BusParams, None] = None,
                       **engine_kw) -> StreamEngine:
    """A heterogeneous lane group: one slot whose replicas mix accelerator
    types (e.g. ``["ncs2", "coral", "coral"]``), or hand-built
    ``DeviceModel``s for straggler scenarios (slow sticks, jitter).

    All lanes share one bus — calibrated from ``bus`` (default: the first
    calibrated device in the list, else a generic USB3 hub).  The weighted
    dispatcher seeds each lane's EWMA from its own DeviceModel, so a
    mixed group load-balances by service time from the first frame.
    """
    if not devices:
        raise ValueError("need at least one device")
    devs = [_device_model(d) for d in devices]
    reg = CapabilityRegistry()
    spec = msg.MessageSpec(msg.IMAGE_FRAME)
    primary = FnCartridge(f"{devs[0].name}_infer", lambda p, x: x,
                          spec, spec, capability_id=7, device=devs[0])
    reg.insert(0, primary, mode=mode)
    for i, dv in enumerate(devs[1:], 1):
        reg.add_replica(0, primary.clone(f"{dv.name}#m{i}", device=dv))
    if bus is None:
        cal = next((d for d in devices
                    if isinstance(d, (str, BusParams))), None)
        bp = _params(cal) if cal is not None else \
            BusParams("mixed_hub", base_overhead_s=1e-4, arbitration_s=2e-4)
    else:
        bp = _params(bus)
    return StreamEngine(reg, SharedBus(bp), queue_cap=queue_cap,
                        **engine_kw)


def run_replicated(device: Union[str, BusParams], n_devices: int,
                   mode: str = "broadcast", n_frames: int = 200,
                   frame_bytes: int = FRAME_BYTES,
                   **engine_kw) -> EngineReport:
    """Stream a closed-loop burst through the replicated engine."""
    eng = build_replicated_engine(device, n_devices, mode=mode, **engine_kw)
    # interval 0 = frames always available (the experiment is closed-loop:
    # the next frame dispatches as soon as the devices can take it)
    eng.feed(n_frames, interval_s=0.0, frame_bytes=frame_bytes)
    return eng.run(until=float("inf"))


def engine_broadcast_fps(device: Union[str, BusParams], n_devices: int,
                         n_frames: int = 200) -> float:
    """Per-device FPS when every frame is broadcast to all replicas —
    the Table 1 measurement, engine-driven."""
    return run_replicated(device, n_devices, "broadcast",
                          n_frames).throughput()


def engine_shard_fps(device: Union[str, BusParams], n_devices: int,
                     n_frames: int = 200, **engine_kw) -> float:
    """Aggregate FPS when frames are load-balanced across replicas."""
    return run_replicated(device, n_devices, "shard", n_frames,
                          **engine_kw).throughput()
