"""Event queues for the StreamEngine's discrete-event core.

The engine's hot loop is push/pop of timestamped events; with lane groups
and micro-batch retries a large scenario keeps tens of thousands of events
queued, so the pop discipline dominates simulated events/sec.

``HeapEventQueue`` — the engine core: O(log n) push/pop via ``heapq``,
with a monotonically increasing sequence number so events at equal
timestamps pop in FIFO order (deterministic replay).  The engine has
always popped from a heap; this module makes the queue a first-class,
injectable component.

Cancellation.  ``push`` returns a handle and ``cancel(handle)`` kills the
event before it fires — the hedged-dispatch path arms a deadline event
per service cycle and cancels it when the lane finishes on time, which is
the common case, so cancellation must be cheap.  The heap uses lazy
deletion (an O(1) set insert; dead entries are skipped when they surface
at the heap top), keeping push/pop asymptotics intact.  Under sustained
hedging the dead set would otherwise grow without bound and every
push/pop would pay log(dead + live); ``cancel`` therefore compacts the
heap (rebuild excluding dead entries + re-heapify) whenever dead entries
outnumber live ones.  ``compactions`` counts rebuilds and ``dead_peak``
tracks the worst dead-set size ever reached, so a regression in the
threshold logic is observable.

Cohorts.  ``pop_cohort`` drains *every* live event at the earliest
timestamp in one call (seq order — identical to repeated ``pop``).  The
epoch-stepped engine core uses it to amortize queue overhead across a
whole wall-clock instant.  Drained entries enter a *pending* state:
``cancel`` still works on them until the engine commits each one with
``fire(handle)``, which is what preserves same-timestamp cancellation
semantics (e.g. a fault killing a completion scheduled for the same
instant).  ``fire`` returns False for a cohort member cancelled after the
drain, and only fired events advance the ``popped`` counter — so
events/sec accounting matches the pop-per-event core exactly.

``ListEventQueue`` — a reference implementation of the naive O(n)
linear-scan-for-minimum discipline.  It never shipped as the engine
core; it exists so the engine bench can quantify, on the identical
workload, what the heap core buys (``BENCH_engine.json`` tracks the
heap-vs-list events/sec ratio, so a future regression of the engine's
event discipline is visible against a fixed yardstick).  Pop order is
identical to the heap queue (min timestamp, FIFO on ties), only the
asymptotics differ — do not use it outside benchmarks.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, List, Tuple

Event = Tuple[float, int, Callable, tuple]


class HeapEventQueue:
    """Binary-heap priority queue: O(log n) push/pop, FIFO on time ties,
    O(1) lazy cancellation with threshold compaction."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()
        self._live: set = set()        # handles pushed and not fired/killed
        self._dead: set = set()        # handles cancelled but not yet popped
        self._pending: set = set()     # drained by pop_cohort, not yet fired
        self._killed: set = set()      # cancelled while pending
        self.pushed = 0
        self.popped = 0
        self.cancelled = 0
        self.compactions = 0
        self.dead_peak = 0

    def push(self, t: float, fn: Callable, args: tuple) -> int:
        handle = next(self._seq)
        heapq.heappush(self._heap, (t, handle, fn, args))
        self._live.add(handle)
        self.pushed += 1
        return handle

    def cancel(self, handle: int) -> bool:
        """Kill a pending event.  Returns False if it already fired (or was
        already cancelled) — callers may cancel unconditionally.  O(1)
        amortized: the heap entry dies lazily, and the heap is rebuilt
        without dead entries once they outnumber live ones."""
        if handle in self._pending:
            # drained by pop_cohort but not yet fired: kill it in place
            self._pending.discard(handle)
            self._killed.add(handle)
            self.cancelled += 1
            return True
        if handle not in self._live:
            return False
        self._live.discard(handle)
        self._dead.add(handle)
        self.cancelled += 1
        if len(self._dead) > self.dead_peak:
            self.dead_peak = len(self._dead)
        if len(self._dead) > len(self._heap) - len(self._dead):
            self._compact()
        return True

    def _compact(self):
        """Rebuild the heap without dead entries (threshold compaction)."""
        self._heap = [ev for ev in self._heap if ev[1] not in self._dead]
        heapq.heapify(self._heap)
        self._dead.clear()
        self.compactions += 1

    def _drop_dead(self):
        while self._heap and self._heap[0][1] in self._dead:
            self._dead.discard(heapq.heappop(self._heap)[1])

    def pop(self) -> Event:
        """Pop the earliest live event.  Raises a descriptive
        ``IndexError`` on an exhausted queue *without* touching the
        ``popped`` counter — a failed pop must not corrupt the
        events/sec stats ``BENCH_engine.json`` tracks."""
        self._drop_dead()
        if not self._heap:
            raise IndexError("pop from empty HeapEventQueue")
        ev = heapq.heappop(self._heap)
        self.popped += 1
        self._live.discard(ev[1])
        return ev

    def pop_cohort(self) -> List[Event]:
        """Drain every live event at the earliest timestamp, in seq order
        (identical to repeated ``pop`` at that instant).  Entries move to
        a pending state: ``cancel`` still kills them until ``fire`` is
        called per entry.  ``popped`` advances only on ``fire``."""
        self._drop_dead()
        if not self._heap:
            raise IndexError("pop_cohort from empty HeapEventQueue")
        heap, dead = self._heap, self._dead
        t0 = heap[0][0]
        out: List[Event] = []
        while heap and heap[0][0] == t0:
            ev = heapq.heappop(heap)
            h = ev[1]
            if h in dead:
                dead.discard(h)
                continue
            self._live.discard(h)
            self._pending.add(h)
            out.append(ev)
        return out

    def fire(self, handle: int) -> bool:
        """Commit one ``pop_cohort`` entry for execution.  Returns False
        (and counts nothing) if the entry was cancelled after the drain."""
        if handle in self._pending:
            self._pending.discard(handle)
            self.popped += 1
            return True
        self._killed.discard(handle)
        return False

    def peek_time(self) -> float:
        self._drop_dead()
        if not self._heap:
            raise IndexError("peek_time on empty HeapEventQueue")
        return self._heap[0][0]

    def stats(self) -> dict:
        """Lifetime counters (the ``engine.events.*`` metrics
        namespace): fed into the metrics registry at report time so the
        queue's health — cancellation pressure, compaction churn — is
        visible next to the engine's own counters."""
        return {"pushed": self.pushed, "popped": self.popped,
                "cancelled": self.cancelled,
                "compactions": self.compactions,
                "dead_peak": self.dead_peak}

    def __len__(self) -> int:
        return len(self._heap) - len(self._dead)


class ListEventQueue:
    """The linear-scan baseline: append on push, scan for the minimum on
    pop (and on peek).  Same pop order + cancellation + cohort semantics
    as ``HeapEventQueue``; O(n) per event instead of O(log n)."""

    def __init__(self):
        self._q: list = []
        self._seq = itertools.count()
        self._pending: set = set()
        self._killed: set = set()
        self.pushed = 0
        self.popped = 0
        self.cancelled = 0
        self.compactions = 0   # API parity: eager removal never compacts
        self.dead_peak = 0

    def push(self, t: float, fn: Callable, args: tuple) -> int:
        handle = next(self._seq)
        self._q.append((t, handle, fn, args))
        self.pushed += 1
        return handle

    def cancel(self, handle: int) -> bool:
        if handle in self._pending:
            self._pending.discard(handle)
            self._killed.add(handle)
            self.cancelled += 1
            return True
        for ev in self._q:
            if ev[1] == handle:
                self._q.remove(ev)
                self.cancelled += 1
                return True
        return False

    def pop(self) -> Event:
        if not self._q:                # mirror HeapEventQueue's contract
            raise IndexError("pop from empty ListEventQueue")
        # seq numbers are unique, so tuple comparison never reaches fn
        ev = min(self._q)
        self._q.remove(ev)
        self.popped += 1
        return ev

    def pop_cohort(self) -> List[Event]:
        if not self._q:
            raise IndexError("pop_cohort from empty ListEventQueue")
        t0 = min(self._q)[0]
        out = sorted(ev for ev in self._q if ev[0] == t0)
        for ev in out:
            self._q.remove(ev)
            self._pending.add(ev[1])
        return out

    def fire(self, handle: int) -> bool:
        if handle in self._pending:
            self._pending.discard(handle)
            self.popped += 1
            return True
        self._killed.discard(handle)
        return False

    def peek_time(self) -> float:
        if not self._q:
            raise IndexError("peek_time on empty ListEventQueue")
        return min(self._q)[0]

    def stats(self) -> dict:
        return {"pushed": self.pushed, "popped": self.popped,
                "cancelled": self.cancelled,
                "compactions": self.compactions,
                "dead_peak": self.dead_peak}

    def __len__(self) -> int:
        return len(self._q)
