"""Event queues for the StreamEngine's discrete-event core.

The engine's hot loop is push/pop of timestamped events; with lane groups
and micro-batch retries a large scenario keeps tens of thousands of events
queued, so the pop discipline dominates simulated events/sec.

``HeapEventQueue`` — the engine core: O(log n) push/pop via ``heapq``,
with a monotonically increasing sequence number so events at equal
timestamps pop in FIFO order (deterministic replay).  The engine has
always popped from a heap; this module makes the queue a first-class,
injectable component.

Cancellation.  ``push`` returns a handle and ``cancel(handle)`` kills the
event before it fires — the hedged-dispatch path arms a deadline event
per service cycle and cancels it when the lane finishes on time, which is
the common case, so cancellation must be cheap.  The heap uses lazy
deletion (an O(1) set insert; dead entries are skipped when they surface
at the heap top), keeping push/pop asymptotics intact.

``ListEventQueue`` — a reference implementation of the naive O(n)
linear-scan-for-minimum discipline.  It never shipped as the engine
core; it exists so ``benchmarks/gallery_bench.py`` can quantify, on the
identical workload, what the heap core buys (``BENCH_engine.json``
tracks the heap-vs-list events/sec ratio, so a future regression of the
engine's event discipline is visible against a fixed yardstick).  Pop
order is identical to the heap queue (min timestamp, FIFO on ties),
only the asymptotics differ — do not use it outside benchmarks.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Tuple

Event = Tuple[float, int, Callable, tuple]


class HeapEventQueue:
    """Binary-heap priority queue: O(log n) push/pop, FIFO on time ties,
    O(1) lazy cancellation."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()
        self._live: set = set()        # handles pushed and not fired/killed
        self._dead: set = set()        # handles cancelled but not yet popped
        self.pushed = 0
        self.popped = 0
        self.cancelled = 0

    def push(self, t: float, fn: Callable, args: tuple) -> int:
        handle = next(self._seq)
        heapq.heappush(self._heap, (t, handle, fn, args))
        self._live.add(handle)
        self.pushed += 1
        return handle

    def cancel(self, handle: int) -> bool:
        """Kill a pending event.  Returns False if it already fired (or was
        already cancelled) — callers may cancel unconditionally.  O(1):
        the heap entry dies lazily when it surfaces at the top."""
        if handle not in self._live:
            return False
        self._live.discard(handle)
        self._dead.add(handle)
        self.cancelled += 1
        return True

    def _drop_dead(self):
        while self._heap and self._heap[0][1] in self._dead:
            self._dead.discard(heapq.heappop(self._heap)[1])

    def pop(self) -> Event:
        """Pop the earliest live event.  Raises a descriptive
        ``IndexError`` on an exhausted queue *without* touching the
        ``popped`` counter — a failed pop must not corrupt the
        events/sec stats ``BENCH_engine.json`` tracks."""
        self._drop_dead()
        if not self._heap:
            raise IndexError("pop from empty HeapEventQueue")
        ev = heapq.heappop(self._heap)
        self.popped += 1
        self._live.discard(ev[1])
        return ev

    def peek_time(self) -> float:
        self._drop_dead()
        if not self._heap:
            raise IndexError("peek_time on empty HeapEventQueue")
        return self._heap[0][0]

    def __len__(self) -> int:
        return len(self._heap) - len(self._dead)


class ListEventQueue:
    """The linear-scan baseline: append on push, scan for the minimum on
    pop (and on peek).  Same pop order + cancellation semantics as
    ``HeapEventQueue``; O(n) per event instead of O(log n)."""

    def __init__(self):
        self._q: list = []
        self._seq = itertools.count()
        self.pushed = 0
        self.popped = 0
        self.cancelled = 0

    def push(self, t: float, fn: Callable, args: tuple) -> int:
        handle = next(self._seq)
        self._q.append((t, handle, fn, args))
        self.pushed += 1
        return handle

    def cancel(self, handle: int) -> bool:
        for ev in self._q:
            if ev[1] == handle:
                self._q.remove(ev)
                self.cancelled += 1
                return True
        return False

    def pop(self) -> Event:
        if not self._q:                # mirror HeapEventQueue's contract
            raise IndexError("pop from empty ListEventQueue")
        # seq numbers are unique, so tuple comparison never reaches fn
        ev = min(self._q)
        self._q.remove(ev)
        self.popped += 1
        return ev

    def peek_time(self) -> float:
        if not self._q:
            raise IndexError("peek_time on empty ListEventQueue")
        return min(self._q)[0]

    def __len__(self) -> int:
        return len(self._q)
