"""Event queues for the StreamEngine's discrete-event core.

The engine's hot loop is push/pop of timestamped events; with lane groups
and micro-batch retries a large scenario keeps tens of thousands of events
queued, so the pop discipline dominates simulated events/sec.

``HeapEventQueue`` — the engine core: O(log n) push/pop via ``heapq``,
with a monotonically increasing sequence number so events at equal
timestamps pop in FIFO order (deterministic replay).  The engine has
always popped from a heap; this module makes the queue a first-class,
injectable component.

``ListEventQueue`` — a reference implementation of the naive O(n)
linear-scan-for-minimum discipline.  It never shipped as the engine
core; it exists so ``benchmarks/gallery_bench.py`` can quantify, on the
identical workload, what the heap core buys (``BENCH_engine.json``
tracks the heap-vs-list events/sec ratio, so a future regression of the
engine's event discipline is visible against a fixed yardstick).  Pop
order is identical to the heap queue (min timestamp, FIFO on ties),
only the asymptotics differ — do not use it outside benchmarks.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Tuple

Event = Tuple[float, int, Callable, tuple]


class HeapEventQueue:
    """Binary-heap priority queue: O(log n) push/pop, FIFO on time ties."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()
        self.pushed = 0
        self.popped = 0

    def push(self, t: float, fn: Callable, args: tuple):
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))
        self.pushed += 1

    def pop(self) -> Event:
        self.popped += 1
        return heapq.heappop(self._heap)

    def peek_time(self) -> float:
        return self._heap[0][0]

    def __len__(self) -> int:
        return len(self._heap)


class ListEventQueue:
    """The linear-scan baseline: append on push, scan for the minimum on
    pop (and on peek).  Same pop order as ``HeapEventQueue``; O(n) per
    event instead of O(log n)."""

    def __init__(self):
        self._q: list = []
        self._seq = itertools.count()
        self.pushed = 0
        self.popped = 0

    def push(self, t: float, fn: Callable, args: tuple):
        self._q.append((t, next(self._seq), fn, args))
        self.pushed += 1

    def pop(self) -> Event:
        # seq numbers are unique, so tuple comparison never reaches fn
        ev = min(self._q)
        self._q.remove(ev)
        self.popped += 1
        return ev

    def peek_time(self) -> float:
        return min(self._q)[0]

    def __len__(self) -> int:
        return len(self._q)
