"""Streaming latency metrics for the dispatch hot path.

The engine records one sample per completed frame (end-to-end) and one per
stage visit, so the recorder must be O(1) per sample with no growing
state — a sorted-list percentile would turn the hot loop quadratic.

``StreamingHistogram`` keeps log-spaced bins (fixed count, geometric
edges): ``record`` is a single ``log`` + increment, quantiles walk the
(small, fixed) bin array and interpolate geometrically inside the winning
bin.  Relative quantile error is bounded by the bin width ratio
(``10**(1/bins_per_decade)``, ~7% at the default 32 bins/decade), which is
far below the 2x-scale effects the tail-latency benchmarks track.

The same class doubles as each lane's observed service-time distribution:
the hedge deadline is a quantile of it, so the estimator must stay cheap
enough to update on every ``_lane_done``.

``record_many`` is the bulk-ingest path for the vectorized engine core:
bin indices are computed with one ``np.log`` over the whole batch.
NumPy's SIMD log is *not* bitwise-identical to ``math.log`` (it can
differ in the last ulp), which only matters when a sample's scaled log
position lands exactly on a bin boundary — so the handful of elements
within 1e-9 of an integer position (the ulp of the scaled value is
~5e-13) are recomputed with the scalar formula.  Bin counts, ``count``,
``min`` and ``max`` are therefore bit-identical to repeated ``record``;
only ``total`` (and hence ``mean``) may differ by float-summation order,
which quantiles never read.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

_LOG10 = math.log(10.0)


class StreamingHistogram:
    """Log-spaced histogram: O(1) record, O(bins) quantile, fixed memory."""

    __slots__ = ("lo", "hi", "bpd", "_log_lo", "_nbins", "counts",
                 "count", "total", "min", "max")

    def __init__(self, lo: float = 1e-6, hi: float = 1e5,
                 bins_per_decade: int = 32):
        assert lo > 0 and hi > lo
        self.lo = lo
        self.hi = hi
        self.bpd = bins_per_decade
        self._log_lo = math.log(lo) / _LOG10
        decades = math.log(hi / lo) / _LOG10
        self._nbins = int(math.ceil(decades * bins_per_decade)) + 1
        self.counts = np.zeros(self._nbins, dtype=np.int64)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def _bin(self, x: float) -> int:
        if x <= self.lo:
            return 0
        i = int((math.log(x) / _LOG10 - self._log_lo) * self.bpd)
        return min(i, self._nbins - 1)

    def _edge(self, i: int) -> float:
        return self.lo * 10.0 ** (i / self.bpd)

    def record(self, x: float):
        self.counts[self._bin(x)] += 1
        self.count += 1
        self.total += x
        if self.min is None or x < self.min:
            self.min = x
        if self.max is None or x > self.max:
            self.max = x

    def record_many(self, xs) -> None:
        """Bulk ingest: one vectorized bin pass for a batch of samples.

        Bin counts / count / min / max are bit-identical to calling
        ``record`` per element (boundary elements are recomputed with the
        scalar formula — see module docstring); ``total`` may differ in
        the last ulps from the sequential sum.
        """
        xs = np.asarray(xs, dtype=np.float64).ravel()
        if xs.size == 0:
            return
        if xs.size == 1:
            self.record(float(xs[0]))
            return
        pos = (np.log(np.maximum(xs, self.lo)) / _LOG10 - self._log_lo) \
            * self.bpd
        bins = np.minimum(pos.astype(np.int64), self._nbins - 1)
        np.maximum(bins, 0, out=bins)
        # boundary guard: np.log vs math.log ulp differences flip int()
        # only exactly at integer positions — redo those few scalars
        near = np.abs(pos - np.rint(pos)) < 1e-9
        if near.any():
            for j in np.nonzero(near)[0]:
                bins[j] = self._bin(float(xs[j]))
        np.add.at(self.counts, bins, 1)
        self.count += int(xs.size)
        self.total += float(xs.sum())
        mn = float(xs.min())
        mx = float(xs.max())
        if self.min is None or mn < self.min:
            self.min = mn
        if self.max is None or mx > self.max:
            self.max = mx

    def merge(self, other: "StreamingHistogram") -> "StreamingHistogram":
        """Fold ``other``'s samples into this histogram in place.

        Bin counts add exactly, so a merge is bit-identical (counts /
        count / min / max) to having recorded the concatenated sample
        streams into one histogram — per-lane and per-stage histograms
        aggregate into fleet-level views without re-recording.  Requires
        identical bin geometry; returns ``self`` for chaining."""
        if (self.lo, self.hi, self.bpd) != (other.lo, other.hi, other.bpd):
            raise ValueError(
                f"cannot merge histograms with different bin geometry: "
                f"(lo={self.lo}, hi={self.hi}, bpd={self.bpd}) vs "
                f"(lo={other.lo}, hi={other.hi}, bpd={other.bpd})")
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        if other.min is not None:
            self.min = other.min if self.min is None \
                else min(self.min, other.min)
        if other.max is not None:
            self.max = other.max if self.max is None \
                else max(self.max, other.max)
        return self

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Interpolated quantile; 0.0 when empty (zero-completion safe)."""
        if not self.count:
            return 0.0
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        rank = q * (self.count - 1)
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            c = int(c)          # counts is int64 array: keep the math in
            if seen + c > rank:  # Python floats (JSON-serializable output)
                # mid-rank fraction: the k-th of c samples in a bin sits
                # at (k + 0.5)/c of the bin's span, so a single-count bin
                # interpolates to its geometric MIDPOINT instead of
                # pinning to the upper edge (which biased every sparse
                # low-q quantile a full bin high)
                frac = (rank - seen + 0.5) / c
                lo, hi = self._edge(i), self._edge(i + 1)
                est = lo * (hi / lo) ** min(max(frac, 0.0), 1.0)
                # exact extrema beat bin edges at the distribution ends
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def p50(self) -> float:
        return self.quantile(0.50)

    def p95(self) -> float:
        return self.quantile(0.95)

    def p99(self) -> float:
        return self.quantile(0.99)

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean(),
            "p50": self.p50(),
            "p95": self.p95(),
            "p99": self.p99(),
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
        }

    def __repr__(self):
        s = self.summary()
        return (f"<StreamingHistogram n={s['count']} mean={s['mean']:.4g} "
                f"p50={s['p50']:.4g} p99={s['p99']:.4g}>")
