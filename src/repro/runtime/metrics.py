"""Streaming latency metrics for the dispatch hot path.

The engine records one sample per completed frame (end-to-end) and one per
stage visit, so the recorder must be O(1) per sample with no growing
state — a sorted-list percentile would turn the hot loop quadratic.

``StreamingHistogram`` keeps log-spaced bins (fixed count, geometric
edges): ``record`` is a single ``log`` + increment, quantiles walk the
(small, fixed) bin array and interpolate geometrically inside the winning
bin.  Relative quantile error is bounded by the bin width ratio
(``10**(1/bins_per_decade)``, ~7% at the default 32 bins/decade), which is
far below the 2x-scale effects the tail-latency benchmarks track.

The same class doubles as each lane's observed service-time distribution:
the hedge deadline is a quantile of it, so the estimator must stay cheap
enough to update on every ``_lane_done``.
"""
from __future__ import annotations

import math
from typing import Optional

_LOG10 = math.log(10.0)


class StreamingHistogram:
    """Log-spaced histogram: O(1) record, O(bins) quantile, fixed memory."""

    __slots__ = ("lo", "hi", "bpd", "_log_lo", "_nbins", "counts",
                 "count", "total", "min", "max")

    def __init__(self, lo: float = 1e-6, hi: float = 1e5,
                 bins_per_decade: int = 32):
        assert lo > 0 and hi > lo
        self.lo = lo
        self.hi = hi
        self.bpd = bins_per_decade
        self._log_lo = math.log(lo) / _LOG10
        decades = math.log(hi / lo) / _LOG10
        self._nbins = int(math.ceil(decades * bins_per_decade)) + 1
        self.counts = [0] * self._nbins
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def _bin(self, x: float) -> int:
        if x <= self.lo:
            return 0
        i = int((math.log(x) / _LOG10 - self._log_lo) * self.bpd)
        return min(i, self._nbins - 1)

    def _edge(self, i: int) -> float:
        return self.lo * 10.0 ** (i / self.bpd)

    def record(self, x: float):
        self.counts[self._bin(x)] += 1
        self.count += 1
        self.total += x
        if self.min is None or x < self.min:
            self.min = x
        if self.max is None or x > self.max:
            self.max = x

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Interpolated quantile; 0.0 when empty (zero-completion safe)."""
        if not self.count:
            return 0.0
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        rank = q * (self.count - 1)
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c > rank:
                # mid-rank fraction: the k-th of c samples in a bin sits
                # at (k + 0.5)/c of the bin's span, so a single-count bin
                # interpolates to its geometric MIDPOINT instead of
                # pinning to the upper edge (which biased every sparse
                # low-q quantile a full bin high)
                frac = (rank - seen + 0.5) / c
                lo, hi = self._edge(i), self._edge(i + 1)
                est = lo * (hi / lo) ** min(max(frac, 0.0), 1.0)
                # exact extrema beat bin edges at the distribution ends
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def p50(self) -> float:
        return self.quantile(0.50)

    def p95(self) -> float:
        return self.quantile(0.95)

    def p99(self) -> float:
        return self.quantile(0.99)

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean(),
            "p50": self.p50(),
            "p95": self.p95(),
            "p99": self.p99(),
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
        }

    def __repr__(self):
        s = self.summary()
        return (f"<StreamingHistogram n={s['count']} mean={s['mean']:.4g} "
                f"p50={s['p50']:.4g} p99={s['p99']:.4g}>")
