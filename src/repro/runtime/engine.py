"""VDiSK streaming engine: discrete-event execution of cartridge pipelines.

This is the CHAMP fork of VDiSK's core loop, §2.3/§3.3 of the paper:

  * pub/sub message routing between chained cartridges over the shared bus
  * bounded inter-stage queues with backpressure ("if a cartridge's
    processing time is slower than the input rate, it can signal upstream
    modules ... to throttle the data flow")
  * hot-swap events: removal pauses the pipeline ~0.5 s, buffers in-flight
    frames, bridges the gap (PassThrough) when types allow or raises an
    operator alert; insertion pauses ~2 s (dominated by model re-load)
  * zero message loss across swaps (buffered frames replay afterward)
  * per-stage utilization -> the §4.3 power model

Replicated stages (paper §4.1, Table 1).  A capability slot is a *lane
group*: it may hold N replica cartridges (N identical sticks on the hub).
Dispatch over a group follows the slot's mode:

  * ``shard``     — each frame goes to the least-loaded replica; the group
                    streams at ~N× a single device (modulo shared-bus
                    contention).  Pulling one replica of a multi-lane group
                    degrades throughput instead of pausing the pipeline.
  * ``broadcast`` — every frame is transferred to every replica (serially
                    on the shared bus) and completes when the slowest
                    finishes: the Table 1 redundant-inference experiment.
                    With a single broadcast group the engine reproduces the
                    published 1→5-device FPS curve exactly.

Adaptive micro-batching: when a shard lane falls behind (≥2 frames
queued) it drains up to ``queue_cap`` frames in one service cycle at
``DeviceModel.batch_marginal`` marginal cost per extra frame, and the
batch crosses the bus as one transfer (amortized base overhead).

Final-stage outputs cross the bus back to the host like any other hop —
except in broadcast mode, where the per-replica result fetch (a few score
bytes) overlaps the next frame's compute window, matching how §4.1
measures pure inference FPS.

Tail-latency fast path.  Field biometrics is latency-bound: the operator
waits on the *slowest* frame.  Three mechanisms keep the dispatch hot
path tail-aware:

  * Heterogeneous lane groups — a slot may mix accelerator types
    (ncs2 + coral replicas).  ``dispatch="ewma"`` (the default) picks the
    lane minimizing estimated completion time ``(backlog + 1) * est_s``,
    where ``est_s`` is a per-lane EWMA of observed service time seeded
    from the replica's ``DeviceModel`` and updated on every
    ``_lane_done`` — a slow stick carries proportionally less load
    instead of gating the group.  ``dispatch="naive"`` keeps the PR 2
    queue-depth-only discipline as the measurable baseline.
  * Hedged dispatch (``hedge=True``, shard mode) — when a lane has not
    finished a service cycle by an adaptive deadline (a quantile of its
    own observed service distribution), the cycle's frames are
    speculatively re-enqueued on the best alternate lane.  First
    completion wins; the loser's queued copy is cancelled, an in-service
    loser finishes but its bus handoff is *suppressed*
    (``SharedBus.suppress``), and delivery stays exactly-once.  This is
    the event-driven face of ``runtime.health``'s tied-request machinery:
    lane service start/finish and every hedge flow through a
    ``HealthMonitor`` so one straggler ledger covers both paths.
  * Streaming latency histograms — ``EngineReport`` records end-to-end
    and per-stage latency into O(1)-per-sample log-spaced histograms
    (``runtime.metrics``), so p50/p95/p99 come free without the hot loop
    retaining or sorting per-frame samples.

Multi-hub bus fabric.  The ``bus`` argument may be a ``FabricRouter``
(``repro.bus.fabric``) instead of a bare ``SharedBus``: devices are
partitioned across hubs (the registry tracks each replica's hub), every
transfer is charged to its route — source-hub egress, inter-hub link,
destination-hub ingress, with *per-hub* endpoint counts driving the
arbitration term — and lane groups may span hubs.  Handoffs pre-route
to the destination lane's hub (the arrival prefers a lane on the
charged hub); hedge backup copies crossing to another hub are charged
ingress-only to the *destination* hub's bus and arrive only after that
transfer completes; hedge losers are suppressed at the router, saving
link + destination-hub time before the inter-hub leg starts.  A one-hub
fabric is bit-identical to the bare bus.

Quorum broadcast.  A broadcast slot with ``quorum=k`` decides each
frame at the k-th replica completion instead of the slowest (first k of
N results win); the stragglers keep computing but their result
handoffs are suppressed on the bus.  ``quorum=None`` (or ``k >= N``)
reproduces Table 1 exactly.

Power-governed dispatch (paper §4.3).  A ``PowerGovernor``
(``runtime.power``) rides every engine: per-lane energy is integrated
from busy/idle time (O(1) per service cycle; ``EngineReport.power``
carries the per-hub/per-lane breakdown), and when per-hub watt budgets
are configured (``power_budget_w=``) a thermal state machine throttles
(duty-stretched service cycles — the stretch is forced idle, and a
throttled lane's *effective* ``est_s`` inflates in ``pick_lane`` so it
sheds load) or parks an over-budget hub (no new cycles until the draw
estimate cools; dispatch routes around parked hubs, their queued frames
wait — zero loss).  Unbudgeted runs are bit-identical to the
pre-governor engine.

Fabric-aware dispatch.  On a fabric, ``pick_lane`` is no longer
hub-blind: the pre-routed handoff decision folds the router's current
route cost (src egress + link + dst ingress, including each leg's FIFO
backlog) into the ``(backlog + 1) * est_s`` completion estimate, so a
cross-hub dispatch only wins when it beats the local queue *including*
the toll — traffic stays hub-local when the link runs hot.
``route_aware=False`` keeps the hub-blind discipline as the measurable
baseline; on a one-hub fabric (or a bare bus) the toll is constant
across lanes, so behavior is bit-identical either way.

Vectorized epoch-stepped core (``core="epoch"``, the default).  The
classic loop pops one heap event at a time and pays a linear Python scan
over lanes per dispatch; at fleet scale (10k lanes) the scan *is* the
simulator.  The epoch core drains event *cohorts* — every live event at
the earliest timestamp, in the identical seq order — via
``HeapEventQueue.pop_cohort``/``fire`` (so same-instant cancellations
still work), and reads dispatch state from lane-id-indexed NumPy arrays
(``runtime.lanestate``): ``pick_lane`` becomes an argmin over
``(backlog + 1) * est_s`` arrays and ``free_capacity`` a clipped sum.
Every ``_Lane`` mutation writes through to the arrays, so the vectorized
expressions read the very same float64 the scalar path would — the
argmin fast path is an *exact* replacement (NumPy argmin and ``min()``
both take the first minimal element), and the epoch core fires events in
exactly heap order; runs are therefore bit-identical between cores.  The
fast path engages only for plain weighted shard dispatch over
``VECTOR_PICK_MIN``-or-more lanes with no fabric toll / governor /
chaos hooks — everything else (control events, hedge alternates,
routed handoffs, chaos exclusions) keeps the scalar scan, which is
exact by construction.  ``core="heap"`` keeps the original
pop-per-event loop with the scalar scan as the measurable baseline
(``BENCH_engine.json`` tracks the epoch/heap events-per-sec ratio).

Timing is virtual (deterministic, calibrated DeviceModels); payload compute
is optionally real JAX (``execute_payloads=True``) so correctness tests can
assert data flows through reconfigurations unchanged.  Service-time jitter
(``DeviceModel.jitter_p``) is drawn from a hash of (lane, seq), keeping
straggler scenarios replayable.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import time
import warnings
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import numpy as np

from repro.bus.fabric import FabricRouter
from repro.bus.simulator import BusParams, SharedBus
from repro.core.cartridge import Cartridge, PassThrough
from repro.core import messages as msg
from repro.runtime.events import HeapEventQueue
from repro.runtime import faults as flt
from repro.runtime import trace as trc
from repro.runtime.lanestate import LaneStateBank, TrackedDeque
from repro.runtime.faults import (FaultPlan, QuarantinePolicy, RetryPolicy,
                                  frame_checksum)
from repro.runtime.frontdoor import FrontDoor
from repro.runtime.health import HealthMonitor, QuarantineLedger
from repro.runtime.metrics import StreamingHistogram
from repro.runtime.power import PowerGovernor
from repro.runtime.registry import CapabilityRegistry, SlotRecord
from repro.runtime.trace import FlightRecorder, MetricsRegistry, jsonable

HANDSHAKE_S = 0.35       # detection + addressing + capability handshake
REMOVE_PAUSE_S = 0.5     # paper §4.2: ~0.5 s reconfiguration on removal
# a broadcast replica's result fetch ("a few score bytes", §4.1) — the
# per-straggler handoff a quorum decision suppresses
BROADCAST_RESULT_BYTES = 256

DISPATCH_DISCIPLINES = ("ewma", "naive")
ENGINE_CORES = ("epoch", "heap")
# below this group size the argmin fast path loses to the scalar scan
# (NumPy fancy-indexing has ~µs fixed cost); both paths are exact, so the
# threshold is purely a speed knob
VECTOR_PICK_MIN = 16

# profiling-hook phase classification, by event callback name
_DISPATCH_EVENTS = frozenset((
    "_frame_arrival", "_try_start_lane", "_unpark_retry",
    "_try_start_broadcast", "_arrive_next", "_arrive_checked",
    "_hedge_copy_arrive", "_migrate_arrive", "_retry_handoff",
    "_retry_broadcast", "_reinject"))
_SERVICE_EVENTS = frozenset(("_lane_done", "_broadcast_done"))


def _event_phase(fn: Callable) -> str:
    name = getattr(fn, "__name__", "")
    if name in _DISPATCH_EVENTS:
        return "dispatch"
    if name in _SERVICE_EVENTS:
        return "service"
    return "control"

# routed handoff verdict: the destination group exists but no lane of it
# is reachable right now (dead lanes / down links) — hold and retry, never
# pretend the route is local
_BLOCKED = object()


@dataclass
class StageStats:
    processed: int = 0
    busy_s: float = 0.0
    blocked_s: float = 0.0
    batches: int = 0
    max_batch: int = 0


def _hedge_counters() -> dict:
    return {"issued": 0, "won_by_backup": 0, "wasted": 0,
            "cancelled_queued": 0, "migrated": 0,
            "cross_hub": 0, "dropped_in_flight": 0}


def _fault_counters() -> dict:
    return {"injected": 0, "lane_crash": 0, "lane_hang": 0,
            "hub_power_loss": 0, "link_down": 0, "link_up": 0,
            "hang_promoted": 0, "redispatched": 0, "retries": 0,
            "budget_exhausted": 0, "corrupt_detected": 0, "resends": 0,
            "quarantined": 0, "reinstated": 0,
            "reroute_blocked": 0, "duplicates": 0}


class _ProfileDict(dict):
    """Deprecation shim for direct ``report.profile[...]`` access.

    Phase timings now live in the metrics registry under
    ``engine.profile.*`` (``EngineReport.metrics()``); keyed reads of
    this dict warn once per call site so downstream code migrates.
    Equality/iteration stay silent — tests asserting ``profile == {}``
    and the registry's own ingest are not deprecated usage."""

    def _warn(self):
        warnings.warn(
            "direct EngineReport.profile[...] access is deprecated; read "
            "engine.profile.* from EngineReport.metrics() instead",
            DeprecationWarning, stacklevel=3)

    def __getitem__(self, key):
        self._warn()
        return dict.__getitem__(self, key)

    def get(self, key, default=None):
        self._warn()
        return dict.get(self, key, default)


@dataclass
class EngineReport:
    frames_in: int = 0
    frames_out: int = 0
    # per-frame samples, kept for debugging and exact-equality assertions
    # (tests, serve.py); all aggregate stats come from latency_hist
    latencies: list = field(default_factory=list)
    downtime: list = field(default_factory=list)  # (t0, t1, reason)
    alerts: list = field(default_factory=list)
    stage_stats: dict = field(default_factory=dict)   # lane name -> StageStats
    groups: dict = field(default_factory=dict)        # slot -> group summary
    swap_log: list = field(default_factory=list)      # (t, kind, detail)
    bus: dict = field(default_factory=dict)           # SharedBus.stats()
    bus_bytes: int = 0
    sim_time: float = 0.0
    # streaming latency accounting: O(1) per completed frame / stage visit
    latency_hist: StreamingHistogram = field(default_factory=StreamingHistogram)
    stage_hist: dict = field(default_factory=dict)    # stage name -> histogram
    hedges: dict = field(default_factory=_hedge_counters)
    power: dict = field(default_factory=dict)         # PowerGovernor.report()
    faults: dict = field(default_factory=_fault_counters)
    last_out_t: float = 0.0    # when the last frame completed — goodput
                               # denominator robust to trailing fault events
    # per-phase wall time (dispatch/service/bookkeeping/control), filled
    # only when the engine runs with profile=True.  Keyed access is
    # deprecated in favour of metrics() -> engine.profile.*
    profile: dict = field(default_factory=_ProfileDict)
    # event-queue lifetime counters (HeapEventQueue.stats()), filled at
    # the end of run()
    events: dict = field(default_factory=dict)
    # FrontDoor.summary() — per-tenant admission/shed/SLO ledger, filled
    # at the end of run() when a front door is attached
    frontdoor: dict = field(default_factory=dict)
    # the flight recorder, when the engine ran with trace enabled
    trace: Optional[FlightRecorder] = None

    def energy_j(self) -> float:
        """Total electrical energy the fleet drew (joules, virtual time)."""
        return self.power.get("total_j", 0.0)

    def avg_power_w(self) -> float:
        return self.power.get("avg_w", 0.0)

    @property
    def lost(self) -> int:
        return self.frames_in - self.frames_out

    def throughput(self) -> float:
        # zero-completion safe: an idle/empty run reports 0.0, not a crash
        if not self.frames_out or self.sim_time <= 0.0:
            return 0.0
        return self.frames_out / self.sim_time

    def mean_latency(self) -> float:
        # exact: the histogram keeps a running total/count (not binned),
        # so this is O(1) and zero-completion safe
        return self.latency_hist.mean()

    def p50(self) -> float:
        return self.latency_hist.p50()

    def p95(self) -> float:
        return self.latency_hist.p95()

    def p99(self) -> float:
        return self.latency_hist.p99()

    def latency_summary(self) -> dict:
        """End-to-end + per-stage latency percentiles (hedge-aware: only
        winning copies ever reach the end-to-end histogram)."""
        return {
            "end_to_end": self.latency_hist.summary(),
            "stages": {k: h.summary() for k, h in self.stage_hist.items()},
            "hedges": dict(self.hedges),
        }

    def merged_downtime(self) -> list:
        """Downtime windows with overlaps coalesced.  Swap pauses stack
        (``_pause`` extends ``paused_until``) and a halt window can span
        a pause, so the raw ``downtime`` entries may overlap; summing
        them double-counts the shared seconds.  Returns disjoint
        ``(t0, t1)`` intervals, sorted."""
        spans = sorted((t0, t1) for t0, t1, _ in self.downtime if t1 > t0)
        merged: list = []
        for t0, t1 in spans:
            if merged and t0 <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], t1)
            else:
                merged.append([t0, t1])
        return [(t0, t1) for t0, t1 in merged]

    def total_downtime(self) -> float:
        return sum(t1 - t0 for t0, t1 in self.merged_downtime())

    def availability(self) -> float:
        """Fraction of the run the pipeline accepted work, computed over
        the merged (non-overlapping) downtime windows."""
        if self.sim_time <= 0.0:
            return 1.0
        return max(0.0, 1.0 - self.total_downtime() / self.sim_time)

    def metrics(self) -> MetricsRegistry:
        """One namespaced snapshot of every counter the run produced.

        Stable dotted names (``engine.*``, ``hedge.*``, ``faults.*``,
        ``power.*``, ``bus.*``, ``stage.*``, ``trace.*``) so dashboards
        and regression gates can key on them across releases.  Scalar
        leaves only — list-valued stats (per-frame latencies, downtime
        windows) stay on the report itself."""
        reg = MetricsRegistry()
        reg.set("engine.frames.in", self.frames_in)
        reg.set("engine.frames.out", self.frames_out)
        reg.set("engine.frames.lost", self.lost)
        reg.set("engine.sim_time_s", self.sim_time)
        reg.set("engine.throughput_fps", self.throughput())
        reg.set("engine.availability", self.availability())
        reg.set("engine.downtime_s", self.total_downtime())
        reg.set("engine.alerts", len(self.alerts))
        reg.set("engine.swaps", len(self.swap_log))
        reg.ingest("engine.latency", self.latency_hist.summary())
        reg.ingest("engine.events", self.events)
        # dict.copy keeps the deprecation shim silent on internal reads
        reg.ingest("engine.profile", dict.copy(self.profile))
        reg.ingest("hedge", self.hedges)
        reg.ingest("faults", self.faults)
        reg.ingest("bus", self.bus)
        reg.ingest("power",
                   {k: v for k, v in self.power.items() if k != "lanes"})
        for name, tstats in self.frontdoor.get("tenants", {}).items():
            reg.ingest(f"tenant.{name}", tstats)
        for name, hist in self.stage_hist.items():
            reg.ingest(f"stage.{name}", hist.summary())
        for name, st in self.stage_stats.items():
            reg.ingest(f"lane.{name}", dataclasses.asdict(st))
        if self.trace is not None:
            reg.ingest("trace", self.trace.snapshot())
        return reg

    def to_dict(self) -> dict:
        """JSON-safe dict with a stable schema (numpy scalars coerced)."""
        return jsonable({
            "schema": "champ.engine_report.v1",
            "frames": {"in": self.frames_in, "out": self.frames_out,
                       "lost": self.lost},
            "sim_time_s": self.sim_time,
            "last_out_t": self.last_out_t,
            "throughput_fps": self.throughput(),
            "availability": self.availability(),
            "latency": self.latency_summary(),
            "downtime": [list(w) for w in self.downtime],
            "downtime_merged": [list(w) for w in self.merged_downtime()],
            "alerts": list(self.alerts),
            "swap_log": [list(e) for e in self.swap_log],
            "groups": self.groups,
            "stage_stats": {k: dataclasses.asdict(v)
                            for k, v in self.stage_stats.items()},
            "bus": self.bus,
            "bus_bytes": self.bus_bytes,
            "power": self.power,
            "faults": self.faults,
            "hedges": dict(self.hedges),
            "events": self.events,
            "frontdoor": self.frontdoor,
            "profile": dict.copy(self.profile),
            "metrics": self.metrics().snapshot(),
        })

    def to_json(self, path: Optional[str] = None,
                indent: Optional[int] = None) -> str:
        """Serialize ``to_dict()``; optionally also write it to ``path``."""
        text = json.dumps(self.to_dict(), indent=indent, sort_keys=True)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text)
        return text


class _Lane:
    """One physical replica device inside a lane group.

    Dispatch-relevant scalars (``est_s``, ``ready_at``, busy/held
    occupancy, queue depth) are mirrored into a ``LaneStateBank`` row so
    the vectorized pick path reads them as arrays.  Scalar *reads* stay
    plain attributes (no property overhead on the hot path); the few
    mutation sites go through ``set_*`` write-through helpers (or the
    ``TrackedDeque`` for queue depth)."""

    def __init__(self, cart: Cartridge, queue_cap: int,
                 bank: LaneStateBank):
        self.cart = cart
        self.bank = bank
        self.lid = bank.alloc()            # row in the lane-state arrays
        self.queue: deque = TrackedDeque(bank, self.lid)
        self.queue_cap = queue_cap
        self.busy = False
        self.held: Optional[list] = None   # finished batch, downstream full
        self.ready_at = 0.0                # handshake+load gate for live adds
        self.parked_wait = False           # an unpark retry is already queued
        self.stats = StageStats()
        self.pos = 0                       # last known chain position
        self.slot = -1                     # last known capability slot
        self.hub = 0                       # fabric hub this device plugs into
        self.bfree_at = 0.0                # broadcast: this replica's own
                                           # previous frame's finish time
        # chaos-fabric state (inert unless a FaultPlan is installed)
        self.inflight = None               # (svc_handle, batch) in service
        self.wd_handle: Optional[int] = None  # armed watchdog event
        self.cycle_seq = 0                 # guards stale watchdog firings
        self.hang_next = False             # hang fault latched while idle
        # per-lane service-time model: EWMA point estimate (seeded from the
        # calibrated DeviceModel) + streaming distribution for the hedge
        # deadline quantile.  Both are per batch-normalized frame cost.
        self.est_s = cart.device.service_s
        bank.est_s[self.lid] = self.est_s
        self.svc_hist = StreamingHistogram(lo=1e-7, hi=1e4)

    def observe(self, svc_norm: float, alpha: float):
        """Online service-time update on every completed service cycle."""
        self.est_s += alpha * (svc_norm - self.est_s)
        self.bank.est_s[self.lid] = self.est_s
        self.svc_hist.record(svc_norm)

    def set_busy(self, busy: bool):
        self.busy = busy
        self.bank.busy[self.lid] = 1 if busy else 0

    def set_held(self, held: Optional[list]):
        self.held = held
        self.bank.heldn[self.lid] = len(held) if held else 0

    def set_ready_at(self, t: float):
        self.ready_at = t
        self.bank.ready_at[self.lid] = t

    def reset_queue(self, items=()):
        """Replace the queue contents (migration keep-list)."""
        self.queue = TrackedDeque(self.bank, self.lid, items)

    def backlog(self) -> int:
        return len(self.queue) + (1 if self.busy else 0) + \
            (len(self.held) if self.held else 0)


class _HedgeTask:
    """Tracks one hedged message through a lane group: which copies exist,
    where, and whether the race is decided.  Exactly-once delivery hinges
    on ``copies`` reaching zero exactly when every live copy has been
    delivered (winner), cancelled (queued loser), or suppressed
    (in-service loser)."""

    __slots__ = ("seq", "message", "primary", "backup", "check_handle",
                 "winner", "copies")

    def __init__(self, seq: int, message: msg.Message, primary: _Lane,
                 check_handle: Optional[int]):
        self.seq = seq
        self.message = message         # as enqueued at this stage (pre-fn)
        self.primary = primary
        self.backup: Optional[_Lane] = None
        self.check_handle = check_handle
        self.winner: Optional[_Lane] = None
        self.copies = 1


class _LaneGroup:
    """All replicas of one capability slot, plus broadcast-mode state."""

    def __init__(self, rec: SlotRecord, queue_cap: int):
        self.slot = rec.slot
        self.mode = rec.mode
        self.quorum = rec.quorum
        self.lanes: List[_Lane] = []
        self.lane_ids: set = set()         # id(lane) index for O(1) lookup
        self.lids = np.empty(0, dtype=np.int64)  # member rows, lane order
        self.queue_cap = queue_cap
        self.bqueue: deque = deque()       # broadcast: group-level queue
        self.bbusy = False
        self.bheld: Optional[msg.Message] = None
        self.pos = 0

    @property
    def name(self) -> str:
        return self.lanes[0].cart.name if self.lanes else f"slot{self.slot}"

    def refresh_lids(self):
        """Re-derive the member lane-id array (after any membership
        change); index i of ``lids`` is ``lanes[i]``, so an argmin over
        bank rows maps straight back to a lane."""
        self.lids = np.fromiter((l.lid for l in self.lanes),
                                dtype=np.int64, count=len(self.lanes))

    def free_capacity(self, bank: Optional[LaneStateBank] = None) -> int:
        if self.mode == "broadcast":
            return max(self.queue_cap - len(self.bqueue), 0)
        if bank is not None and len(self.lanes) >= VECTOR_PICK_MIN:
            return int(np.maximum(self.queue_cap - bank.qlen[self.lids],
                                  0).sum())
        return sum(max(self.queue_cap - len(l.queue), 0) for l in self.lanes)

    def _pick_vector(self, now: float,
                     bank: LaneStateBank) -> Optional[_Lane]:
        """Argmin-over-arrays fast path for plain weighted shard dispatch.

        Bit-exact vs. the scalar scan: the arrays hold the very same
        float64s the attributes do, ``(backlog + 1) * est_s`` runs the
        same float ops elementwise, masking the not-ready pool with +inf
        preserves index order, and ``np.argmin`` returns the *first*
        minimal element exactly like ``min()``."""
        lids = self.lids
        eta = (bank.qlen[lids] + bank.busy[lids] + bank.heldn[lids] + 1) \
            * bank.est_s[lids]
        ready = bank.ready_at[lids] <= now
        if not ready.all() and ready.any():
            eta = np.where(ready, eta, np.inf)
        return self.lanes[int(np.argmin(eta))]

    def pick_lane(self, now: float, weighted: bool = True,
                  exclude: Optional[_Lane] = None,
                  prefer_hub: Optional[int] = None,
                  toll=None, est_scale=None,
                  parked=None, dead=None,
                  bank: Optional[LaneStateBank] = None) -> Optional[_Lane]:
        """Dispatch choice; prefers lanes past their handshake gate.

        ``weighted`` (the default) minimizes estimated completion time of
        one more frame, ``(backlog + 1) * est_s`` — with heterogeneous or
        drifting replicas the slow stick only wins when the fast lanes'
        queues outweigh its service-time handicap.  For equal ``est_s``
        the ordering degenerates to plain least-loaded, so homogeneous
        groups behave exactly like the unweighted discipline.
        ``weighted=False`` is the queue-depth-only baseline.  ``exclude``
        lets the hedge path pick the best *alternate* lane.
        ``prefer_hub`` narrows the pool to one fabric hub when possible —
        a routed handoff already paid to reach that hub, so the arrival
        lands there unless the hub has no lanes left.

        ``toll`` (lane -> seconds) adds the routed transfer cost to the
        weighted estimate — fabric-aware dispatch: a remote lane only
        wins when it beats the local queue *including* the route.  On a
        one-hub fabric the toll is constant across lanes, so the argmin
        (and therefore the run) is unchanged.  ``est_scale``
        (lane -> multiplier) inflates a throttled lane's effective
        ``est_s``.  ``parked`` (hub -> bool) steers work away from
        power-parked hubs; they remain a last resort so frames are never
        dropped when every lane of a group is parked (they queue and run
        after the unpark).

        ``dead`` (lane -> bool) is a *hard* exclusion — a crashed or
        quarantined lane must never be picked, not even as a last
        resort.  With every lane dead the pick returns None and the
        caller buffers the frame (zero loss; reinstatement drains it).

        ``bank`` (the epoch core's lane-state arrays) enables the
        ``_pick_vector`` fast path when no other hook narrows or rescores
        the pool — the O(n) scan collapses to one argmin.
        """
        if bank is not None and weighted and exclude is None \
                and prefer_hub is None and toll is None \
                and est_scale is None and parked is None and dead is None \
                and len(self.lanes) >= VECTOR_PICK_MIN:
            return self._pick_vector(now, bank)
        lanes = self.lanes if exclude is None else \
            [l for l in self.lanes if l is not exclude]
        if dead is not None:
            lanes = [l for l in lanes if not dead(l)]
        if not lanes:
            return None
        ready = [l for l in lanes if l.ready_at <= now]
        pool = ready or lanes
        if parked is not None:
            awake = [l for l in pool if not parked(l.hub)]
            if awake:
                pool = awake
        if prefer_hub is not None:
            on_hub = [l for l in pool if l.hub == prefer_hub]
            if on_hub:
                pool = on_hub
        if weighted:
            if toll is None and est_scale is None:
                return min(pool, key=lambda l: (l.backlog() + 1) * l.est_s)

            def eta(l):
                est = (l.backlog() + 1) * l.est_s
                if est_scale is not None:
                    est *= est_scale(l)
                if toll is not None:
                    est += toll(l)
                return est
            return min(pool, key=eta)
        return min(pool, key=lambda l: (len(l.queue) + (1 if l.busy else 0)))


class StreamEngine:
    """Lane-group topology engine. Groups are rebuilt on registry events."""

    # fraction of a frame's remaining SLO budget the hedge deadline may
    # consume before forking a backup (front-door tenants with slo_s)
    slo_hedge_frac = 0.5

    def __init__(self, registry: CapabilityRegistry, bus,
                 *, queue_cap: int = 8, execute_payloads: bool = False,
                 microbatch: bool = True, event_queue=None,
                 dispatch: str = "ewma", hedge: bool = False,
                 hedge_quantile: float = 0.95, hedge_min_obs: int = 8,
                 hedge_margin: float = 1.25, ewma_alpha: float = 0.25,
                 governor: Optional[PowerGovernor] = None,
                 power_budget_w=None, route_aware: bool = True,
                 fault_plan: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None,
                 quarantine: Optional[QuarantinePolicy] = None,
                 watchdog_margin: float = 8.0,
                 core: str = "epoch", profile: bool = False,
                 trace=None, trace_sample: int = 1,
                 trace_capacity: int = 65536,
                 frontdoor: Optional[FrontDoor] = None):
        if dispatch not in DISPATCH_DISCIPLINES:
            raise ValueError(f"unknown dispatch discipline {dispatch!r}")
        if core not in ENGINE_CORES:
            raise ValueError(f"unknown engine core {core!r}")
        self.core = core
        self.profile_enabled = bool(profile)
        self._prof = {"dispatch_s": 0.0, "service_s": 0.0,
                      "control_s": 0.0, "bookkeeping_s": 0.0,
                      "events": {"dispatch": 0, "service": 0, "control": 0}}
        # lane-id-indexed dispatch state; row 0 is a reserved scrap row
        # that retired lanes point at, so a late in-flight completion on a
        # detached lane can never scribble on a recycled row
        self.lanestate = LaneStateBank()
        self._scrap_lid = self.lanestate.alloc()
        # the heap core keeps the scalar scan as the measurable baseline
        self._pick_bank = self.lanestate if core == "epoch" else None
        self.registry = registry
        self.bus = bus                  # SharedBus, or a FabricRouter
        self.fabric: Optional[FabricRouter] = \
            bus if isinstance(bus, FabricRouter) else None
        self.queue_cap = queue_cap
        self.execute_payloads = execute_payloads
        self.microbatch = microbatch
        self.dispatch = dispatch
        # energy metering is always on; budgets engage the state machine
        self.governor = governor if governor is not None \
            else PowerGovernor(budget_w=power_budget_w)
        self.route_aware = route_aware
        self.hedge = hedge
        self.hedge_quantile = hedge_quantile
        self.hedge_min_obs = hedge_min_obs
        self.hedge_margin = hedge_margin
        self.ewma_alpha = ewma_alpha
        # the tied-request ledger shared with the polled datacenter path:
        # lane service start/finish + every hedge land here, and its
        # straggler_factor doubles as the cold-start hedge deadline factor
        self.health = HealthMonitor()
        self.now = 0.0
        self.paused_until = 0.0
        self.halted_since: Optional[float] = None   # missing capability
        self._in_swap = False
        self.report = EngineReport()
        # O(log n) event core; benchmarks inject events.ListEventQueue to
        # measure the linear-scan baseline on the same workload
        self._events = event_queue if event_queue is not None \
            else HeapEventQueue()
        self._groups: List[_LaneGroup] = []
        self._live_groups: set = set()       # id(group) of current groups
        self._group_by_slot: dict = {}       # slot -> _LaneGroup
        self._slot_index: dict = {}          # slot -> chain position
        self._lane_by_cart: dict = {}        # id(cart) -> _Lane (live lanes)
        self._retired_stats: dict = {}       # name -> StageStats (unplugged)
        self._hold_buffer: deque = deque()   # frames buffered during pauses
        self._hedges: dict = {}              # (slot, seq) -> _HedgeTask
        self._frame_seq = itertools.count()
        # chaos fabric: everything below is inert (and every chaos branch
        # in the hot path is skipped) until a non-empty FaultPlan is
        # installed, so fault-free runs stay bit-identical to Table 1
        self.faults: FaultPlan = fault_plan or FaultPlan()
        self.retry = retry or RetryPolicy()
        self.qledger = QuarantineLedger(quarantine)
        self.watchdog_margin = watchdog_margin
        self._chaos = False
        self._down: set = set()              # id(lane) of failed lanes
        self._delivered: set = set()         # seqs delivered (chaos only)
        # flight recorder: ONE flag gates every instrumentation branch
        # (the _chaos lesson) — trace=None means zero touched state, so
        # untraced runs are structurally bit-identical to Table 1
        if isinstance(trace, FlightRecorder):
            self._trace: Optional[FlightRecorder] = trace
        elif trace:
            self._trace = FlightRecorder(
                capacity=trace_capacity, sample=trace_sample,
                seed=fault_plan.seed if fault_plan is not None else 0)
        else:
            self._trace = None
        self._svc_sids: dict = {}            # id(lane) -> open service sids
        if self._trace is not None:
            rec = self._trace
            rec.clock = lambda: self.now
            self.report.trace = rec
            self.qledger.tracer = rec
            self.governor.tracer = rec
        # fleet front door: every frame source flows through it; a
        # trivial door (one default tenant, no caps) is a pure
        # pass-through, so single-operator runs stay bit-identical
        self._fd: Optional[FrontDoor] = None
        registry.subscribe(self._on_registry_event)
        self._rebuild()
        if fault_plan is not None:
            self.install_fault_plan(fault_plan)
        if frontdoor is not None:
            self.attach_frontdoor(frontdoor)

    # -- pipeline construction ------------------------------------------------
    def _rebuild(self):
        """Re-derive lane groups from the registry.  Group and lane objects
        are *reused* (keyed by slot / cartridge identity) so in-flight
        events referencing them stay valid across hot-swaps."""
        old_groups = self._groups
        # snapshot lane membership NOW: group objects are reused below, so
        # their .lanes lists get overwritten before the rescue pass runs
        old_membership = [(g, list(g.lanes)) for g in old_groups]
        old_group_by_slot = {g.slot: g for g in old_groups}
        records = self.registry.records()
        validate_chain([r.cartridge for r in records])
        self._groups = []
        kept_lanes = set()
        for i, rec in enumerate(records):
            g = old_group_by_slot.get(rec.slot) or _LaneGroup(
                rec, self.queue_cap)
            g.mode = rec.mode
            g.quorum = rec.quorum
            g.pos = i
            g.lanes = []
            for cart in rec.replicas:
                lane = self._lane_by_cart.get(id(cart)) or _Lane(
                    cart, self.queue_cap, self.lanestate)
                self._lane_by_cart[id(cart)] = lane
                lane.pos = i
                lane.slot = rec.slot
                lane.hub = self.registry.hub_of(cart)
                self.lanestate.hub[lane.lid] = lane.hub
                if self.fabric is not None and \
                        not 0 <= lane.hub < self.fabric.n_hubs:
                    # fail at (hot-)plug time, not frames later inside a
                    # routed transfer deep in the event loop
                    raise ValueError(
                        f"{cart.name} placed on hub {lane.hub}, but the "
                        f"fabric has hubs 0..{self.fabric.n_hubs - 1}")
                g.lanes.append(lane)
                kept_lanes.add(id(lane))
            g.lane_ids = {id(l) for l in g.lanes}
            g.refresh_lids()
            self._groups.append(g)
        # rescue queued/held frames of lanes and groups that left the chain.
        # A held batch has already been serviced: when the lane's slot
        # survives (replica detach) it must re-enter DOWNSTREAM of the
        # group, not through it again; when the whole slot vanished, its
        # old position already maps to the stage that shifted into the gap.
        kept_slots = {g.slot for g in self._groups}
        for g, lanes in old_membership:
            held_off = 1 if g.slot in kept_slots else 0
            for l in lanes:
                if id(l) not in kept_lanes:
                    self._rescue_lane(l, l.pos, held_off)
            if g.slot not in kept_slots:
                for m in g.bqueue:
                    self._hold_buffer.append((g.pos, m))
                g.bqueue.clear()
                if g.bheld is not None:
                    self._hold_buffer.append((g.pos, g.bheld))
                    g.bheld = None
        # prune unplugged lanes (no unbounded growth across swaps) but keep
        # a handle on their stats — the StageStats object is shared with any
        # still-in-flight batch, so late updates remain visible in reports
        for key, lane in list(self._lane_by_cart.items()):
            if id(lane) not in kept_lanes:
                self._retired_stats[lane.cart.name] = lane.stats
                del self._lane_by_cart[key]
                # recycle the bank row; the lane object (which in-flight
                # events may still reference) is repointed at the scrap
                # row so its late writes land nowhere meaningful
                self.lanestate.release(lane.lid)
                lane.lid = lane.queue._lid = self._scrap_lid
        self._group_by_slot = {g.slot: g for g in self._groups}
        self._live_groups = {id(g) for g in self._groups}
        # records() is slot-sorted, so position == sorted-slot index
        self._slot_index = {g.slot: i for i, g in enumerate(self._groups)}
        # power meter follows the physical population (detached sticks
        # stop drawing; new ones start accruing idle immediately)
        self._sync_governor()

    def _sync_governor(self):
        """Reconcile the power meter with the *powered* population: a
        crashed lane or a hub that lost power stops drawing exactly like
        a detached stick (and resumes idle draw on reinstatement)."""
        self.governor.sync(self.now, {
            id(lane.cart): (lane.cart.name, lane.cart.device, lane.hub)
            for lane in self._lane_by_cart.values()
            if id(lane) not in self._down})

    def _rescue_lane(self, lane: _Lane, pos: int, held_off: int = 0):
        for m in lane.queue:
            task = self._hedges.get((lane.slot, m.seq))
            if task is not None and m.meta.get("_hedge_copy"):
                if task.copies > 1:
                    # a speculative duplicate whose other copy is still
                    # live: dropping it preserves exactly-once delivery
                    task.copies -= 1
                    task.backup = None
                    self.report.hedges["cancelled_queued"] += 1
                    continue
                # defensive: last live copy — promote it to sole owner
                del self._hedges[(lane.slot, m.seq)]
                m.meta.pop("_hedge_copy", None)
            self._hold_buffer.append((pos, m))
        lane.queue.clear()
        if lane.held is not None:
            for m in lane.held:
                self._hold_buffer.append((pos + held_off, m))
            lane.set_held(None)

    def _on_registry_event(self, kind: str, rec):
        # engine-driven swaps rebuild once at the end of their transaction;
        # direct registry edits (tests) get a safe rebuild here.
        if not self._in_swap:
            self._rebuild()

    def _group_of_lane(self, lane: _Lane) -> Optional[_LaneGroup]:
        g = self._group_by_slot.get(lane.slot)
        if g is not None and id(lane) in g.lane_ids:
            return g
        return None

    def _n_endpoints(self, hub: Optional[int] = None) -> int:
        """Arbitration contention count: the whole fleet on a single bus,
        or — with a fabric — just the endpoints sharing one hub."""
        if hub is None or self.fabric is None:
            return self.registry.n_endpoints() or 1
        return self.registry.n_endpoints_on(hub) or 1

    def _gov_pick_kwargs(self) -> dict:
        """Power-aware dispatch hooks for ``pick_lane`` — empty (zero
        overhead) unless a budget is configured."""
        if not self.governor.active:
            return {}
        gov, now = self.governor, self.now
        return {"est_scale": lambda l: gov.inflation(now, l.hub),
                "parked": lambda h: gov.parked(now, h)}

    def _pick_kwargs(self) -> dict:
        """All dispatch hooks for ``pick_lane``: the governor's (when a
        budget is active) plus — under a fault plan — the chaos fabric's
        hard exclusion of down lanes and the quarantine ledger's
        probation penalty (a reinstated lane re-earns traffic instead of
        re-entering the EWMA loop at full weight)."""
        kw = self._gov_pick_kwargs()
        if not self._chaos:
            return kw
        down = self._down
        kw["dead"] = lambda l: id(l) in down
        ql, now = self.qledger, self.now
        gov_scale = kw.get("est_scale")
        if gov_scale is None:
            kw["est_scale"] = lambda l: ql.penalty(l.cart.name, now)
        else:
            kw["est_scale"] = \
                lambda l: gov_scale(l) * ql.penalty(l.cart.name, now)
        return kw

    def _route_hub(self, idx: int, src_hub: Optional[int] = None,
                   nbytes: int = 0) -> Optional[int]:
        """Where the router should land a handoff bound for stage ``idx``:
        the hub of the lane the group would dispatch to right now.  None
        for the sink, a broadcast group (host-staged: its per-lane ingress
        is charged at broadcast start), or an empty group — those routes
        stay local to the source hub.

        With ``src_hub`` given (fabric-aware dispatch, the default) the
        choice charges each candidate the router's *current* cost of
        reaching its hub — src egress + link + ingress, including FIFO
        backlog — so a cross-hub lane only wins when it beats the local
        queue including the toll.  ``route_aware=False`` (or the naive
        discipline) keeps the hub-blind estimate as the measurable
        baseline.

        Under a fault plan the choice is also reachability-aware: down
        lanes are excluded, and with any fabric link down so are lanes
        the source hub cannot reach.  If the group has lanes but none is
        reachable, returns the ``_BLOCKED`` sentinel — the caller must
        hold the batch and retry, never route as if local."""
        if self.fabric is None or idx >= len(self._groups):
            return None
        g = self._groups[idx]
        if g.mode == "broadcast":
            return None
        weighted = self.dispatch == "ewma"
        toll = None
        if self.route_aware and weighted and src_hub is not None:
            fab, now = self.fabric, self.now
            toll = lambda l: fab.route_cost(src_hub, l.hub, nbytes, t=now)
        kw = self._pick_kwargs()
        guarded = False
        if self._chaos and src_hub is not None \
                and self.fabric.has_down_links():
            guarded = True
            fab2, prev_dead = self.fabric, kw.get("dead")
            kw["dead"] = lambda l: ((prev_dead is not None and prev_dead(l))
                                    or not fab2.link_ok(src_hub, l.hub))
        lane = g.pick_lane(self.now, weighted=weighted, toll=toll,
                           bank=self._pick_bank, **kw)
        if lane is None and g.lanes and (guarded or self._chaos):
            return _BLOCKED
        return lane.hub if lane is not None else None

    # -- event queue ----------------------------------------------------------
    def _push_event(self, t: float, fn: Callable, *args) -> int:
        return self._events.push(t, fn, args)

    def _run_heap(self, until: float):
        """The classic pop-per-event loop (``core="heap"``)."""
        ev = self._events
        while len(ev) and ev.peek_time() <= until:
            t, _, fn, args = ev.pop()
            self.now = max(self.now, t)
            fn(*args)

    def _run_epoch(self, until: float):
        """Cohort-draining loop (``core="epoch"``): all live events at the
        earliest timestamp come out in one queue call, in the identical
        seq order the heap loop would pop them.  ``fire`` skips members
        cancelled by an earlier member of the same cohort; events pushed
        *during* a cohort at the same instant get larger seqs and form
        the next cohort at that timestamp, exactly matching heap order."""
        ev = self._events
        fire = ev.fire
        while len(ev) and ev.peek_time() <= until:
            cohort = ev.pop_cohort()
            t = cohort[0][0]
            if t > self.now:
                self.now = t
            for _, h, fn, args in cohort:
                if fire(h):
                    fn(*args)

    def _run_profiled(self, until: float):
        """Either core, with per-event phase timing (``profile=True`` —
        kept out of the unprofiled loops so profiling costs nothing when
        off)."""
        ev = self._events
        prof = self._prof
        counts = prof["events"]
        clock = time.perf_counter
        cohorts = self.core == "epoch"
        while len(ev) and ev.peek_time() <= until:
            if cohorts:
                cohort = ev.pop_cohort()
                t = cohort[0][0]
                if t > self.now:
                    self.now = t
            else:
                e = ev.pop()
                self.now = max(self.now, e[0])
                cohort = (e,)
            for _, h, fn, args in cohort:
                # fire() must interleave with execution: an earlier
                # member of this cohort may cancel a later one
                if cohorts and not ev.fire(h):
                    continue
                phase = _event_phase(fn)
                t0 = clock()
                fn(*args)
                prof[phase + "_s"] += clock() - t0
                counts[phase] += 1

    def run(self, until: float) -> EngineReport:
        if self.profile_enabled:
            self._run_profiled(until)
            t_book = time.perf_counter()
        elif self.core == "heap":
            self._run_heap(until)
        else:
            self._run_epoch(until)
        # sim_time = when work actually finished (not the horizon)
        self.report.sim_time = self.now
        self.report.bus_bytes = self.bus.bytes_moved
        self.report.bus = self.bus.stats()
        self.report.events = self._events.stats()
        self.report.power = self.governor.report(self.now)
        if self._fd is not None:
            self.report.frontdoor = self._fd.summary()
        if self._chaos:
            self.report.faults["quarantine"] = self.qledger.summary()
        self.report.stage_stats.update(self._retired_stats)
        for lane in self._lane_by_cart.values():
            self.report.stage_stats[lane.cart.name] = lane.stats
        for g in self._groups:
            self.report.groups[g.slot] = {
                "mode": g.mode,
                "quorum": g.quorum,
                "lanes": [l.cart.name for l in g.lanes],
                "devices": [l.cart.device.name for l in g.lanes],
                "hubs": [l.hub for l in g.lanes],
                # broadcast: how far each replica's own compute trails the
                # group's quorum decisions.  A permanently slower stick
                # under quorum=k accumulates real backlog — the pipeline
                # does not wait for it, but operators must see it lagging
                # rather than read its dispatch-time busy_s as >100%
                # utilization.
                "straggler_lag_s": [round(max(0.0, l.bfree_at - self.now), 6)
                                    for l in g.lanes]
                if g.mode == "broadcast" else None,
                "est_s": [round(l.est_s, 6) for l in g.lanes],
                "heterogeneous": len({(l.cart.device.name,
                                       l.cart.device.service_s)
                                      for l in g.lanes}) > 1,
                "processed": sum(l.stats.processed for l in g.lanes),
            }
        if self.profile_enabled:
            self._prof["bookkeeping_s"] += time.perf_counter() - t_book
            p = self._prof
            self.report.profile = _ProfileDict({
                "core": self.core,
                "dispatch_s": p["dispatch_s"],
                "service_s": p["service_s"],
                "control_s": p["control_s"],
                "bookkeeping_s": p["bookkeeping_s"],
                "events": dict(p["events"]),
            })
        return self.report

    # -- source ---------------------------------------------------------------
    def attach_frontdoor(self, fd: FrontDoor) -> FrontDoor:
        """Install the multi-tenant admission controller.  All frame
        sources flow through it from here on: ``feed()`` targets its
        default tenant, ``feed_tenant()`` any registered tenant.  The
        door paces off live fleet capacity (parked/throttled hubs and
        quarantined lanes shrink the credit pool — backpressure instead
        of ballooning queues)."""
        if self._fd is not None:
            raise RuntimeError("a front door is already attached")
        fd.bind(clock=lambda: self.now,
                schedule=lambda t, fn: self._push_event(t, fn),
                admit=self._admit_frame,
                capacity=self._capacity_fps,
                tracer=self._trace)
        self._fd = fd
        return fd

    def feed(self, n_frames: int, interval_s: float, payload_fn=None,
             frame_bytes: int = 150528, t0: float = 0.0):
        """Single-operator source: the single-default-tenant special case
        of ``feed_tenant`` (a trivial front door is attached lazily; its
        pass-through admission is bit-identical to direct ingest)."""
        if self._fd is None:
            self.attach_frontdoor(FrontDoor())
        self.feed_tenant(self._fd.default_tenant, n_frames, interval_s,
                         payload_fn=payload_fn, frame_bytes=frame_bytes,
                         t0=t0)

    def feed_tenant(self, tenant: str, n_frames: int, interval_s: float,
                    payload_fn=None, frame_bytes: int = 150528,
                    t0: float = 0.0):
        """Schedule ``n_frames`` arrivals for ``tenant``; each is offered
        to the front door at its arrival instant."""
        if self._fd is None:
            raise RuntimeError("no front door attached — construct the "
                               "engine with frontdoor=, or use feed()")
        if tenant not in self._fd.tenant_names:
            raise KeyError(f"unknown tenant {tenant!r}")
        for i in range(n_frames):
            self._push_event(t0 + i * interval_s, self._tenant_arrival,
                             tenant, payload_fn(i) if payload_fn else None,
                             frame_bytes)

    def _tenant_arrival(self, tenant: str, payload, frame_bytes):
        """One offered frame: tenant id rides the message end-to-end,
        and the SLO deadline (when the tenant has one) is stamped so the
        hedge machinery can spend the remaining budget."""
        meta = {"bytes": frame_bytes, "tenant": tenant}
        slo = self._fd.tenant(tenant).slo_s
        if slo is not None:
            meta["_slo_t"] = self.now + slo
        m = msg.Message(kind=msg.IMAGE_FRAME, seq=next(self._frame_seq),
                        payload=payload, t_created=self.now, meta=meta)
        self._fd.offer(tenant, m, self.now)

    def _frame_arrival(self, payload, frame_bytes):
        m = msg.Message(kind=msg.IMAGE_FRAME, seq=next(self._frame_seq),
                        payload=payload, t_created=self.now,
                        meta={"bytes": frame_bytes})
        self._admit_frame(m)

    def _admit_frame(self, m: msg.Message):
        """A frame passed admission (or arrived pre-door): count it,
        trace ingest, and dispatch — or hold-buffer during pauses.
        ``m.t_created`` is the offer time, so any front-door queue wait
        counts toward end-to-end latency and the tenant's SLO."""
        self.report.frames_in += 1
        if self._trace is not None and self._trace.admit(m.seq):
            self._trace.frame_begin(m.seq, self.now)
            args = {"bytes": m.meta.get("bytes", 0)}
            if "tenant" in m.meta:
                args["tenant"] = m.meta["tenant"]
            self._trace.instant(trc.INGEST, self.now, m.seq, track="source",
                                **args)
        if self.now < self.paused_until or self.halted_since is not None \
                or not self._groups:
            self._hold_buffer.append((0, m))  # paper: buffered, not dropped
            return
        self._enqueue(0, m)

    def _capacity_fps(self):
        """``(live_fps, nominal_fps)`` of the bottleneck stage — the
        front door's pacing signal.  Nominal counts every lane at its
        EWMA rate; live drops dead lanes and parked hubs, stretches
        throttled hubs by their duty inflation, and discounts lanes on
        quarantine probation — so admission shrinks with fleet health
        instead of letting queues balloon.  A paused or halted pipeline
        is live-zero: the door parks arrivals in bounded tenant queues
        rather than flooding the hold buffer."""
        halted = self.now < self.paused_until or self.halted_since is not None
        gov = self.governor if self.governor.active else None
        live_min = nom_min = float("inf")
        for g in self._groups:
            if not g.lanes:
                continue
            if g.mode == "broadcast":
                # barrier-paced: the group advances at the slowest replica
                nom = 1.0 / max(max(l.est_s for l in g.lanes), 1e-9)
                up = [l for l in g.lanes
                      if not (self._chaos and id(l) in self._down)
                      and not (gov is not None
                               and gov.parked(self.now, l.hub))]
                live = 0.0 if not up else \
                    1.0 / max(max(l.est_s for l in up), 1e-9)
            else:
                nom = live = 0.0
                for l in g.lanes:
                    r = 1.0 / max(l.est_s, 1e-9)
                    nom += r
                    if self._chaos and id(l) in self._down:
                        continue
                    if gov is not None:
                        if gov.parked(self.now, l.hub):
                            continue
                        r /= max(gov.inflation(self.now, l.hub), 1e-9)
                    if self._chaos:
                        r /= max(self.qledger.penalty(l.cart.name, self.now),
                                 1e-9)
                    live += r
            nom_min = min(nom_min, nom)
            live_min = min(live_min, live)
        if nom_min == float("inf"):
            return 0.0, 0.0
        # The shared bus is a serialized medium every hop crosses, and on
        # USB-class fabrics it — not the lanes — can be the bottleneck.
        # Hop count and payload sizes vary per pipeline, so it is measured
        # rather than modeled: amortized bus-busy seconds per delivered
        # frame is exact in the limit and independent of offered load.
        done = self.report.frames_out
        if done >= 8 and self.bus.busy_s > 0:
            bus_fps = done / self.bus.busy_s
            nom_min = min(nom_min, bus_fps)
            live_min = min(live_min, bus_fps)
        return (0.0 if halted else live_min), nom_min

    # -- stage machinery ------------------------------------------------------
    # Events reference _Lane/_LaneGroup objects, not indices: hot-swap
    # rebuilds the topology mid-flight, so positions are resolved at event
    # time and a message whose lane vanished is re-buffered (zero loss).
    def _enqueue(self, idx: int, m: msg.Message):
        if idx >= len(self._groups):
            self._complete(m)
            return
        g = self._groups[idx]
        m.meta["_t_stage"] = self.now      # per-stage latency breakdown
        if g.mode == "broadcast":
            m.meta.pop("_hub", None)
            g.bqueue.append(m)
            if self._trace is not None and self._trace.watches(m.seq):
                self._trace.instant(trc.DISPATCH, self.now, m.seq,
                                    track=g.name, stage=g.name,
                                    mode="broadcast", quorum=g.quorum)
            self._try_start_broadcast(g)
            return
        lane = g.pick_lane(self.now, weighted=self.dispatch == "ewma",
                           prefer_hub=m.meta.pop("_hub", None),
                           bank=self._pick_bank,
                           **self._pick_kwargs())
        if lane is None:
            # no live lane right now (all down/quarantined): buffer, zero
            # loss — reinstatement drains the hold buffer
            self._hold_buffer.append((idx, m))
            return
        if self._trace is not None and self._trace.watches(m.seq):
            self._trace_dispatch(g, lane, m)
        lane.queue.append(m)
        self._try_start_lane(lane)

    def _trace_dispatch(self, g, lane: _Lane, m: msg.Message):
        """DISPATCH instant carrying the argmin inputs that chose the
        lane — backlog, EWMA estimate, the resulting ETA, plus throttle
        inflation and probation toll when those hooks were active — so a
        frame's routing decision is auditable from the trace alone."""
        backlog = lane.backlog()
        args = {"stage": g.name, "lane": lane.cart.name, "hub": lane.hub,
                "backlog": backlog, "est_s": lane.est_s,
                "eta_s": (backlog + 1) * lane.est_s, "mode": g.mode}
        if self.governor.active:
            args["est_scale"] = self.governor.inflation(self.now, lane.hub)
        if self._chaos:
            args["probation_toll_s"] = self.qledger.penalty(
                lane.cart.name, self.now)
        self._trace.instant(trc.DISPATCH, self.now, m.seq,
                            track=lane.cart.name, **args)

    def _trace_service_begin(self, lane: _Lane, batch, b: int, infl: float):
        """Open one SERVICE span per traced frame in the cycle.  A lane
        runs at most one cycle at a time (busy flag), so the open sids
        key by lane identity; ``_lane_done`` / ``_fail_lane`` close
        them."""
        rec = self._trace
        gal = getattr(lane.cart, "gallery", None)
        if gal is not None and getattr(gal, "tracer", None) is None:
            gal.tracer = rec          # late-bound: carts attach post-init
        sids = None
        for m in batch:
            if rec.watches(m.seq):
                sid = rec.begin(trc.SERVICE, self.now, m.seq,
                                track=lane.cart.name, batch=b,
                                hub=lane.hub, infl=infl)
                if sids is None:
                    sids = []
                sids.append(sid)
        if sids is not None:
            self._svc_sids[id(lane)] = sids

    def _trace_service_end(self, lane: _Lane, status: str):
        """Close the lane's open SERVICE spans.  Completed match-stage
        cycles attach the gallery scan counters (rows_scored /
        scan_fraction) so ANN pruning is visible per frame."""
        sids = self._svc_sids.pop(id(lane), None)
        if sids is None:
            return
        rec = self._trace
        extra = {"status": status}
        gal = getattr(lane.cart, "gallery", None)
        if gal is not None:
            ms = getattr(gal, "last_match_stats", None)
            if ms:
                extra["rows_scored"] = ms.get("rows_scored")
                extra["scan_fraction"] = ms.get("scan_fraction")
                extra["match_mode"] = ms.get("mode")
        for sid in sids:
            rec.end(sid, self.now, **extra)

    def _trace_transfer(self, batch, done: float, nbytes: int,
                        src: Optional[int], dst: Optional[int], **extra):
        """Emit a (pre-closed) TRANSFER span per traced frame: arrival
        time is deterministic at schedule time, so no open/close pairing
        is needed.  On a fabric the per-leg breakdown (source egress /
        inter-hub link / destination ingress) rides along."""
        rec = self._trace
        watched = [m for m in batch if rec.watches(m.seq)]
        if not watched:
            return
        if src is None and dst is None:
            track = "bus"
        else:
            # mirror the router's collapse rule: a missing side is a
            # host-local leg on the other's hub (``FabricRouter._route``)
            s = src if src is not None else dst
            d = dst if dst is not None else s
            track = f"hub{s}->hub{d}" if s != d else f"hub{s}"
        args = {"bytes": nbytes, **extra}
        if self.fabric is not None:
            legs = self.fabric.route_legs(src, dst, nbytes)
            if legs:
                args.update(legs)
        for m in watched:
            rec.span(trc.TRANSFER, self.now, done, m.seq, track=track,
                     **args)

    def _serviced_orphan_target(self, slot: int, pos: int) -> int:
        """Where an already-serviced message of a vanished lane/group goes:
        past its slot's current position if the slot still exists, else the
        old position (which the downstream neighbor shifted into)."""
        if slot in self._slot_index:
            return self._slot_index[slot] + 1
        return pos

    def _reinject(self, pos: int, m: msg.Message):
        """Put an orphaned in-flight message back into the pipeline at the
        slot that shifted into its old position.  During a pause/halt it
        waits in the hold buffer (drained by ``_resume``); otherwise — e.g.
        after a pauseless replica detach — it re-enters immediately."""
        if self.now < self.paused_until or self.halted_since is not None \
                or not self._groups:
            self._hold_buffer.append((pos, m))
            return
        self._enqueue(min(pos, len(self._groups)), m)

    def _service_time(self, lane: _Lane, b: int, seq: int):
        """Batch service time on a lane, with deterministic heavy-tail
        jitter (stall multiplier drawn from a hash of lane identity and the
        leading frame's seq).  Returns (svc, batch_factor) so callers can
        recover the batch-normalized per-cycle cost ``svc / factor``."""
        dev = lane.cart.device
        factor = 1.0 + (b - 1) * dev.batch_marginal
        svc = dev.service_s * factor
        if dev.jitter_p > 0.0:
            u = zlib.crc32(f"{lane.cart.name}:{seq}".encode()) / 0xFFFFFFFF
            if u < dev.jitter_p:
                svc *= dev.jitter_mult
        return svc, factor

    def _try_start_lane(self, lane: _Lane):
        g = self._group_of_lane(lane)
        if g is None or self.halted_since is not None:
            return
        if self._chaos and id(lane) in self._down:
            return                           # quarantined: no new cycles
        if lane.busy or lane.held is not None or not lane.queue:
            return
        if self.now < self.paused_until:
            self._push_event(self.paused_until, self._try_start_lane, lane)
            return
        if lane.ready_at > self.now:         # replica still handshaking
            self._push_event(lane.ready_at, self._try_start_lane, lane)
            return
        if self.governor.active and self.governor.parked(self.now, lane.hub):
            # hub over its watt budget even throttled: no new cycles until
            # the draw estimate cools.  The governor's closed-form decay
            # gives the recheck time; queued frames wait (zero loss).  One
            # pending retry per lane — a deep queue must not multiply
            # identical wake-ups every park interval.
            if not lane.parked_wait:
                lane.parked_wait = True
                eta = self.governor.unpark_eta(self.now, lane.hub)
                self._push_event(max(eta, self.now + 1e-3),
                                 self._unpark_retry, lane)
            return
        # throttled hub: the cycle is duty-stretched (the stretch is forced
        # idle — the compute itself is unchanged, so est_s/svc_hist keep
        # learning the *device*, and dispatch sees the stretch via
        # est_scale instead of a poisoned EWMA)
        infl = self.governor.inflation(self.now, lane.hub) \
            if self.governor.active else 1.0
        # adaptive micro-batch: drain the backlog in one service cycle.
        # Under throttle the batch is capped so one duty-stretched cycle
        # commits at most half the thermal horizon of draw — otherwise a
        # single stretched 8-frame cycle outlives the control period and
        # the governor can only watch the budget sail by.
        b = 1
        if self.microbatch and len(lane.queue) >= 2:
            b = min(len(lane.queue), self.queue_cap)
            if infl > 1.0:
                dev = lane.cart.device
                room = 0.5 * self.governor.tau_of(lane.hub) / \
                    max(dev.service_s * infl, 1e-12)
                b_cap = 1 + int(max(room - 1.0, 0.0) /
                                max(dev.batch_marginal, 1e-6))
                b = max(1, min(b, b_cap))
        batch = [lane.queue.popleft() for _ in range(b)]
        lane.set_busy(True)
        svc, factor = self._service_time(lane, b, batch[0].seq)
        dur = svc * infl if infl != 1.0 else svc
        if self.hedge and g.mode == "shard" and len(g.lanes) > 1:
            self._arm_hedges(g, lane, batch, factor, infl)
        if self.execute_payloads:
            # one dispatch per micro-batch: match-type stages coalesce the
            # whole batch into a single kernel call (Cartridge.process_batch)
            batch = lane.cart.process_batch(batch)
        self.health.start_request(lane.cart.name, batch[0].seq, self.now)
        lane.stats.busy_s += dur
        lane.stats.batches += 1
        lane.stats.max_batch = max(lane.stats.max_batch, b)
        self.governor.on_cycle_start(self.now, lane.cart, dur, svc)
        if self._trace is not None:
            self._trace_service_begin(lane, batch, b, infl)
        handle = self._push_event(self.now + dur, self._lane_done, lane,
                                  batch, svc / factor)
        if self._chaos:
            # remember the cycle so a crash can cancel it and recover the
            # batch; arm the watchdog that promotes a hang into a failure
            lane.cycle_seq += 1
            lane.inflight = (handle, batch)
            if lane.hang_next:
                lane.hang_next = False       # the service never completes
                self._events.cancel(handle)
            lane.wd_handle = self._push_event(
                self.now + max(self._watchdog_deadline(lane, factor) * infl,
                               dur + 1e-6),
                self._watchdog_fire, lane, lane.cycle_seq)

    def _unpark_retry(self, lane: _Lane):
        lane.parked_wait = False
        self._try_start_lane(lane)

    # -- hedged dispatch (tied requests over shard lanes) ---------------------
    def _hedge_deadline(self, lane: _Lane, factor: float) -> float:
        """Adaptive deadline for one service cycle: a quantile of the
        lane's own observed (batch-normalized) service distribution, with
        a margin so a typical cycle never triggers; before enough
        observations exist, fall back to the health monitor's straggler
        factor over the EWMA estimate."""
        h = lane.svc_hist
        if h.count >= self.hedge_min_obs:
            base = max(h.quantile(self.hedge_quantile), lane.est_s)
        else:
            base = lane.est_s * self.health.straggler_factor
        return base * factor * self.hedge_margin

    def _arm_hedges(self, g: _LaneGroup, lane: _Lane, batch: list,
                    factor: float, infl: float = 1.0):
        """Register hedge tasks for every first-copy message entering
        service, sharing one deadline event per cycle (they finish
        together, so they stall together).  ``infl`` scales the deadline
        by the hub's throttle stretch: a duty-cycled lane is slow by
        decree, not stalling."""
        fresh = [m for m in batch
                 if (lane.slot, m.seq) not in self._hedges]
        if not fresh:
            return
        deadline = self._hedge_deadline(lane, factor) * infl
        if self._fd is not None and self._fd.has_slo:
            # SLO-driven hedging: spend at most half the tightest
            # remaining per-tenant budget waiting on a straggler, but
            # never hedge inside a single expected service time (a
            # blown deadline is already lost; a zero-delay hedge storm
            # would finish the job)
            cap = None
            for m in fresh:
                s = m.meta.get("_slo_t")
                if s is not None and (cap is None or s < cap):
                    cap = s
            if cap is not None:
                room = (cap - self.now) * self.slo_hedge_frac
                if room < deadline:
                    deadline = max(room, lane.est_s * factor * infl)
        handle = self._push_event(self.now + deadline, self._hedge_check,
                                  g, lane, tuple(m.seq for m in fresh))
        for m in fresh:
            self._hedges[(lane.slot, m.seq)] = _HedgeTask(
                m.seq, m, lane, handle)

    def _hedge_check(self, g: _LaneGroup, lane: _Lane, seqs: tuple):
        """Deadline fired before the primary finished: speculatively
        re-enqueue each still-undecided message on the best alternate
        lane.  First completion wins (``_filter_hedged``).  The stalled
        lane's *queued* frames haven't started anywhere, so they migrate
        to healthy lanes outright — rebalancing, not speculation."""
        if self._group_by_slot.get(g.slot) is not g:
            return                          # group left the chain mid-wait
        stalled = False
        for seq in seqs:
            task = self._hedges.get((g.slot, seq))
            if task is None or task.winner is not None \
                    or task.backup is not None:
                continue
            stalled = True
            alt = g.pick_lane(self.now, weighted=self.dispatch == "ewma",
                              exclude=task.primary, bank=self._pick_bank,
                              **self._pick_kwargs())
            if alt is None or len(alt.queue) >= self.queue_cap:
                continue                    # no headroom to speculate into
            task.check_handle = None
            task.backup = alt
            task.copies += 1
            copy = dataclasses.replace(
                task.message,
                meta=dict(task.message.meta, _hedge_copy=True))
            self.report.hedges["issued"] += 1
            self.health.record_backup(task.primary.cart.name, self.now, seq)
            if self._trace is not None and self._trace.watches(seq):
                self._trace.instant(
                    trc.HEDGE_FORK, self.now, seq, track=alt.cart.name,
                    primary=task.primary.cart.name, backup=alt.cart.name,
                    stalled_s=self.now - task.message.meta.get(
                        "_t_stage", self.now),
                    cross_hub=self.fabric is not None
                    and alt.hub != task.primary.hub)
            if self.fabric is not None and alt.hub != task.primary.hub:
                # the speculative copy must cross to the backup's hub.  It
                # is charged ingress-only to the *destination* hub's bus
                # (the host re-sends from its own buffer: no source-hub
                # egress, no inter-hub link), so speculation never erodes
                # the source hub's arbitration budget.  The copy only
                # becomes runnable once that transfer lands.
                self.report.hedges["cross_hub"] += 1
                done = self.fabric.transfer(
                    self.now, self._msg_bytes(copy),
                    self._n_endpoints(alt.hub), src=None, dst=alt.hub)
                if self._trace is not None and self._trace.watches(seq):
                    self._trace.span(
                        trc.TRANSFER, self.now, done, seq,
                        track=f"host->hub{alt.hub}",
                        bytes=self._msg_bytes(copy), hedge_copy=True)
                self._push_event(done, self._hedge_copy_arrive,
                                 task, alt, copy)
            else:
                alt.queue.append(copy)
                self._try_start_lane(alt)
        if stalled and id(lane) in g.lane_ids:
            self._migrate_queue(g, lane)

    def _hedge_copy_arrive(self, task: _HedgeTask, alt: _Lane,
                           copy: msg.Message):
        """A cross-hub speculative copy finished its ingress transfer.  If
        the race resolved, the backup lane unplugged, or the lane's queue
        filled while it was on the wire, drop it at the hub boundary — it
        was never queued, so exactly-once needs only the copy count
        decrement."""
        if task.winner is not None or task.backup is not alt \
                or self._group_of_lane(alt) is None \
                or len(alt.queue) >= self.queue_cap:
            task.copies -= 1
            if task.backup is alt:
                task.backup = None
            if task.copies <= 0:
                self._hedges.pop((task.primary.slot, task.seq), None)
            self.report.hedges["dropped_in_flight"] += 1
            return
        alt.queue.append(copy)
        self._try_start_lane(alt)

    def _migrate_queue(self, g: _LaneGroup, lane: _Lane):
        """Move a presumed-stalled lane's unstarted backlog to its peers.
        Backup copies parked here stay put (their primary is live
        elsewhere); everything else re-lands on the best alternate lane
        with headroom.  On a fabric, migrating to a lane on another hub
        is a real host re-send: like hedge copies it is charged
        ingress-only to the *destination* hub's bus, and the frame only
        becomes runnable there once the transfer lands — no free
        cross-hub moves."""
        if not lane.queue:
            return
        keep: deque = deque()
        weighted = self.dispatch == "ewma"
        gov_kw = self._pick_kwargs()
        for m in lane.queue:
            if m.meta.get("_hedge_copy"):
                keep.append(m)
                continue
            alt = g.pick_lane(self.now, weighted=weighted, exclude=lane,
                              bank=self._pick_bank, **gov_kw)
            if alt is None or len(alt.queue) >= self.queue_cap:
                keep.append(m)
                continue
            self.report.hedges["migrated"] += 1
            if self.fabric is not None and alt.hub != lane.hub:
                done = self.fabric.transfer(
                    self.now, self._msg_bytes(m),
                    self._n_endpoints(alt.hub), src=None, dst=alt.hub)
                self._push_event(done, self._migrate_arrive, alt, m)
                continue
            alt.queue.append(m)
            self._try_start_lane(alt)
        lane.reset_queue(keep)

    def _migrate_arrive(self, alt: _Lane, m: msg.Message):
        """A migrated frame finished crossing to the healthy lane's hub.
        Unlike a hedge copy it is the frame's ONLY live instance, so if
        the target vanished or filled while it was on the wire it
        re-enters the pipeline (zero loss) instead of being dropped."""
        if self._group_of_lane(alt) is not None \
                and len(alt.queue) < self.queue_cap:
            alt.queue.append(m)
            self._try_start_lane(alt)
            return
        self._reinject(self._slot_index.get(alt.slot, alt.pos), m)

    def _cancel_queued_copy(self, lane: _Lane, seq: int) -> bool:
        for m in lane.queue:
            if m.seq == seq and m.meta.get("_hedge_copy"):
                lane.queue.remove(m)
                return True
        return False

    def _filter_hedged(self, lane: _Lane, batch: list) -> list:
        """Resolve hedge races for a completed service cycle.  Returns the
        messages this lane may deliver downstream: first copy home wins,
        every other copy is cancelled (queued) or suppressed (serviced) —
        delivery is exactly-once by construction."""
        deliver = []
        slot = lane.slot
        for m in batch:
            task = self._hedges.get((slot, m.seq))
            if task is None:
                deliver.append(m)
                continue
            if task.winner is None:
                task.winner = lane
                if task.check_handle is not None:
                    self._events.cancel(task.check_handle)
                    task.check_handle = None
                if lane is task.backup:
                    self.report.hedges["won_by_backup"] += 1
                task.copies -= 1            # the winning copy exits
                loser = task.primary if lane is task.backup else task.backup
                if task.copies > 0 and loser is not None and \
                        self._cancel_queued_copy(loser, m.seq):
                    task.copies -= 1
                    self.report.hedges["cancelled_queued"] += 1
                if task.copies <= 0:
                    del self._hedges[(slot, m.seq)]
                m.meta.pop("_hedge_copy", None)
                if self._trace is not None and self._trace.watches(m.seq):
                    self._trace.instant(
                        trc.HEDGE_WIN, self.now, m.seq,
                        track=lane.cart.name, winner=lane.cart.name,
                        won_by_backup=lane is task.backup)
                deliver.append(m)
            else:
                # this copy lost the race after being serviced: its result
                # never crosses the bus (suppressed handoff).  On a fabric
                # the suppression happens at the router, before the
                # inter-hub leg starts — saving link + destination-hub
                # time, not just the local egress.
                task.copies -= 1
                if task.copies <= 0:
                    del self._hedges[(slot, m.seq)]
                self.report.hedges["wasted"] += 1
                if self._trace is not None and self._trace.watches(m.seq):
                    self._trace.instant(
                        trc.HEDGE_LOSS, self.now, m.seq,
                        track=lane.cart.name, loser=lane.cart.name,
                        suppressed=True)
                if self.fabric is not None:
                    g2 = self._group_by_slot.get(slot)
                    dst = self._route_hub(g2.pos + 1, src_hub=lane.hub,
                                          nbytes=self._msg_bytes(m)) \
                        if g2 is not None else None
                    if dst is _BLOCKED:     # nothing reachable to save:
                        dst = None          # book the local egress only
                    self.fabric.suppress(
                        self._msg_bytes(m), src=lane.hub, dst=dst,
                        t=self.now, n_endpoints=self._n_endpoints(lane.hub),
                        dst_endpoints=self._n_endpoints(dst)
                        if dst is not None else 1)
                else:
                    self.bus.suppress(self._msg_bytes(m))
        return deliver

    def _lane_done(self, lane: _Lane, batch: list, svc_norm: float = 0.0):
        if self._chaos:
            lane.inflight = None
            if lane.wd_handle is not None:   # cycle completed: disarm
                self._events.cancel(lane.wd_handle)
                lane.wd_handle = None
        lane.stats.processed += len(batch)
        lane.set_busy(False)
        self.governor.on_cycle_end(self.now, lane.cart)
        if svc_norm > 0.0:
            lane.observe(svc_norm, self.ewma_alpha)
        self.health.finish_request(lane.cart.name, self.now)
        if self._trace is not None:
            self._trace_service_end(lane, status="ok")
        deliver = self._filter_hedged(lane, batch) if self._hedges else batch
        if not deliver:                     # whole cycle lost its races
            self._try_start_lane(lane)
            return
        g = self._group_of_lane(lane)
        name = g.name if g is not None else lane.cart.name
        hist = self.report.stage_hist.get(name)
        if hist is None:
            hist = self.report.stage_hist[name] = StreamingHistogram()
        if len(deliver) > 1:
            # bulk ingest for micro-batched cycles: one vectorized bin
            # pass (bin counts bit-identical to per-sample record)
            now = self.now
            hist.record_many([now - m.meta.get("_t_stage", now)
                              for m in deliver])
        else:
            hist.record(self.now - deliver[0].meta.get("_t_stage", self.now))
        self._handoff(lane, deliver)

    def _handoff(self, lane: _Lane, batch: list):
        """Bus transfer of a (micro-)batch to the next group, honoring
        backpressure."""
        g = self._group_of_lane(lane)
        if g is None:
            # lane removed mid-flight: the batch is already serviced, so it
            # re-enters downstream — at pos+1 while the slot survives
            # (replica detach), or at the old pos when the whole slot
            # vanished (the next stage shifted into the gap)
            tgt = self._serviced_orphan_target(lane.slot, lane.pos)
            for m in batch:
                self._reinject(tgt, m)
            return
        nxt = g.pos + 1
        if nxt < len(self._groups) and \
                self._groups[nxt].free_capacity(self._pick_bank) \
                < len(batch):
            # downstream full: hold (upstream throttles automatically since
            # this lane won't start its next frame while holding)
            lane.set_held(batch)
            self._push_event(self.now + 1e-3, self._retry_handoff, lane)
            return
        nbytes = sum(self._msg_bytes(m) for m in batch)
        if self.fabric is not None:
            # host-side routing: egress on the source hub, inter-hub link,
            # ingress on the routed destination hub (local legs collapse).
            # The pre-route decision is fabric-aware: it charges each
            # candidate lane the current cost of the route to its hub.
            dst_hub = self._route_hub(nxt, src_hub=lane.hub, nbytes=nbytes)
            if dst_hub is _BLOCKED:
                # every destination lane is down or unreachable over the
                # surviving links: hold the serviced batch at the source
                # and re-probe the route with backoff (zero loss — link
                # restore or lane reinstatement unblocks it)
                self.report.faults["reroute_blocked"] += 1
                lane.set_held(batch)
                m0 = batch[0]
                attempt = m0.meta.get("_retries", 0)
                m0.meta["_retries"] = attempt + 1
                self._note_retry(m0)
                self._push_event(
                    self.now + self.retry.backoff(attempt,
                                                  key=f"route:{m0.seq}"),
                    self._retry_handoff, lane)
                return
            done = self.fabric.transfer(
                self.now, nbytes, self._n_endpoints(lane.hub),
                src=lane.hub, dst=dst_hub,
                dst_endpoints=self._n_endpoints(dst_hub)
                if dst_hub is not None else 1)
            if dst_hub is not None:
                for m in batch:     # arrival should land on the paid-for
                    m.meta["_hub"] = dst_hub    # hub (local routes too —
                    # a silent hub switch at arrival would be a free
                    # cross-hub move the router never charged)
        else:
            done = self.bus.transfer(self.now, nbytes, self._n_endpoints())
        if self._trace is not None:
            self._trace_transfer(
                batch, done, nbytes,
                src=lane.hub if self.fabric is not None else None,
                dst=dst_hub if self.fabric is not None else None)
        nxt_group = self._groups[nxt] if nxt < len(self._groups) else None
        self._send_batch(done, lane.hub if self.fabric is not None else None,
                         nxt_group, batch)
        self._try_start_lane(lane)

    @staticmethod
    def _msg_bytes(m: msg.Message) -> int:
        return m.meta.get("bytes", m.nbytes() if m.payload is not None else 0)

    def _retry_handoff(self, lane: _Lane):
        if lane.held is None:
            return
        batch = lane.held
        lane.set_held(None)
        lane.stats.blocked_s += 1e-3
        self._handoff(lane, batch)

    def _arrive_next(self, nxt_group: Optional[_LaneGroup], batch: list):
        if nxt_group is None:               # sink: results reached the host
            for m in batch:
                self._complete(m)
            return
        if id(nxt_group) not in self._live_groups:
            # target vanished between transfer start and arrival
            for m in batch:
                self._reinject(nxt_group.pos, m)
            return
        for m in batch:
            self._enqueue(nxt_group.pos, m)

    def _complete(self, m: msg.Message):
        if self._chaos:
            # exactly-once audit: every recovery path must deliver each
            # frame once.  A duplicate is counted (and the chaos bench
            # fails on it), never silently dropped — masking a recovery
            # bug would be worse than double delivery.
            if m.seq in self._delivered:
                self.report.faults["duplicates"] += 1
            else:
                self._delivered.add(m.seq)
        self.report.frames_out += 1
        self.report.last_out_t = self.now
        lat = self.now - m.t_created
        self.report.latencies.append(lat)
        self.report.latency_hist.record(lat)
        if self._fd is not None:
            tenant = m.meta.get("tenant")
            if tenant is not None:
                self._fd.on_complete(tenant, lat, self.now)
        if self._trace is not None and self._trace.watches(m.seq):
            self._trace.instant(trc.COMPLETE, self.now, m.seq, track="sink",
                                latency_s=lat)
            self._trace.frame_end(m.seq, self.now, latency_s=lat)

    # -- broadcast lanes (paper §4.1, Table 1) --------------------------------
    def _try_start_broadcast(self, g: _LaneGroup):
        if id(g) not in self._live_groups or self.halted_since is not None:
            return
        if g.bbusy or g.bheld is not None or not g.bqueue:
            return
        if self.now < self.paused_until:
            self._push_event(self.paused_until, self._try_start_broadcast, g)
            return
        pool = g.lanes
        if self._chaos and self._down:
            pool = [l for l in pool if id(l) not in self._down]
            if not pool:
                # every replica is down: the frame waits in bqueue and
                # reinstatement re-kicks the group (zero loss)
                return
        lanes = [l for l in pool if l.ready_at <= self.now]
        if not lanes:
            self._push_event(min(l.ready_at for l in pool),
                             self._try_start_broadcast, g)
            return
        m = g.bqueue.popleft()
        g.bbusy = True
        if self.execute_payloads and m.payload is not None:
            m = lanes[0].cart.process(m)   # replicas compute identically
        nbytes = self._msg_bytes(m)
        finishes = []
        for lane in lanes:
            if self.fabric is not None:
                # host fan-out: each replica's copy is charged ingress on
                # its own hub (per-hub arbitration domain)
                arr = self.fabric.transfer(
                    self.now, nbytes, self._n_endpoints(lane.hub),
                    src=None, dst=lane.hub)
            else:
                arr = self.bus.transfer(self.now, nbytes,
                                        self._n_endpoints())
            svc, _ = self._service_time(lane, 1, m.seq)
            # broadcast lanes are barrier-paced, so a watt budget applies
            # feed-forward (population duty, no EWMA feedback); with no
            # budget the stretch is exactly 1.0 — Table 1 is bit-identical
            binfl = self.governor.duty_inflation(self.now, lane.hub) \
                if self.governor.active else 1.0
            dur = svc * binfl if binfl != 1.0 else svc
            lane.stats.busy_s += dur
            lane.stats.processed += 1
            lane.stats.batches += 1
            lane.stats.max_batch = max(lane.stats.max_batch, 1)
            self.governor.on_window(self.now, lane.cart, dur, svc)
            # a replica cannot start this frame while still computing the
            # previous one: under a quorum decision a straggler works off
            # its own backlog instead of being >100% utilized.  With the
            # full barrier (quorum=N) every lane finished before the next
            # dispatch, so the gate is a no-op and Table 1 is untouched.
            finish = max(arr, lane.bfree_at) + dur
            lane.bfree_at = finish
            finishes.append(finish)
            if self._trace is not None and self._trace.watches(m.seq):
                self._trace.span(trc.TRANSFER, self.now, arr, m.seq,
                                 track=lane.cart.name, bytes=nbytes,
                                 broadcast=True)
                self._trace.span(trc.SERVICE, finish - dur, finish, m.seq,
                                 track=lane.cart.name, hub=lane.hub,
                                 broadcast=True, status="ok")
        # quorum: the frame is decided at the k-th replica completion
        # (k = N, the default, is Table 1's full barrier — exactly
        # max(finishes)).  Stragglers keep computing (busy time already
        # charged) but their result handoffs are suppressed — exactly
        # N-k of them by rank, not by comparing against the decision
        # time: on symmetric multi-hub fabrics finishes tie exactly, and
        # a tie is still a loser (only k results are fetched).
        k = min(g.quorum or len(finishes), len(finishes))
        order = sorted(range(len(finishes)), key=finishes.__getitem__)
        decide = finishes[order[k - 1]]
        for i in order[k:]:
            if self.fabric is not None:
                self.fabric.suppress(BROADCAST_RESULT_BYTES,
                                     src=lanes[i].hub, t=self.now,
                                     n_endpoints=self._n_endpoints(
                                         lanes[i].hub))
            else:
                self.bus.suppress(BROADCAST_RESULT_BYTES)
        self._push_event(decide, self._broadcast_done, g, m)

    def _broadcast_done(self, g: _LaneGroup, m: msg.Message):
        g.bbusy = False
        hist = self.report.stage_hist.get(g.name)
        if hist is None:
            hist = self.report.stage_hist[g.name] = StreamingHistogram()
        hist.record(self.now - m.meta.get("_t_stage", self.now))
        self._broadcast_handoff(g, m)

    def _broadcast_handoff(self, g: _LaneGroup, m: msg.Message):
        if id(g) not in self._live_groups:
            self._reinject(self._serviced_orphan_target(g.slot, g.pos), m)
            return
        nxt = g.pos + 1
        if nxt >= len(self._groups):
            # broadcast results (a few score bytes per replica) are fetched
            # during the NEXT frame's compute window — the §4.1 FPS
            # measurement does not charge them to the cycle
            self._complete(m)
            self._try_start_broadcast(g)
            return
        if self._groups[nxt].free_capacity(self._pick_bank) < 1:
            g.bheld = m
            self._push_event(self.now + 1e-3, self._retry_broadcast, g)
            return
        src = None
        if self.fabric is not None:
            src = g.lanes[0].hub if g.lanes else None
            dst_hub = self._route_hub(nxt, src_hub=src,
                                      nbytes=self._msg_bytes(m))
            if dst_hub is _BLOCKED:
                self.report.faults["reroute_blocked"] += 1
                g.bheld = m
                attempt = m.meta.get("_retries", 0)
                m.meta["_retries"] = attempt + 1
                self._note_retry(m)
                self._push_event(
                    self.now + self.retry.backoff(attempt,
                                                  key=f"route:{m.seq}"),
                    self._retry_broadcast, g)
                return
            done = self.fabric.transfer(
                self.now, self._msg_bytes(m),
                self._n_endpoints(src) if src is not None else 1,
                src=src, dst=dst_hub,
                dst_endpoints=self._n_endpoints(dst_hub)
                if dst_hub is not None else 1)
            if dst_hub is not None:
                m.meta["_hub"] = dst_hub
        else:
            done = self.bus.transfer(self.now, self._msg_bytes(m),
                                     self._n_endpoints())
        if self._trace is not None:
            self._trace_transfer(
                [m], done, self._msg_bytes(m), src=src,
                dst=dst_hub if self.fabric is not None else None)
        self._send_batch(done, src, self._groups[nxt], [m])
        self._try_start_broadcast(g)

    def _retry_broadcast(self, g: _LaneGroup):
        if g.bheld is None:
            return
        m, g.bheld = g.bheld, None
        self._broadcast_handoff(g, m)

    # -- chaos fabric (fault injection + recovery) ----------------------------
    # Every branch below is gated on self._chaos, which only a non-empty
    # FaultPlan sets: a fault-free engine pushes exactly the same events
    # in exactly the same order as before this subsystem existed, so
    # Table 1 (and every committed BENCH headline) stays bit-identical.

    def install_fault_plan(self, plan: FaultPlan):
        """Arm a fault plan: schedules its events into the engine queue
        and enables the recovery machinery.  Call before ``run`` (the
        usual path is the ``fault_plan=`` constructor argument)."""
        self.faults = plan
        if plan.empty:
            return
        self._chaos = True
        if self._trace is not None:
            self._trace.instant("fault.plan", self.now, track="faults",
                                **plan.describe())
        for ev in plan.events:
            self._push_event(ev.t, self._fault_event, ev)

    def _note_retry(self, m: msg.Message):
        """Book one retry against a frame's budget.  The budget never
        drops the frame (zero loss is the contract) — exhausting it
        raises an operator alert so pathological cells are visible."""
        self.report.faults["retries"] += 1
        if self._trace is not None and self._trace.watches(m.seq):
            self._trace.instant(trc.RETRY, self.now, m.seq,
                                attempt=m.meta.get("_retries", 0))
        if m.meta.get("_retries", 0) == self.retry.budget + 1:
            self.report.faults["budget_exhausted"] += 1
            self.report.alerts.append(
                (self.now, f"frame {m.seq}: retry budget "
                           f"({self.retry.budget}) exhausted; still "
                           f"retrying with capped backoff"))

    def _retry_dispatch(self, pos: int, m: msg.Message):
        """Re-dispatch a recovered frame with exponential backoff +
        deterministic jitter (keyed on the frame, so replays agree)."""
        attempt = m.meta.get("_retries", 0)
        m.meta["_retries"] = attempt + 1
        self._note_retry(m)
        self._push_event(
            self.now + self.retry.backoff(attempt, key=str(m.seq)),
            self._reinject, pos, m)

    # .. transfer integrity (frame checksum on bus handoffs) ..................
    def _send_batch(self, done: float, src_hub: Optional[int],
                    nxt_group: Optional[_LaneGroup], batch: list):
        """Schedule a transferred batch's arrival.  Fault-free (or with a
        zero corruption rate) this is exactly the old direct
        ``_arrive_next`` push; under a corruption rate each frame is
        stamped with a checksum and the arrival verifies it."""
        if not self._chaos or self.faults.corrupt_p <= 0.0:
            self._push_event(done, self._arrive_next, nxt_group, batch)
            return
        for m in batch:
            m.meta["_csum"] = frame_checksum(m)
        m0 = batch[0]
        xmit = m0.meta.get("_xmit", 0)
        m0.meta["_xmit"] = xmit + 1
        if self.faults.corrupt_draw(m0.seq, xmit):
            m0.meta["_csum"] ^= 1           # wire bit-flip
        self._push_event(done, self._arrive_checked, src_hub, nxt_group,
                         batch)

    def _arrive_checked(self, src_hub: Optional[int],
                        nxt_group: Optional[_LaneGroup], batch: list):
        """Receiver-side checksum verification: a clean batch proceeds,
        a corrupted one is re-sent from the host's source-side buffer
        after a backoff (detection signal: checksum mismatch; recovery:
        bounded re-send; the frame is never delivered corrupted)."""
        clean = all(m.meta.pop("_csum", None) == frame_checksum(m)
                    for m in batch)
        if clean:
            self._arrive_next(nxt_group, batch)
            return
        for m in batch:
            m.meta.pop("_csum", None)       # strip survivors' stale stamps
        self.report.faults["corrupt_detected"] += 1
        m0 = batch[0]
        if self._trace is not None and self._trace.watches(m0.seq):
            self._trace.instant(trc.CORRUPT, self.now, m0.seq,
                                xmit=m0.meta.get("_xmit", 0))
        attempt = m0.meta.get("_retries", 0)
        m0.meta["_retries"] = attempt + 1
        self._note_retry(m0)
        self._push_event(
            self.now + self.retry.backoff(attempt, key=f"csum:{m0.seq}"),
            self._resend_batch, src_hub, nxt_group, batch)

    def _resend_batch(self, src_hub: Optional[int],
                      nxt_group: Optional[_LaneGroup], batch: list):
        """Re-send a corrupted batch over the same route (the host still
        holds the source-side buffer).  If the route's link died in the
        meantime, wait it out with backoff — restore unblocks it."""
        nbytes = sum(self._msg_bytes(m) for m in batch)
        dst_hub = batch[0].meta.get("_hub")
        if self.fabric is not None:
            if not self.fabric.link_ok(src_hub, dst_hub):
                self.report.faults["reroute_blocked"] += 1
                m0 = batch[0]
                attempt = m0.meta.get("_retries", 0)
                m0.meta["_retries"] = attempt + 1
                self._note_retry(m0)
                self._push_event(
                    self.now + self.retry.backoff(attempt,
                                                  key=f"resend:{m0.seq}"),
                    self._resend_batch, src_hub, nxt_group, batch)
                return
            done = self.fabric.transfer(
                self.now, nbytes,
                self._n_endpoints(src_hub) if src_hub is not None else 1,
                src=src_hub, dst=dst_hub,
                dst_endpoints=self._n_endpoints(dst_hub)
                if dst_hub is not None else 1)
        else:
            done = self.bus.transfer(self.now, nbytes, self._n_endpoints())
        self.report.faults["resends"] += 1
        if self._trace is not None:
            for m in batch:
                if self._trace.watches(m.seq):
                    self._trace.instant(trc.RESEND, self.now, m.seq)
            self._trace_transfer(batch, done, nbytes, src=src_hub,
                                 dst=dst_hub, resend=True)
        self._send_batch(done, src_hub, nxt_group, batch)

    # .. watchdog (timeout promotion of hangs into failures) ..................
    def _watchdog_deadline(self, lane: _Lane, factor: float) -> float:
        """How long a cycle may run before a hang is declared: the hedge
        machinery's own service histogram quantile (p99 of the lane's
        observed batch-normalized service time) with a wide margin, so a
        jittery-but-alive cycle never trips it; cold lanes fall back to
        the straggler factor over the EWMA estimate."""
        h = lane.svc_hist
        if h.count >= self.hedge_min_obs:
            base = max(h.quantile(0.99), lane.est_s)
        else:
            base = lane.est_s * max(self.health.straggler_factor, 1.0)
        return base * factor * self.watchdog_margin

    def _watchdog_fire(self, lane: _Lane, cycle: int):
        """The service cycle outlived its deadline: promote the hang into
        a failure — same recovery as a crash (the device may be wedged in
        a way only a power cycle fixes)."""
        lane.wd_handle = None
        if not self._chaos or not lane.busy or lane.cycle_seq != cycle:
            return
        if self._group_of_lane(lane) is None or id(lane) in self._down:
            return
        self.report.faults["hang_promoted"] += 1
        if self._trace is not None:
            self._trace.instant(trc.WATCHDOG, self.now,
                                track=lane.cart.name, cycle=cycle)
        self._fail_lane(lane, "hang promoted by watchdog")

    # .. fault events ..........................................................
    def _fault_event(self, ev: flt.FaultEvent):
        self.report.faults["injected"] += 1
        if self._trace is not None:
            self._trace.instant(trc.FAULT, self.now, track="faults",
                                **ev.describe())
        if ev.kind == flt.LANE_CRASH:
            lane = self._find_lane(ev.target)
            if lane is not None and id(lane) not in self._down:
                self.report.faults["lane_crash"] += 1
                self._fail_lane(lane, "crash", min_lease_s=ev.duration)
        elif ev.kind == flt.LANE_HANG:
            lane = self._find_lane(ev.target)
            if lane is not None and id(lane) not in self._down:
                self.report.faults["lane_hang"] += 1
                if lane.busy and lane.inflight is not None:
                    # the in-service cycle silently never completes; the
                    # watchdog armed with it will promote the hang
                    self._events.cancel(lane.inflight[0])
                else:
                    lane.hang_next = True    # idle: the next cycle hangs
        elif ev.kind == flt.HUB_POWER_LOSS:
            hub = int(ev.target)
            victims = [l for l in self._lane_by_cart.values()
                       if l.hub == hub and id(l) not in self._down]
            if victims:
                self.report.faults["hub_power_loss"] += 1
                self.report.alerts.append(
                    (self.now, f"hub {ev.target} power loss "
                               f"({len(victims)} lanes)"))
                for lane in victims:
                    self._fail_lane(lane, f"hub {ev.target} power loss",
                                    min_lease_s=ev.duration)
        elif ev.kind == flt.LINK_DOWN:
            if self.fabric is not None:
                a, b = ev.target
                self.fabric.set_link_state(a, b, up=False)
                self.report.faults["link_down"] += 1
                if ev.duration > 0:
                    self._push_event(self.now + ev.duration,
                                     self._fault_link_restore, (a, b))

    def _fault_link_restore(self, pair: tuple):
        self.fabric.set_link_state(pair[0], pair[1], up=True)
        self.report.faults["link_up"] += 1
        # blocked handoffs re-probe on their own backoff timers; frames
        # parked in the hold buffer can flow again now
        self._drain_hold_buffer()

    def _find_lane(self, name) -> Optional[_Lane]:
        for lane in self._lane_by_cart.values():
            if lane.cart.name == name:
                return lane
        return None

    # .. failure + recovery ....................................................
    def _fail_lane(self, lane: _Lane, reason: str, min_lease_s: float = 0.0):
        """The device is gone (crash, hub power loss, or a promoted
        hang): quarantine it, recover every frame it owned, stop its
        power draw, and schedule the lease-expiry reinstatement."""
        name = lane.cart.name
        until = self.qledger.quarantine(name, self.now,
                                        min_lease_s=min_lease_s)
        self._down.add(id(lane))
        self.registry.set_failed(lane.cart, True)
        self.report.faults["quarantined"] += 1
        self.report.swap_log.append(
            (self.now, "fault", f"{name}: {reason}; quarantined until "
                                f"{until:.3f}"))
        lane.hang_next = False
        if lane.wd_handle is not None:
            self._events.cancel(lane.wd_handle)
            lane.wd_handle = None
        if lane.busy:
            inflight_batch: list = []
            if lane.inflight is not None:
                handle, inflight_batch = lane.inflight
                self._events.cancel(handle)  # False if already hung: fine
                lane.inflight = None
            lane.set_busy(False)
            if self._trace is not None:
                self._trace_service_end(lane, status="aborted")
            # settle the energy uplift and clear the health ledger without
            # teaching either that the aborted cycle was a completion
            self.governor.on_cycle_end(self.now, lane.cart)
            self.health.abort_request(name, self.now)
            self._recover_copies(lane, inflight_batch)
        if lane.queue:
            queued = list(lane.queue)
            lane.queue.clear()
            self._recover_copies(lane, queued)
        if lane.held is not None:
            # the serviced results died in the device's output buffer:
            # recompute (re-dispatch at the lane's own stage)
            held = lane.held
            lane.set_held(None)
            self._recover_copies(lane, held)
        self._sync_governor()                # a dead stick stops drawing
        self._push_event(until, self._reinstate_lane, lane)

    def _recover_copies(self, lane: _Lane, msgs: list):
        """Re-dispatch frames a dead lane owned, preserving exactly-once
        through the hedge ledger: if another live copy of a frame exists
        the dead copy is simply dropped (the loser-suppression accounting
        already guarantees single delivery); the last live copy is
        stripped of hedge state and re-dispatched with backoff."""
        pos = self._slot_index.get(lane.slot, lane.pos)
        for m in msgs:
            key = (lane.slot, m.seq)
            task = self._hedges.get(key)
            if task is not None:
                if task.winner is not None:
                    # race already decided elsewhere: this copy is a dead
                    # loser whose suppression now happens for free
                    task.copies -= 1
                    if task.copies <= 0:
                        self._hedges.pop(key, None)
                    continue
                if task.copies > 1:
                    # another live copy survives: drop this one
                    task.copies -= 1
                    if lane is task.backup or m.meta.get("_hedge_copy"):
                        task.backup = None
                    self.report.hedges["cancelled_queued"] += 1
                    continue
                # last live copy: promote to sole owner and re-dispatch
                if task.check_handle is not None:
                    self._events.cancel(task.check_handle)
                self._hedges.pop(key, None)
                m.meta.pop("_hedge_copy", None)
            self.report.faults["redispatched"] += 1
            self._retry_dispatch(pos, m)

    def _reinstate_lane(self, lane: _Lane):
        """Lease expiry: return a quarantined lane to service — on
        probation (its pick-loop estimate carries the probation penalty
        until the window passes cleanly)."""
        if id(lane) not in self._down:
            return                           # already reinstated/handled
        name = lane.cart.name
        if self._group_of_lane(lane) is None:
            # unplugged while benched; registry cleared its fault state
            self._down.discard(id(lane))
            return
        if self.qledger.quarantined(name, self.now):
            # a flap extended the lease while this event was in flight
            self._push_event(self.qledger.until(name),
                             self._reinstate_lane, lane)
            return
        self._down.discard(id(lane))
        self.registry.set_failed(lane.cart, False)
        self.qledger.reinstate(name, self.now)
        self.report.faults["reinstated"] += 1
        self.report.swap_log.append(
            (self.now, "reinstate", f"{name} (on probation)"))
        self._sync_governor()                # idle draw resumes
        self._drain_hold_buffer()
        for g in list(self._groups):
            if g.mode == "broadcast":
                self._try_start_broadcast(g)
        self._try_start_lane(lane)

    # -- hot-swap (paper §3.2/§4.2) -------------------------------------------
    def schedule_remove(self, t: float, slot: int):
        self._push_event(t, self._do_remove, slot)

    def schedule_insert(self, t: float, slot: int, cart: Cartridge,
                        mode: str = "shard"):
        self._push_event(t, self._do_insert, slot, cart, mode)

    def schedule_add_replica(self, t: float, slot: int, cart: Cartridge,
                             hub: Optional[int] = None):
        self._push_event(t, self._do_add_replica, slot, cart, hub)

    def schedule_remove_replica(self, t: float, slot: int,
                                cart: Optional[Cartridge] = None):
        self._push_event(t, self._do_remove_replica, slot, cart)

    def _pause(self, dur: float, reason: str):
        t1 = max(self.paused_until, self.now + dur)
        self.report.downtime.append((self.now, t1, reason))
        self.paused_until = t1
        self._push_event(t1, self._resume)

    def _drain_hold_buffer(self):
        if self.now < self.paused_until or self.halted_since is not None:
            return
        # snapshot: with chaos active _enqueue may re-buffer a frame whose
        # whole group is still down — draining in place would spin forever
        pending = list(self._hold_buffer)
        self._hold_buffer.clear()
        for idx, m in pending:
            self._enqueue(min(idx, len(self._groups)), m)

    def _resume(self):
        if self.now < self.paused_until:
            return
        self._drain_hold_buffer()
        for g in list(self._groups):
            if g.mode == "broadcast":
                self._try_start_broadcast(g)
            else:
                for l in list(g.lanes):
                    self._try_start_lane(l)

    def _do_remove(self, slot: int):
        rec = self.registry.slots.get(slot)
        if rec is None:
            return
        idx = self._slot_index[slot]
        chain = self.registry.chain()
        up = chain[idx - 1] if idx > 0 else None
        down = chain[idx + 1] if idx + 1 < len(chain) else None
        # re-buffer frames queued at the removed group (zero loss); they
        # re-enter at this position, i.e. at the bridge or next stage
        victim = self._groups[idx]
        for lane in victim.lanes:
            self._rescue_lane(lane, idx)
        for m in victim.bqueue:
            self._hold_buffer.append((idx, m))
        victim.bqueue.clear()
        if victim.bheld is not None:
            self._hold_buffer.append((idx, victim.bheld))
            victim.bheld = None
        self._in_swap = True
        try:
            self.registry.remove(slot, self.now)
            upspec = up.produces if up else None
            downspec = down.consumes if down else None
            compatible = (up is None or down is None
                          or downspec.accepts(upspec))
            self.report.swap_log.append(
                (self.now, "remove", f"slot {slot} ({rec.cartridge.name})"))
            if self._trace is not None:
                self._trace.instant(trc.SWAP, self.now, track="engine",
                                    op="remove", slot=slot,
                                    name=rec.cartridge.name,
                                    bridged=compatible)
            if compatible:
                # paper: 'bridge the gap if the pipeline can continue
                # without that function' — chain shortens (pass-through)
                self._rebuild()
                self._pause(REMOVE_PAUSE_S, f"remove slot {slot}")
            else:
                # paper: 'triggers an alert for operator intervention' —
                # halt; frames buffer (zero loss) until a compatible
                # cartridge is inserted
                self.halted_since = self.now
                self.report.alerts.append(
                    (self.now, f"capability '{rec.cartridge.name}' missing;"
                               f" pipeline halted for operator"))
        finally:
            self._in_swap = False

    def _do_insert(self, slot: int, cart: Cartridge, mode: str = "shard"):
        self._in_swap = True
        try:
            # clear any bridge occupying this slot
            if slot in self.registry.slots and isinstance(
                    self.registry.slots[slot].cartridge, PassThrough):
                self.registry.remove(slot, self.now)
            load_s = cart.device.load_s
            self.registry.insert(slot, cart, self.now, mode=mode)
            self._stub_load(cart)
            self._rebuild()
        finally:
            self._in_swap = False
        self.report.swap_log.append(
            (self.now, "insert", f"slot {slot} ({cart.name})"))
        if self._trace is not None:
            self._trace.instant(trc.SWAP, self.now, track="engine",
                                op="insert", slot=slot, name=cart.name,
                                load_s=load_s)
        if self.halted_since is not None:
            # operator supplied the missing capability: close the halt
            # window and resume
            t0 = self.halted_since
            self.halted_since = None
            self.report.downtime.append(
                (t0, self.now, f"halted awaiting capability (slot {slot})"))
        self._pause(HANDSHAKE_S + load_s, f"insert slot {slot}")

    def _do_add_replica(self, slot: int, cart: Cartridge,
                        hub: Optional[int] = None):
        """Plug one more device into an existing lane group (optionally on
        a specific fabric hub).  The pipeline keeps streaming; the new
        lane joins after handshake + model load."""
        if slot not in self.registry.slots:
            return
        self._in_swap = True
        try:
            self.registry.add_replica(slot, cart, self.now, hub=hub)
            self._stub_load(cart)
            self._rebuild()
        finally:
            self._in_swap = False
        for g in self._groups:
            for lane in g.lanes:
                if lane.cart is cart:
                    lane.set_ready_at(self.now + HANDSHAKE_S +
                                      cart.device.load_s)
        self.report.swap_log.append(
            (self.now, "add_replica", f"slot {slot} ({cart.name})"))
        if self._trace is not None:
            self._trace.instant(trc.SWAP, self.now, track="engine",
                                op="add_replica", slot=slot, name=cart.name)

    def _do_remove_replica(self, slot: int, cart: Optional[Cartridge]):
        """Unplug one replica.  With surviving lanes the group degrades
        throughput (no pause, no halt); the last replica falls back to the
        whole-slot removal semantics (bridge or operator alert)."""
        rec = self.registry.slots.get(slot)
        if rec is None:
            return
        victim_cart = cart if cart is not None else rec.replicas[-1]
        if len(rec.replicas) <= 1:
            self._do_remove(slot)
            return
        self._in_swap = True
        try:
            self.registry.remove_replica(slot, victim_cart, self.now)
            self._rebuild()
        finally:
            self._in_swap = False
        self.report.swap_log.append(
            (self.now, "remove_replica", f"slot {slot} "
                                         f"({victim_cart.name})"))
        if self._trace is not None:
            self._trace.instant(trc.SWAP, self.now, track="engine",
                                op="remove_replica", slot=slot,
                                name=victim_cart.name)
        # the rebuild's rescue pass parked the victim's backlog in the hold
        # buffer; with no pause it redistributes to surviving lanes now
        # (the victim's in-flight batch still completes before detach)
        self._drain_hold_buffer()

    def _stub_load(self, cart: Cartridge):
        if not cart._loaded:
            if self.execute_payloads:
                cart.load()
            else:
                cart._loaded = True
                cart._fn = lambda p, x: x


def validate_chain(chain: List[Cartridge]):
    """Type-check consume/produce contracts along the chain (registration-
    time validation, paper §3.2)."""
    for a, b in zip(chain, chain[1:]):
        if not b.consumes.accepts(a.produces):
            raise msg.TypeError_(
                f"{a.name} produces {a.produces.describe()} but "
                f"{b.name} consumes {b.consumes.describe()}")
