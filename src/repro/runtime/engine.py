"""VDiSK streaming engine: discrete-event execution of cartridge pipelines.

This is the CHAMP fork of VDiSK's core loop, §2.3/§3.3 of the paper:

  * pub/sub message routing between chained cartridges over the shared bus
  * bounded inter-stage queues with backpressure ("if a cartridge's
    processing time is slower than the input rate, it can signal upstream
    modules ... to throttle the data flow")
  * hot-swap events: removal pauses the pipeline ~0.5 s, buffers in-flight
    frames, bridges the gap (PassThrough) when types allow or raises an
    operator alert; insertion pauses ~2 s (dominated by model re-load)
  * zero message loss across swaps (buffered frames replay afterward)
  * per-stage utilization -> the §4.3 power model

Timing is virtual (deterministic, calibrated DeviceModels); payload compute
is optionally real JAX (``execute_payloads=True``) so correctness tests can
assert data flows through reconfigurations unchanged.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

from repro.bus.simulator import BusParams, SharedBus
from repro.core.cartridge import Cartridge, PassThrough
from repro.core import messages as msg
from repro.runtime.registry import CapabilityRegistry

HANDSHAKE_S = 0.35       # detection + addressing + capability handshake
REMOVE_PAUSE_S = 0.5     # paper §4.2: ~0.5 s reconfiguration on removal


@dataclass
class StageStats:
    processed: int = 0
    busy_s: float = 0.0
    blocked_s: float = 0.0


@dataclass
class EngineReport:
    frames_in: int = 0
    frames_out: int = 0
    latencies: list = field(default_factory=list)
    downtime: list = field(default_factory=list)  # (t0, t1, reason)
    alerts: list = field(default_factory=list)
    stage_stats: dict = field(default_factory=dict)
    bus_bytes: int = 0
    sim_time: float = 0.0

    @property
    def lost(self) -> int:
        return self.frames_in - self.frames_out

    def throughput(self) -> float:
        return self.frames_out / self.sim_time if self.sim_time else 0.0

    def mean_latency(self) -> float:
        return sum(self.latencies) / len(self.latencies) if self.latencies \
            else 0.0

    def total_downtime(self) -> float:
        return sum(t1 - t0 for t0, t1, _ in self.downtime)


class _Stage:
    def __init__(self, cart: Cartridge, queue_cap: int):
        self.cart = cart
        self.queue: deque = deque()
        self.queue_cap = queue_cap
        self.busy = False
        self.held: Optional[msg.Message] = None   # done but downstream full
        self.stats = StageStats()
        self.pos = 0                              # last known chain position


class StreamEngine:
    """Chain topology engine. Stages are rebuilt on registry events."""

    def __init__(self, registry: CapabilityRegistry, bus: SharedBus,
                 *, queue_cap: int = 8, execute_payloads: bool = False):
        self.registry = registry
        self.bus = bus
        self.queue_cap = queue_cap
        self.execute_payloads = execute_payloads
        self.now = 0.0
        self.paused_until = 0.0
        self.halted_since: Optional[float] = None   # missing capability
        self._in_swap = False
        self.report = EngineReport()
        self._events: list = []
        self._eseq = itertools.count()
        self._stages: List[_Stage] = []
        self._hold_buffer: deque = deque()   # frames buffered during pauses
        self._frame_seq = itertools.count()
        self._source_exhausted = False
        registry.subscribe(self._on_registry_event)
        self._rebuild()

    # -- pipeline construction ------------------------------------------------
    def _rebuild(self):
        old_list = self._stages
        old = {s.cart: s for s in old_list}
        chain = self.registry.chain()
        validate_chain(chain)
        self._stages = []
        for i, cart in enumerate(chain):
            st = old.get(cart) or _Stage(cart, self.queue_cap)
            st.pos = i
            self._stages.append(st)
        # rescue queued/held frames of stages that left the chain
        kept = set(id(s) for s in self._stages)
        for s in old_list:
            if id(s) not in kept:
                for m in s.queue:
                    self._hold_buffer.append((s.pos, m))
                s.queue.clear()
                if s.held is not None:
                    self._hold_buffer.append((s.pos, s.held))
                    s.held = None

    def _on_registry_event(self, kind: str, rec):
        # engine-driven swaps rebuild once at the end of their transaction;
        # direct registry edits (tests) get a safe rebuild here.
        if not self._in_swap:
            self._rebuild()

    # -- event queue ----------------------------------------------------------
    def _push_event(self, t: float, fn: Callable, *args):
        heapq.heappush(self._events, (t, next(self._eseq), fn, args))

    def run(self, until: float) -> EngineReport:
        while self._events and self._events[0][0] <= until:
            t, _, fn, args = heapq.heappop(self._events)
            self.now = max(self.now, t)
            fn(*args)
        # sim_time = when work actually finished (not the horizon)
        self.report.sim_time = self.now
        self.report.bus_bytes = self.bus.bytes_moved
        for st in self._stages:
            self.report.stage_stats[st.cart.name] = st.stats
        return self.report

    # -- source ---------------------------------------------------------------
    def feed(self, n_frames: int, interval_s: float, payload_fn=None,
             frame_bytes: int = 150528, t0: float = 0.0):
        for i in range(n_frames):
            self._push_event(t0 + i * interval_s, self._frame_arrival,
                             payload_fn(i) if payload_fn else None,
                             frame_bytes)

    def _frame_arrival(self, payload, frame_bytes):
        m = msg.Message(kind=msg.IMAGE_FRAME, seq=next(self._frame_seq),
                        payload=payload, t_created=self.now,
                        meta={"bytes": frame_bytes})
        self.report.frames_in += 1
        if self.now < self.paused_until or self.halted_since is not None \
                or not self._stages:
            self._hold_buffer.append((0, m))  # paper: buffered, not dropped
            return
        self._enqueue(0, m)

    # -- stage machinery ------------------------------------------------------
    # Events reference _Stage objects, not indices: hot-swap rebuilds the
    # stage list mid-flight, so positions are resolved at event time and a
    # message whose stage vanished is re-buffered (zero loss).
    def _enqueue(self, idx: int, m: msg.Message):
        if idx >= len(self._stages):
            self._complete(m)
            return
        st = self._stages[idx]
        st.queue.append(m)
        self._try_start(st)

    def _try_start(self, st: _Stage):
        if st not in self._stages or self.halted_since is not None:
            return
        if st.busy or st.held is not None or not st.queue:
            return
        if self.now < self.paused_until:
            self._push_event(self.paused_until, self._try_start, st)
            return
        m = st.queue.popleft()
        st.busy = True
        svc = st.cart.device.service_s
        if self.execute_payloads and m.payload is not None:
            m = st.cart.process(m)
        st.stats.busy_s += svc
        self._push_event(self.now + svc, self._stage_done, st, m)

    def _stage_done(self, st: _Stage, m: msg.Message):
        st.stats.processed += 1
        st.busy = False
        self._handoff(st, m)

    def _handoff(self, st: _Stage, m: msg.Message):
        """Bus transfer to the next stage, honoring backpressure."""
        try:
            idx = self._stages.index(st)
        except ValueError:
            # stage removed mid-flight: its output re-enters at the slot
            # that shifted into its old position (= downstream of the gap)
            self._hold_buffer.append((st.pos, m))
            return
        nxt = idx + 1
        if nxt < len(self._stages) and \
                len(self._stages[nxt].queue) >= self.queue_cap:
            # downstream full: hold (upstream throttles automatically since
            # this stage won't start its next frame while holding)
            st.held = m
            self._push_event(self.now + 1e-3, self._retry_handoff, st)
            return
        nbytes = m.meta.get("bytes", m.nbytes() if m.payload is not None
                            else 0)
        done = self.bus.transfer(self.now, nbytes, len(self._stages))
        nxt_stage = self._stages[nxt] if nxt < len(self._stages) else None
        self._push_event(done, self._arrive_next, nxt_stage, m)
        self._try_start(st)

    def _retry_handoff(self, st: _Stage):
        if st.held is None:
            return
        m, st.held = st.held, None
        st.stats.blocked_s += 1e-3
        self._handoff(st, m)

    def _arrive_next(self, nxt_stage, m: msg.Message):
        if nxt_stage is None:
            self._complete(m)
            return
        if nxt_stage not in self._stages:
            # target vanished between transfer start and arrival
            self._hold_buffer.append((nxt_stage.pos, m))
            return
        nxt_stage.queue.append(m)
        self._try_start(nxt_stage)

    def _complete(self, m: msg.Message):
        self.report.frames_out += 1
        self.report.latencies.append(self.now - m.t_created)

    # -- hot-swap (paper §3.2/§4.2) -------------------------------------------
    def schedule_remove(self, t: float, slot: int):
        self._push_event(t, self._do_remove, slot)

    def schedule_insert(self, t: float, slot: int, cart: Cartridge):
        self._push_event(t, self._do_insert, slot, cart)

    def _pause(self, dur: float, reason: str):
        t1 = max(self.paused_until, self.now + dur)
        self.report.downtime.append((self.now, t1, reason))
        self.paused_until = t1
        self._push_event(t1, self._resume)

    def _resume(self):
        if self.now < self.paused_until:
            return
        while self._hold_buffer:
            idx, m = self._hold_buffer.popleft()
            self._enqueue(min(idx, len(self._stages)), m)
        for st in list(self._stages):
            self._try_start(st)

    def _do_remove(self, slot: int):
        rec = self.registry.slots.get(slot)
        if rec is None:
            return
        idx = sorted(self.registry.slots).index(slot)
        up = self._stages[idx - 1].cart if idx > 0 else None
        down = self._stages[idx + 1].cart if idx + 1 < len(self._stages) \
            else None
        # re-buffer frames queued at the removed stage (zero loss); they
        # re-enter at this position, i.e. at the bridge or next stage
        victim = self._stages[idx]
        for m in victim.queue:
            self._hold_buffer.append((idx, m))
        victim.queue.clear()
        if victim.held is not None:
            self._hold_buffer.append((idx, victim.held))
            victim.held = None
        self._in_swap = True
        try:
            self.registry.remove(slot, self.now)
            upspec = up.produces if up else None
            downspec = down.consumes if down else None
            compatible = (up is None or down is None
                          or downspec.accepts(upspec))
            if compatible:
                # paper: 'bridge the gap if the pipeline can continue
                # without that function' — chain shortens (pass-through)
                self._rebuild()
                self._pause(REMOVE_PAUSE_S, f"remove slot {slot}")
            else:
                # paper: 'triggers an alert for operator intervention' —
                # halt; frames buffer (zero loss) until a compatible
                # cartridge is inserted
                self.halted_since = self.now
                self.report.alerts.append(
                    (self.now, f"capability '{rec.cartridge.name}' missing;"
                               f" pipeline halted for operator"))
        finally:
            self._in_swap = False

    def _do_insert(self, slot: int, cart: Cartridge):
        self._in_swap = True
        try:
            # clear any bridge occupying this slot
            if slot in self.registry.slots and isinstance(
                    self.registry.slots[slot].cartridge, PassThrough):
                self.registry.remove(slot, self.now)
            load_s = cart.device.load_s
            self.registry.insert(slot, cart, self.now)
            if not cart._loaded:
                if self.execute_payloads:
                    cart.load()
                else:
                    cart._loaded = True
                    cart._fn = lambda p, x: x
            self._rebuild()
        finally:
            self._in_swap = False
        if self.halted_since is not None:
            # operator supplied the missing capability: close the halt
            # window and resume
            t0 = self.halted_since
            self.halted_since = None
            self.report.downtime.append(
                (t0, self.now, f"halted awaiting capability (slot {slot})"))
        self._pause(HANDSHAKE_S + load_s, f"insert slot {slot}")


def validate_chain(chain: List[Cartridge]):
    """Type-check consume/produce contracts along the chain (registration-
    time validation, paper §3.2)."""
    for a, b in zip(chain, chain[1:]):
        if not b.consumes.accepts(a.produces):
            raise msg.TypeError_(
                f"{a.name} produces {a.produces.describe()} but "
                f"{b.name} consumes {b.consumes.describe()}")
