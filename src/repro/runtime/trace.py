"""Flight recorder: causal per-frame tracing + unified metrics registry.

The engine's counters (``EngineReport``) explain *what* happened over a
run; they cannot explain *why one frame was slow* — which lane won the
dispatch argmin and by how much, whether a hedge fork fired, how many
checksum resends a storm cost it, which cross-hub legs it paid for.
``FlightRecorder`` answers that with typed **spans** (begin/end pairs:
frame lifetime, service cycles, bus/fabric transfers) and **instants**
(dispatch decisions, hedge fork/win/loss, retries, quarantine, power
state transitions, fault injections) recorded into a preallocated
structure-of-arrays ring buffer — the PR 8 ``SoABank`` idiom, so a 10k
lane chaos storm traces in fixed memory (old entries are evicted, never
reallocated).

Design constraints, in order:

1. **Bit-identity when off.**  Following the PR 7 ``_chaos`` learning,
   every instrumentation site in the engine is gated on a single
   ``self._trace is not None`` check; with ``trace=`` unset the engine
   pushes exactly the same events in exactly the same order as before
   this module existed.  Tracing *on* must also never perturb virtual
   time: the recorder only observes, so traced and untraced runs produce
   float-for-float identical reports (pinned in the test suite and by
   ``benchmarks/obs_bench.py``).
2. **Low overhead when on.**  Sampling is decided once per frame at
   ingest (a crc32 hash of the frame id — replays of the same seed trace
   the *same* frames); per-site cost for unsampled frames is one set
   lookup.  Span writes are a handful of array stores.
3. **Deterministic.**  No wall clock, no ``random``: timestamps are the
   engine's virtual clock, sampling is hash-based, and the ring's entry
   ids are a monotonic counter — two runs of the same scenario produce
   byte-identical exports.

Exporters: ``frame_trace(frame_id)`` returns one frame's causal timeline
as plain dicts (tests, debugging); ``to_perfetto(path)`` writes Chrome
trace-event JSON that loads directly in Perfetto / ``chrome://tracing``
(tracks = lanes/hubs, slices = spans, arrows come free from the frame id
in each slice's args).

``MetricsRegistry`` is the other half of the observability story: one
namespaced, stable-name snapshot (``engine.frames.out``,
``hedge.issued``, ``faults.retries``, ``power.hub0.state``,
``gallery.match.rows_scored``, ...) unifying the stats surfaces that
previously lived in six different dicts.  ``EngineReport.metrics()``
builds it; ``ingest()`` merges any component's dict under a prefix.
"""
from __future__ import annotations

import json
import zlib
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.runtime.lanestate import SoABank

# span/instant kinds the engine emits; any string works — these are the
# stable names tests and docs refer to
FRAME = "frame"                 # span: ingest -> completion
SERVICE = "service"             # span: one lane service cycle
TRANSFER = "transfer"           # span: one bus/fabric hop (emitted closed)
DISPATCH = "dispatch"           # instant: lane chosen + argmin inputs
INGEST = "ingest"               # instant: frame entered the engine
COMPLETE = "complete"           # instant: frame delivered to the host
HEDGE_FORK = "hedge.fork"       # instant: backup copy issued
HEDGE_WIN = "hedge.win"         # instant: race decided
HEDGE_LOSS = "hedge.loss"       # instant: serviced loser suppressed
RETRY = "retry"                 # instant: one retry booked
CORRUPT = "corrupt.detected"    # instant: checksum mismatch at receiver
RESEND = "resend"               # instant: corrupted batch re-sent
QUARANTINE = "quarantine"       # instant: lane benched
REINSTATE = "reinstate"         # instant: lane back on probation
WATCHDOG = "watchdog.promoted"  # instant: hang promoted to failure
FAULT = "fault.injected"        # instant: a FaultPlan event landed
SWAP = "swap"                   # instant: hot-swap transaction
POWER = "power.state"           # instant: hub throttle/park transition
TENANT_ADMIT = "tenant.admit"   # instant: queued frame passed the door
TENANT_SHED = "tenant.shed"     # instant: front door shed a frame


def _sample_hash(seed: int, frame_id: int) -> int:
    """Replay-stable sampling draw, matching the faults.py crc32
    discipline (no PYTHONHASHSEED dependence)."""
    return zlib.crc32(f"{seed}:trace:{frame_id}".encode()) & 0xFFFFFFFF


class _TraceRing(SoABank):
    """Fixed-capacity SoA slab for trace entries.  Unlike the lane bank
    it never grows and never recycles through the free list: entry id
    modulo capacity IS the row, so eviction is a plain overwrite and the
    memory budget is set once at construction."""

    FIELDS_F64 = {"t0": 0.0, "t1": -1.0}
    # eid -1 marks a never-written row; kind/track index the intern
    # table; frame -1 marks engine-scoped (non-frame) entries
    FIELDS_I64 = {"eid": -1, "kind": -1, "frame": -1, "track": -1}


class FlightRecorder:
    """Typed span/instant ring buffer with deterministic frame sampling.

    ``capacity``   ring size (entries); oldest entries evict first.
    ``sample``     trace one frame in ``sample`` (1 = every frame),
                   chosen by a crc32 hash of ``(seed, frame_id)`` so the
                   same seed replays the identical traced-frame set.
    ``seed``       sampling key; engines seed it from their fault plan.

    The engine decides admission once per frame (``admit``); all other
    sites gate on ``watches(frame_id)`` — an O(1) set lookup.  Entries
    whose ``frame`` is -1 (power transitions, faults, swaps) bypass
    sampling: they are rare and fleet-scoped.
    """

    def __init__(self, capacity: int = 65536, sample: int = 1,
                 seed: int = 0):
        if capacity < 2:
            raise ValueError("ring capacity must be >= 2")
        if sample < 1:
            raise ValueError("sample must be >= 1 (1 = trace every frame)")
        self.capacity = capacity
        self.sample = int(sample)
        self.seed = int(seed)
        self._ring = _TraceRing(capacity)
        self._args: List[Optional[dict]] = [None] * capacity
        # string interning: kinds and track names repeat endlessly
        self._codes: Dict[str, int] = {}
        self._names: List[str] = []
        self._next = 0                      # monotonic entry id
        self._sampled: set = set()          # admitted frame ids
        self._open_frames: Dict[int, int] = {}   # frame id -> frame-span sid
        # virtual clock hook: components without engine access (gallery,
        # quarantine ledger) emit instants at clock(); the engine wires
        # this to its own ``now``
        self.clock: Callable[[], float] = lambda: 0.0
        # counters (the ``trace.*`` metrics namespace)
        self.spans_opened = 0
        self.spans_closed = 0
        self.instants = 0
        self.evicted = 0
        self.end_misses = 0                 # end() after the row evicted
        self.frames_admitted = 0
        self.frames_skipped = 0

    # -- sampling -------------------------------------------------------------
    def admit(self, frame_id: int) -> bool:
        """Decide once, at ingest, whether this frame is traced."""
        if self.sample > 1 and \
                _sample_hash(self.seed, frame_id) % self.sample != 0:
            self.frames_skipped += 1
            return False
        self._sampled.add(frame_id)
        self.frames_admitted += 1
        return True

    def watches(self, frame_id: int) -> bool:
        return frame_id in self._sampled

    def sampled(self, frame_id: int) -> bool:
        """Pure sampling probe (no admission bookkeeping): would this
        frame be traced?  Pre-admission sites — the front door sheds
        frames the engine never ingests — gate on this so shed instants
        follow the same deterministic 1/N policy as everything else."""
        return self.sample <= 1 or \
            _sample_hash(self.seed, frame_id) % self.sample == 0

    # -- recording ------------------------------------------------------------
    def _code(self, name: str) -> int:
        c = self._codes.get(name)
        if c is None:
            c = self._codes[name] = len(self._names)
            self._names.append(name)
        return c

    def _write(self, kind: str, t0: float, t1: float, frame: int,
               track: str, args: Optional[dict]) -> int:
        eid = self._next
        self._next = eid + 1
        i = eid % self.capacity
        ring = self._ring
        old = ring.eid[i]
        if old >= 0:
            self.evicted += 1
            # an open frame span falling off the ring can never be
            # closed; forget the stale sid so end() misses cleanly
            if ring.t1[i] < 0.0 and ring.kind[i] == self._codes.get(FRAME):
                self._open_frames.pop(int(ring.frame[i]), None)
        ring.eid[i] = eid
        ring.kind[i] = self._code(kind)
        ring.frame[i] = frame
        ring.track[i] = self._code(track)
        ring.t0[i] = t0
        ring.t1[i] = t1
        self._args[i] = args
        return eid

    def begin(self, kind: str, t: float, frame: int = -1,
              track: str = "engine", **args) -> int:
        """Open a span; returns its id for ``end``."""
        self.spans_opened += 1
        return self._write(kind, t, -1.0, frame, track, args or None)

    def end(self, sid: int, t: float, **args):
        """Close a span.  A span already evicted from the ring is a
        counted miss, never an error — eviction is the memory contract."""
        i = sid % self.capacity
        ring = self._ring
        if ring.eid[i] != sid or ring.t1[i] >= 0.0:
            self.end_misses += 1
            return
        ring.t1[i] = t
        if args:
            prev = self._args[i]
            self._args[i] = dict(prev, **args) if prev else args
        self.spans_closed += 1

    def span(self, kind: str, t0: float, t1: float, frame: int = -1,
             track: str = "engine", **args) -> int:
        """Emit an already-closed span (transfers: the arrival time is
        known at schedule time, so no open/close pairing is needed)."""
        self.spans_opened += 1
        self.spans_closed += 1
        return self._write(kind, t0, t1, frame, track, args or None)

    def instant(self, kind: str, t: float, frame: int = -1,
                track: str = "engine", **args) -> int:
        self.instants += 1
        return self._write(kind, t, t, frame, track, args or None)

    # frame-lifetime spans: the engine opens one per admitted frame at
    # ingest and closes it at completion; the recorder keeps the open
    # sid so re-dispatch/retry paths need no bookkeeping of their own
    def frame_begin(self, frame_id: int, t: float):
        self._open_frames[frame_id] = self.begin(FRAME, t, frame_id,
                                                 track=FRAME)

    def frame_end(self, frame_id: int, t: float, **args):
        sid = self._open_frames.pop(frame_id, None)
        if sid is not None:
            self.end(sid, t, **args)

    @property
    def open_frames(self) -> int:
        return len(self._open_frames)

    # -- export ---------------------------------------------------------------
    def _entry(self, i: int) -> dict:
        ring = self._ring
        d = {
            "id": int(ring.eid[i]),
            "kind": self._names[int(ring.kind[i])],
            "frame": int(ring.frame[i]),
            "track": self._names[int(ring.track[i])],
            "t0": float(ring.t0[i]),
        }
        t1 = float(ring.t1[i])
        if t1 != d["t0"]:
            d["t1"] = t1 if t1 >= 0.0 else None   # None = never closed
        args = self._args[i]
        if args:
            d["args"] = dict(args)
        return d

    def _live_rows(self) -> np.ndarray:
        """Row indices of written entries, oldest first (eid order)."""
        ring = self._ring
        rows = np.nonzero(ring.eid >= 0)[0]
        return rows[np.argsort(ring.eid[rows], kind="stable")]

    def frame_trace(self, frame_id: int) -> List[dict]:
        """One frame's causal timeline, in event order: ingest ->
        dispatch decision -> transfers -> service -> hedge activity ->
        retries -> completion.  Plain dicts for tests and debugging."""
        ring = self._ring
        rows = np.nonzero((ring.frame == frame_id) & (ring.eid >= 0))[0]
        rows = rows[np.argsort(ring.eid[rows], kind="stable")]
        return [self._entry(int(i)) for i in rows]

    def entries(self) -> List[dict]:
        """Every live ring entry, oldest first."""
        return [self._entry(int(i)) for i in self._live_rows()]

    def to_perfetto(self, path: str, time_unit_s: float = 1.0) -> int:
        """Write Chrome trace-event JSON (loads in Perfetto and
        chrome://tracing).  Virtual seconds map to trace microseconds
        scaled by ``time_unit_s``; tracks (lanes, hubs, the frame
        timeline) become threads of one process.  Returns the number of
        events written."""
        scale = 1e6 * time_unit_s
        tids: Dict[str, int] = {}
        events: List[dict] = []
        for name in sorted({self._names[int(self._ring.track[i])]
                            for i in self._live_rows()}):
            tids[name] = len(tids)
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tids[name], "args": {"name": name}})
        for i in self._live_rows():
            e = self._entry(int(i))
            args = dict(e.get("args") or {})
            if e["frame"] >= 0:
                args["frame"] = e["frame"]
            base = {"name": e["kind"], "pid": 0, "tid": tids[e["track"]],
                    "ts": e["t0"] * scale, "args": args}
            t1 = e.get("t1", e["t0"])
            if t1 is not None and t1 != e["t0"]:
                events.append(dict(base, ph="X",
                                   dur=(t1 - e["t0"]) * scale))
            elif t1 is None:                      # never closed: open slice
                events.append(dict(base, ph="X", dur=0.0))
            else:
                events.append(dict(base, ph="i", s="t"))
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
        return len(events)

    def snapshot(self) -> dict:
        """The ``trace.*`` metrics namespace."""
        return {
            "capacity": self.capacity,
            "sample": self.sample,
            "entries": int((self._ring.eid >= 0).sum()),
            "spans_opened": self.spans_opened,
            "spans_closed": self.spans_closed,
            "instants": self.instants,
            "evicted": self.evicted,
            "end_misses": self.end_misses,
            "frames_admitted": self.frames_admitted,
            "frames_skipped": self.frames_skipped,
            "open_frames": self.open_frames,
        }

    def __repr__(self):
        s = self.snapshot()
        return (f"<FlightRecorder entries={s['entries']}/{s['capacity']} "
                f"spans={s['spans_opened']} instants={s['instants']} "
                f"evicted={s['evicted']}>")


# ---------------------------------------------------------------------------
# metrics registry: one namespaced snapshot over every stats surface
# ---------------------------------------------------------------------------
def _scalar(v: Any):
    """Coerce numpy scalars to plain Python (the np.int64 -> json.dump
    TypeError class of bug); passthrough for everything json-native."""
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v


def jsonable(obj: Any):
    """Recursively coerce a nested structure to json-serializable plain
    Python: numpy scalars become int/float/bool, numpy arrays become
    lists, tuples become lists, dict keys become strings."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [jsonable(v) for v in obj.tolist()]
    return _scalar(obj)


class MetricsRegistry:
    """Flat, namespaced metric snapshot with stable dotted names.

    Every value is a plain Python scalar (or string); nested component
    dicts flatten on ingest (``{"hubs": {0: {"state": ...}}}`` under
    prefix ``power`` becomes ``power.hubs.0.state``).  Iteration order
    is sorted by name, so two snapshots of the same run diff cleanly.
    """

    def __init__(self):
        self._vals: Dict[str, Any] = {}

    def set(self, name: str, value: Any):
        self._vals[name] = _scalar(value)

    def get(self, name: str, default=None):
        return self._vals.get(name, default)

    def ingest(self, prefix: str, mapping: dict):
        """Merge a component's stats dict under ``prefix``, flattening
        nested dicts into dotted names.  Lists and other non-scalar
        leaves are skipped — the registry holds metrics, not payloads."""
        for k, v in mapping.items():
            name = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, dict):
                self.ingest(name, v)
            elif isinstance(v, (list, tuple, np.ndarray)):
                continue
            else:
                self.set(name, v)
        return self

    def names(self) -> List[str]:
        return sorted(self._vals)

    def snapshot(self) -> Dict[str, Any]:
        return {k: self._vals[k] for k in self.names()}

    def __len__(self):
        return len(self._vals)

    def __contains__(self, name):
        return name in self._vals

    def __getitem__(self, name):
        return self._vals[name]

    def __repr__(self):
        return f"<MetricsRegistry {len(self._vals)} metrics>"
