"""Lane-id-indexed state arrays for the vectorized engine core.

The epoch-stepped ``StreamEngine`` core replaces the per-dispatch linear
scan over ``_Lane`` objects with an argmin over NumPy arrays.  For that
to be exact, every scalar the scan used to read from lane attributes —
EWMA service estimate, queue depth, busy/held occupancy, warm-up
``ready_at`` — must live in arrays that are *always* current.  This
module owns those arrays; ``_Lane`` objects stay the API for the control
path (hot-swap, chaos recovery, migration) and write through on every
mutation:

- ``LaneStateBank`` — a growable structure-of-arrays slab keyed by lane
  id (``lid``).  Lane ids are recycled through a free list so a
  long-lived engine with hot-swap churn keeps the slab dense.
- ``TrackedDeque`` — a ``collections.deque`` that mirrors its length
  into ``bank.qlen[lid]`` after every mutating call, so queue depth is
  readable as an array without touching lane objects.
- ``MeterBank`` — the same slab pattern for ``PowerGovernor`` lane
  meters (power draw, duty-cycle integration state), so per-lane energy
  integrates as one array expression at report time.

Write-through keeps both views bitwise equal: the arrays store the very
same float64 the attribute holds, so a vectorized ``(backlog+1)*est_s``
is bit-identical to the scalar expression, and the argmin fast path can
be an *exact* replacement for ``min()`` (NumPy's argmin returns the
first minimal index, matching ``min``'s first-minimal tie-break).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List

import numpy as np


class SoABank:
    """Growable structure-of-arrays with slot recycling.

    Subclasses declare ``FIELDS_F64`` / ``FIELDS_I64`` as
    ``{name: default}`` dicts; each becomes a same-length array
    attribute.  ``alloc`` returns a row id (smallest recycled id first),
    ``release`` resets the row to defaults and recycles it.  Growth
    doubles capacity and *replaces* the arrays — consumers must read
    arrays through the bank attribute, never cache them across allocs.
    """

    FIELDS_F64: Dict[str, float] = {}
    FIELDS_I64: Dict[str, int] = {}

    def __init__(self, capacity: int = 64):
        assert capacity > 0
        self._cap = capacity
        self._top = 0
        self._free: List[int] = []
        for name, default in self.FIELDS_F64.items():
            setattr(self, name, np.full(capacity, default, dtype=np.float64))
        for name, default in self.FIELDS_I64.items():
            setattr(self, name, np.full(capacity, default, dtype=np.int64))

    def _grow(self):
        new_cap = self._cap * 2
        for fields in (self.FIELDS_F64, self.FIELDS_I64):
            for name, default in fields.items():
                old = getattr(self, name)
                grown = np.full(new_cap, default, dtype=old.dtype)
                grown[: self._cap] = old
                setattr(self, name, grown)
        self._cap = new_cap

    def _reset(self, row: int):
        for fields in (self.FIELDS_F64, self.FIELDS_I64):
            for name, default in fields.items():
                getattr(self, name)[row] = default

    def alloc(self) -> int:
        if self._free:
            row = self._free.pop()
            self._reset(row)
            return row
        if self._top == self._cap:
            self._grow()
        row = self._top
        self._top += 1
        return row

    def release(self, row: int):
        self._reset(row)
        self._free.append(row)

    @property
    def capacity(self) -> int:
        return self._cap

    def __len__(self) -> int:
        return self._top - len(self._free)


class LaneStateBank(SoABank):
    """Per-lane dispatch state, indexed by lane id.

    ``qlen + busy + heldn`` is exactly ``_Lane.backlog()``; ``est_s`` and
    ``ready_at`` mirror the attributes of the same name.  ``hub`` mirrors
    the lane's hub index (-1 for the default hub) for future fabric-aware
    vector paths.
    """

    FIELDS_F64 = {"est_s": 0.0, "ready_at": 0.0}
    FIELDS_I64 = {"qlen": 0, "busy": 0, "heldn": 0, "hub": -1}


class MeterBank(SoABank):
    """Per-lane power-meter state for ``PowerGovernor``.

    ``detached_at`` < 0 means the meter is still attached; ``energy``
    integrates idle floor + active uplift for a set of rows in one array
    expression — elementwise float64, so each lane's joules are bitwise
    identical to the scalar formula.
    """

    FIELDS_F64 = {"power_w": 0.0, "idle_w": 0.0, "attached_at": 0.0,
                  "detached_at": -1.0, "active_s": 0.0, "uplift_w": 0.0}
    FIELDS_I64 = {"hub": 0, "cycles": 0}

    def energy(self, t: float, rows: np.ndarray) -> np.ndarray:
        """Joules per row at time ``t`` (attach-to-now idle floor plus
        accumulated active uplift), vectorized."""
        det = self.detached_at[rows]
        end = np.where(det >= 0.0, det, t)
        elapsed = np.maximum(end - self.attached_at[rows], 0.0)
        return (elapsed * self.idle_w[rows]
                + self.active_s[rows] * (self.power_w[rows]
                                         - self.idle_w[rows]))


class TrackedDeque(deque):
    """A deque that mirrors ``len(self)`` into ``bank.qlen[lid]`` after
    every mutating operation, so the vectorized dispatch path reads
    queue depth from an array instead of calling ``len`` per lane."""

    def __init__(self, bank: LaneStateBank, lid: int, iterable=()):
        super().__init__(iterable)
        self._bank = bank
        self._lid = lid
        bank.qlen[lid] = len(self)

    def _sync(self):
        self._bank.qlen[self._lid] = len(self)

    def append(self, x):
        super().append(x)
        self._sync()

    def appendleft(self, x):
        super().appendleft(x)
        self._sync()

    def pop(self):
        v = super().pop()
        self._sync()
        return v

    def popleft(self):
        v = super().popleft()
        self._sync()
        return v

    def clear(self):
        super().clear()
        self._sync()

    def remove(self, x):
        super().remove(x)
        self._sync()

    def extend(self, xs):
        super().extend(xs)
        self._sync()

    def extendleft(self, xs):
        super().extendleft(xs)
        self._sync()

    def insert(self, i, x):
        super().insert(i, x)
        self._sync()

    def __delitem__(self, i):
        super().__delitem__(i)
        self._sync()
