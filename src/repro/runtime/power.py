"""Power/thermal governor: per-hub energy budgets over the dispatch stack.

CHAMP is a *field* architecture: the §4.3 power model (1-2 W per stick
under load, ~0.3 W idle) is a battery budget, not a footnote.  Until now
the reproduction carried ``DeviceModel.power_w``/``idle_w`` as dead
fields; this module makes them load-bearing:

  * **Per-lane energy accounting.**  Every service cycle's *active*
    seconds are charged at ``power_w`` and everything else at ``idle_w``
    — O(1) bookkeeping per cycle (no per-sample state), integrated from
    the same virtual clock the engine runs on.  A lane's energy at time
    ``t`` is exactly::

        E(t) = (t - attached_at) * idle_w + active_s * (power_w - idle_w)

    so a parked (or simply idle) stick still accrues its idle draw —
    unplugging is the only way to zero a device's power, exactly like
    the hardware.

  * **Per-hub watt budgets.**  Each fabric hub may carry a budget
    (``budget_w``); the governor tracks the hub's recent electrical
    draw as an exact exponentially-weighted average (the EWMA ODE has a
    closed form over the piecewise-constant draw the engine produces,
    so the estimate is deterministic and integration-error-free) with
    the hub's thermal time constant (``DeviceModel.therm_tau_s``) as
    the smoothing horizon.

  * **A thermal state machine** per hub::

        nominal --p>budget--> throttled --still over at min duty--> parked
           ^                     |  ^                                  |
           +----p<=exit----------+  +-------------p<=exit-------------+

    *Throttled* hubs duty-cycle their lanes: each service cycle is
    stretched by ``1/duty`` (the stretch is forced idle at ``idle_w``,
    the compute itself is unchanged), with the duty chosen feed-forward
    so the hub's full-load draw lands at ``duty_target * budget`` —
    the margin that pays for the EWMA's ramp-in lag, keeping the
    *average* power under the cap, not just the steady state.
    *Parked* hubs start no new cycles at all (their queued frames wait;
    dispatch routes around them) until the draw estimate cools below
    the exit threshold.  Hysteresis: entry at ``p > budget``, exit at
    ``idle_floor + exit_ratio * (duty_target * budget - idle_floor)``
    — strictly below the throttled steady-state draw, so a throttled
    hub settles instead of flapping, the exit is always reachable by
    cooling, and a draw sitting *exactly at* the budget never flips
    the machine (entry is a strict inequality and the EWMA approaches
    a constant draw from below).  When the required duty falls below
    ``min_duty`` the nominal exit is disabled outright: the hub
    duty-cycles throttled <-> parked rather than celebrating every
    cooldown with a full-draw burst.

  * A budget below the hub's *idle floor* (sum of idle draws) is
    unsatisfiable by scheduling — only unplugging helps.  The governor
    flags it (``unsatisfiable``) and holds the hub at the deepest
    throttle instead of parking forever (a park could never cool below
    the floor, which would deadlock the pipeline).

Broadcast groups are barrier-paced, so their lanes get the feed-forward
duty stretch only (``duty_inflation``) — with no budget configured the
stretch is exactly 1.0 and the Table 1 reproduction is bit-identical.

The governor is always attached to the engine (energy accounting is
free); the state machine only engages when a budget is configured
(``active``), so unbudgeted runs are bit-identical to pre-governor
behavior.

Meter storage is a ``MeterBank`` slab (``runtime.lanestate``): each
``_LaneMeter`` is a thin view over one lane-id-indexed row, so the
report-time energy integral runs as one array expression over the live
fleet instead of a Python loop of scalar formulas (elementwise float64,
bitwise identical per lane).  A detached meter's energy is settled, so
it is frozen into an immutable snapshot and its row recycled.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Union

import numpy as np

from repro.core.cartridge import DeviceModel
from repro.runtime.lanestate import MeterBank

STATES = ("nominal", "throttled", "parked")

BudgetSpec = Union[None, float, int, Dict[int, float]]


class _LaneMeter:
    """Energy ledger for one physical device (one engine lane) — a view
    over one ``MeterBank`` row, so per-lane energy integrates as array
    math at report time."""

    __slots__ = ("name", "_bank", "_row")

    def __init__(self, name: str, hub: int, dev: DeviceModel, t: float,
                 bank: MeterBank):
        self.name = name
        self._bank = bank
        r = self._row = bank.alloc()
        bank.hub[r] = hub
        bank.power_w[r] = dev.power_w
        bank.idle_w[r] = dev.idle_w
        bank.attached_at[r] = t
        # row defaults: detached_at = -1 (attached), active_s = 0,
        # cycles = 0, uplift_w = 0

    # thin property layer: scalar reads/writes go straight to the row,
    # so the view and the arrays can never disagree
    @property
    def hub(self) -> int:
        return int(self._bank.hub[self._row])

    @hub.setter
    def hub(self, v: int):
        self._bank.hub[self._row] = v

    @property
    def power_w(self) -> float:
        return float(self._bank.power_w[self._row])

    @property
    def idle_w(self) -> float:
        return float(self._bank.idle_w[self._row])

    @property
    def attached_at(self) -> float:
        return float(self._bank.attached_at[self._row])

    @property
    def detached_at(self) -> Optional[float]:
        d = float(self._bank.detached_at[self._row])
        return None if d < 0.0 else d

    @detached_at.setter
    def detached_at(self, v: Optional[float]):
        self._bank.detached_at[self._row] = -1.0 if v is None else v

    @property
    def active_s(self) -> float:
        return float(self._bank.active_s[self._row])

    @active_s.setter
    def active_s(self, v: float):
        self._bank.active_s[self._row] = v

    @property
    def cycles(self) -> int:
        return int(self._bank.cycles[self._row])

    @cycles.setter
    def cycles(self, v: int):
        self._bank.cycles[self._row] = v

    @property
    def _uplift_w(self) -> float:
        return float(self._bank.uplift_w[self._row])

    @_uplift_w.setter
    def _uplift_w(self, v: float):
        self._bank.uplift_w[self._row] = v

    def elapsed(self, t: float) -> float:
        end = self.detached_at if self.detached_at is not None else t
        return max(end - self.attached_at, 0.0)

    def energy_j(self, t: float) -> float:
        return self.elapsed(t) * self.idle_w + \
            self.active_s * (self.power_w - self.idle_w)

    def freeze(self) -> "_FrozenMeter":
        """Snapshot a detached meter and recycle its slab row."""
        f = _FrozenMeter(self)
        self._bank.release(self._row)
        return f

    def summary(self, t: float, energy: Optional[float] = None) -> dict:
        el = self.elapsed(t)
        e = self.energy_j(t) if energy is None else energy
        return {
            "hub": self.hub,
            "active_s": round(self.active_s, 6),
            "cycles": self.cycles,
            "active_j": round(self.active_s * self.power_w, 6),
            "idle_j": round(max(el - self.active_s, 0.0) * self.idle_w, 6),
            "energy_j": round(e, 6),
            "avg_w": round(e / el, 4) if el > 0 else 0.0,
            "detached": self.detached_at is not None,
        }


class _FrozenMeter:
    """Immutable snapshot of a detached meter.  Once ``detached_at`` is
    set the meter's energy no longer depends on ``t``, so the snapshot
    precomputes it and the live bank row can be recycled."""

    __slots__ = ("name", "hub", "power_w", "idle_w", "active_s", "cycles",
                 "_elapsed", "_energy")

    def __init__(self, m: _LaneMeter):
        self.name = m.name
        self.hub = m.hub
        self.power_w = m.power_w
        self.idle_w = m.idle_w
        self.active_s = m.active_s
        self.cycles = m.cycles
        self._elapsed = m.elapsed(0.0)   # detached: t-independent
        self._energy = m.energy_j(0.0)

    def elapsed(self, t: float) -> float:
        return self._elapsed

    def energy_j(self, t: float) -> float:
        return self._energy

    def summary(self, t: float) -> dict:
        el = self._elapsed
        e = self._energy
        return {
            "hub": self.hub,
            "active_s": round(self.active_s, 6),
            "cycles": self.cycles,
            "active_j": round(self.active_s * self.power_w, 6),
            "idle_j": round(max(el - self.active_s, 0.0) * self.idle_w, 6),
            "energy_j": round(e, 6),
            "avg_w": round(e / el, 4) if el > 0 else 0.0,
            "detached": True,
        }


class _HubState:
    """One hub's draw estimate + thermal state machine."""

    __slots__ = ("hub", "budget_w", "state", "last_t", "draw_w", "p_hat",
                 "tau", "min_duty", "idle_floor_w", "active_ceiling_w",
                 "duty", "throttle_events", "park_events", "throttled_s",
                 "parked_s", "unsatisfiable")

    def __init__(self, hub: int, budget_w: Optional[float]):
        self.hub = hub
        self.budget_w = budget_w
        self.state = "nominal"
        self.last_t = 0.0
        self.draw_w = 0.0              # running cycles' draw above idle
        self.p_hat = 0.0               # EWMA of floor + draw_w (thermal est)
        self.tau = 1.0
        self.min_duty = 0.2
        self.idle_floor_w = 0.0
        self.active_ceiling_w = 0.0
        self.duty = 1.0
        self.throttle_events = 0
        self.park_events = 0
        self.throttled_s = 0.0
        self.parked_s = 0.0
        self.unsatisfiable = False

    def inflation(self) -> float:
        return 1.0 if self.state == "nominal" else 1.0 / self.duty


class PowerGovernor:
    """Always-on energy meter + optional per-hub budget enforcement.

    ``budget_w`` may be a scalar (the same cap on every hub — the
    common battery-kit case), a ``{hub_id: watts}`` dict (hubs absent
    from the dict are uncapped), or ``None`` (metering only).
    """

    def __init__(self, budget_w: BudgetSpec = None, *,
                 exit_ratio: float = 0.85, duty_target: float = 0.92,
                 park_duty_floor: Optional[float] = None):
        if isinstance(budget_w, dict):
            for h, w in budget_w.items():
                if w is not None and w <= 0:
                    raise ValueError(f"hub {h} budget must be > 0, got {w}")
        elif budget_w is not None and budget_w <= 0:
            raise ValueError(f"power budget must be > 0, got {budget_w}")
        if not 0.0 < exit_ratio < 1.0:
            raise ValueError("exit_ratio must be in (0, 1)")
        if not 0.0 < duty_target <= 1.0:
            raise ValueError("duty_target must be in (0, 1]")
        self._budget = budget_w
        self.exit_ratio = exit_ratio
        self.duty_target = duty_target
        self.park_duty_floor = park_duty_floor   # None -> per-device field
        self._bank = MeterBank()                     # meter state arrays
        self._lanes: Dict[int, _LaneMeter] = {}      # id(cart) -> meter
        self._lane_dev: Dict[int, DeviceModel] = {}  # id(cart) -> device
        self._retired: Dict[str, _FrozenMeter] = {}  # name -> snapshot
        self._hubs: Dict[int, _HubState] = {}
        # optional FlightRecorder: state transitions emit power.state
        # instants (the engine wires this when tracing is enabled)
        self.tracer = None

    # -- configuration --------------------------------------------------------
    @property
    def active(self) -> bool:
        """Whether any budget is configured (state machine engaged)."""
        if isinstance(self._budget, dict):
            return any(w is not None for w in self._budget.values())
        return self._budget is not None

    def budget_of(self, hub: int) -> Optional[float]:
        if isinstance(self._budget, dict):
            return self._budget.get(hub)
        return self._budget

    def set_budget(self, budget_w: BudgetSpec, t: float = 0.0):
        """Re-budget at runtime (battery saver kicking in mid-mission).
        Existing hub states re-evaluate against the new cap at their
        next touch."""
        self._budget = budget_w
        for hs in self._hubs.values():
            hs.budget_w = self.budget_of(hs.hub)
            # a cap dropped below the idle floor is unsatisfiable from
            # this moment on — it must take the deepest-duty hold, not
            # the park path (which could never cool below the floor)
            hs.unsatisfiable = (hs.budget_w is not None
                                and hs.idle_floor_w > hs.budget_w)
            self._advance(hs, t)
            self._evaluate(hs)

    # -- lane population ------------------------------------------------------
    def _hub_state(self, hub: int) -> _HubState:
        hs = self._hubs.get(hub)
        if hs is None:
            hs = self._hubs[hub] = _HubState(hub, self.budget_of(hub))
        return hs

    def _recalibrate(self, hs: _HubState):
        """Re-derive the hub's thermal constants from its population."""
        lanes = [m for m in self._lanes.values()
                 if m.hub == hs.hub and m.detached_at is None]
        hs.idle_floor_w = sum(m.idle_w for m in lanes)
        hs.active_ceiling_w = sum(m.power_w for m in lanes)
        if lanes:
            devs = [self._lane_dev[k] for k, m in self._lanes.items()
                    if m.hub == hs.hub and m.detached_at is None]
            hs.tau = max(d.therm_tau_s for d in devs)
            hs.min_duty = self.park_duty_floor if self.park_duty_floor \
                is not None else min(d.min_duty for d in devs)
        # a hub never draws below its idle floor while sticks are plugged:
        # seed/raise the estimate so a cold hub starts at idle, not zero
        hs.p_hat = max(hs.p_hat, hs.idle_floor_w)
        hs.unsatisfiable = (hs.budget_w is not None
                            and hs.idle_floor_w > hs.budget_w)

    def sync(self, t: float, population: Dict[int, tuple]):
        """Reconcile with the engine's live lane set after a rebuild.
        ``population`` maps ``id(cartridge) -> (name, DeviceModel, hub)``."""
        touched = set()
        for key, (name, dev, hub) in population.items():
            m = self._lanes.get(key)
            if m is None:
                m = self._lanes[key] = _LaneMeter(name, hub, dev, t,
                                                  self._bank)
                self._lane_dev[key] = dev
                touched.add(hub)
            elif m.hub != hub:           # re-plugged onto another hub
                touched.add(m.hub)
                touched.add(hub)
                hs_old = self._hub_state(m.hub)
                self._advance(hs_old, t)
                hs_old.draw_w -= m._uplift_w
                m._uplift_w = 0.0
                m.hub = hub
        for key, m in list(self._lanes.items()):
            if key not in population and m.detached_at is None:
                m.detached_at = t
                hub = m.hub          # capture before freeze releases the row
                hs = self._hub_state(hub)
                self._advance(hs, t)
                hs.draw_w -= m._uplift_w
                m._uplift_w = 0.0
                self._retired[m.name] = m.freeze()
                del self._lanes[key]
                del self._lane_dev[key]
                touched.add(hub)
        for hub in touched:
            hs = self._hub_state(hub)
            self._advance(hs, t)
            self._recalibrate(hs)
            self._evaluate(hs)

    # -- draw integration -----------------------------------------------------
    def _advance(self, hs: _HubState, t: float):
        """Advance the hub's EWMA draw estimate to ``t``.  The draw —
        idle floor plus the running cycles' uplift — is piecewise
        constant between engine events, so the EWMA update is the exact
        solution of dp/dt = (draw - p)/tau over the interval."""
        dt = t - hs.last_t
        if dt <= 0.0:
            return
        draw = hs.idle_floor_w + hs.draw_w
        hs.p_hat += (draw - hs.p_hat) * (1.0 - math.exp(-dt / hs.tau))
        if hs.state == "throttled":
            hs.throttled_s += dt
        elif hs.state == "parked":
            hs.parked_s += dt
        hs.last_t = t

    def _evaluate(self, hs: _HubState):
        """Run the state machine against the current draw estimate.
        With a tracer attached, any state transition emits a
        ``power.state`` instant (at ``hs.last_t``, the virtual time the
        estimate was advanced to) — the machine itself is untouched, so
        traced runs stay float-for-float identical."""
        prev = hs.state
        self._step_state(hs)
        if self.tracer is not None and hs.state != prev:
            self.tracer.instant(
                "power.state", hs.last_t, track=f"hub{hs.hub}",
                state=hs.state, prev=prev, p_hat_w=hs.p_hat,
                duty=hs.duty)

    def _step_state(self, hs: _HubState):
        b = hs.budget_w
        if b is None:
            hs.state = "nominal"
            hs.duty = 1.0
            return
        span = hs.active_ceiling_w - hs.idle_floor_w
        if span <= 0.0:                  # empty hub (or zero-draw devices)
            hs.state = "nominal"
            hs.duty = 1.0
            return
        target = b * self.duty_target
        d_req = (target - hs.idle_floor_w) / span
        hs.duty = min(max(d_req, hs.min_duty), 1.0)
        # exit strictly below the throttle *target* (the draw a throttled
        # hub settles at), proportionally to its headroom over the idle
        # floor — so the throttled steady state never re-crosses the exit
        # and the machine cannot oscillate on a constant load
        exit_w = hs.idle_floor_w + self.exit_ratio * \
            max(target - hs.idle_floor_w, 0.0)
        if hs.unsatisfiable:
            # idle draw alone busts the cap: parking cannot cool below
            # the floor, so hold the deepest duty cycle and keep moving
            if hs.state != "throttled":
                hs.state = "throttled"
                hs.throttle_events += 1
            hs.duty = hs.min_duty
            return
        if hs.state == "nominal":
            if hs.p_hat > b:
                hs.state = "throttled"
                hs.throttle_events += 1
        elif hs.state == "throttled":
            if hs.p_hat <= exit_w and d_req >= hs.min_duty:
                # only drop the throttle when an untrottled burst could
                # ever be re-contained: if the budget needs a duty below
                # the floor, the hub duty-cycles throttled <-> parked
                # instead of bursting at full draw
                hs.state = "nominal"
                hs.duty = 1.0
            elif d_req < hs.min_duty and hs.p_hat > b:
                # even the deepest duty cycle cannot hold the cap with
                # lanes running: stop starting cycles until it cools
                hs.state = "parked"
                hs.park_events += 1
        elif hs.state == "parked":
            if hs.p_hat <= exit_w:
                hs.state = "throttled"

    # -- engine hooks (O(1) each) ---------------------------------------------
    def on_cycle_start(self, t: float, cart, dur_s: float, active_s: float):
        """A shard-lane service cycle begins: charge its nominal compute
        (``active_s``) now and raise the hub draw for ``dur_s`` (the
        possibly duty-stretched occupancy)."""
        m = self._lanes.get(id(cart))
        if m is None:
            return
        m.active_s += active_s
        m.cycles += 1
        if not self.active or dur_s <= 0.0:
            return
        hs = self._hub_state(m.hub)
        self._advance(hs, t)
        # average draw above idle over the (stretched) cycle: the active
        # fraction runs at power_w, the forced-idle remainder at idle_w
        uplift = (active_s / dur_s) * (m.power_w - m.idle_w)
        m._uplift_w += uplift
        hs.draw_w += uplift
        self._evaluate(hs)

    def on_cycle_end(self, t: float, cart):
        m = self._lanes.get(id(cart))
        if m is None:
            return
        # settle the uplift even if the budget was dropped mid-cycle
        # (set_budget(None) while a lane is in service): leaving it in
        # draw_w would haunt the estimate as a phantom permanent load
        if not self.active and m._uplift_w == 0.0:
            return
        hs = self._hub_state(m.hub)
        self._advance(hs, t)
        hs.draw_w -= m._uplift_w
        m._uplift_w = 0.0
        self._evaluate(hs)

    def on_window(self, t: float, cart, dur_s: float, active_s: float):
        """A broadcast service window was scheduled (it may start in the
        future — barrier pacing): charge its compute energy in one lump.
        Broadcast draw stays out of the EWMA feedback loop; broadcast
        hubs are governed feed-forward via ``duty_inflation``."""
        m = self._lanes.get(id(cart))
        if m is None:
            return
        m.active_s += active_s
        m.cycles += 1

    # -- dispatch-facing queries ----------------------------------------------
    def inflation(self, t: float, hub: int) -> float:
        """Service-time stretch for a shard cycle starting on ``hub`` now
        (also the dispatch-estimate multiplier: a throttled lane looks
        proportionally slower to ``pick_lane``)."""
        if not self.active:
            return 1.0
        hs = self._hub_state(hub)
        self._advance(hs, t)
        self._evaluate(hs)
        return hs.inflation()

    def duty_inflation(self, t: float, hub: int) -> float:
        """Feed-forward stretch for barrier-paced (broadcast) lanes:
        population-derived duty, no EWMA feedback.  1.0 when the hub is
        unbudgeted — Table 1 parity is bit-exact."""
        b = self.budget_of(hub)
        if b is None:
            return 1.0
        hs = self._hub_state(hub)
        self._advance(hs, t)
        span = hs.active_ceiling_w - hs.idle_floor_w
        if span <= 0.0:
            return 1.0
        d = (b * self.duty_target - hs.idle_floor_w) / span
        d = min(max(d, hs.min_duty), 1.0)
        return 1.0 / d

    def tau_of(self, hub: int) -> float:
        """The hub's thermal time constant (control horizon)."""
        hs = self._hubs.get(hub)
        return hs.tau if hs is not None else 1.0

    def parked(self, t: float, hub: int) -> bool:
        if not self.active:
            return False
        hs = self._hub_state(hub)
        self._advance(hs, t)
        self._evaluate(hs)
        return hs.state == "parked"

    def unpark_eta(self, t: float, hub: int) -> float:
        """When a parked hub's draw estimate will cross its exit
        threshold, from the closed-form EWMA decay toward the current
        draw.  Conservative fallback (one thermal horizon) while cycles
        are still draining."""
        hs = self._hub_state(hub)
        self._advance(hs, t)
        b = hs.budget_w
        if b is None or hs.state != "parked":
            return t
        exit_w = hs.idle_floor_w + self.exit_ratio * \
            max(b * self.duty_target - hs.idle_floor_w, 0.0)
        if hs.p_hat <= exit_w:
            return t
        draw = hs.idle_floor_w + hs.draw_w
        if draw >= exit_w:               # in-flight cycles still drawing
            return t + hs.tau
        eta = hs.tau * math.log((hs.p_hat - draw) / (exit_w - draw))
        return t + max(eta, 0.0)

    # -- reporting ------------------------------------------------------------
    def report(self, t: float) -> dict:
        """Energy/throttle breakdown at time ``t`` (idempotent; the
        engine calls this at the end of every ``run``)."""
        lanes = {}
        hub_energy: Dict[int, float] = {}
        hub_lanes: Dict[int, int] = {}
        # live meter joules: one array expression over the slab rows;
        # elementwise float64 → each value is bitwise equal to the scalar
        # energy_j, and per-hub totals still accumulate in meter order
        live = list(self._lanes.values())
        if live:
            rows = np.fromiter((m._row for m in live), dtype=np.int64,
                               count=len(live))
            live_e = self._bank.energy(t, rows)
        else:
            live_e = ()
        # retired first: a re-used name reports the live lane's ledger
        for m in self._retired.values():
            lanes[m.name] = m.summary(t)
            hub_energy[m.hub] = hub_energy.get(m.hub, 0.0) + m.energy_j(t)
            hub_lanes[m.hub] = hub_lanes.get(m.hub, 0) + 1
        for m, ev in zip(live, live_e):
            e = float(ev)
            lanes[m.name] = m.summary(t, energy=e)
            hub_energy[m.hub] = hub_energy.get(m.hub, 0.0) + e
            hub_lanes[m.hub] = hub_lanes.get(m.hub, 0) + 1
        hubs = {}
        for hub in sorted(set(hub_energy) | set(self._hubs)):
            hs = self._hubs.get(hub)
            if hs is not None:
                self._advance(hs, t)
                self._evaluate(hs)
            e = hub_energy.get(hub, 0.0)
            el = t  # hub clock starts with the engine
            hubs[hub] = {
                "energy_j": round(e, 6),
                "avg_w": round(e / el, 4) if el > 0 else 0.0,
                "lanes": hub_lanes.get(hub, 0),
                "budget_w": self.budget_of(hub),
                "state": hs.state if hs is not None else "nominal",
                "p_hat_w": round(hs.p_hat, 4) if hs is not None else 0.0,
                "idle_floor_w": round(hs.idle_floor_w, 4)
                if hs is not None else 0.0,
                "inflation": round(hs.inflation(), 4)
                if hs is not None else 1.0,
                "throttle_events": hs.throttle_events
                if hs is not None else 0,
                "park_events": hs.park_events if hs is not None else 0,
                "throttled_s": round(hs.throttled_s, 6)
                if hs is not None else 0.0,
                "parked_s": round(hs.parked_s, 6)
                if hs is not None else 0.0,
                "unsatisfiable": hs.unsatisfiable
                if hs is not None else False,
            }
        total = sum(hub_energy.values())
        return {
            "lanes": lanes,
            "hubs": hubs,
            "total_j": round(total, 6),
            "avg_w": round(total / t, 4) if t > 0 else 0.0,
            "governed": self.active,
        }
